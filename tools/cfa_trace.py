"""Run one stencil under the trace recorder and export a Chrome trace.

A thin CLI over ``cfa.compile(..., trace=True)``: compile one (program,
space) request, run it on seeded random inputs, and write the recorded
timeline as Chrome trace-event JSON (load the file in Perfetto or
``chrome://tracing``).  ``--validate`` additionally checks the emitted
JSON against the schema in ``docs/tracing.md`` and asserts the runtime
counters reconcile exactly against the per-tile ``TransferPlan``
accounting — the leg CI's ``trace`` job runs on jacobi2d5p.

    PYTHONPATH=src python tools/cfa_trace.py jacobi2d5p 8 8 8 \
        --layout 4,4,4 --backend dataflow -o trace.json --validate
    PYTHONPATH=src python tools/cfa_trace.py heat3d 4 8 8 8 \
        --backend sweep --summary
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro import cfa
from repro.core.cfa.obs import validate_chrome_trace
from repro.core.cfa.programs import get_program


def parse_layout(text: str):
    """``autotune`` / ``default`` verbatim, else a comma-separated tile."""
    if text in ("autotune", "default"):
        return text
    return tuple(int(x) for x in text.replace(",", " ").split())


def seeded_inputs(name: str, space: tuple[int, ...], seed: int):
    """Random flow-in block shaped (w_0, *space[1:]) — what every executor
    consumes as the time-axis boundary."""
    w0 = get_program(name).widths[0]
    rng = np.random.default_rng(seed)
    return rng.normal(size=(w0, *space[1:]))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("program", help="Table I program name, e.g. jacobi2d5p")
    ap.add_argument("space", type=int, nargs="+", help="iteration-space sizes")
    ap.add_argument("--target", default="axi-zc706",
                    help="registered target name (default: axi-zc706)")
    ap.add_argument("--layout", default="default", type=parse_layout,
                    help='"autotune", "default", or a tile like 4,4,4 '
                         '(default: default — no search)')
    ap.add_argument("--backend", default="auto",
                    help="backend name or auto (default: auto)")
    ap.add_argument("--storage", default="redundant",
                    choices=("redundant", "irredundant", "compressed"))
    ap.add_argument("--n-ports", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0,
                    help="input RNG seed (default: 0)")
    ap.add_argument("-o", "--out", type=Path, default=None,
                    help="write the Chrome trace JSON here "
                         "(default: stdout)")
    ap.add_argument("--validate", action="store_true",
                    help="check the JSON against the docs/tracing.md "
                         "schema and assert counters reconcile against "
                         "the plan accounting; non-zero exit on failure")
    ap.add_argument("--summary", action="store_true",
                    help="print span/counter totals to stderr")
    args = ap.parse_args(argv)

    compiled = cfa.compile(
        args.program, tuple(args.space), target=args.target,
        layout=args.layout, backend=args.backend, storage=args.storage,
        n_ports=args.n_ports, trace=True,
    )
    compiled(seeded_inputs(args.program, tuple(args.space), args.seed))
    rec = compiled.last_trace()
    trace = rec.to_chrome()

    if args.out is not None:
        rec.save_chrome(args.out)
        print(f"wrote {args.out} ({len(trace['traceEvents'])} events)",
              file=sys.stderr)
    else:
        json.dump(trace, sys.stdout, indent=1)
        print()

    if args.summary:
        print(f"{rec.label}: {len(rec.spans)} spans, "
              f"counters={json.dumps(rec.counters.as_dict(), sort_keys=True)}",
              file=sys.stderr)

    if args.validate:
        problems = validate_chrome_trace(trace)
        for p in problems:
            print(f"schema: {p}", file=sys.stderr)
        recon = rec.reconcile(compiled.pipeline)
        for m in recon["mismatches"]:
            print(f"reconcile: {m}", file=sys.stderr)
        if problems or not recon["ok"]:
            return 1
        print(f"validated: schema ok, counters reconcile "
              f"({recon['expected']['wire_bytes_read'] + recon['expected']['wire_bytes_write']}"
              f" wire bytes over {recon['expected']['tiles']} tiles)",
              file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
