"""Dump a compile's pass trace as JSON — the lowering, stage by stage.

A thin CLI over ``cfa.compile``: lower one (program, space) request through
the default ``PassPipeline`` and print every ``PassTrace`` entry (pass name,
version, wall seconds, artifact diff) plus a summary of the resulting
``CompiledStencil``.  What CI smokes, and what a human reaches for when a
compile picks a surprising backend or layout.

    PYTHONPATH=src python tools/dump_pipeline.py jacobi2d5p 16 32 32
    PYTHONPATH=src python tools/dump_pipeline.py heat3d 4 8 8 8 \
        --layout default --backend sweep
    PYTHONPATH=src python tools/dump_pipeline.py jacobi2d5p 8 8 8 \
        --target axi-zc706 --storage irredundant --layout 4,4,4
    PYTHONPATH=src python tools/dump_pipeline.py jacobi2d5p 8 8 8 \
        --host-budget 2000      # watch the distribute pass raise n_ports
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro import cfa


def parse_layout(text: str):
    """``autotune`` / ``default`` verbatim, else a comma-separated tile."""
    if text in ("autotune", "default"):
        return text
    return tuple(int(x) for x in text.replace(",", " ").split())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("program", help="Table I program name, e.g. jacobi2d5p")
    ap.add_argument("space", type=int, nargs="+", help="iteration-space sizes")
    ap.add_argument("--target", default="axi-zc706",
                    help="registered target name (default: axi-zc706)")
    ap.add_argument("--layout", default="default", type=parse_layout,
                    help='"autotune", "default", or a tile like 4,4,4 '
                         '(default: default — no search)')
    ap.add_argument("--backend", default="auto",
                    help="backend name or auto (default: auto)")
    ap.add_argument("--storage", default="redundant",
                    choices=("redundant", "irredundant", "compressed"))
    ap.add_argument("--n-ports", type=int, default=1)
    ap.add_argument("--overlap", action="store_true",
                    help="rank/lower for overlapped fetch/compute/commit")
    ap.add_argument("--host-budget", type=int, default=None,
                    help="per-host facet-memory budget in bytes (the "
                         "distribute pass shards spaces that exceed it)")
    ap.add_argument("--budget", type=int, default=32,
                    help="autotune evaluation budget (only with "
                         "--layout autotune)")
    ap.add_argument("--verify", action="store_true",
                    help="run the static analysis suite and append its "
                         "AnalysisReport to the JSON trace")
    args = ap.parse_args(argv)

    compiled = cfa.compile(
        args.program, tuple(args.space), target=args.target,
        layout=args.layout, backend=args.backend, storage=args.storage,
        n_ports=args.n_ports, overlap=args.overlap,
        host_budget=args.host_budget,
        autotune_kwargs=(dict(budget=args.budget)
                         if args.layout == "autotune" else None),
    )
    out = {
        "program": args.program,
        "space": list(args.space),
        "target": args.target,
        "passes": [t.to_dict() for t in compiled.trace()],
        "compiled": {
            "backend": compiled.backend,
            "layout": compiled.layout.key,
            "storage": compiled.storage,
            "n_ports": compiled.n_ports,
            "distributed": compiled.distributed,
        },
    }
    if args.verify:
        report = cfa.verify(compiled, raise_on_error=False)
        out["analysis"] = report.to_dict()
    json.dump(out, sys.stdout, indent=1)
    print()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
