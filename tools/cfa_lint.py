"""Lint the program x storage x backend matrix with the static verifier.

A CLI over ``cfa.compile(..., verify=True)``'s analysis suite
(``repro.core.cfa.analysis``): compile every requested combination, collect
each :class:`AnalysisReport`, and render the findings as text or JSON.  The
exit code is the matrix's max severity — ``0`` clean (or INFO only), ``1``
WARN, ``2`` ERROR — so CI can gate on it; ``--strict`` promotes WARN to the
failing exit code.

    PYTHONPATH=src python tools/cfa_lint.py
    PYTHONPATH=src python tools/cfa_lint.py jacobi2d5p heat3d --json
    PYTHONPATH=src python tools/cfa_lint.py --storages irredundant \
        --backends wavefront --strict
    PYTHONPATH=src python tools/cfa_lint.py jacobi2d5p --include-baselines

JSON schema (``--json``; documented in docs/analysis.md):

    {
      "target": "axi-zc706",
      "max_severity": "WARN" | "ERROR" | "INFO" | null,
      "exit_code": 0 | 1 | 2,
      "entries": [
        {
          "program": "jacobi2d5p",
          "space": [8, 8, 8],
          "storage": "redundant",
          "backend": "wavefront",          # or "plan:original" for baselines
          "layout": "cfa[t=4x4x4,intra-tile]",
          "max_severity": ...,             # null when clean
          "diagnostics": [Diagnostic.to_dict(), ...]
        }, ...
      ]
    }
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro import cfa
from repro.core.cfa import (
    STORAGE_MODES,
    IterSpace,
    available_backends,
    get_program,
    get_target,
)
from repro.core.cfa.analysis import SEVERITIES, lint_plan
from repro.core.cfa.plans import (
    bounding_box_plan,
    data_tiling_plan,
    original_layout_plan,
)
from repro.core.cfa.spaces import Tiling

#: every Table I program plus the 2-D/4-D extension cases — the green matrix
DEFAULT_PROGRAMS = (
    "jacobi2d5p", "jacobi2d9p", "jacobi2d9p-gol", "gaussian",
    "smith-waterman-3seq", "heat1d", "heat3d",
)

#: the Fig. 15 baseline layouts ``--include-baselines`` lints (plan-only:
#: baselines are not executable, so only the CFA3xx lint applies)
BASELINE_PLANS = {
    "original": original_layout_plan,
    "bbox": bounding_box_plan,
    "data-tiling": data_tiling_plan,
}


def _exit_code(max_severity: str | None, *, strict: bool) -> int:
    if max_severity == "ERROR":
        return 2
    if max_severity == "WARN":
        return 2 if strict else 1
    return 0


def _worst(severities) -> str | None:
    sevs = [s for s in severities if s is not None]
    return max(sevs, key=SEVERITIES.index) if sevs else None


def lint_matrix(
    programs=DEFAULT_PROGRAMS,
    *,
    target="axi-zc706",
    storages=STORAGE_MODES,
    backends=None,
    include_baselines=False,
) -> list[dict]:
    """Compile + verify every combination; one JSON-ready entry each."""
    tgt = get_target(target)
    entries: list[dict] = []
    for name in programs:
        prog = get_program(name)
        space = tuple(2 * t for t in prog.default_tile)
        for storage in storages:
            capable = available_backends(prog, IterSpace(space), 1, storage)
            if backends is not None:
                capable = [b for b in capable if b in backends]
            for be in capable:
                compiled = cfa.compile(name, space, target=tgt, layout="default",
                                       backend=be, storage=storage)
                report = cfa.verify(compiled, raise_on_error=False)
                entries.append({
                    "program": name,
                    "space": list(space),
                    "storage": storage,
                    "backend": be,
                    "layout": compiled.layout.key,
                    "max_severity": report.max_severity,
                    "diagnostics": [d.to_dict() for d in report.diagnostics],
                })
        if include_baselines:
            for bname, builder in BASELINE_PLANS.items():
                plan = builder(IterSpace(space), prog.deps,
                               Tiling(prog.default_tile))
                diags = lint_plan(plan, tgt.model)
                entries.append({
                    "program": name,
                    "space": list(space),
                    "storage": "redundant",
                    "backend": f"plan:{bname}",
                    "layout": plan.scheme,
                    "max_severity": _worst(d.severity for d in diags),
                    "diagnostics": [d.to_dict() for d in diags],
                })
    return entries


def render_text(entries: list[dict], out) -> None:
    clean = 0
    for e in entries:
        where = (f"{e['program']} @ {tuple(e['space'])} "
                 f"[{e['storage']}, {e['backend']}]")
        if not e["diagnostics"]:
            clean += 1
            continue
        print(f"{where}: {e['layout']}", file=out)
        for d in e["diagnostics"]:
            loc = f" [facet {d['facet']}]" if "facet" in d else ""
            fix = f" (fixit: {d['fixit']})" if "fixit" in d else ""
            print(f"  {d['severity']} {d['code']}{loc}: {d['message']}{fix}",
                  file=out)
    flagged = len(entries) - clean
    print(f"{len(entries)} combination(s) linted: {clean} clean, "
          f"{flagged} with findings", file=out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("programs", nargs="*", default=None,
                    help=f"programs to lint (default: all of "
                         f"{', '.join(DEFAULT_PROGRAMS)})")
    ap.add_argument("--target", default="axi-zc706",
                    help="registered target name (default: axi-zc706)")
    ap.add_argument("--storages", nargs="+", default=list(STORAGE_MODES),
                    choices=STORAGE_MODES, metavar="STORAGE",
                    help="storage disciplines to cover (default: all)")
    ap.add_argument("--backends", nargs="+", default=None, metavar="BACKEND",
                    help="restrict to these backends (default: every "
                         "capable one)")
    ap.add_argument("--include-baselines", action="store_true",
                    help="also lint the Fig. 15 baseline layouts "
                         "(original/bbox/data-tiling; plan-level CFA3xx only)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output (schema in docs/analysis.md)")
    ap.add_argument("--strict", action="store_true",
                    help="WARN exits 2 like ERROR (warnings-as-errors)")
    args = ap.parse_args(argv)

    entries = lint_matrix(
        tuple(args.programs) if args.programs else DEFAULT_PROGRAMS,
        target=args.target, storages=tuple(args.storages),
        backends=tuple(args.backends) if args.backends else None,
        include_baselines=args.include_baselines,
    )
    worst = _worst(e["max_severity"] for e in entries)
    code = _exit_code(worst, strict=args.strict)
    if args.as_json:
        json.dump({"target": args.target, "max_severity": worst,
                   "exit_code": code, "entries": entries},
                  sys.stdout, indent=1)
        print()
    else:
        render_text(entries, sys.stdout)
    return code


if __name__ == "__main__":
    raise SystemExit(main())
