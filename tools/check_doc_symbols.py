#!/usr/bin/env python
"""CI guard: every code symbol or path the docs reference must still exist.

Scans the inline-backtick tokens of ``docs/*.md``, the top-level README
(whose quickstart snippets name live API symbols, e.g. the N-D
``heat1d``/``heat3d`` example) and the results README — fenced code blocks
are shell/transcript examples and are skipped — and checks each against
the repository:

* tokens containing ``/`` or ending in a file suffix are treated as paths
  (globs allowed) and must match at least one file;
* identifier-shaped tokens (``snake_case``, ``CamelCase``, dotted
  ``pkg.mod.attr``, optional trailing ``()``) must appear, word-bounded, in
  at least one Python source file — so renaming or deleting a symbol without
  updating the docs fails CI.

The check also runs in reverse for the public front-end surface: every name
in ``repro.cfa.__all__`` (parsed statically from ``src/repro/cfa.py`` — no
imports, so this works in the dependency-free docs CI job) must be
mentioned, word-bounded, in at least one checked doc.  Adding a public
symbol without documenting it fails CI just like documenting a deleted one.

Exit status: 0 clean, 1 with a listing of stale references.

    python tools/check_doc_symbols.py            # check the default doc set
    python tools/check_doc_symbols.py docs/x.md  # check specific files
"""
from __future__ import annotations

import ast
import glob
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

# the public front-end surface checked in reverse (docs must cover it)
API_MODULE = ROOT / "src" / "repro" / "cfa.py"

DEFAULT_DOCS = ("docs/*.md", "docs/analysis.md", "docs/tracing.md",
                "docs/architecture.md", "README.md",
                "benchmarks/results/README.md", "PAPERS.md")

# directories whose .py files make up the symbol corpus
CODE_DIRS = ("src", "benchmarks", "tests", "tools", "examples")

PATH_SUFFIXES = (".py", ".md", ".json", ".txt", ".toml", ".yml", ".yaml", ".csv")

# doc-prose words that look like identifiers but are not repo symbols
ALLOWLIST = {
    "null", "true", "false", "None",
}

_IDENT = re.compile(r"^[A-Za-z_][A-Za-z0-9_]*(\.[A-Za-z_][A-Za-z0-9_]*)*(\(\))?$")
_FENCE = re.compile(r"```.*?```", re.S)
_TICK = re.compile(r"`([^`\n]+)`")


def _corpus() -> str:
    parts = []
    for d in CODE_DIRS:
        for f in sorted((ROOT / d).rglob("*.py")):
            parts.append(f.read_text(errors="replace"))
    return "\n".join(parts)


def _repo_paths() -> list[str]:
    """All tracked-ish repo paths (files and dirs), '/'-normalised, for
    suffix matching of relative doc mentions like ``spaces.py`` or
    ``kernels/block_attention/``."""
    out = []
    for p in ROOT.rglob("*"):
        rel = p.relative_to(ROOT).as_posix()
        if rel.startswith((".git/", ".git")) or "__pycache__" in rel:
            continue
        out.append(rel + ("/" if p.is_dir() else ""))
    return out


def _doc_tokens(path: Path) -> list[str]:
    text = _FENCE.sub("", path.read_text(errors="replace"))
    return _TICK.findall(text)


def _is_path_token(tok: str) -> bool:
    return "/" in tok or tok.endswith(PATH_SUFFIXES)


def _api_symbols() -> list[str]:
    """``repro.cfa.__all__``, parsed statically (no repo imports needed)."""
    if not API_MODULE.is_file():
        return []
    tree = ast.parse(API_MODULE.read_text())
    for node in tree.body:
        targets = (node.targets if isinstance(node, ast.Assign)
                   else [node.target] if isinstance(node, ast.AnnAssign) else [])
        if any(isinstance(t, ast.Name) and t.id == "__all__" for t in targets):
            value = ast.literal_eval(node.value)
            return [str(name) for name in value]
    return []


def check_api_coverage(files: list[Path]) -> list[str]:
    """Every public front-end symbol must be mentioned in some checked doc."""
    docs = "\n".join(f.read_text(errors="replace") for f in files)
    missing = []
    for name in _api_symbols():
        if not re.search(rf"\b{re.escape(name)}\b", docs):
            missing.append(
                f"public API symbol `{name}` (repro.cfa.__all__) is not "
                f"documented in any checked doc"
            )
    return missing


def check(files: list[Path]) -> list[str]:
    corpus = _corpus()
    repo_paths = _repo_paths()
    word_cache: dict[str, bool] = {}

    def word_exists(name: str) -> bool:
        if name not in word_cache:
            word_cache[name] = bool(
                re.search(rf"\b{re.escape(name)}\b", corpus))
        return word_cache[name]

    def path_exists(tok: str, doc_dir: Path) -> bool:
        # `{tag}`-style placeholders and shell globs both mean "any"
        pattern = re.sub(r"\{[^}]*\}", "*", tok).rstrip("/")
        if glob.glob(str(ROOT / pattern)) or glob.glob(str(doc_dir / pattern)):
            return True
        # a bare or partial path (`spaces.py`, `kernels/block_attention/`)
        # counts when some repo path ends with it
        if any("*" in part for part in pattern.split("/")):
            return False
        suffix = pattern + ("/" if tok.endswith("/") else "")
        return any(
            p == suffix or p.endswith("/" + suffix) or p.rstrip("/").endswith("/" + pattern)
            for p in repo_paths
        )

    stale = []
    for doc in files:
        for tok in _doc_tokens(doc):
            tok = tok.strip()
            if not tok or " " in tok or tok.startswith(("-", "$", "#", "~")):
                continue
            if not tok.isascii():
                continue  # inline math, not a code reference
            if _is_path_token(tok):
                if not path_exists(tok, doc.parent):
                    stale.append(f"{doc.relative_to(ROOT)}: path `{tok}` matches nothing")
                continue
            if not _IDENT.match(tok) or tok in ALLOWLIST:
                continue
            name = tok[:-2] if tok.endswith("()") else tok
            # for dotted references every component chain is too strict;
            # require the final attribute (the symbol being named) to exist
            leaf = name.rsplit(".", 1)[-1]
            if not word_exists(leaf):
                stale.append(f"{doc.relative_to(ROOT)}: symbol `{tok}` not found in sources")
    return stale


def main(argv: list[str]) -> int:
    if argv:
        files = [ROOT / a if not Path(a).is_absolute() else Path(a) for a in argv]
    else:
        files = []
        for pat in DEFAULT_DOCS:
            files.extend(sorted(ROOT.glob(pat)))
        files = list(dict.fromkeys(files))  # explicit entries may re-glob
    missing = [f for f in files if not f.is_file()]
    if missing:
        print(f"no such doc file(s): {', '.join(map(str, missing))}")
        return 1
    stale = check(files)
    if not argv:  # API coverage runs against the full default doc set only
        stale += check_api_coverage(files)
    for s in stale:
        print(s)
    if stale:
        print(f"\n{len(stale)} stale doc reference(s); update the docs or the code.")
        return 1
    print(f"doc symbols OK ({len(files)} file(s) checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
