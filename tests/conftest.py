"""Test configuration: enable f64 (oracle precision) before jax initialises.

Note: device count is deliberately NOT forced here — smoke tests and benches
must see the single real CPU device; only launch/dryrun.py sets
``xla_force_host_platform_device_count`` (as its first statement).
"""
import functools
import os
import subprocess
import sys

import jax

jax.config.update("jax_enable_x64", True)


@functools.lru_cache(maxsize=1)
def multidevice_emulation_reason() -> str | None:
    """None when XLA_FLAGS forced-host-device emulation works, else why not.

    The subprocess tests (test_distributed.py, test_specs.py) rely on
    ``--xla_force_host_platform_device_count`` giving a fresh interpreter
    several CPU devices.  Some jaxlib builds / constrained sandboxes ignore
    the flag or refuse to spawn; those environments should *skip* the
    multi-device tests with a clear reason instead of failing them.
    """
    probe = (
        "import os; "
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'; "
        "import jax; print(jax.device_count())"
    )
    try:
        res = subprocess.run(
            [sys.executable, "-c", probe], capture_output=True, text=True,
            timeout=120, env=dict(os.environ),
        )
    except (OSError, subprocess.SubprocessError) as e:
        return f"cannot spawn a python subprocess here ({e!r})"
    if res.returncode != 0:
        return f"probe subprocess failed (rc={res.returncode}): {res.stderr[-500:]}"
    try:
        n = int(res.stdout.strip().splitlines()[-1])
    except (ValueError, IndexError):
        return f"probe printed no device count: {res.stdout[-200:]!r}"
    if n < 4:
        return (
            f"XLA_FLAGS --xla_force_host_platform_device_count is ignored "
            f"(got {n} device(s), need >= 4)"
        )
    return None
