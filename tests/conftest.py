"""Test configuration: enable f64 (oracle precision) before jax initialises.

Note: device count is deliberately NOT forced here — smoke tests and benches
must see the single real CPU device; only launch/dryrun.py sets
``xla_force_host_platform_device_count`` (as its first statement).
"""
import jax

jax.config.update("jax_enable_x64", True)
