"""Test configuration: enable f64 (oracle precision) before jax initialises.

Note: device count is deliberately NOT forced here — smoke tests and benches
must see the single real CPU device; only launch/dryrun.py sets
``xla_force_host_platform_device_count`` (as its first statement).
"""
import functools
import os
import subprocess
import sys
import types

import jax
import pytest

jax.config.update("jax_enable_x64", True)


@functools.lru_cache(maxsize=1)
def multidevice_emulation_reason() -> str | None:
    """None when XLA_FLAGS forced-host-device emulation works, else why not.

    The subprocess tests (test_distributed.py, test_specs.py) rely on
    ``--xla_force_host_platform_device_count`` giving a fresh interpreter
    several CPU devices.  Some jaxlib builds / constrained sandboxes ignore
    the flag or refuse to spawn; those environments should *skip* the
    multi-device tests with a clear reason instead of failing them.
    """
    probe = (
        "import os; "
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'; "
        "import jax; print(jax.device_count())"
    )
    try:
        res = subprocess.run(
            [sys.executable, "-c", probe], capture_output=True, text=True,
            timeout=120, env=dict(os.environ),
        )
    except (OSError, subprocess.SubprocessError) as e:
        return f"cannot spawn a python subprocess here ({e!r})"
    if res.returncode != 0:
        return f"probe subprocess failed (rc={res.returncode}): {res.stderr[-500:]}"
    try:
        n = int(res.stdout.strip().splitlines()[-1])
    except (ValueError, IndexError):
        return f"probe printed no device count: {res.stdout[-200:]!r}"
    if n < 4:
        return (
            f"XLA_FLAGS --xla_force_host_platform_device_count is ignored "
            f"(got {n} device(s), need >= 4)"
        )
    return None


@functools.lru_cache(maxsize=1)
def timing_test_reason() -> str | None:
    """None when wall-clock measurement is trustworthy here, else why not.

    Same pattern as ``multidevice_emulation_reason``: the timing tests
    (test_calibration.py) must *skip with a reason* on hosts whose clock
    resolution or scheduling noise makes a median-of-k sample unusable,
    never flake.  ``REPRO_TIMING_TESTS=skip|force`` overrides the probe.
    """
    from repro.core.cfa.calibrate import timing_unusable_reason

    return timing_unusable_reason()


@pytest.fixture
def measured_timer():
    """Deterministic-enough measurement: warmup + median-of-k helpers.

    Skips (with the probe's reason) when this host cannot time reliably.
    The returned namespace carries ``measure_runs``/``measure_plan`` bound
    to a slightly higher default fidelity than the library's
    (median-of-7 unless ``REPRO_MEASURE_REPEATS`` overrides), the host's
    measured relative ``noise``, and a derived comparison ``tolerance``
    factor: two measurements closer than ``tolerance`` x their magnitude
    are indistinguishable on this host.
    """
    reason = timing_test_reason()
    if reason is not None:
        pytest.skip(f"timing unusable on this host: {reason}")
    from repro.core.cfa.calibrate import (measure_plan as _measure_plan,
                                          measure_runs as _measure_runs,
                                          measurement_noise)

    warmup = int(os.environ.get("REPRO_MEASURE_WARMUP", 1))
    repeats = int(os.environ.get("REPRO_MEASURE_REPEATS", 7))

    def measure_runs(runs, elem_bytes=8, **kw):
        kw.setdefault("warmup", warmup)
        kw.setdefault("repeats", repeats)
        return _measure_runs(runs, elem_bytes, **kw)

    def measure_plan(plan, model, **kw):
        kw.setdefault("warmup", warmup)
        kw.setdefault("repeats", repeats)
        return _measure_plan(plan, model, **kw)

    noise = measurement_noise()
    return types.SimpleNamespace(
        measure_runs=measure_runs,
        measure_plan=measure_plan,
        warmup=warmup,
        repeats=repeats,
        noise=noise,
        tolerance=max(0.35, 2.0 * noise),
    )
