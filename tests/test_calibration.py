"""Measured-vs-modeled calibration tests (repro.core.cfa.calibrate).

Three layers, per the ISSUE-6 acceptance bar:

* *deterministic* — wire-byte accounting, sample validation, fit -> predict
  round-trips on synthetic (analytically generated) samples, JSON
  round-trips: no wall clock involved, never skipped.
* *differential* — the fitted model must rank plans in the same order as
  direct measurement on jacobi2d5p and heat3d (rank-correlation, not
  absolute time), and ``autotune(score="measured")`` must agree rank-exact
  with direct wall-clock measurement of its top candidates.  These use the
  ``measured_timer`` fixture (tests/conftest.py), which *skips with a
  reason* when the host clock is unusable.
* *integration* — measured decisions carry ``measured_time_s`` /
  ``model_error``, ``CompiledStencil.report(measured=True)`` fills
  ``model_error``, and the calibration record serialises.
"""
import dataclasses
import json
import math

import pytest

from repro.core.cfa import (
    AXI_ZC706,
    TPU_V5E_HBM,
    BurstModel,
    IterSpace,
    PROGRAMS,
    Tiling,
    autotune,
)
from repro.core.cfa.bandwidth import PortedPlan
from repro.core.cfa.calibrate import (
    Calibration,
    CalibratedModel,
    CalibrationError,
    TransferSample,
    calibrate,
    fit_burst_model,
    measure_plan,
    measure_runs,
    wire_bytes,
    _wire_words,
)
from repro.core.cfa.compress import get_codec, stored_bits
from repro.core.cfa.plans import (
    bounding_box_plan,
    cfa_plan,
    interior_tile,
    original_layout_plan,
)

MEASURE_KW = dict(warmup=1, repeats=3)  # cheap fidelity for non-assertive timing


def _plans_for(prog_name):
    """(cfa, original, bbox) interior-tile plans at the default tile."""
    prog = PROGRAMS[prog_name]
    sp = IterSpace(tuple(2 * t for t in prog.default_tile))
    tiling = Tiling(prog.default_tile)
    tile = interior_tile(sp, tiling)
    return (
        cfa_plan(sp, prog.deps, tiling, tile),
        original_layout_plan(sp, prog.deps, tiling, tile),
        bounding_box_plan(sp, prog.deps, tiling, tile),
    )


def _synthetic_samples(model, schedules=None, ports=()):
    """Samples generated *analytically* from ``model`` — zero noise, so the
    fit must reproduce the generator exactly (deterministic, no clock)."""
    schedules = schedules or [
        (1,), (1,) * 16, (64,) * 4, (512,) * 8, (4096,), (4096,) * 4]
    out = [
        TransferSample(runs_by_port=(s,), elem_bytes=model.elem_bytes,
                       measured_s=model.time_s(s), label=f"synth/{len(s)}")
        for s in schedules
    ]
    for p in ports:
        per_port = tuple((256,) * 4 for _ in range(p))
        t = max(model.time_s(port) for port in per_port)
        out.append(TransferSample(runs_by_port=per_port,
                                  elem_bytes=model.elem_bytes, measured_s=t,
                                  label=f"synth/p{p}"))
    return out


# ---------------------------------------------------------------------------
# deterministic: wire bytes + samples
# ---------------------------------------------------------------------------

def test_wire_bytes_matches_burst_model():
    for L in (1, 7, 64, 4095):
        assert wire_bytes(L, 8) == AXI_ZC706.burst_bytes(L)
        assert wire_bytes(L, 8, 16) == AXI_ZC706.burst_bytes(L, 16)
        assert wire_bytes(L, 2, 8) == TPU_V5E_HBM.burst_bytes(L, 8)


def test_wire_words_floor_and_compression():
    # a 1-element burst is at least one device word
    assert _wire_words(1, 8, None) == 2  # 8 bytes = 2 float32 words
    assert _wire_words(1, 2, None) == 1  # sub-word rounds up to 1
    # compression shrinks the wire footprint for long runs
    assert _wire_words(1024, 8, 16) < _wire_words(1024, 8, None)
    # and the compressed word count tracks stored_bits exactly
    want = max(1, math.ceil(stored_bits(1024, 64, 16) / 8 / 4))
    assert _wire_words(1024, 8, 16) == want


def test_transfer_sample_validation():
    with pytest.raises(ValueError, match="at least one port"):
        TransferSample(runs_by_port=(), elem_bytes=8, measured_s=1.0)
    with pytest.raises(ValueError, match="positive"):
        TransferSample(runs_by_port=((0, 4),), elem_bytes=8, measured_s=1.0)
    with pytest.raises(ValueError, match="elem_bytes"):
        TransferSample(runs_by_port=((4,),), elem_bytes=0, measured_s=1.0)
    with pytest.raises(ValueError, match="measured_s"):
        TransferSample(runs_by_port=((4,),), elem_bytes=8, measured_s=-1.0)
    with pytest.raises(ValueError, match="measured_s"):
        TransferSample(runs_by_port=((4,),), elem_bytes=8,
                       measured_s=float("nan"))


def test_transfer_sample_accounting():
    s = TransferSample(runs_by_port=((4, 8), (16,)), elem_bytes=8,
                       measured_s=1e-3)
    assert s.n_ports == 2
    assert s.runs == (4, 8, 16)
    assert s.n_bursts == 3
    assert s.wire_bytes == (4 + 8 + 16) * 8


# ---------------------------------------------------------------------------
# deterministic: fit -> predict round-trip (the ISSUE's satellite #1 half 2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", [AXI_ZC706, TPU_V5E_HBM],
                         ids=lambda m: m.name)
def test_fit_recovers_known_model_exactly(model):
    fit = fit_burst_model(_synthetic_samples(model), model)
    assert fit.setup_s == pytest.approx(model.setup_s, rel=1e-6)
    assert fit.peak_bytes_per_s == pytest.approx(model.peak_bytes_per_s,
                                                 rel=1e-6)
    assert fit.elem_bytes == model.elem_bytes
    assert fit.base_name == model.name


@pytest.mark.parametrize("model", [AXI_ZC706, TPU_V5E_HBM],
                         ids=lambda m: m.name)
def test_fit_predict_reproduces_training_samples(model):
    samples = _synthetic_samples(model, ports=(2, 4))
    fit = fit_burst_model(samples, model)
    for s in samples:
        pred = max(fit.time_s(port, s.codec_bits)
                   for port in s.runs_by_port if port)
        pred *= fit.port_factor(s.n_ports)
        assert pred == pytest.approx(s.measured_s, rel=1e-6), s.label


def test_fit_port_factors_identity_on_synthetic():
    # synthetic multi-port samples ARE the analytic max-over-ports time, so
    # the fitted port factors must come out 1.0
    fit = fit_burst_model(_synthetic_samples(AXI_ZC706, ports=(2, 3)),
                          AXI_ZC706)
    assert dict(fit.port_factors).keys() == {2, 3}
    for _, f in fit.port_factors:
        assert f == pytest.approx(1.0, rel=1e-6)


def test_fit_requires_single_port_samples():
    per_port = ((8,), (8,))
    s = TransferSample(runs_by_port=per_port, elem_bytes=8, measured_s=1e-3)
    with pytest.raises(CalibrationError, match="single-port"):
        fit_burst_model([s], AXI_ZC706)
    with pytest.raises(CalibrationError):
        fit_burst_model([], AXI_ZC706)


def test_fit_degenerate_samples_stay_physical():
    # one sample cannot identify two parameters; the fit must still return
    # a usable model (setup >= 0, finite positive peak), not a singular one
    s = TransferSample(runs_by_port=((64,),), elem_bytes=8, measured_s=1e-4)
    fit = fit_burst_model([s], AXI_ZC706)
    assert fit.setup_s >= 0.0
    assert 0.0 < fit.peak_bytes_per_s < float("inf")
    assert fit.time_s((64,)) > 0.0


def test_calibrated_model_is_a_burst_model():
    fit = fit_burst_model(_synthetic_samples(AXI_ZC706), AXI_ZC706)
    assert isinstance(fit, BurstModel)
    assert isinstance(fit, CalibratedModel)
    # drop-in: the autotuner accepts it as the scoring model
    d = autotune(PROGRAMS["jacobi2d5p"], (32, 32, 32), fit, budget=12,
                 seed=0, cache=False)
    assert d.model == fit.name


def test_calibrated_model_port_factor_scaling():
    base = dataclasses.asdict(AXI_ZC706)
    m = CalibratedModel(**base, port_factors=((2, 1.5), (4, 2.0)))
    pp = PortedPlan(
        scheme="cfa", n_ports=2, strategy="facet-lpt",
        read_runs_by_port=((64,), (64,)), write_runs_by_port=((), ()),
        read_useful=128, write_useful=0,
    )
    unscaled = BurstModel(**base).time(pp)
    assert m.time(pp) == pytest.approx(1.5 * unscaled)
    # nearest calibrated count: 3 -> factor of 2 (ties break low)
    assert m.port_factor(3) == 1.5
    assert m.port_factor(5) == 2.0
    assert m.port_factor(1) == 1.0
    # single-port plans are never scaled
    plan = cfa_plan(IterSpace((32, 32, 32)), PROGRAMS["jacobi2d5p"].deps,
                    Tiling((16, 16, 16)))
    assert m.time(plan) == pytest.approx(BurstModel(**base).time(plan))


# ---------------------------------------------------------------------------
# measured: the harness itself (skip-with-reason via the fixture)
# ---------------------------------------------------------------------------

def test_measure_runs_positive_and_finite(measured_timer):
    t = measured_timer.measure_runs((256,) * 4)
    assert t > 0.0 and math.isfinite(t)


def test_measure_runs_empty_schedule_is_free():
    assert measure_runs((), 8, **MEASURE_KW) == 0.0


def test_measure_runs_rejects_bad_lengths():
    with pytest.raises(ValueError, match="positive"):
        measure_runs((0, 4), 8, **MEASURE_KW)
    with pytest.raises(ValueError, match="repeats"):
        measure_runs((4,), 8, warmup=1, repeats=0)
    with pytest.raises(ValueError, match="warmup"):
        measure_runs((4,), 8, warmup=-1, repeats=1)


def test_more_bursts_measure_slower(measured_timer):
    # 64 dispatches vs 1 dispatch of the same total bytes: the per-burst
    # setup cost must dominate — this is the knee the whole paper exploits,
    # and the fit cannot see a setup term if the harness doesn't produce it
    t_many = measured_timer.measure_runs((64,) * 64)
    t_one = measured_timer.measure_runs((4096,))
    assert t_many > t_one


def test_measure_plan_ported_takes_the_slowest_port(measured_timer):
    # two ports carrying the SAME schedule: max-over-ports semantics gives
    # ~1x one schedule's time, sum-over-ports would give ~2x — a factor-2
    # separation that survives host noise where exact equality would flake
    runs = (512,) * 8
    pp = PortedPlan(
        scheme="cfa", n_ports=2, strategy="facet-lpt",
        read_runs_by_port=(runs, runs), write_runs_by_port=((), ()),
        read_useful=2 * sum(runs), write_useful=0,
    )
    t_pp = measured_timer.measure_plan(pp, AXI_ZC706)
    t_runs = measured_timer.measure_runs(runs, AXI_ZC706.elem_bytes)
    assert 0.4 * t_runs < t_pp < 1.6 * t_runs


def test_measured_env_overrides(monkeypatch):
    from repro.core.cfa.calibrate import _measure_defaults

    monkeypatch.setenv("REPRO_MEASURE_WARMUP", "0")
    monkeypatch.setenv("REPRO_MEASURE_REPEATS", "1")
    assert _measure_defaults(None, None) == (0, 1)
    # explicit arguments beat the environment
    assert _measure_defaults(2, 3) == (2, 3)


# ---------------------------------------------------------------------------
# differential: fitted model ranks plans like measurement (satellite #1)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("prog_name", ["jacobi2d5p", "heat3d"])
def test_fitted_model_ranks_plans_like_measurement(prog_name, measured_timer):
    plans = _plans_for(prog_name)
    fit = fit_burst_model(_synthetic_samples(AXI_ZC706), AXI_ZC706)
    measured = [measured_timer.measure_plan(p, AXI_ZC706) for p in plans]
    # only compare pairs the host can actually distinguish: times closer
    # than the measured noise band carry no rank information
    tol = measured_timer.tolerance
    for i in range(len(plans)):
        for j in range(i + 1, len(plans)):
            lo, hi = sorted((measured[i], measured[j]))
            if hi - lo <= tol * hi:
                continue
            model_order = fit.time(plans[i]) < fit.time(plans[j])
            clock_order = measured[i] < measured[j]
            assert model_order == clock_order, (
                f"{prog_name}: fitted model ranks plans {i},{j} "
                f"({fit.time(plans[i]):.2e} vs {fit.time(plans[j]):.2e}) "
                f"against the measurement ({measured[i]:.2e} vs "
                f"{measured[j]:.2e})"
            )


@pytest.mark.parametrize("prog_name", ["jacobi2d5p", "heat3d"])
def test_fitted_and_measured_rank_correlation_is_perfect(prog_name,
                                                         measured_timer):
    """Kendall tau over the distinguishable pairs must be exactly +1: a
    model fitted from *measured* samples on this host may never invert a
    pair of plans the wall clock separates beyond its noise band.  Ties
    (pairs inside the noise band) carry no rank information and are
    excluded — rank-correlation, not absolute-time, per the ISSUE."""
    plans = _plans_for(prog_name)
    # host-calibrated fit: the synthetic grid measured for real
    samples = [
        TransferSample(runs_by_port=(s,), elem_bytes=AXI_ZC706.elem_bytes,
                       measured_s=measured_timer.measure_runs(s),
                       label=f"grid/{len(s)}")
        for s in [(1,), (1,) * 16, (64,) * 4, (512,) * 8, (4096,),
                  (4096,) * 4]
    ]
    fit = fit_burst_model(samples, AXI_ZC706)
    measured = [measured_timer.measure_plan(p, AXI_ZC706) for p in plans]
    tol = measured_timer.tolerance
    concordant = discordant = 0
    for i in range(len(plans)):
        for j in range(i + 1, len(plans)):
            lo, hi = sorted((measured[i], measured[j]))
            if hi - lo <= tol * hi:
                continue  # tie on this host
            same = ((fit.time(plans[i]) < fit.time(plans[j]))
                    == (measured[i] < measured[j]))
            concordant += same
            discordant += not same
    # cfa sits ~20x below the single-array baselines here, so at least
    # those pairs must be distinguishable — the assertion is never vacuous
    assert concordant >= 2
    assert discordant == 0, (
        f"{prog_name}: fitted ranking inverts {discordant} measured "
        f"pair(s) (tau = {(concordant - discordant) / (concordant + discordant):.2f})"
    )


# ---------------------------------------------------------------------------
# integration: autotune(score="measured") (the tentpole's acceptance bar)
# ---------------------------------------------------------------------------

def test_autotune_measured_sets_fields(tmp_path):
    d = autotune(PROGRAMS["jacobi2d5p"], (32, 32, 32), AXI_ZC706, budget=16,
                 seed=0, score="measured", measure_top=3,
                 measure_kwargs=MEASURE_KW, cache_dir=tmp_path)
    assert d.score == "measured"
    measured = [s for s in d.ranked if s.measured_time_s is not None]
    assert len(measured) == 3
    # the measured candidates lead the ranking, in wall-clock order
    assert d.ranked[: len(measured)] == tuple(measured)
    times = [s.measured_time_s for s in measured]
    assert times == sorted(times)
    for s in measured:
        assert s.measured_time_s > 0.0
        assert s.model_error is not None and s.model_error >= 0.0
    # unmeasured candidates keep modeled order behind them
    rest = d.ranked[len(measured):]
    bws = [s.effective_bw for s in rest]
    assert bws == sorted(bws, reverse=True)


def test_autotune_measured_top3_agrees_with_direct_measurement(
        tmp_path, measured_timer):
    """ISSUE-6 acceptance: the measured decision's top-3 order on
    jacobi2d5p@host is rank-exact against an independent direct wall-clock
    measurement of those same candidates' plans."""
    prog = PROGRAMS["jacobi2d5p"]
    d = autotune(prog, (32, 32, 32), AXI_ZC706, budget=16, seed=0,
                 score="measured", measure_top=3,
                 measure_kwargs=dict(warmup=measured_timer.warmup,
                                     repeats=measured_timer.repeats),
                 cache_dir=tmp_path)
    top = [s for s in d.ranked if s.measured_time_s is not None][:3]
    sp = IterSpace((32, 32, 32))
    direct = [measured_timer.measure_plan(s.candidate.plan(sp, prog),
                                          AXI_ZC706) for s in top]
    stored = [s.measured_time_s for s in top]
    tol = measured_timer.tolerance

    def distinguishable(a, b):
        lo, hi = sorted((a, b))
        return hi - lo > tol * hi

    for i in range(len(top)):
        for j in range(i + 1, len(top)):
            # a rank claim needs the pair separated beyond noise in BOTH
            # the decision's own timing and the independent re-measurement;
            # near-tied candidates may legitimately order either way
            if not (distinguishable(direct[i], direct[j])
                    and distinguishable(stored[i], stored[j])):
                continue
            assert (direct[i] < direct[j]) == (i < j), (
                f"decision rank {i} vs {j} disagrees with direct "
                f"measurement {direct[i]:.2e} vs {direct[j]:.2e}"
            )


def test_autotune_measured_decision_roundtrips(tmp_path):
    from repro.core.cfa import LayoutDecision

    d = autotune(PROGRAMS["heat1d"], (8, 64), AXI_ZC706, budget=8, seed=0,
                 score="measured", measure_top=2, measure_kwargs=MEASURE_KW,
                 cache_dir=tmp_path)
    back = LayoutDecision.from_json(d.to_json())
    assert back == d
    assert back.best.measured_time_s == d.best.measured_time_s
    assert back.score == "measured"


def test_report_measured_fills_model_error(tmp_path):
    from repro import cfa

    compiled = cfa.compile("jacobi2d5p", (32, 32, 32), layout="default",
                           backend="wavefront")
    plain = compiled.report()
    assert plain.measured_time_s is None and plain.model_error is None
    rep = compiled.report(measured=True, **MEASURE_KW)
    assert rep.measured_time_s is not None and rep.measured_time_s > 0.0
    assert rep.model_error is not None and rep.model_error >= 0.0
    assert rep.model_error == pytest.approx(
        abs(AXI_ZC706.time(compiled.plan) - rep.measured_time_s)
        / rep.measured_time_s)


def test_report_measured_reuses_decision_measurement(tmp_path):
    from repro import cfa

    compiled = cfa.compile(
        "jacobi2d5p", (32, 32, 32), backend="wavefront",
        autotune_kwargs=dict(budget=12, seed=0, score="measured",
                             measure_top=2, measure_kwargs=MEASURE_KW,
                             cache_dir=tmp_path))
    assert compiled.decision is not None
    best = compiled.decision.best
    if best.candidate != compiled.layout:  # pragma: no cover - defensive
        pytest.skip("winner is not the compiled layout; nothing to reuse")
    rep = compiled.report(measured=True)
    assert rep.measured_time_s == best.measured_time_s


# ---------------------------------------------------------------------------
# integration: the calibration sweep + its JSON record
# ---------------------------------------------------------------------------

def test_calibrate_records_plan_errors(measured_timer):
    c = calibrate(AXI_ZC706, programs=("jacobi2d5p",),
                  storages=("redundant", "compressed"), ports=(1, 2),
                  lengths=(1, 64, 1024), counts=(1, 8),
                  warmup=measured_timer.warmup,
                  repeats=measured_timer.repeats)
    assert c.target == AXI_ZC706.name
    # every (program, storage, ports) plan has an error row with both
    # modeled- and fitted-vs-measured relative error recorded
    assert len(c.plan_errors) == 1 * 2 * 2
    for row in c.plan_errors:
        assert row["measured_s"] > 0.0
        assert row["rel_err_modeled"] is not None
        assert row["rel_err_fitted"] is not None
        assert row["rel_err_modeled"] >= 0.0
        assert row["rel_err_fitted"] >= 0.0
    assert c.max_rel_err("fitted") >= 0.0
    assert "calibration of axi-zc706" in c.summary()
    # the fitted model stays physical
    assert c.fitted.setup_s >= 0.0 and c.fitted.peak_bytes_per_s > 0.0


def test_calibration_json_roundtrip(measured_timer):
    c = calibrate(AXI_ZC706, programs=("jacobi2d5p",),
                  storages=("redundant",), ports=(1,),
                  lengths=(1, 256), counts=(1, 4),
                  warmup=measured_timer.warmup,
                  repeats=measured_timer.repeats)
    back = Calibration.from_json(c.to_json())
    assert back == c
    assert back.fitted == c.fitted
    assert isinstance(back.fitted, CalibratedModel)


def test_calibration_save(tmp_path, measured_timer):
    c = calibrate(AXI_ZC706, programs=("jacobi2d5p",),
                  storages=("redundant",), ports=(1,),
                  lengths=(1, 256), counts=(1,),
                  warmup=measured_timer.warmup,
                  repeats=measured_timer.repeats)
    out = c.save(tmp_path / "nested" / "cal.json")
    blob = json.loads(out.read_text())
    assert blob["target"] == "axi-zc706"
    assert blob["plan_errors"][0]["rel_err_modeled"] is not None


# ---------------------------------------------------------------------------
# measured: the overlapped (dataflow) schedule vs the sequential one
# ---------------------------------------------------------------------------

def test_measure_runs_rejects_negative_compute():
    with pytest.raises(ValueError, match="compute_s"):
        measure_runs((4,), 8, compute_s=-1e-3, **MEASURE_KW)


def test_measure_runs_compute_only_pass_takes_the_compute_time():
    # an empty schedule with compute still occupies the compute's wall time
    # (the _burn contract: elapsed >= seconds, by construction)
    for ovl in (False, True):
        t = measure_runs((), 8, warmup=0, repeats=1, compute_s=5e-4,
                         overlap=ovl)
        assert t >= 5e-4


def test_measured_overlap_hides_compute_behind_transfers(measured_timer):
    """The dataflow schedule measured for real: at the balanced point
    (compute ~ transfer) the overlapped pass must undercut the sequential
    one beyond the host's noise band — the wall-clock proof that fetch and
    compute genuinely overlap.  Large bursts keep the schedule copy-bound
    rather than dispatch-bound (python dispatch cannot overlap python
    compute on a single host thread)."""
    runs = (1 << 22,) * 4
    kw = dict(repeats=5)
    t0 = measured_timer.measure_runs(runs, **kw)
    c = t0  # balanced point: the modeled separation is maximal (~2x)
    t_seq = measured_timer.measure_runs(runs, compute_s=c, overlap=False, **kw)
    t_ovl = measured_timer.measure_runs(runs, compute_s=c, overlap=True, **kw)
    tol = measured_timer.tolerance
    # overlapping never hurts ...
    assert t_ovl <= t_seq * (1.0 + tol)
    # ... here it must genuinely help.  The modeled balanced-point speedup
    # is 2x; demand a healthy fraction of it.  The noise-derived tolerance
    # is capped: on a loud host it can exceed 1.0, which would make any
    # separation demand unsatisfiable even for a perfect pipeline.
    sep = min(max(tol, 0.2), 0.45)
    assert t_seq - t_ovl > sep * t_seq, (
        f"no measured overlap: seq={t_seq:.3e} ovl={t_ovl:.3e} (sep={sep})"
    )
    # ... and the overlapped pass cannot beat its critical path
    assert t_ovl > (1.0 - min(tol, 0.9)) * max(t0, c)


def test_fitted_overlapped_model_ranks_regimes_like_measurement(measured_timer):
    """ISSUE-7: a model fitted from measured samples must rank a
    transfer-heavy plan against a compute-heavy one the same way the wall
    clock does, under the overlapped composition — on pairs the host can
    distinguish (the same tolerance-pair rule as the sequential ranking
    tests above)."""
    kw = dict(repeats=3)
    grid = [(4096,), (1 << 20,), (1 << 22,), (1 << 22,) * 2]
    samples = [
        TransferSample(runs_by_port=(s,), elem_bytes=AXI_ZC706.elem_bytes,
                       measured_s=measured_timer.measure_runs(s, **kw),
                       label=f"grid/{sum(s)}")
        for s in grid
    ]
    fit = fit_burst_model(samples, AXI_ZC706)
    from repro.core.cfa.plans import TransferPlan

    plan_heavy = TransferPlan("x", (1 << 22,) * 4, (), 4 * (1 << 22), 0)
    plan_lean = TransferPlan("x", (1 << 20,), (), 1 << 20, 0)
    c_big = 2.0 * fit.transfer_time_s(plan_heavy)
    # (plan, per-tile compute): transfer-heavy, lean, compute-heavy
    configs = [(plan_heavy, 0.0), (plan_lean, 0.0), (plan_lean, c_big)]
    modeled = [fit.time(p, compute_s=c, overlap=True) for p, c in configs]
    measured = [measured_timer.measure_plan(p, AXI_ZC706, compute_s=c,
                                            overlap=True, **kw)
                for p, c in configs]
    tol = measured_timer.tolerance
    checked = 0
    for i in range(len(configs)):
        for j in range(i + 1, len(configs)):
            lo, hi = sorted((measured[i], measured[j]))
            if hi - lo <= tol * hi:
                continue  # tie on this host: no rank information
            checked += 1
            assert (modeled[i] < modeled[j]) == (measured[i] < measured[j]), (
                f"overlapped fit ranks configs {i},{j} "
                f"({modeled[i]:.2e} vs {modeled[j]:.2e}) against the "
                f"measurement ({measured[i]:.2e} vs {measured[j]:.2e})"
            )
    # the heavy-vs-lean pair differs ~4x in bytes and the compute-heavy
    # config doubles the lean one: at least one pair must be decidable
    assert checked >= 1


def test_calibrate_overlap_records_overlapped_plan_rows(measured_timer):
    c = calibrate(AXI_ZC706, programs=("jacobi2d5p",),
                  storages=("redundant",), ports=(1,),
                  lengths=(1, 64), counts=(1, 4),
                  warmup=measured_timer.warmup,
                  repeats=measured_timer.repeats,
                  overlap=True)
    seq = [r for r in c.plan_errors if not r["overlap"]]
    ovl = [r for r in c.plan_errors if r["overlap"]]
    # one sequential + one overlapped row per (program, storage, ports)
    assert len(seq) == 1 and len(ovl) == 1
    assert seq[0]["compute_s"] == 0.0
    assert ovl[0]["compute_s"] > 0.0  # the balanced point: compute ~ transfer
    for row in c.plan_errors:
        assert row["measured_s"] > 0.0
        assert row["rel_err_modeled"] >= 0.0
        assert row["rel_err_fitted"] >= 0.0
    # the overlapped rows survive the JSON round-trip
    back = Calibration.from_json(c.to_json())
    assert back == c
    assert [r["overlap"] for r in back.plan_errors] == [False, True]


def test_calibrate_rows_carry_overlap_keys_by_default(measured_timer):
    c = calibrate(AXI_ZC706, programs=("jacobi2d5p",),
                  storages=("redundant",), ports=(1,),
                  lengths=(1, 64), counts=(1,),
                  warmup=measured_timer.warmup,
                  repeats=measured_timer.repeats)
    assert all(r["overlap"] is False and r["compute_s"] == 0.0
               for r in c.plan_errors)


def test_timing_probe_env_escape_hatch(monkeypatch):
    from repro.core.cfa.calibrate import (_timing_probe, measurement_noise,
                                          timing_unusable_reason)

    monkeypatch.setenv("REPRO_TIMING_TESTS", "skip")
    _timing_probe.cache_clear()
    try:
        reason = timing_unusable_reason()
        assert reason is not None and "REPRO_TIMING_TESTS" in reason
        monkeypatch.setenv("REPRO_TIMING_TESTS", "force")
        _timing_probe.cache_clear()
        assert timing_unusable_reason() is None
        assert measurement_noise() == 0.0
    finally:
        _timing_probe.cache_clear()


def test_host_fingerprint_is_stable_and_jsonable():
    from repro.core.cfa.executors import host_fingerprint

    a, b = host_fingerprint(), host_fingerprint()
    assert a == b
    json.dumps(a)  # must be cache-key material
    assert [k for k, _ in a] == ["machine", "system", "python", "jax",
                                 "backend", "device"]
