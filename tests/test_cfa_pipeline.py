"""End-to-end CFA pipeline: tiled sweep through facet storage == oracle.

(The hypothesis-based pack/unpack round-trip property lives in
``test_cfa_properties.py`` so this module collects without the optional
``hypothesis`` extra.)
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.cfa import (
    CFAPipeline,
    IterSpace,
    Tiling,
    build_facet_specs,
    get_program,
    pack_all,
    pack_facet,
    unpack_into,
)


def test_pack_rejects_non_dividing_width():
    prog = get_program("smith-waterman-3seq")  # w0 = 3
    space, tiling = IterSpace((16, 16, 16)), Tiling((16, 16, 16))
    specs = build_facet_specs(space, prog.deps, tiling)
    with pytest.raises(ValueError):
        pack_facet(jnp.zeros(space.sizes), specs[0])


# ---------------------------------------------------------------------------
# tiled sweep through facets == untiled oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "name,space,tile",
    [
        ("jacobi2d5p", (8, 8, 8), (4, 4, 4)),
        ("jacobi2d5p", (6, 12, 8), (2, 4, 4)),
        ("jacobi2d9p", (8, 8, 8), (4, 4, 4)),
        ("jacobi2d9p-gol", (8, 8, 8), (4, 4, 4)),
        ("gaussian", (4, 16, 16), (2, 8, 8)),
        ("smith-waterman-3seq", (9, 8, 8), (3, 4, 4)),
        # tile-dependent modulo labelling (w does not divide t on axis 0)
        ("smith-waterman-3seq", (8, 8, 8), (4, 4, 4)),
    ],
)
def test_sweep_matches_oracle(name, space, tile):
    prog = get_program(name)
    pipe = CFAPipeline(prog, IterSpace(space), Tiling(tile))
    w0 = pipe.specs[0].width
    rng = np.random.default_rng(0)
    inputs = jnp.asarray(rng.normal(size=(w0, *space[1:])))

    facets = pipe._sweep(inputs, dtype=jnp.float64)
    V = pipe.reference_volume(inputs)

    # Strongest check: every facet block equals the packed oracle volume,
    # i.e. the tiled pipeline stored exactly the right values in the right
    # (burst-contiguous) places.  Covers copy-in, execute and copy-out.
    for k, spec in pipe.specs.items():
        got = facets[k]
        if k == 0:
            got = got[1:]  # drop the virtual live-in row
        if spec.tile_sizes[spec.axis] % spec.width == 0:
            want = pack_facet(V, spec)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-12, atol=1e-12)
        else:
            # general modulo labelling: compare via per-tile gather
            from repro.core.cfa.spaces import facet_points, facet_widths
            import itertools
            wds = facet_widths(prog.deps)
            for q in itertools.product(*map(range, pipe.num_tiles)):
                pts = facet_points(pipe.tiling, wds, k, q)
                offs = spec.offsets(pts)
                if k == 0:
                    offs = offs + spec.block_elems * int(
                        np.prod([spec.num_tiles[a] for a in spec.outer_axes[1:]])
                    )
                vals = np.asarray(facets[k]).ravel()[offs]
                want = np.asarray(V)[tuple(pts.T)]
                np.testing.assert_allclose(vals, want, rtol=1e-12, atol=1e-12)


def test_final_time_plane_recoverable():
    """The application's result (last time plane) lives in facet_0 blocks."""
    prog = get_program("jacobi2d5p")
    space, tile = (8, 8, 8), (4, 4, 4)
    pipe = CFAPipeline(prog, IterSpace(space), Tiling(tile))
    rng = np.random.default_rng(1)
    inputs = jnp.asarray(rng.normal(size=(1, 8, 8)))
    facets = pipe._sweep(inputs, dtype=jnp.float64)
    V = pipe.reference_volume(inputs)

    spec = pipe.specs[0]
    want = pack_facet(V, spec)  # w0 = 1 divides t0
    got = facets[0][1:]
    # last time-tile row holds the final plane
    np.testing.assert_allclose(
        np.asarray(got[-1]), np.asarray(want[-1]), rtol=1e-12, atol=1e-12
    )
