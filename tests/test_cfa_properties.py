"""Property tests for the CFA core (the appendix coverage proofs).

Requires the optional ``hypothesis`` test extra (``pip install .[test]``);
the whole module is skipped when it is absent so tier-1 collection never
breaks on a minimal install.
"""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.cfa import (
    AXI_ZC706,
    TPU_V5E_HBM,
    BandwidthReport,
    Deps,
    IterSpace,
    Tiling,
    build_facet_specs,
    cfa_plan,
    facet_widths,
    flow_in_points,
    overlap_speedup,
)
from repro.core.cfa.plans import TransferPlan, _assign_hosts

dep_component = st.integers(min_value=-2, max_value=0)


@st.composite
def dep_patterns(draw, d):
    n = draw(st.integers(min_value=1, max_value=4))
    vecs = []
    for _ in range(n):
        v = tuple(draw(dep_component) for _ in range(d))
        vecs.append(v)
    if all(all(c == 0 for c in v) for v in vecs):
        vecs[0] = tuple(-1 for _ in range(d))
    return Deps(tuple(vecs))


@given(st.data())
@settings(max_examples=60, deadline=None)
def test_flow_in_contained_in_facets(data):
    """Appendix B: every flow-in point of T lies in a facet of its own tile."""
    d = data.draw(st.integers(min_value=1, max_value=3), label="d")
    deps = data.draw(dep_patterns(d), label="deps")
    w = facet_widths(deps)
    tiles = tuple(
        data.draw(st.integers(min_value=max(1, w[a]), max_value=4), label=f"t{a}")
        for a in range(d)
    )
    nt = tuple(data.draw(st.integers(min_value=1, max_value=3), label=f"n{a}") for a in range(d))
    space = IterSpace(tuple(t * n for t, n in zip(tiles, nt)))
    tiling = Tiling(tiles)
    specs = build_facet_specs(space, deps, tiling)
    tile = tuple(min(1, n - 1) for n in nt)
    fin = flow_in_points(space, deps, tiling, tile)
    for y in fin:
        assert any(spec.domain_mask(y[None, :])[0] for spec in specs.values()), (
            f"flow-in point {y} not covered by any facet (deps={deps.vectors})"
        )


@given(st.data())
@settings(max_examples=30, deadline=None)
def test_host_assignment_total_and_valid(data):
    d = 3
    deps = data.draw(dep_patterns(d), label="deps")
    w = facet_widths(deps)
    tiles = tuple(max(2, wa + 1) for wa in w)
    space = IterSpace(tuple(t * 3 for t in tiles))
    tiling = Tiling(tiles)
    specs = build_facet_specs(space, deps, tiling)
    tile = (1, 1, 1)
    fin = flow_in_points(space, deps, tiling, tile)
    hosts = _assign_hosts(fin, tile, tiling, w, specs)
    assigned = sum(len(v) for v in hosts.values())
    assert assigned == len(fin)
    for k, idx in hosts.items():
        if idx.size:
            assert bool(specs[k].domain_mask(fin[idx]).all())


@given(runs=st.lists(st.integers(1, 4096), min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_bandwidth_report_bounded_by_peak(runs):
    plan = TransferPlan("x", tuple(runs), (), sum(runs), 0)
    rep = BandwidthReport.evaluate(plan, AXI_ZC706)
    assert 0 < rep.peak_fraction_raw <= 1.0
    assert rep.peak_fraction_effective <= rep.peak_fraction_raw + 1e-12


@given(
    w=st.integers(1, 3),
    t=st.integers(3, 6),
)
@settings(max_examples=20, deadline=None)
def test_write_always_single_burst_per_facet(w, t):
    """The paper's stance: ALL writes are bursts — any dep pattern, any tile."""
    if w > t:
        return
    deps = Deps(((-w, 0, 0), (0, -w, 0), (0, 0, -w)))
    space = IterSpace((3 * t, 3 * t, 3 * t))
    tiling = Tiling((t, t, t))
    plan = cfa_plan(space, deps, tiling, (1, 1, 1))
    assert plan.n_write_bursts == 3
    assert all(r > 0 for r in plan.write_runs)


# ---------------------------------------------------------------------------
# N-dimensional spaces (2-D and 4-D): single-assignment + sweep == oracle
# ---------------------------------------------------------------------------

@given(st.data())
@settings(max_examples=30, deadline=None)
def test_nd_single_assignment_no_collisions(data):
    """§IV-F4 in any dimension: per facet, every tile's block occupies
    distinct offsets inside the array bounds (random 2-D and 4-D setups)."""
    import itertools

    from repro.core.cfa.spaces import facet_points

    d = data.draw(st.sampled_from([2, 4]), label="d")
    deps = data.draw(dep_patterns(d), label="deps")
    w = facet_widths(deps)
    tiles = tuple(
        data.draw(st.integers(min_value=max(1, w[a]), max_value=4), label=f"t{a}")
        for a in range(d)
    )
    nt = tuple(data.draw(st.integers(min_value=1, max_value=2), label=f"n{a}")
               for a in range(d))
    space = IterSpace(tuple(t * n for t, n in zip(tiles, nt)))
    tiling = Tiling(tiles)
    specs = build_facet_specs(space, deps, tiling)
    import numpy as np
    for k, spec in specs.items():
        offs = [
            spec.offsets(facet_points(tiling, w, k, q))
            for q in itertools.product(*map(range, nt))
        ]
        flat = np.concatenate(offs)
        assert len(np.unique(flat)) == len(flat), f"facet_{k} offsets collide"
        assert flat.min() >= 0 and flat.max() < spec.size


@given(st.data())
@settings(max_examples=6, deadline=None)
def test_nd_sweep_matches_oracle_random_tilings(data):
    """The N-D executor is exact for random tilings of the 2-D and 4-D
    example programs (sweep through facet storage == untiled oracle)."""
    import numpy as np
    import jax.numpy as jnp

    from repro.core.cfa import CFAPipeline, get_program, pack_facet

    name = data.draw(st.sampled_from(["heat1d", "heat3d"]), label="program")
    prog = get_program(name)
    w = facet_widths(prog.deps)
    d = prog.ndim
    # keep 4-D spaces tiny: the sweep is a python tile loop
    tmax = 4 if d == 2 else 3
    tiles = tuple(
        data.draw(st.integers(min_value=max(1, w[a]), max_value=tmax),
                  label=f"t{a}")
        for a in range(d)
    )
    nt = tuple(data.draw(st.integers(min_value=1, max_value=2), label=f"n{a}")
               for a in range(d))
    space = tuple(t * n for t, n in zip(tiles, nt))
    pipe = CFAPipeline(prog, IterSpace(space), Tiling(tiles))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1),
                                          label="seed"))
    inputs = jnp.asarray(rng.normal(size=(pipe.specs[0].width, *space[1:])))
    facets = pipe._sweep(inputs, dtype=jnp.float64)
    V = pipe.reference_volume(inputs)
    for k, spec in pipe.specs.items():
        got = facets[k][1:] if k == 0 else facets[k]
        if spec.tile_sizes[spec.axis] % spec.width == 0:
            want = pack_facet(V, spec)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-12, atol=1e-12)


@given(st.data())
@settings(max_examples=40, deadline=None)
def test_cfa1xx_static_verdict_matches_sampled_property(data):
    """The CFA1xx static verifier agrees with directly sampling the
    single-assignment property on random 2-D/3-D/4-D spaces: at a randomly
    chosen tile, per-facet offsets are injective and every flow-in point
    resolves (a unique owner under irredundant storage), if and only if the
    static report is ERROR-free."""
    import numpy as np

    from repro.core.cfa import build_storage_map, owner_of
    from repro.core.cfa.analysis import check_facet_family
    from repro.core.cfa.spaces import facet_points

    d = data.draw(st.sampled_from([2, 3, 4]), label="d")
    deps = data.draw(dep_patterns(d), label="deps")
    w = facet_widths(deps)
    tiles = tuple(
        data.draw(st.integers(min_value=max(1, w[a]), max_value=4), label=f"t{a}")
        for a in range(d)
    )
    nt = tuple(data.draw(st.integers(min_value=1, max_value=2), label=f"n{a}")
               for a in range(d))
    space = IterSpace(tuple(t * n for t, n in zip(tiles, nt)))
    tiling = Tiling(tiles)
    storage = data.draw(st.sampled_from(["redundant", "irredundant"]),
                        label="storage")

    errors = [x for x in check_facet_family(space, deps, tiling,
                                            storage=storage)
              if x.severity == "ERROR"]

    # the sampled oracle, at a random tile of the grid
    tile = tuple(data.draw(st.integers(0, n - 1), label=f"q{a}")
                 for a, n in enumerate(nt))
    specs = build_facet_specs(space, deps, tiling)
    sampled_ok = True
    for k in specs:
        offs = specs[k].offsets(facet_points(tiling, w, k, tile))
        if len(np.unique(offs)) != len(offs):
            sampled_ok = False
    fin = flow_in_points(space, deps, tiling, tile)
    if len(fin):
        if storage == "redundant":
            if (owner_of(specs, fin) < 0).any():
                sampled_ok = False
        else:
            smap = build_storage_map(specs)
            counts = sum(smap.stores(k, fin).astype(int) for k in specs)
            if (counts != 1).any():
                sampled_ok = False

    # the family construction is legal by design, so both sides must say
    # "clean" — and in particular must say the *same* thing
    assert sampled_ok, (
        f"sampled single-assignment violated (deps={deps.vectors}, "
        f"tiles={tiles}, nt={nt}, tile={tile}, storage={storage})"
    )
    assert not errors, [str(x) for x in errors]


# ---------------------------------------------------------------------------
# Irredundant storage (Ferry 2024): single assignment over random spaces
# ---------------------------------------------------------------------------

@given(st.data())
@settings(max_examples=40, deadline=None)
def test_irredundant_single_assignment_partition(data):
    """Every canonical point covered by some facet is owned by *exactly one*
    facet block — the irredundant discipline's invariant — over random
    2-D/3-D/4-D spaces and dependence patterns."""
    import numpy as np

    from repro.core.cfa import build_storage_map, owner_of
    from repro.core.cfa.spaces import facet_points

    d = data.draw(st.sampled_from([2, 3, 4]), label="d")
    deps = data.draw(dep_patterns(d), label="deps")
    w = facet_widths(deps)
    tiles = tuple(
        data.draw(st.integers(min_value=max(1, w[a]), max_value=4), label=f"t{a}")
        for a in range(d)
    )
    nt = tuple(data.draw(st.integers(min_value=1, max_value=2), label=f"n{a}")
               for a in range(d))
    space = IterSpace(tuple(t * n for t, n in zip(tiles, nt)))
    tiling = Tiling(tiles)
    specs = build_facet_specs(space, deps, tiling)
    smap = build_storage_map(specs)
    assert smap.redundancy == 1.0
    tile = tuple(min(1, n - 1) for n in nt)
    pts = np.concatenate([facet_points(tiling, w, k, tile) for k in specs])
    uniq = np.unique(pts, axis=0)
    own = owner_of(specs, uniq)
    # total: every facet-union point has an owner ...
    assert (own >= 0).all()
    # ... the owner's facet covers it ...
    for k in specs:
        sel = own == k
        if sel.any():
            assert bool(specs[k].domain_mask(uniq[sel]).all())
    # ... and the static per-block masks count exactly the owned points,
    # so ownership partitions the union (stored slots == distinct points)
    for k in specs:
        assert smap.owned_per_block[k] == int((own == k).sum())
    assert sum(smap.owned_per_block.values()) == len(uniq)
    n_blocks = int(np.prod(nt))
    assert smap.stored_elems == len(uniq) * n_blocks
    assert smap.stored_elems <= smap.redundant_elems


@given(st.data())
@settings(max_examples=6, deadline=None)
def test_irredundant_sweep_matches_redundant_random_tilings(data):
    """The irredundant executor path is exact for random tilings of the
    2-D and 4-D example programs: rehydrate(irredundant sweep) equals the
    redundant sweep bit-for-bit."""
    import numpy as np
    import jax.numpy as jnp

    from repro.core.cfa import CFAPipeline, dedup_facets, get_program, rehydrate_facets
    from repro.core.cfa.irredundant import IrredundantPipeline

    name = data.draw(st.sampled_from(["heat1d", "heat3d"]), label="program")
    prog = get_program(name)
    w = facet_widths(prog.deps)
    d = prog.ndim
    tmax = 4 if d == 2 else 3
    tiles = tuple(
        data.draw(st.integers(min_value=max(1, w[a]), max_value=tmax),
                  label=f"t{a}")
        for a in range(d)
    )
    nt = tuple(data.draw(st.integers(min_value=1, max_value=2), label=f"n{a}")
               for a in range(d))
    space = tuple(t * n for t, n in zip(tiles, nt))
    red = CFAPipeline(prog, IterSpace(space), Tiling(tiles))
    irr = IrredundantPipeline(prog, IterSpace(space), Tiling(tiles))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1),
                                          label="seed"))
    inputs = jnp.asarray(rng.normal(size=(red.specs[0].width, *space[1:])))
    f_red = red._sweep(inputs, dtype=jnp.float64)
    f_irr = irr._sweep(inputs, dtype=jnp.float64)
    dd = dedup_facets(f_red, irr.storage_map)
    for k in f_red:
        assert (np.asarray(f_irr[k]) == np.asarray(dd[k])).all(), f"facet {k}"
    rh = rehydrate_facets(f_irr, irr.storage_map)
    for k in f_red:
        assert (np.asarray(rh[k]) == np.asarray(f_red[k])).all(), f"facet {k}"


# ---------------------------------------------------------------------------
# Calibration layer (measured-vs-modeled): model + fit invariants
# ---------------------------------------------------------------------------

run_lengths = st.lists(st.integers(1, 1 << 16), min_size=1, max_size=32)
codec_bits_or_none = st.sampled_from([None, 4, 8, 16, 32])


@given(runs=run_lengths, bits=codec_bits_or_none, grow=st.integers(1, 1 << 12),
       at=st.integers(0, 31))
@settings(max_examples=60, deadline=None)
def test_time_s_monotone_in_run_lengths(runs, bits, grow, at):
    """Lengthening any single run never makes the modeled schedule faster."""
    at %= len(runs)
    longer = tuple(r + grow if i == at else r for i, r in enumerate(runs))
    for model in (AXI_ZC706, TPU_V5E_HBM):
        assert model.time_s(longer, bits) >= model.time_s(tuple(runs), bits)


@given(n=st.integers(2, 1 << 16), cut=st.integers(1, (1 << 16) - 1),
       bits=codec_bits_or_none)
@settings(max_examples=60, deadline=None)
def test_burst_bytes_superadditive_under_run_splitting(n, cut, bits):
    """Splitting one run into two never shrinks the wire bytes (compression
    headers are per burst) and strictly adds a setup to the modeled time —
    the first-order reason CFA prefers few long bursts (§II-E)."""
    cut %= n
    if cut == 0:
        cut = 1
    a, b = cut, n - cut
    for model in (AXI_ZC706, TPU_V5E_HBM):
        whole = model.burst_bytes(n, bits)
        split = model.burst_bytes(a, bits) + model.burst_bytes(b, bits)
        assert split >= whole - 1e-9
        t_whole = model.time_s((n,), bits)
        t_split = model.time_s((a, b), bits)
        assert t_split >= t_whole + model.setup_s - 1e-15


@given(n=st.integers(1, 1 << 16), bits=st.integers(1, 64))
@settings(max_examples=60, deadline=None)
def test_compressed_burst_bytes_at_least_header_floor(n, bits):
    """A compressed burst always carries at least its raw header word, and
    never exceeds the uncompressed burst's bytes."""
    for model in (AXI_ZC706, TPU_V5E_HBM):
        got = model.burst_bytes(n, bits)
        assert got >= model.elem_bytes  # one raw header word minimum
        assert got <= model.burst_bytes(n, None) + 1e-9


@given(
    setup_s=st.floats(1e-9, 1e-5),
    peak=st.floats(1e8, 1e12),
    elem_bytes=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_fit_burst_model_recovers_random_true_model(setup_s, peak, elem_bytes,
                                                    seed):
    """Fitting noiseless samples synthesized from a random 'true' BurstModel
    must return a physical model (setup >= 0, peak > 0) whose predictions
    round-trip to the samples within a tight relative tolerance."""
    import numpy as np

    from repro.core.cfa import BurstModel
    from repro.core.cfa.calibrate import TransferSample, fit_burst_model

    true = BurstModel(name="true", peak_bytes_per_s=peak, setup_s=setup_s,
                      elem_bytes=elem_bytes)
    rng = np.random.default_rng(seed)
    # anchors condition the two regressors (burst count vs byte volume);
    # the random schedules fuzz everything in between
    schedules = [(1,), (1,) * 16, (65536,)]
    schedules += [
        tuple(int(x) for x in rng.integers(1, 8192, size=rng.integers(1, 12)))
        for _ in range(5)
    ]
    samples = [
        TransferSample(runs_by_port=(tuple(s),), elem_bytes=elem_bytes,
                       measured_s=true.time_s(tuple(s)), label="synth")
        for s in schedules
    ]
    fit = fit_burst_model(samples, true)
    assert fit.setup_s >= 0.0
    assert fit.peak_bytes_per_s > 0.0
    assert fit.elem_bytes == elem_bytes
    for s in samples:
        want = s.measured_s
        got = fit.time_s(s.runs)
        assert got == pytest.approx(want, rel=1e-4), (
            f"fit {got:.3e} vs true {want:.3e} on {s.runs[:4]}..."
        )


@given(
    factors=st.lists(
        st.tuples(st.integers(2, 16), st.floats(0.25, 4.0)),
        min_size=1, max_size=5,
        unique_by=lambda pf: pf[0],
    ),
    query=st.integers(1, 32),
)
@settings(max_examples=60, deadline=None)
def test_calibrated_model_port_factor_properties(factors, query):
    """port_factor(1) is always 1; any other query resolves to the nearest
    calibrated port count (ties toward the smaller count), so predictions
    never extrapolate outside the measured factor range."""
    from repro.core.cfa.calibrate import CalibratedModel

    cm = CalibratedModel(
        name="cal", peak_bytes_per_s=AXI_ZC706.peak_bytes_per_s,
        setup_s=AXI_ZC706.setup_s, elem_bytes=AXI_ZC706.elem_bytes,
        port_factors=tuple(sorted(factors)), base_name=AXI_ZC706.name,
    )
    assert cm.port_factor(1) == 1.0
    got = cm.port_factor(query)
    if query == 1:
        assert got == 1.0
    else:
        table = dict(cm.port_factors)
        best = min(table, key=lambda p: (abs(p - query), p))
        assert got == table[best]
        lo, hi = min(table.values()), max(table.values())
        assert lo <= got <= hi


# ---------------------------------------------------------------------------
# Overlap model (Fig. 13 DATAFLOW): bounds on the pipelined tile time
# ---------------------------------------------------------------------------

compute_seconds = st.floats(0.0, 1e-2, allow_nan=False, allow_infinity=False)


@given(runs=st.lists(st.integers(1, 4096), min_size=1, max_size=64),
       c=compute_seconds)
@settings(max_examples=60, deadline=None)
def test_overlap_time_bounded_by_sequential_and_critical_path(runs, c):
    """The pipelined tile time can never beat its critical path
    (max of transfer and compute) nor lose to running the phases back to
    back (transfer + compute); zero compute degenerates to the plain
    transfer time."""
    plan = TransferPlan("x", tuple(runs), (), sum(runs), 0)
    for model in (AXI_ZC706, TPU_V5E_HBM):
        t = model.transfer_time_s(plan)
        seq = model.time(plan, compute_s=c, overlap=False)
        ovl = model.time(plan, compute_s=c, overlap=True)
        assert seq == pytest.approx(t + c)
        assert ovl <= seq + 1e-18
        assert ovl >= max(t, c) - 1e-18
        # no compute to hide: overlapping buys exactly nothing
        assert model.time(plan, overlap=True) == pytest.approx(t)


@given(runs=st.lists(st.integers(1, 4096), min_size=1, max_size=64),
       c1=compute_seconds, c2=compute_seconds)
@settings(max_examples=60, deadline=None)
def test_overlap_time_monotone_in_compute(runs, c1, c2):
    """More per-tile compute never makes the overlapped schedule faster."""
    lo, hi = sorted((c1, c2))
    plan = TransferPlan("x", tuple(runs), (), sum(runs), 0)
    for model in (AXI_ZC706, TPU_V5E_HBM):
        assert (model.time(plan, compute_s=hi, overlap=True)
                >= model.time(plan, compute_s=lo, overlap=True) - 1e-18)
        assert (model.time(plan, compute_s=hi, overlap=False)
                >= model.time(plan, compute_s=lo, overlap=False) - 1e-18)


@given(runs=st.lists(st.integers(1, 4096), min_size=1, max_size=64),
       c=compute_seconds)
@settings(max_examples=60, deadline=None)
def test_overlap_speedup_between_one_and_bound(runs, c):
    """The modeled overlapped-vs-sequential gain is >= 1 (overlap never
    hurts) and <= the perfect-pipelining bound (t_seq / critical path)."""
    plan = TransferPlan("x", tuple(runs), (), sum(runs), 0)
    for model in (AXI_ZC706, TPU_V5E_HBM):
        s = overlap_speedup(plan, model, compute_s=c)
        assert s["t_sequential_s"] == pytest.approx(
            s["transfer_s"] + s["compute_s"])
        assert s["speedup"] >= 1.0 - 1e-12
        assert s["speedup"] <= s["bound"] + 1e-12
        assert s["bound"] == pytest.approx(
            s["t_sequential_s"] / max(s["transfer_s"], s["compute_s"]))


@given(
    nt=st.tuples(*[st.integers(1, 3)] * 3),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_pack_unpack_roundtrip(nt, seed):
    import jax.numpy as jnp
    import numpy as np

    from repro.core.cfa import get_program, pack_all, unpack_into

    prog = get_program("jacobi2d5p")  # w = (1, 2, 2)
    t = (2, 4, 4)  # w | t on every axis
    space = IterSpace(tuple(n * x for n, x in zip(nt, t)))
    tiling = Tiling(t)
    specs = build_facet_specs(space, prog.deps, tiling)
    rng = np.random.default_rng(seed)
    V = jnp.asarray(rng.normal(size=space.sizes))
    facets = pack_all(V, specs)
    # unpack into a fresh volume: facet-domain points must match V exactly
    out = jnp.full(space.sizes, jnp.nan)
    for k, spec in specs.items():
        out = unpack_into(out, facets[k], spec)
        assert facets[k].shape == spec.shape
    mask = ~jnp.isnan(out)
    assert bool(mask.any())
    np.testing.assert_array_equal(np.asarray(out)[np.asarray(mask)],
                                  np.asarray(V)[np.asarray(mask)])
