"""Property tests for the CFA core (the appendix coverage proofs).

Requires the optional ``hypothesis`` test extra (``pip install .[test]``);
the whole module is skipped when it is absent so tier-1 collection never
breaks on a minimal install.
"""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.cfa import (
    AXI_ZC706,
    BandwidthReport,
    Deps,
    IterSpace,
    Tiling,
    build_facet_specs,
    cfa_plan,
    facet_widths,
    flow_in_points,
)
from repro.core.cfa.plans import TransferPlan, _assign_hosts

dep_component = st.integers(min_value=-2, max_value=0)


@st.composite
def dep_patterns(draw, d):
    n = draw(st.integers(min_value=1, max_value=4))
    vecs = []
    for _ in range(n):
        v = tuple(draw(dep_component) for _ in range(d))
        vecs.append(v)
    if all(all(c == 0 for c in v) for v in vecs):
        vecs[0] = tuple(-1 for _ in range(d))
    return Deps(tuple(vecs))


@given(st.data())
@settings(max_examples=60, deadline=None)
def test_flow_in_contained_in_facets(data):
    """Appendix B: every flow-in point of T lies in a facet of its own tile."""
    d = data.draw(st.integers(min_value=1, max_value=3), label="d")
    deps = data.draw(dep_patterns(d), label="deps")
    w = facet_widths(deps)
    tiles = tuple(
        data.draw(st.integers(min_value=max(1, w[a]), max_value=4), label=f"t{a}")
        for a in range(d)
    )
    nt = tuple(data.draw(st.integers(min_value=1, max_value=3), label=f"n{a}") for a in range(d))
    space = IterSpace(tuple(t * n for t, n in zip(tiles, nt)))
    tiling = Tiling(tiles)
    specs = build_facet_specs(space, deps, tiling)
    tile = tuple(min(1, n - 1) for n in nt)
    fin = flow_in_points(space, deps, tiling, tile)
    for y in fin:
        assert any(spec.domain_mask(y[None, :])[0] for spec in specs.values()), (
            f"flow-in point {y} not covered by any facet (deps={deps.vectors})"
        )


@given(st.data())
@settings(max_examples=30, deadline=None)
def test_host_assignment_total_and_valid(data):
    d = 3
    deps = data.draw(dep_patterns(d), label="deps")
    w = facet_widths(deps)
    tiles = tuple(max(2, wa + 1) for wa in w)
    space = IterSpace(tuple(t * 3 for t in tiles))
    tiling = Tiling(tiles)
    specs = build_facet_specs(space, deps, tiling)
    tile = (1, 1, 1)
    fin = flow_in_points(space, deps, tiling, tile)
    hosts = _assign_hosts(fin, tile, tiling, w, specs)
    assigned = sum(len(v) for v in hosts.values())
    assert assigned == len(fin)
    for k, idx in hosts.items():
        if idx.size:
            assert bool(specs[k].domain_mask(fin[idx]).all())


@given(runs=st.lists(st.integers(1, 4096), min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_bandwidth_report_bounded_by_peak(runs):
    plan = TransferPlan("x", tuple(runs), (), sum(runs), 0)
    rep = BandwidthReport.evaluate(plan, AXI_ZC706)
    assert 0 < rep.peak_fraction_raw <= 1.0
    assert rep.peak_fraction_effective <= rep.peak_fraction_raw + 1e-12


@given(
    w=st.integers(1, 3),
    t=st.integers(3, 6),
)
@settings(max_examples=20, deadline=None)
def test_write_always_single_burst_per_facet(w, t):
    """The paper's stance: ALL writes are bursts — any dep pattern, any tile."""
    if w > t:
        return
    deps = Deps(((-w, 0, 0), (0, -w, 0), (0, 0, -w)))
    space = IterSpace((3 * t, 3 * t, 3 * t))
    tiling = Tiling((t, t, t))
    plan = cfa_plan(space, deps, tiling, (1, 1, 1))
    assert plan.n_write_bursts == 3
    assert all(r > 0 for r in plan.write_runs)


# ---------------------------------------------------------------------------
# N-dimensional spaces (2-D and 4-D): single-assignment + sweep == oracle
# ---------------------------------------------------------------------------

@given(st.data())
@settings(max_examples=30, deadline=None)
def test_nd_single_assignment_no_collisions(data):
    """§IV-F4 in any dimension: per facet, every tile's block occupies
    distinct offsets inside the array bounds (random 2-D and 4-D setups)."""
    import itertools

    from repro.core.cfa.spaces import facet_points

    d = data.draw(st.sampled_from([2, 4]), label="d")
    deps = data.draw(dep_patterns(d), label="deps")
    w = facet_widths(deps)
    tiles = tuple(
        data.draw(st.integers(min_value=max(1, w[a]), max_value=4), label=f"t{a}")
        for a in range(d)
    )
    nt = tuple(data.draw(st.integers(min_value=1, max_value=2), label=f"n{a}")
               for a in range(d))
    space = IterSpace(tuple(t * n for t, n in zip(tiles, nt)))
    tiling = Tiling(tiles)
    specs = build_facet_specs(space, deps, tiling)
    import numpy as np
    for k, spec in specs.items():
        offs = [
            spec.offsets(facet_points(tiling, w, k, q))
            for q in itertools.product(*map(range, nt))
        ]
        flat = np.concatenate(offs)
        assert len(np.unique(flat)) == len(flat), f"facet_{k} offsets collide"
        assert flat.min() >= 0 and flat.max() < spec.size


@given(st.data())
@settings(max_examples=6, deadline=None)
def test_nd_sweep_matches_oracle_random_tilings(data):
    """The N-D executor is exact for random tilings of the 2-D and 4-D
    example programs (sweep through facet storage == untiled oracle)."""
    import numpy as np
    import jax.numpy as jnp

    from repro.core.cfa import CFAPipeline, get_program, pack_facet

    name = data.draw(st.sampled_from(["heat1d", "heat3d"]), label="program")
    prog = get_program(name)
    w = facet_widths(prog.deps)
    d = prog.ndim
    # keep 4-D spaces tiny: the sweep is a python tile loop
    tmax = 4 if d == 2 else 3
    tiles = tuple(
        data.draw(st.integers(min_value=max(1, w[a]), max_value=tmax),
                  label=f"t{a}")
        for a in range(d)
    )
    nt = tuple(data.draw(st.integers(min_value=1, max_value=2), label=f"n{a}")
               for a in range(d))
    space = tuple(t * n for t, n in zip(tiles, nt))
    pipe = CFAPipeline(prog, IterSpace(space), Tiling(tiles))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1),
                                          label="seed"))
    inputs = jnp.asarray(rng.normal(size=(pipe.specs[0].width, *space[1:])))
    facets = pipe.sweep(inputs, dtype=jnp.float64)
    V = pipe.reference_volume(inputs)
    for k, spec in pipe.specs.items():
        got = facets[k][1:] if k == 0 else facets[k]
        if spec.tile_sizes[spec.axis] % spec.width == 0:
            want = pack_facet(V, spec)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-12, atol=1e-12)


# ---------------------------------------------------------------------------
# Irredundant storage (Ferry 2024): single assignment over random spaces
# ---------------------------------------------------------------------------

@given(st.data())
@settings(max_examples=40, deadline=None)
def test_irredundant_single_assignment_partition(data):
    """Every canonical point covered by some facet is owned by *exactly one*
    facet block — the irredundant discipline's invariant — over random
    2-D/3-D/4-D spaces and dependence patterns."""
    import numpy as np

    from repro.core.cfa import build_storage_map, owner_of
    from repro.core.cfa.spaces import facet_points

    d = data.draw(st.sampled_from([2, 3, 4]), label="d")
    deps = data.draw(dep_patterns(d), label="deps")
    w = facet_widths(deps)
    tiles = tuple(
        data.draw(st.integers(min_value=max(1, w[a]), max_value=4), label=f"t{a}")
        for a in range(d)
    )
    nt = tuple(data.draw(st.integers(min_value=1, max_value=2), label=f"n{a}")
               for a in range(d))
    space = IterSpace(tuple(t * n for t, n in zip(tiles, nt)))
    tiling = Tiling(tiles)
    specs = build_facet_specs(space, deps, tiling)
    smap = build_storage_map(specs)
    assert smap.redundancy == 1.0
    tile = tuple(min(1, n - 1) for n in nt)
    pts = np.concatenate([facet_points(tiling, w, k, tile) for k in specs])
    uniq = np.unique(pts, axis=0)
    own = owner_of(specs, uniq)
    # total: every facet-union point has an owner ...
    assert (own >= 0).all()
    # ... the owner's facet covers it ...
    for k in specs:
        sel = own == k
        if sel.any():
            assert bool(specs[k].domain_mask(uniq[sel]).all())
    # ... and the static per-block masks count exactly the owned points,
    # so ownership partitions the union (stored slots == distinct points)
    for k in specs:
        assert smap.owned_per_block[k] == int((own == k).sum())
    assert sum(smap.owned_per_block.values()) == len(uniq)
    n_blocks = int(np.prod(nt))
    assert smap.stored_elems == len(uniq) * n_blocks
    assert smap.stored_elems <= smap.redundant_elems


@given(st.data())
@settings(max_examples=6, deadline=None)
def test_irredundant_sweep_matches_redundant_random_tilings(data):
    """The irredundant executor path is exact for random tilings of the
    2-D and 4-D example programs: rehydrate(irredundant sweep) equals the
    redundant sweep bit-for-bit."""
    import numpy as np
    import jax.numpy as jnp

    from repro.core.cfa import CFAPipeline, dedup_facets, get_program, rehydrate_facets
    from repro.core.cfa.irredundant import IrredundantPipeline

    name = data.draw(st.sampled_from(["heat1d", "heat3d"]), label="program")
    prog = get_program(name)
    w = facet_widths(prog.deps)
    d = prog.ndim
    tmax = 4 if d == 2 else 3
    tiles = tuple(
        data.draw(st.integers(min_value=max(1, w[a]), max_value=tmax),
                  label=f"t{a}")
        for a in range(d)
    )
    nt = tuple(data.draw(st.integers(min_value=1, max_value=2), label=f"n{a}")
               for a in range(d))
    space = tuple(t * n for t, n in zip(tiles, nt))
    red = CFAPipeline(prog, IterSpace(space), Tiling(tiles))
    irr = IrredundantPipeline(prog, IterSpace(space), Tiling(tiles))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1),
                                          label="seed"))
    inputs = jnp.asarray(rng.normal(size=(red.specs[0].width, *space[1:])))
    f_red = red._sweep(inputs, dtype=jnp.float64)
    f_irr = irr._sweep(inputs, dtype=jnp.float64)
    dd = dedup_facets(f_red, irr.storage_map)
    for k in f_red:
        assert (np.asarray(f_irr[k]) == np.asarray(dd[k])).all(), f"facet {k}"
    rh = rehydrate_facets(f_irr, irr.storage_map)
    for k in f_red:
        assert (np.asarray(rh[k]) == np.asarray(f_red[k])).all(), f"facet {k}"


@given(
    nt=st.tuples(*[st.integers(1, 3)] * 3),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_pack_unpack_roundtrip(nt, seed):
    import jax.numpy as jnp
    import numpy as np

    from repro.core.cfa import get_program, pack_all, unpack_into

    prog = get_program("jacobi2d5p")  # w = (1, 2, 2)
    t = (2, 4, 4)  # w | t on every axis
    space = IterSpace(tuple(n * x for n, x in zip(nt, t)))
    tiling = Tiling(t)
    specs = build_facet_specs(space, prog.deps, tiling)
    rng = np.random.default_rng(seed)
    V = jnp.asarray(rng.normal(size=space.sizes))
    facets = pack_all(V, specs)
    # unpack into a fresh volume: facet-domain points must match V exactly
    out = jnp.full(space.sizes, jnp.nan)
    for k, spec in specs.items():
        out = unpack_into(out, facets[k], spec)
        assert facets[k].shape == spec.shape
    mask = ~jnp.isnan(out)
    assert bool(mask.any())
    np.testing.assert_array_equal(np.asarray(out)[np.asarray(mask)],
                                  np.asarray(V)[np.asarray(mask)])
