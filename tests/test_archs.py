"""Per-architecture smoke tests (reduced same-family configs, CPU):
one forward + one train step, shape and finiteness assertions, plus
prefill/decode cache consistency."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.models.lm import init_lm, lm_decode, lm_forward, lm_prefill
from repro.optim import make_optimizer
from repro.train.steps import TrainHParams, make_train_step


# the jamba smoke config is by far the heaviest compile (tens of seconds
# for a train step / prefill-decode pair); those two cells are `slow` so
# tier-1 stays fast — the CI slow leg still runs them
def _heavy_marked(names):
    return [
        pytest.param(n, marks=pytest.mark.slow) if "jamba" in n else n
        for n in names
    ]


def _inputs(cfg, B=2, S=16, seed=1):
    tokens = jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0, cfg.vocab)
    ctx = None
    if cfg.family in ("vlm", "encdec"):
        ctx = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (B, cfg.n_context_tokens, cfg.d_model)
        ).astype(jnp.bfloat16) * 0.02
    return tokens, ctx


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finiteness(name):
    cfg = get_smoke_config(name)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    tokens, ctx = _inputs(cfg)
    logits, aux = lm_forward(params, tokens, cfg, cross_src=ctx, remat=False)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("name", _heavy_marked(ARCH_NAMES))
def test_one_train_step(name):
    cfg = get_smoke_config(name)
    hp = TrainHParams(remat=False, warmup=1, total_steps=10)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    opt_init, _ = make_optimizer(cfg.optimizer)
    opt_state = opt_init(params)
    tokens, ctx = _inputs(cfg)
    batch = {"tokens": tokens}
    if ctx is not None:
        batch["context"] = ctx
    step = make_train_step(cfg, hp)
    new_params, new_opt, metrics = step(params, opt_state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert float(metrics["loss"]) > 0
    # parameters must actually move
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                                - b.astype(jnp.float32)).sum()),
                     params, new_params),
    )
    assert delta > 0
    assert int(new_opt.step) == 1


@pytest.mark.parametrize("name", _heavy_marked(ARCH_NAMES))
def test_prefill_decode_consistency(name):
    """Decode over filled caches == full forward on the extended sequence.

    Exact for deterministic-routing archs; MoE archs use a no-drop capacity
    factor (capacity dropping is a train/decode semantic difference, not a
    bug — verified in f64 during development)."""
    cfg = get_smoke_config(name)
    if cfg.moe_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    tokens, ctx = _inputs(cfg, B, S)
    lg_last, caches = lm_prefill(params, tokens, cfg, cross_src=ctx, max_seq=2 * S)
    full, _ = lm_forward(params, tokens, cfg, cross_src=ctx, remat=False)
    np.testing.assert_allclose(
        np.asarray(lg_last, np.float32), np.asarray(full[:, -1], np.float32),
        rtol=1e-2, atol=1e-2)
    nxt = jnp.argmax(lg_last[:, : cfg.vocab], -1).astype(jnp.int32)
    lg_dec, _ = lm_decode(params, caches, nxt, jnp.int32(S), cfg)
    toks2 = jnp.concatenate([tokens, nxt[:, None]], 1)
    full2, _ = lm_forward(params, toks2, cfg, cross_src=ctx, remat=False)
    scale = max(float(np.abs(np.asarray(full2[:, -1], np.float32)).max()), 1.0)
    err = float(np.abs(np.asarray(lg_dec, np.float32)
                       - np.asarray(full2[:, -1], np.float32)).max()) / scale
    # hybrid (8 stacked mixers/period) accumulates the most bf16 noise; its
    # decode path was verified exact in f64 during development
    tol = 0.12 if cfg.family == "hybrid" else 0.06
    assert err < tol, f"{name}: relative decode error {err}"


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_config_is_well_formed(name):
    """The published config must satisfy every TPU-shardability derived
    property without touching device memory (eval_shape only)."""
    cfg = get_config(name)
    assert cfg.padded_vocab % (cfg.tp * 128) == 0
    if cfg.period != ("mamba",):
        assert cfg.padded_q_heads % cfg.tp == 0
        assert cfg.stored_kv_heads % min(cfg.tp, cfg.stored_kv_heads) == 0
    abstract = jax.eval_shape(lambda k: init_lm(k, cfg), jax.random.PRNGKey(0))
    n_params = sum(
        int(np.prod(l.shape)) for l in jax.tree.leaves(abstract)
    )
    assert n_params > 0
    # analytic parameter count is within 2x of materialised count (padding
    # and kv replication inflate the latter)
    analytic = cfg.param_count()
    assert 0.4 < n_params / analytic < 2.6, (name, n_params, analytic)


def test_remat_matches_no_remat():
    cfg = get_smoke_config("qwen3-0.6b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    tokens, _ = _inputs(cfg)
    a, _ = lm_forward(params, tokens, cfg, remat=False)
    b, _ = lm_forward(params, tokens, cfg, remat=True)
    np.testing.assert_array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))
