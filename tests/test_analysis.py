"""The static verifier + burst lint (``repro.core.cfa.analysis``).

Covers the acceptance criteria of the analysis subsystem:

* the green matrix — every Table I + heat1d/heat3d program x storage x
  capable backend — compiles with ``verify=True`` and zero ERROR
  diagnostics (a fast representative slice stays in tier-1; the full
  matrix runs on the CI slow leg, repo convention);
* mutation tests: a deliberately corrupted plan or wave schedule makes
  ``cfa.verify`` raise :class:`VerificationError` with exactly the pinned
  diagnostic code (duplicate write run -> CFA101, dropped owner block ->
  CFA102, starved reads -> CFA105, aliasing overlap -> CFA201/202);
* the CFA3xx lint prices the jacobi2d5p baselines as burst-hostile
  (CFA301) while the CFA plan passes — the paper's Fig. 15 contrast as a
  static diagnostic;
* CFA4xx contract checks fire on capability violations a hand-built
  ``CompiledStencil`` can express (wrong backend caps, codec without
  compressed storage, over-budget ports);
* ``autotune`` discards candidates whose plans fail the static
  accounting;
* the framework itself: Diagnostic/AnalysisReport validation and
  serialisation, ``verify_pipeline`` composition, the analysis passes in
  the lowering trace, and both CLIs (``cfa_lint``, ``dump_pipeline
  --verify``).
"""
import dataclasses
import importlib.util
import itertools
import json
import sys
from pathlib import Path

import pytest

from repro import cfa
from repro.core.cfa import (
    AXI_ZC706,
    IterSpace,
    Tiling,
    available_backends,
    get_program,
    interior_tile,
    original_layout_plan,
)
from repro.core.cfa import analysis as an
from repro.core.cfa.analysis import (
    AnalysisReport,
    Diagnostic,
    VerificationError,
    check_facet_family,
    check_overlap_schedule,
    lint_plan,
    plan_accounting,
)
from repro.core.cfa.plans import cfa_plan

CASES = [
    ("jacobi2d5p", (8, 8, 8), (4, 4, 4)),
    ("jacobi2d9p", (8, 8, 8), (4, 4, 4)),
    ("jacobi2d9p-gol", (8, 8, 8), (4, 4, 4)),
    ("gaussian", (4, 16, 16), (2, 8, 8)),
    ("smith-waterman-3seq", (9, 8, 8), (3, 4, 4)),
    ("heat1d", (8, 8), (4, 4)),
    ("heat3d", (4, 4, 4, 4), (2, 2, 2, 2)),
]


def _compile(name="jacobi2d5p", space=(8, 8, 8), tile=(4, 4, 4), **kw):
    kw.setdefault("backend", "sweep")
    return cfa.compile(name, space, layout=tile, **kw)


# ---------------------------------------------------------------------------
# the green matrix: zero ERROR diagnostics everywhere
# ---------------------------------------------------------------------------


def _matrix_params(fast_only):
    out = []
    for name, space, tile in CASES:
        prog = get_program(name)
        for storage in ("redundant", "irredundant", "compressed"):
            for be in available_backends(prog, IterSpace(space), 1, storage):
                # tier-1 keeps one backend per (program, storage) cell; the
                # full backend fan-out rides the CI slow leg
                fast = be == "sweep"
                if fast_only != fast:
                    continue
                out.append(pytest.param(name, space, tile, storage, be,
                                        id=f"{name}-{storage}-{be}"))
    return out


@pytest.mark.parametrize("name,space,tile,storage,backend",
                         _matrix_params(fast_only=True))
def test_green_matrix_verifies_clean(name, space, tile, storage, backend):
    c = cfa.compile(name, space, layout=tile, backend=backend,
                    storage=storage, verify=True)
    report = c.diagnostics()
    assert report.ok, report.summary()
    assert not report.errors


@pytest.mark.slow
@pytest.mark.parametrize("name,space,tile,storage,backend",
                         _matrix_params(fast_only=False))
def test_green_matrix_verifies_clean_slow(name, space, tile, storage, backend):
    c = cfa.compile(name, space, layout=tile, backend=backend,
                    storage=storage, verify=True)
    assert c.diagnostics().ok, c.diagnostics().summary()


def test_verify_true_attaches_report_and_trace():
    c = _compile(verify=True)
    report = c.diagnostics()
    assert isinstance(report, AnalysisReport)
    assert [a[0] for a in report.analyses] == [
        "verify_single_assignment", "verify_overlap", "lint_bursts",
        "verify_contracts"]
    # the analysis passes show up in the lowering trace, after lower_backend
    names = [t.name for t in c.trace()]
    assert names.index("verify_single_assignment") > names.index("lower_backend")
    # diagnostics accreted on the state appear in the trace diff summary
    diag_changes = [dict(t.changed).get("diagnostics") for t in c.trace()
                    if "diagnostics" in dict(t.changed)]
    assert any("diagnostic(s)" in s for s in diag_changes)


def test_diagnostics_runs_on_demand_without_verify():
    c = _compile()
    assert c.analysis is None
    report = c.diagnostics()
    assert isinstance(report, AnalysisReport) and report.ok


# ---------------------------------------------------------------------------
# mutation tests: corrupted artifacts pin exact diagnostic codes
# ---------------------------------------------------------------------------


def test_mutation_duplicate_write_run_is_cfa101():
    c = _compile()
    plan = c.plan
    dup = dataclasses.replace(
        plan,
        write_runs=tuple(plan.write_runs) + (plan.write_runs[0],),
        write_run_hosts=tuple(plan.write_run_hosts) + (plan.write_run_hosts[0],),
    )
    with pytest.raises(VerificationError) as ei:
        cfa.verify(c, plan=dup)
    report = ei.value.report
    assert "CFA101" in report.codes
    assert all(d.severity != "ERROR" or d.code == "CFA101"
               for d in report.diagnostics)
    assert "CFA101" in str(ei.value)


def test_mutation_dropped_owner_block_is_cfa102():
    c = _compile(storage="irredundant")
    plan = c.plan
    dropped = dataclasses.replace(
        plan,
        write_runs=tuple(plan.write_runs[:-1]),
        write_run_hosts=tuple(plan.write_run_hosts[:-1]),
    )
    with pytest.raises(VerificationError) as ei:
        cfa.verify(c, plan=dropped)
    report = ei.value.report
    assert "CFA102" in report.codes
    assert all(d.severity != "ERROR" or d.code == "CFA102"
               for d in report.diagnostics)


def test_mutation_starved_reads_is_cfa105():
    """Shrinking every read run below the burst threshold starves the tile:
    CFA105 (reads under-transfer) fires, and the burst lint flags the
    all-short schedule too."""
    c = _compile()
    plan = c.plan
    starved = dataclasses.replace(
        plan, read_runs=tuple(1 for _ in plan.read_runs))
    with pytest.raises(VerificationError) as ei:
        cfa.verify(c, plan=starved)
    report = ei.value.report
    assert "CFA105" in report.codes
    assert [d.code for d in report.errors] == ["CFA105"]
    assert "CFA301" in report.codes  # 1-elem runs are also burst-hostile


def _waves(nt):
    by = {}
    for q in itertools.product(*(range(n) for n in nt)):
        by.setdefault(sum(q), []).append(q)
    return [by[s] for s in sorted(by)]


def test_mutation_merged_waves_is_cfa201():
    """Merging all tiles into one wave makes the dataflow prefetch of a
    consumer race its producer's deferred commit: the same-wave race."""
    c = _compile()
    merged = [list(itertools.product(range(2), range(2), range(2)))]
    with pytest.raises(VerificationError) as ei:
        cfa.verify(c, waves=merged)
    report = ei.value.report
    assert [d.code for d in report.errors] == ["CFA201"]
    assert "race" in report.errors[0].message


def test_mutation_reversed_waves_is_cfa202():
    rev = list(reversed(_waves((2, 2, 2))))
    c = _compile()
    with pytest.raises(VerificationError) as ei:
        cfa.verify(c, waves=rev)
    assert [d.code for d in ei.value.report.errors] == ["CFA202"]


def test_mutation_missing_tile_is_cfa202():
    waves = _waves((2, 2, 2))
    waves[-1] = waves[-1][:-1]  # drop the last tile from the schedule
    c = _compile()
    with pytest.raises(VerificationError) as ei:
        cfa.verify(c, waves=waves)
    assert any(d.code == "CFA202" and "omits" in d.message
               for d in ei.value.report.errors)


def test_legal_default_waves_verify_clean():
    c = _compile()
    report = cfa.verify(c, waves=_waves((2, 2, 2)), raise_on_error=False)
    assert report.ok


# ---------------------------------------------------------------------------
# the pure checkers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,space,tile", CASES)
def test_facet_family_proofs_clean_both_storages(name, space, tile):
    prog = get_program(name)
    for storage in ("redundant", "irredundant"):
        diags = check_facet_family(IterSpace(space), prog.deps, Tiling(tile),
                                   storage=storage)
        assert diags == [], [str(d) for d in diags]


@pytest.mark.parametrize("name,space,tile", CASES)
def test_overlap_schedule_clean_on_default_waves(name, space, tile):
    prog = get_program(name)
    assert check_overlap_schedule(IterSpace(space), prog.deps,
                                  Tiling(tile)) == []


@pytest.mark.parametrize("name,space,tile", CASES)
def test_plan_accounting_clean_on_real_plans(name, space, tile):
    prog = get_program(name)
    for storage in ("redundant", "irredundant"):
        plan = cfa_plan(IterSpace(space), prog.deps, Tiling(tile),
                        storage=storage)
        assert plan_accounting(plan) == []


def test_cfa301_flags_original_layout_not_cfa():
    """The acceptance pin: on jacobi2d5p the row-major baseline is
    descriptor-bound (burst-hostile) under the ZC706 model while the CFA
    plan is not — Fig. 15's contrast, statically."""
    prog = get_program("jacobi2d5p")
    sp, til = IterSpace((8, 8, 8)), Tiling((4, 4, 4))
    orig = lint_plan(original_layout_plan(sp, prog.deps, til), AXI_ZC706)
    mine = lint_plan(cfa_plan(sp, prog.deps, til), AXI_ZC706)
    assert any(d.code == "CFA301" for d in orig)
    assert not any(d.code == "CFA301" for d in mine)
    hostile = next(d for d in orig if d.code == "CFA301")
    assert hostile.severity == "WARN"
    assert hostile.fixit == "contiguity"
    assert hostile.cost_s is not None and hostile.cost_s > 0


def test_cfa303_prices_redundancy():
    from repro.core.cfa import data_tiling_plan

    prog = get_program("jacobi2d5p")
    sp, til = IterSpace((8, 8, 8)), Tiling((4, 4, 4))
    dt = lint_plan(data_tiling_plan(sp, prog.deps, til), AXI_ZC706)
    red = next(d for d in dt if d.code == "CFA303")
    assert red.fixit == "storage" and red.cost_s > 0
    # the irredundant CFA plan stores each value once: no redundancy lint
    irr = lint_plan(cfa_plan(sp, prog.deps, til, storage="irredundant"),
                    AXI_ZC706)
    assert not any(d.code == "CFA303" for d in irr)


def test_cfa302_contiguity_info_on_weaker_level():
    plan = cfa_plan(IterSpace((8, 8, 8)), get_program("jacobi2d5p").deps,
                    Tiling((4, 4, 4)))
    diags = lint_plan(plan, AXI_ZC706, contiguity="inter-tile")
    info = [d for d in diags if d.code == "CFA302"]
    assert info and info[0].severity == "INFO"
    assert info[0].fixit == "contiguity"


def test_cfa302_warns_on_extra_read_bursts():
    plan = cfa_plan(IterSpace((8, 8, 8)), get_program("jacobi2d5p").deps,
                    Tiling((4, 4, 4)))
    diags = lint_plan(plan, AXI_ZC706,
                      expected_read_bursts=plan.n_read_bursts - 1)
    warn = [d for d in diags if d.code == "CFA302"]
    assert warn and warn[0].severity == "WARN" and warn[0].fixit == "ext_dirs"
    assert warn[0].cost_s == pytest.approx(AXI_ZC706.setup_s)


def test_cfa304_port_imbalance_under_lopsided_assignment():
    """Whole facet arrays are atomic under the compile-time port split, so
    a lopsided facet -> port assignment genuinely gates on its slowest
    port; the lint prices the max-vs-mean gap."""
    from repro.core.cfa import TransferPlan
    from repro.core.cfa.multiport import PortAssignment

    lop = TransferPlan("cfa", (4096, 8), (4096, 8), 4104, 0,
                       read_run_hosts=(0, 1), write_run_hosts=(0, 1),
                       stored_elems=4104)
    skew = PortAssignment(2, {0: 0, 1: 1}, (4096.0 * 8, 8.0 * 8))
    diags = lint_plan(lop, AXI_ZC706, n_ports=2, assignment=skew)
    bal = [d for d in diags if d.code == "CFA304"]
    assert bal and bal[0].fixit == "n_ports" and bal[0].cost_s > 0
    assert "facet->port assignment" in bal[0].message
    # the burst-granular fallback CAN split the giant run: no imbalance
    no_assign = lint_plan(lop, AXI_ZC706, n_ports=2)
    assert not any(d.code == "CFA304" for d in no_assign)


# ---------------------------------------------------------------------------
# CFA4xx contract checks
# ---------------------------------------------------------------------------


def test_cfa401_backend_caps_violation():
    from repro.core.cfa.executors import get_executor

    c = _compile("heat3d", (4, 4, 4, 4), (2, 2, 2, 2))
    bad = dataclasses.replace(c, executor=get_executor("pallas"))
    with pytest.raises(VerificationError) as ei:
        cfa.verify(bad)
    err = next(d for d in ei.value.report.errors if d.code == "CFA401")
    assert "3-D" in err.message


def test_cfa401_fixit_names_the_storage_knob():
    from repro.core.cfa.executors import get_executor

    c = _compile(storage="compressed")
    bad = dataclasses.replace(c, executor=get_executor("pallas"))
    with pytest.raises(VerificationError) as ei:
        cfa.verify(bad)
    err = next(d for d in ei.value.report.errors if d.code == "CFA401")
    assert err.fixit == "storage"


def test_cfa403_codec_without_compressed_storage():
    from repro.core.cfa import get_codec

    c = _compile()
    bad = dataclasses.replace(c, codec=get_codec("deltapack16"))
    with pytest.raises(VerificationError) as ei:
        cfa.verify(bad)
    err = next(d for d in ei.value.report.errors if d.code == "CFA403")
    assert err.fixit == "storage"


def test_cfa403_lossy_codec_is_info_only():
    c = _compile(storage="compressed")  # default codec keeps 16-bit residuals
    report = cfa.verify(c, raise_on_error=False)
    lossy = report.by_code("CFA403")
    assert lossy and all(d.severity == "INFO" for d in lossy)


def test_cfa404_port_budget():
    c = _compile("jacobi2d5p", (8, 8, 8), (4, 4, 4), backend="sharded",
                 n_ports=2)
    bad = dataclasses.replace(c, n_ports=99)
    with pytest.raises(VerificationError) as ei:
        cfa.verify(bad)
    codes = [d.code for d in ei.value.report.errors]
    assert "CFA404" in codes
    err = next(d for d in ei.value.report.errors if d.code == "CFA404")
    assert err.fixit == "n_ports"


# ---------------------------------------------------------------------------
# autotune discards statically-broken candidates
# ---------------------------------------------------------------------------


def test_autotune_discards_error_level_candidates(tmp_path, monkeypatch):
    # the package attribute 'autotune' is the function; fetch the module
    at = sys.modules["repro.core.cfa.autotune"]

    kw = dict(budget=16, cache=False, cache_dir=tmp_path)
    base = at.autotune(get_program("jacobi2d5p"), IterSpace((8, 8, 8)),
                       AXI_ZC706, **kw)
    win = base.best_cfa()
    win_plan = win.candidate.plan(IterSpace((8, 8, 8)),
                                  get_program("jacobi2d5p"))
    # pretend the winner's plan fails verification: the search must route
    # around it and crown a different candidate
    real = at._plan_verifies
    monkeypatch.setattr(at, "_plan_verifies",
                        lambda plan: plan != win_plan and real(plan))
    rerun = at.autotune(get_program("jacobi2d5p"), IterSpace((8, 8, 8)),
                        AXI_ZC706, **kw)
    assert rerun.best_cfa().candidate.key != win.candidate.key


def test_plan_verifies_helper():
    at = sys.modules["repro.core.cfa.autotune"]

    plan = cfa_plan(IterSpace((8, 8, 8)), get_program("jacobi2d5p").deps,
                    Tiling((4, 4, 4)))
    assert at._plan_verifies(plan)
    broken = dataclasses.replace(
        plan, read_runs=tuple(1 for _ in plan.read_runs))
    assert not at._plan_verifies(broken)


# ---------------------------------------------------------------------------
# the framework: Diagnostic / AnalysisReport / verify knobs
# ---------------------------------------------------------------------------


def test_diagnostic_validation():
    with pytest.raises(ValueError, match="severity"):
        Diagnostic("CFA999", "FATAL", "boom")
    with pytest.raises(ValueError, match="fixit"):
        Diagnostic("CFA999", "WARN", "boom", fixit="rewrite_everything")
    d = Diagnostic("CFA301", "WARN", "short runs", facet=2,
                   fixit="contiguity", cost_s=1e-6)
    assert "facet 2" in str(d) and "fixit: contiguity" in str(d)
    rec = d.to_dict()
    assert rec["facet"] == 2 and rec["cost_s"] == 1e-6
    assert "run" not in rec  # unset optionals are omitted


def test_report_aggregation_and_serialisation():
    diags = (Diagnostic("CFA101", "ERROR", "dup"),
             Diagnostic("CFA301", "WARN", "short"),
             Diagnostic("CFA403", "INFO", "lossy"))
    r = AnalysisReport(diags, analyses=(("a", "1"),))
    assert r.max_severity == "ERROR" and not r.ok
    assert len(r.errors) == len(r.warnings) == len(r.infos) == 1
    assert r.codes == ("CFA101", "CFA301", "CFA403")
    assert r.by_code("CFA301")[0].severity == "WARN"
    parsed = json.loads(r.to_json())
    assert parsed["max_severity"] == "ERROR"
    assert len(parsed["diagnostics"]) == 3
    assert "1 ERROR" in r.summary()
    assert AnalysisReport(()).max_severity is None
    assert AnalysisReport(()).ok
    assert "clean" in AnalysisReport(()).summary()


def test_verify_strict_promotes_warnings():
    c = _compile()  # redundancy 55% at this tile: a CFA303 WARN
    report = cfa.verify(c, raise_on_error=False)
    assert report.ok and report.warnings
    cfa.verify(c)  # WARN alone does not raise
    with pytest.raises(VerificationError, match="CFA303"):
        cfa.verify(c, strict=True)


def test_verification_error_message_caps_at_four():
    diags = tuple(Diagnostic(f"CFA10{i}", "ERROR", f"bad {i}")
                  for i in range(1, 6))
    err = VerificationError(AnalysisReport(diags))
    assert "+1 more" in str(err) and err.report.codes


def test_verify_pipeline_composes_without_duplicates():
    from repro.core.cfa.passes import default_pipeline

    pipe = an.verify_pipeline()
    assert pipe.names[-4:] == ("verify_single_assignment", "verify_overlap",
                               "lint_bursts", "verify_contracts")
    # idempotent: analyses already present are not appended again
    again = an.verify_pipeline(pipe)
    assert again.names == pipe.names
    assert an.verify_pipeline(default_pipeline()).names == pipe.names


def test_compile_verify_raises_on_error_contract():
    """verify=True turns a contract violation into VerificationError at
    compile time (the codec/storage clash is caught by resolve_program
    even earlier, so exercise the pipeline-level CFA402 instead: a custom
    pipeline that skips select_backend's overlap gate)."""
    # simplest end-to-end ERROR: verify an overlap-incapable stencil that
    # claims overlap via a corrupted state — covered above; here just pin
    # that the green path truly runs the analyses inside the pipeline
    c = cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                    backend="dataflow", overlap=True, verify=True)
    assert c.diagnostics().ok
    assert "verify_overlap" in [t.name for t in c.trace()]


# ---------------------------------------------------------------------------
# CLIs: cfa_lint and dump_pipeline --verify
# ---------------------------------------------------------------------------

TOOLS = Path(__file__).resolve().parents[1] / "tools"


def _load_tool(name):
    spec = importlib.util.spec_from_file_location(name, TOOLS / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_cfa_lint_json_schema(capsys):
    mod = _load_tool("cfa_lint")
    code = mod.main(["jacobi2d5p", "--json", "--backends", "sweep"])
    out = json.loads(capsys.readouterr().out)
    assert set(out) == {"target", "max_severity", "exit_code", "entries"}
    assert out["exit_code"] == code
    assert out["entries"], "matrix must not be empty"
    for e in out["entries"]:
        assert set(e) == {"program", "space", "storage", "backend", "layout",
                          "max_severity", "diagnostics"}
        for d in e["diagnostics"]:
            assert d["severity"] != "ERROR", d
    # exit code by max severity: this matrix has WARNs but no ERRORs
    assert out["max_severity"] in (None, "INFO", "WARN")
    assert code in (0, 1)


def test_cfa_lint_strict_and_baselines(capsys):
    mod = _load_tool("cfa_lint")
    code = mod.main(["jacobi2d5p", "--json", "--strict",
                     "--backends", "sweep", "--include-baselines"])
    out = json.loads(capsys.readouterr().out)
    assert code == 2  # strict promotes the WARNs
    baseline_entries = [e for e in out["entries"]
                        if e["backend"].startswith("plan:")]
    assert {e["backend"] for e in baseline_entries} == {
        "plan:original", "plan:bbox", "plan:data-tiling"}
    orig = next(e for e in baseline_entries if e["backend"] == "plan:original")
    assert any(d["code"] == "CFA301" for d in orig["diagnostics"])


def test_cfa_lint_text_mode(capsys):
    mod = _load_tool("cfa_lint")
    code = mod.main(["heat1d", "--backends", "sweep",
                     "--storages", "redundant"])
    text = capsys.readouterr().out
    assert "combination(s) linted" in text
    assert code in (0, 1)


def test_dump_pipeline_verify_flag(capsys):
    mod = _load_tool("dump_pipeline")
    assert mod.main(["jacobi2d5p", "8", "8", "8", "--layout", "4,4,4",
                     "--verify"]) == 0
    out = json.loads(capsys.readouterr().out)
    assert "analysis" in out
    assert [a[0] for a in out["analysis"]["analyses"]] == [
        "verify_single_assignment", "verify_overlap", "lint_bursts",
        "verify_contracts"]
    for d in out["analysis"]["diagnostics"]:
        assert d["severity"] != "ERROR"


def test_dump_pipeline_without_verify_has_no_analysis(capsys):
    mod = _load_tool("dump_pipeline")
    assert mod.main(["jacobi2d5p", "8", "8", "8", "--layout", "4,4,4"]) == 0
    out = json.loads(capsys.readouterr().out)
    assert "analysis" not in out


# ---------------------------------------------------------------------------
# StorageMap.stores: the counting primitive behind the CFA1xx proofs
# ---------------------------------------------------------------------------


def test_storage_map_stores_partitions_facet_union():
    import numpy as np

    from repro.core.cfa import build_facet_specs, build_storage_map
    from repro.core.cfa.spaces import facet_points, facet_widths

    prog = get_program("jacobi2d5p")
    sp, til = IterSpace((8, 8, 8)), Tiling((4, 4, 4))
    specs = build_facet_specs(sp, prog.deps, til)
    smap = build_storage_map(specs)
    w = facet_widths(prog.deps)
    tile = interior_tile(sp, til)
    union = np.unique(np.concatenate(
        [facet_points(til, w, k, tile) for k in specs]), axis=0)
    counts = sum(smap.stores(k, union).astype(int) for k in specs)
    assert (counts == 1).all()  # every family point stored exactly once
