"""Tests for the layout autotuner (repro.core.cfa.autotune).

Covers the ISSUE-1 acceptance bar: search determinism under a fixed seed,
cache hit/miss round-trips, the chosen layout never losing to the hand-coded
plans (cfa/original/bbox/data-tiling), and the autotuned pipeline staying
bit-exact against the untiled oracle.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.cfa import (
    AXI_ZC706,
    CFAPipeline,
    IterSpace,
    LayoutDecision,
    PROGRAMS,
    autotune,
    candidate_tilings,
    hand_coded_baselines,
    pack_facet,
)
from repro.core.cfa.plans import original_layout_plan, interior_tile
from repro.core.cfa.spaces import Tiling


def _small_space(prog):
    """2 tiles per axis at the default tile — every hand-coded seed is legal."""
    return tuple(2 * t for t in prog.default_tile)


# ---------------------------------------------------------------------------
# determinism + cache
# ---------------------------------------------------------------------------

def test_search_deterministic_given_seed(tmp_path):
    prog = PROGRAMS["jacobi2d5p"]
    kw = dict(budget=40, cache_dir=tmp_path)
    a = autotune(prog, (32, 32, 32), AXI_ZC706, seed=7, cache=False, **kw)
    b = autotune(prog, (32, 32, 32), AXI_ZC706, seed=7, cache=False, **kw)
    assert a.ranked == b.ranked
    assert a.evaluated == b.evaluated


def test_cache_roundtrip_hit_and_miss(tmp_path):
    prog = PROGRAMS["jacobi2d5p"]
    kw = dict(budget=24, seed=0, cache_dir=tmp_path)
    first = autotune(prog, (32, 32, 32), AXI_ZC706, **kw)
    assert not first.from_cache
    again = autotune(prog, (32, 32, 32), AXI_ZC706, **kw)
    assert again.from_cache
    assert again.ranked == first.ranked
    # a different key (other seed) is a miss
    other = autotune(prog, (32, 32, 32), AXI_ZC706, budget=24, seed=1,
                     cache_dir=tmp_path)
    assert not other.from_cache


def test_decision_json_roundtrip(tmp_path):
    prog = PROGRAMS["gaussian"]
    d = autotune(prog, _small_space(prog), AXI_ZC706, budget=16, seed=0,
                 cache=False, cache_dir=tmp_path)
    back = LayoutDecision.from_json(d.to_json())
    assert back == d
    assert back.best.candidate == d.best.candidate


def test_corrupt_cache_entry_recomputed(tmp_path):
    prog = PROGRAMS["jacobi2d5p"]
    kw = dict(budget=16, seed=0, cache_dir=tmp_path)
    first = autotune(prog, (32, 32, 32), AXI_ZC706, **kw)
    for f in tmp_path.glob("*.json"):
        f.write_text("{not json")
    with pytest.warns(RuntimeWarning, match="corrupt"):
        redo = autotune(prog, (32, 32, 32), AXI_ZC706, **kw)
    assert not redo.from_cache
    assert redo.ranked == first.ranked


def test_old_cache_schema_rejected_loudly(tmp_path):
    """Schema v3: an old-version decision under the current key warns and
    re-searches instead of silently deserializing (or silently vanishing)."""
    import json

    from repro.core.cfa import CacheSchemaError

    prog = PROGRAMS["jacobi2d5p"]
    kw = dict(budget=16, seed=0, cache_dir=tmp_path)
    first = autotune(prog, (32, 32, 32), AXI_ZC706, **kw)
    (entry,) = tmp_path.glob("*.json")
    blob = json.loads(entry.read_text())
    blob["version"] = 2
    entry.write_text(json.dumps(blob))
    with pytest.raises(CacheSchemaError, match="schema v2"):
        LayoutDecision.from_json(entry.read_text())
    with pytest.warns(RuntimeWarning, match="schema v2"):
        redo = autotune(prog, (32, 32, 32), AXI_ZC706, **kw)
    assert not redo.from_cache
    assert redo.ranked == first.ranked
    # the re-search overwrote the stale entry: next call is a clean hit
    hit = autotune(prog, (32, 32, 32), AXI_ZC706, **kw)
    assert hit.from_cache


def test_cache_key_records_backend_capability_set(tmp_path):
    """Schema v3: the key folds the executor capability fingerprint in, so
    a decision is not silently reused after the backend envelope changes."""
    from repro.core.cfa.executors import (EXECUTORS, ExecutorCaps,
                                          register_executor)

    prog = PROGRAMS["jacobi2d5p"]
    kw = dict(budget=16, seed=0, cache_dir=tmp_path)
    autotune(prog, (32, 32, 32), AXI_ZC706, **kw)
    assert autotune(prog, (32, 32, 32), AXI_ZC706, **kw).from_cache

    class _Dummy:
        name = "test-dummy"
        caps = ExecutorCaps(ndims=(3,), description="cache-key probe")

        def execute(self, pipeline, inputs, **kw):  # pragma: no cover
            raise NotImplementedError

    register_executor(_Dummy())
    try:
        assert not autotune(prog, (32, 32, 32), AXI_ZC706, **kw).from_cache
    finally:
        del EXECUTORS["test-dummy"]
    assert autotune(prog, (32, 32, 32), AXI_ZC706, **kw).from_cache


# a cheap measured pass for the cache-split tests: tiny program, one repeat
_MEASURED_KW = dict(score="measured", measure_top=2,
                    measure_kwargs=dict(warmup=0, repeats=1))


def test_measured_and_modeled_cache_keys_are_disjoint(tmp_path):
    """Schema v5: the score axis (plus host fingerprint) is folded into the
    cache key, so a modeled decision can never be served for a measured
    query (or vice versa) — each query is a miss in the other's cache."""
    prog = PROGRAMS["heat1d"]
    kw = dict(budget=8, seed=0, cache_dir=tmp_path)
    modeled = autotune(prog, (8, 64), AXI_ZC706, **kw)
    assert not modeled.from_cache
    measured = autotune(prog, (8, 64), AXI_ZC706, **kw, **_MEASURED_KW)
    assert not measured.from_cache  # distinct key: no crosstalk
    assert measured.score == "measured"
    # both populated their own keys: each repeat query is now a clean hit
    assert autotune(prog, (8, 64), AXI_ZC706, **kw).from_cache
    assert autotune(prog, (8, 64), AXI_ZC706, **kw, **_MEASURED_KW).from_cache


def test_modeled_entry_at_measured_key_rejected_loudly(tmp_path):
    """Schema v5: an entry whose recorded score disagrees with the query
    (e.g. written by a buggy tool under the wrong key) warns and re-searches
    instead of silently serving the wrong ranking objective."""
    import json

    from repro.core.cfa.autotune import _cache_load

    prog = PROGRAMS["heat1d"]
    kw = dict(budget=8, seed=0, cache_dir=tmp_path)
    first = autotune(prog, (8, 64), AXI_ZC706, **kw, **_MEASURED_KW)
    (entry,) = tmp_path.glob("*.json")
    blob = json.loads(entry.read_text())
    assert blob["score"] == "measured"
    blob["score"] = "modeled"  # forge a modeled decision under the measured key
    entry.write_text(json.dumps(blob))
    assert _cache_load(entry, "modeled") is not None  # the forgery is valid JSON
    with pytest.warns(RuntimeWarning, match="score='modeled'.*score='measured'"):
        redo = autotune(prog, (8, 64), AXI_ZC706, **kw, **_MEASURED_KW)
    assert not redo.from_cache
    assert redo.best.candidate == first.best.candidate
    # the re-search overwrote the forged entry: next call is a clean hit
    assert autotune(prog, (8, 64), AXI_ZC706, **kw, **_MEASURED_KW).from_cache


def test_decision_records_score_and_roundtrips(tmp_path):
    """The decision carries its scoring mode: 'modeled' by default, and the
    mode survives the JSON round-trip either way."""
    prog = PROGRAMS["heat1d"]
    kw = dict(budget=8, seed=0, cache=False, cache_dir=tmp_path)
    modeled = autotune(prog, (8, 64), AXI_ZC706, **kw)
    assert modeled.score == "modeled"
    assert LayoutDecision.from_json(modeled.to_json()).score == "modeled"
    measured = autotune(prog, (8, 64), AXI_ZC706, **kw, **_MEASURED_KW)
    assert measured.score == "measured"
    assert LayoutDecision.from_json(measured.to_json()).score == "measured"
    assert any(s.measured_time_s is not None for s in measured.ranked)


# ---------------------------------------------------------------------------
# quality: never worse than the hand-coded plans (the acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(PROGRAMS))
def test_decision_beats_every_hand_coded_plan(name, tmp_path):
    prog = PROGRAMS[name]
    space = _small_space(prog)
    decision = autotune(prog, space, AXI_ZC706, budget=48, seed=0,
                        cache_dir=tmp_path)
    base = hand_coded_baselines(prog, IterSpace(space), AXI_ZC706)
    for bname, s in base.items():
        assert decision.best.effective_bw >= s.effective_bw - 1e-9, (
            f"{name}: autotuned {decision.best.effective_bw:.3e} lost to "
            f"hand-coded {bname} {s.effective_bw:.3e}"
        )
    # and the best CFA-family candidate also beats the hand-coded CFA plan
    assert decision.best_cfa().effective_bw >= base["cfa"].effective_bw - 1e-9


@pytest.mark.parametrize("name", sorted(PROGRAMS))
def test_chosen_plan_not_worse_than_original_layout(name, tmp_path):
    """The winner's modeled bursts and transfer time never exceed the
    original-layout baseline (which moves the minimum possible bytes)."""
    prog = PROGRAMS[name]
    space = _small_space(prog)
    decision = autotune(prog, space, AXI_ZC706, budget=48, seed=0,
                        cache_dir=tmp_path)
    sp, tiling = IterSpace(space), Tiling(prog.default_tile)
    orig = original_layout_plan(sp, prog.deps, tiling,
                                interior_tile(sp, tiling))
    t_orig = (AXI_ZC706.time_s(orig.read_runs)
              + AXI_ZC706.time_s(orig.write_runs))
    assert decision.best.n_bursts <= orig.n_bursts
    assert decision.best.time_s <= t_orig + 1e-12


# ---------------------------------------------------------------------------
# the search space itself
# ---------------------------------------------------------------------------

def test_candidate_tilings_legal_and_bounded():
    prog = PROGRAMS["gaussian"]  # widths (1, 4, 4)
    tilings = candidate_tilings(prog.widths, (8, 32, 32), max_halo_elems=4096)
    assert tilings, "search space must be non-empty"
    for t in tilings:
        for n, tk, w in zip((8, 32, 32), t, prog.widths):
            assert n % tk == 0 and tk >= max(1, w)
        halo = np.prod([tk + w for tk, w in zip(t, prog.widths)])
        assert halo <= 4096


def test_ranking_is_sorted_by_effective_bw(tmp_path):
    prog = PROGRAMS["jacobi2d9p"]
    d = autotune(prog, _small_space(prog), AXI_ZC706, budget=32, seed=0,
                 cache=False, cache_dir=tmp_path)
    bws = [s.effective_bw for s in d.ranked]
    assert bws == sorted(bws, reverse=True)
    assert d.evaluated == len(d.ranked)


# ---------------------------------------------------------------------------
# schema v7: the pass-pipeline fingerprint
# ---------------------------------------------------------------------------

def test_cache_key_records_pass_pipeline(tmp_path):
    """Schema v7: the lowering pipeline's (name, version) fingerprint is
    folded into the cache key, so a reordered/edited pipeline searches
    fresh instead of silently reusing the old pipeline's decision."""
    from repro.core.cfa.passes import default_pass_fingerprint

    prog = PROGRAMS["jacobi2d5p"]
    kw = dict(budget=16, seed=0, cache_dir=tmp_path)
    first = autotune(prog, (32, 32, 32), AXI_ZC706, **kw)
    assert first.pass_pipeline == default_pass_fingerprint()
    assert autotune(prog, (32, 32, 32), AXI_ZC706, **kw).from_cache
    # an edited pipeline (bumped pass version) keys differently: a miss
    edited = tuple((n, "99") if n == "layout_search" else (n, v)
                   for n, v in default_pass_fingerprint())
    other = autotune(prog, (32, 32, 32), AXI_ZC706, **kw,
                     pass_fingerprint=edited)
    assert not other.from_cache
    assert other.pass_pipeline == edited
    # both keys now populated: each repeat query is a clean hit
    assert autotune(prog, (32, 32, 32), AXI_ZC706, **kw).from_cache
    assert autotune(prog, (32, 32, 32), AXI_ZC706, **kw,
                    pass_fingerprint=edited).from_cache


def test_foreign_pass_pipeline_entry_rejected_loudly(tmp_path):
    """Schema v7: an entry recording a different pass pipeline than the
    query's (e.g. written by a buggy tool under the wrong key) warns and
    re-searches instead of silently serving a stale lowering's decision."""
    import json

    from repro.core.cfa.autotune import _cache_load
    from repro.core.cfa.passes import default_pass_fingerprint

    prog = PROGRAMS["heat1d"]
    kw = dict(budget=8, seed=0, cache_dir=tmp_path)
    first = autotune(prog, (8, 64), AXI_ZC706, **kw)
    (entry,) = tmp_path.glob("*.json")
    blob = json.loads(entry.read_text())
    blob["pass_pipeline"] = [["bogus_pass", "1"]]  # forge a foreign lowering
    entry.write_text(json.dumps(blob))
    # the forgery is valid JSON — only the fingerprint check rejects it
    assert _cache_load(entry, "modeled") is not None
    with pytest.warns(RuntimeWarning, match="pass pipeline"):
        redo = autotune(prog, (8, 64), AXI_ZC706, **kw)
    assert not redo.from_cache
    assert redo.best.candidate == first.best.candidate
    # the re-search overwrote the forged entry: next call is a clean hit
    assert autotune(prog, (8, 64), AXI_ZC706, **kw).from_cache


def test_decision_pass_pipeline_roundtrips(tmp_path):
    prog = PROGRAMS["heat1d"]
    d = autotune(prog, (8, 64), AXI_ZC706, budget=8, seed=0, cache=False,
                 cache_dir=tmp_path)
    back = LayoutDecision.from_json(d.to_json())
    assert back.pass_pipeline == d.pass_pipeline is not None


# ---------------------------------------------------------------------------
# end-to-end: the autotuned pipeline is still exact
# ---------------------------------------------------------------------------

def test_autotuned_compile_matches_oracle(tmp_path):
    from repro import cfa

    prog = PROGRAMS["jacobi2d5p"]
    space = (16, 16, 16)
    compiled = cfa.compile(prog.name, space, layout="autotune",
                           backend="sweep",
                           autotune_kwargs=dict(budget=24, seed=0,
                                                cache_dir=tmp_path))
    pipe = compiled.pipeline
    assert pipe.decision is not None
    assert pipe.tiling.sizes == pipe.decision.best_cfa().candidate.tile
    rng = np.random.default_rng(0)
    inputs = jnp.asarray(rng.normal(size=(pipe.specs[0].width, *space[1:])),
                         jnp.float32)
    facets = compiled(inputs, dtype=jnp.float32)
    V = pipe.reference_volume(inputs)
    spec = pipe.specs[0]
    if spec.tile_sizes[0] % spec.width:
        pytest.skip("winning tile not a multiple of w0; pack_facet n/a")
    err = float(jnp.abs(facets[0][1:] - pack_facet(V.astype(jnp.float32),
                                                   spec)).max())
    assert err < 1e-4


def test_autotuned_kernel_compatible_fetch(tmp_path):
    from repro.kernels.facet_fetch import fetch_interior_halos

    prog = PROGRAMS["jacobi2d5p"]
    space = (16, 16, 16)
    decision = autotune(prog, space, AXI_ZC706, budget=24, seed=0,
                        cache_dir=tmp_path)
    cand = decision.best_cfa(kernel_compatible=True).candidate
    assert cand.is_default_cfa_layout(3)
    pipe = CFAPipeline(prog, IterSpace(space), Tiling(cand.tile))
    rng = np.random.default_rng(1)
    inputs = jnp.asarray(rng.normal(size=(pipe.specs[0].width, *space[1:])),
                         jnp.float32)
    facets = pipe._sweep(inputs)
    halos = fetch_interior_halos(prog.name, facets, space, cand.tile)
    ref = pipe.copy_in(facets, tuple(1 for _ in range(3)))
    assert float(jnp.abs(halos[0, 0, 0] - ref).max()) < 1e-6
