"""Training loop: convergence, checkpoint/restart, preemption, optimizers."""
import dataclasses
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.data.pipeline import PackedDocs, SyntheticTokens
from repro.models.lm import init_lm
from repro.optim import (adamw_init, adafactor_init, cosine_warmup,
                         clip_by_global_norm, make_optimizer)
from repro.train.loop import Trainer
from repro.train.steps import TrainHParams


def test_loss_decreases_on_learnable_data(tmp_path):
    """Train on a tiny fixed dataset the model can memorise."""
    cfg = dataclasses.replace(get_smoke_config("qwen3-0.6b"), n_layers=2)

    class Fixed(SyntheticTokens):
        def batch_at(self, step):
            rng = np.random.default_rng(42)  # same batch every step
            return {"tokens": rng.integers(0, self.vocab,
                                           size=(self.batch, self.seq),
                                           dtype=np.int32)}

    hp = TrainHParams(peak_lr=1e-2, warmup=2, total_steps=40, remat=False)
    tr = Trainer(cfg, batch=4, seq=32, ckpt_dir=tmp_path, hp=hp,
                 data=Fixed(vocab=cfg.vocab, batch=4, seq=32), ckpt_every=1000)
    log = tr.run(30, log_every=1)
    first, last = log[0]["loss"], log[-1]["loss"]
    assert last < first * 0.7, (first, last)
    tr.data.close()


def test_checkpoint_restart_resumes_identically(tmp_path):
    cfg = dataclasses.replace(get_smoke_config("qwen3-0.6b"), n_layers=2)
    hp = TrainHParams(remat=False, warmup=2, total_steps=50)

    tr1 = Trainer(cfg, batch=2, seq=16, ckpt_dir=tmp_path / "a", hp=hp,
                  ckpt_every=5, seed=3)
    tr1.run(10, log_every=1)
    loss_uninterrupted = tr1.metrics_log[-1]["loss"]
    tr1.data.close()

    # same run, killed after 5 steps then restarted
    tr2 = Trainer(cfg, batch=2, seq=16, ckpt_dir=tmp_path / "b", hp=hp,
                  ckpt_every=5, seed=3)
    tr2.run(5, log_every=1)
    tr2.ckpt.wait()
    tr2.data.close()
    tr3 = Trainer(cfg, batch=2, seq=16, ckpt_dir=tmp_path / "b", hp=hp,
                  ckpt_every=5, seed=3)
    assert tr3.step == 5  # restored
    tr3.run(5, log_every=1)
    loss_resumed = tr3.metrics_log[-1]["loss"]
    np.testing.assert_allclose(loss_resumed, loss_uninterrupted, rtol=1e-5)
    tr3.data.close()


def test_preemption_checkpoint(tmp_path):
    cfg = dataclasses.replace(get_smoke_config("qwen3-0.6b"), n_layers=2)
    hp = TrainHParams(remat=False)
    tr = Trainer(cfg, batch=2, seq=16, ckpt_dir=tmp_path, hp=hp,
                 ckpt_every=1000, seed=1)
    (tr.ckpt.dir / "PREEMPT").write_text("")
    tr.run(10, log_every=1)
    assert tr.step == 1  # stopped after the first step
    assert tr.ckpt.latest_step() == 1  # and checkpointed before exiting
    tr.data.close()


def test_checkpoint_keep_last_k(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    m = CheckpointManager(tmp_path, keep=2)
    tree = {"a": jnp.arange(4.0)}
    for s in (1, 2, 3, 4):
        m.save(s, tree, blocking=True)
    assert m.all_steps() == [3, 4]


def test_checkpoint_restore_rejects_wrong_tree(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    m = CheckpointManager(tmp_path)
    m.save(1, {"a": jnp.arange(4.0)}, blocking=True)
    with pytest.raises(ValueError):
        m.restore(1, {"a": jnp.arange(4.0), "b": jnp.zeros(2)})


def test_grad_accumulation_equivalence():
    """accum=2 == accum=1 on the same global batch (up to f32 tolerance)."""
    cfg = dataclasses.replace(get_smoke_config("qwen3-0.6b"), n_layers=2,
                              compute_dtype="float32")
    from repro.train.steps import make_train_step
    params = init_lm(jax.random.PRNGKey(0), cfg)
    opt_init, _ = make_optimizer(cfg.optimizer)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)
    batch = {"tokens": tokens}
    outs = {}
    for accum in (1, 2):
        hp = TrainHParams(remat=False, accum=accum, warmup=1)
        p, o, m = make_train_step(cfg, hp)(params, opt_init(params), batch)
        outs[accum] = (p, m["loss"])
    np.testing.assert_allclose(float(outs[1][1]), float(outs[2][1]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[2][0])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-4, atol=5e-5)


def test_adafactor_memory_is_sublinear():
    cfg = get_smoke_config("jamba-1.5-large-398b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    adam = adamw_init(params)
    fact = adafactor_init(params)
    size = lambda t: sum(int(np.prod(l.shape)) for l in jax.tree.leaves(t))
    assert size(fact.nu) + size(fact.mu) < 0.25 * (size(adam.mu) + size(adam.nu))


def test_schedule_and_clip():
    lr0 = cosine_warmup(0, peak_lr=1.0, warmup=10, total=100)
    lr10 = cosine_warmup(10, peak_lr=1.0, warmup=10, total=100)
    lr100 = cosine_warmup(100, peak_lr=1.0, warmup=10, total=100)
    assert float(lr0) == pytest.approx(0.1)  # step 0 trains (lr > 0)
    assert float(lr10) == 1.0
    assert 0.09 < float(lr100) < 0.11  # floor = 0.1 * peak
    g = {"w": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["w"])) == pytest.approx(1.0, rel=1e-4)


def test_packed_docs_have_eos_and_full_rows():
    d = PackedDocs(vocab=100, batch=2, seq=64, mean_doc_len=10)
    b = d.next()
    assert b["tokens"].shape == (2, 64)
    assert (b["tokens"] == 0).any(axis=1).all()  # every row has an EOS
    d.close()
