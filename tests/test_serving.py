"""Continuous batching: per-lane positions + scheduler vs single-request."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models.lm import init_lm, lm_decode, lm_prefill
from repro.serve.scheduler import ContinuousBatcher, Request


def _greedy_reference(cfg, params, prompt, n_new, max_seq):
    logits, caches = lm_prefill(params, jnp.asarray(prompt)[None], cfg,
                                max_seq=max_seq)
    toks = [int(jnp.argmax(logits[0, :cfg.vocab]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        logits, caches = lm_decode(params, caches,
                                   jnp.asarray([toks[-1]], jnp.int32),
                                   jnp.int32(pos), cfg)
        toks.append(int(jnp.argmax(logits[0, :cfg.vocab])))
        pos += 1
    return toks


def test_per_lane_positions_match_scalar():
    """(B,) positions with equal values == scalar position decode."""
    cfg = get_smoke_config("qwen3-0.6b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    _, caches = lm_prefill(params, tokens, cfg, max_seq=32)
    nxt = jnp.asarray([3, 7], jnp.int32)
    l_scalar, _ = lm_decode(params, caches, nxt, jnp.int32(12), cfg)
    l_vector, _ = lm_decode(params, caches, nxt,
                            jnp.asarray([12, 12], jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(l_scalar, np.float32),
                               np.asarray(l_vector, np.float32),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-370m"])
def test_continuous_batching_matches_single_request(arch):
    """Mixed-length requests through 2 lanes == one-at-a-time generation."""
    cfg = get_smoke_config(arch)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (5, 9, 7)]
    n_new = [4, 3, 5]

    cb = ContinuousBatcher(cfg, params, lanes=2, max_seq=32)
    reqs = [Request(i, p, k) for i, (p, k) in enumerate(zip(prompts, n_new))]
    for r in reqs:
        cb.submit(r)
    cb.run()

    for r, p, k in zip(reqs, prompts, n_new):
        assert r.done and len(r.out) == k
        want = _greedy_reference(cfg, params, p, k, 32)
        assert r.out == want, (r.rid, r.out, want)
