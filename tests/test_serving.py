"""Continuous batching: per-lane positions + scheduler vs single-request."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.core.cfa.obs import TraceRecorder, validate_chrome_trace
from repro.models.lm import init_lm, lm_decode, lm_prefill
from repro.serve.scheduler import ContinuousBatcher, Request


def _greedy_reference(cfg, params, prompt, n_new, max_seq):
    logits, caches = lm_prefill(params, jnp.asarray(prompt)[None], cfg,
                                max_seq=max_seq)
    toks = [int(jnp.argmax(logits[0, :cfg.vocab]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        logits, caches = lm_decode(params, caches,
                                   jnp.asarray([toks[-1]], jnp.int32),
                                   jnp.int32(pos), cfg)
        toks.append(int(jnp.argmax(logits[0, :cfg.vocab])))
        pos += 1
    return toks


def test_per_lane_positions_match_scalar():
    """(B,) positions with equal values == scalar position decode."""
    cfg = get_smoke_config("qwen3-0.6b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    _, caches = lm_prefill(params, tokens, cfg, max_seq=32)
    nxt = jnp.asarray([3, 7], jnp.int32)
    l_scalar, _ = lm_decode(params, caches, nxt, jnp.int32(12), cfg)
    l_vector, _ = lm_decode(params, caches, nxt,
                            jnp.asarray([12, 12], jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(l_scalar, np.float32),
                               np.asarray(l_vector, np.float32),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-370m"])
def test_continuous_batching_matches_single_request(arch):
    """Mixed-length requests through 2 lanes == one-at-a-time generation."""
    cfg = get_smoke_config(arch)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (5, 9, 7)]
    n_new = [4, 3, 5]

    cb = ContinuousBatcher(cfg, params, lanes=2, max_seq=32)
    reqs = [Request(i, p, k) for i, (p, k) in enumerate(zip(prompts, n_new))]
    for r in reqs:
        cb.submit(r)
    cb.run()

    for r, p, k in zip(reqs, prompts, n_new):
        assert r.done and len(r.out) == k
        want = _greedy_reference(cfg, params, p, k, 32)
        assert r.out == want, (r.rid, r.out, want)


# ---------------------------------------------------------------------------
# Tick accounting + serve spans (a synthetic request stream through 2 lanes)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def _smoke_lm():
    cfg = get_smoke_config("qwen3-0.6b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _drained_batcher(cfg, params, *, recorder=None, lanes=2):
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (4, 6, 5, 3)]
    n_new = [3, 2, 4, 2]
    cb = ContinuousBatcher(cfg, params, lanes=lanes, max_seq=32,
                           recorder=recorder)
    reqs = [Request(i, p, k) for i, (p, k) in enumerate(zip(prompts, n_new))]
    for r in reqs:
        cb.submit(r)
    cb.run()
    return cb, reqs


def test_tick_accounting_totals(_smoke_lm):
    """stats() counts exactly the tokens decode ticks produced (admission
    emits the first token outside of step's live count)."""
    cfg, params = _smoke_lm
    cb, reqs = _drained_batcher(cfg, params)
    st = cb.stats()
    total_out = sum(len(r.out) for r in reqs)
    # each request's first token comes from prefill-at-admit, the rest
    # from decode ticks
    assert st["tokens"] == total_out - len(reqs)
    assert st["ticks"] >= max(k - 1 for k in (3, 2, 4, 2))
    assert st["tokens_per_sec"] > 0.0
    assert st["occupancy"] == 0.0 and st["queue_depth"] == 0


def test_serve_spans_and_counters(_smoke_lm):
    """admit/retire/step spans land on the serve track and the counters
    reconcile with the request stream."""
    cfg, params = _smoke_lm
    rec = TraceRecorder(label="serve-test")
    cb, reqs = _drained_batcher(cfg, params, recorder=rec)

    admits = rec.find("admit", cat="serve")
    retires = rec.find("retire", cat="serve")
    steps = rec.find("step", cat="serve")
    assert len(admits) == len(reqs) == rec.counters["serve_admitted"]
    assert len(retires) == len(reqs) == rec.counters["serve_retired"]
    assert {s.arg("rid") for s in admits} == {r.rid for r in reqs}
    assert {s.arg("rid") for s in retires} == {r.rid for r in reqs}
    assert len(steps) == cb.ticks == rec.counters["serve_ticks"]
    assert rec.counters["serve_tokens"] == cb.tokens
    # per-step occupancy never exceeds the lane count and sums to tokens
    occ = [s.arg("occupancy") for s in steps]
    assert all(0 <= o <= cb.lanes for o in occ)
    assert sum(occ) == cb.tokens
    # occupancy counter samples mirror the step spans
    assert [v for _, n, v in rec.counter_samples if n == "occupancy"] == occ
    validate_chrome_trace(rec.to_chrome())


def test_admit_retire_ordering(_smoke_lm):
    """A lane's retire precedes the admit that reuses it; FIFO admission."""
    cfg, params = _smoke_lm
    rec = TraceRecorder(label="serve-order")
    _drained_batcher(cfg, params, recorder=rec)
    events = [s for s in rec.spans
              if s.cat == "serve" and s.name in ("admit", "retire")]
    # spans are appended in wall order; replay them per lane
    busy: dict[int, int] = {}
    admit_rids = []
    for s in events:
        lane = s.arg("lane")
        if s.name == "admit":
            assert lane not in busy, (lane, busy)
            busy[lane] = s.arg("rid")
            admit_rids.append(s.arg("rid"))
        else:
            assert busy.pop(lane) == s.arg("rid")
    assert not busy
    assert admit_rids == sorted(admit_rids)  # FIFO submit order
