"""Unit tests for the CFA core (spaces, facets, plans).

The hypothesis-based property tests live in ``test_cfa_properties.py`` so
that this module collects even when ``hypothesis`` (an optional test extra,
see pyproject.toml) is not installed.
"""
import numpy as np
import pytest

from repro.core.cfa import (
    Deps,
    IterSpace,
    Tiling,
    build_facet_specs,
    cfa_plan,
    count_runs,
    facet_points,
    facet_widths,
    flow_in_points,
    flow_out_points,
    get_program,
    interior_tile,
    original_layout_plan,
    bounding_box_plan,
    data_tiling_plan,
)


# ---------------------------------------------------------------------------
# widths / basic sets
# ---------------------------------------------------------------------------

def test_facet_widths_table1():
    assert facet_widths(get_program("jacobi2d5p").deps) == (1, 2, 2)
    assert facet_widths(get_program("jacobi2d9p").deps) == (1, 2, 2)
    assert facet_widths(get_program("gaussian").deps) == (1, 4, 4)
    assert facet_widths(get_program("smith-waterman-3seq").deps) == (3, 1, 1)


def test_deps_reject_forward_vectors():
    with pytest.raises(ValueError):
        Deps(((1, 0),))
    with pytest.raises(ValueError):
        Deps(((0, 0),))


def test_flow_sets_simple_1d():
    space, deps, tiling = IterSpace((8,)), Deps(((-1,),)), Tiling((4,))
    fin = flow_in_points(space, deps, tiling, (1,))
    assert fin.tolist() == [[3]]
    fout = flow_out_points(space, deps, tiling, (0,))
    assert fout.tolist() == [[3]]
    # last tile has no consumers
    assert flow_out_points(space, deps, tiling, (1,)).size == 0


# ---------------------------------------------------------------------------
# the paper's layout family (example of §IV, Fig. 5: t=5, w=(1,2,2))
# ---------------------------------------------------------------------------

def test_paper_example_layout():
    space = IterSpace((25, 25, 25))
    deps = Deps(((-1, 0, 0), (0, -1, -2), (0, -2, -1)))  # w = (1, 2, 2)
    tiling = Tiling((5, 5, 5))
    specs = build_facet_specs(space, deps, tiling)
    assert facet_widths(deps) == (1, 2, 2)
    # facet_j[jj][ii][kk][k][i][j%2] (paper §IV-H/I)
    assert specs[1].outer_axes == (1, 0, 2)
    assert specs[1].inner_axes == (2, 0, 1)
    assert specs[1].shape == (5, 5, 5, 5, 5, 2)
    # facet_k[kk][jj][ii][i][j][k%2]
    assert specs[2].outer_axes == (2, 1, 0)
    assert specs[2].inner_axes == (0, 1, 2)
    assert specs[2].shape == (5, 5, 5, 5, 5, 2)
    # facet_i: single-assignment axis first, extension axis j last outer
    assert specs[0].outer_axes == (0, 2, 1)
    assert specs[0].inner_axes == (1, 2, 0)
    assert specs[0].shape == (5, 5, 5, 5, 5, 1)


def test_full_tile_contiguity_every_facet_single_run():
    """§IV-G: each tile's facet block is one contiguous burst."""
    prog = get_program("jacobi2d5p")
    space, tiling = IterSpace((48, 48, 48)), Tiling((16, 16, 16))
    specs = build_facet_specs(space, prog.deps, tiling)
    w = facet_widths(prog.deps)
    for tile in [(0, 0, 0), (1, 1, 1), (2, 0, 1)]:
        for k, spec in specs.items():
            pts = facet_points(tiling, w, k, tile)
            runs = count_runs(spec.offsets(pts))
            assert len(runs) == 1
            assert runs[0] == spec.block_elems


# ---------------------------------------------------------------------------
# facet address maps are injective per facet (single-assignment, §IV-F4)
# ---------------------------------------------------------------------------

def test_single_assignment_no_offset_collisions():
    prog = get_program("smith-waterman-3seq")
    space, tiling = IterSpace((12, 12, 12)), Tiling((6, 6, 6))
    specs = build_facet_specs(space, prog.deps, tiling)
    w = facet_widths(prog.deps)
    for k, spec in specs.items():
        all_offsets = []
        for q0 in range(2):
            for q1 in range(2):
                for q2 in range(2):
                    pts = facet_points(tiling, w, k, (q0, q1, q2))
                    all_offsets.append(spec.offsets(pts))
        flat = np.concatenate(all_offsets)
        assert len(np.unique(flat)) == len(flat), f"facet_{k} offsets collide"
        assert flat.min() >= 0 and flat.max() < spec.size


# ---------------------------------------------------------------------------
# the paper's burst counts: 4 reads + one write per facet for 3-D tiles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["jacobi2d5p", "jacobi2d9p", "gaussian",
                                  "smith-waterman-3seq"])
def test_cfa_four_read_bursts(name):
    prog = get_program(name)
    t = prog.default_tile
    space = IterSpace(tuple(4 * x for x in t))
    tiling = Tiling(t)
    plan = cfa_plan(space, prog.deps, tiling)
    assert plan.n_read_bursts == 4, f"{name}: {plan.read_runs}"
    assert plan.n_write_bursts == len(build_facet_specs(space, prog.deps, tiling))
    assert plan.redundancy < 0.25


@pytest.mark.parametrize("name", ["jacobi2d5p", "smith-waterman-3seq"])
def test_cfa_exact_reads_zero_redundancy(name):
    prog = get_program(name)
    t = prog.default_tile
    space = IterSpace(tuple(4 * x for x in t))
    plan = cfa_plan(space, prog.deps, Tiling(t), boxed=False)
    assert plan.read_transferred == plan.read_useful


def test_cfa_beats_baselines_on_burst_count():
    prog = get_program("jacobi2d5p")
    space, tiling = IterSpace((64, 64, 64)), Tiling((16, 16, 16))
    tile = interior_tile(space, tiling)
    cfa = cfa_plan(space, prog.deps, tiling, tile)
    orig = original_layout_plan(space, prog.deps, tiling, tile)
    bbox = bounding_box_plan(space, prog.deps, tiling, tile)
    dt = data_tiling_plan(space, prog.deps, tiling, tile)
    assert cfa.n_bursts < orig.n_bursts
    assert cfa.n_bursts <= bbox.n_bursts or cfa.redundancy < bbox.redundancy
    # original layout never transfers redundant data; bbox/data-tiling do
    assert orig.redundancy == 0.0
    assert bbox.redundancy > 0.0
    assert dt.redundancy > 0.0
    # CFA moves (nearly) only useful data
    assert cfa.redundancy < bbox.redundancy
    assert cfa.redundancy < dt.redundancy


def test_all_flow_out_covered_by_facet_writes():
    """CFA writes full facets; flow-out must be a subset (appendix proof)."""
    prog = get_program("jacobi2d9p")
    space, tiling = IterSpace((48, 48, 48)), Tiling((16, 16, 16))
    w = facet_widths(prog.deps)
    specs = build_facet_specs(space, prog.deps, tiling)
    tile = (1, 1, 1)
    fout = flow_out_points(space, prog.deps, tiling, tile)
    facet_sets = [
        set(map(tuple, facet_points(tiling, w, k, tile))) for k in specs
    ]
    for x in map(tuple, fout):
        assert any(x in s for s in facet_sets)
