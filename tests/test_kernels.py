"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes as required by the assignment."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.cfa import CFAPipeline, IterSpace, Tiling, get_program
from repro.kernels.stencil import execute_tiles, execute_tiles_ref
from repro.kernels.block_attention import (
    append_token,
    blockify,
    deblockify,
    decode_attention,
    decode_attention_ref,
)
from repro.kernels.ssd import ssd_decode_step, ssd_scan, ssd_scan_ref


# ---------------------------------------------------------------------------
# stencil tile executor
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["jacobi2d5p", "jacobi2d9p", "jacobi2d9p-gol",
                                  "gaussian", "smith-waterman-3seq"])
@pytest.mark.parametrize("tile,batch", [((4, 8, 8), 3), ((8, 16, 16), 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_stencil_kernel_matches_ref(name, tile, batch, dtype):
    prog = get_program(name)
    w = prog.widths
    hshape = (batch, w[0] + tile[0], w[1] + tile[1], w[2] + tile[2])
    rng = np.random.default_rng(42)
    halos = jnp.asarray(rng.normal(size=hshape), dtype)
    got = execute_tiles(name, halos, tile, interpret=True)
    want = execute_tiles_ref(name, halos, tile)
    tol = 1e-4 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_stencil_kernel_agrees_with_pipeline():
    """Kernel path == reference pipeline on a real tiled sweep tile."""
    prog = get_program("jacobi2d5p")
    pipe = CFAPipeline(prog, IterSpace((8, 8, 8)), Tiling((4, 4, 4)))
    rng = np.random.default_rng(0)
    inputs = jnp.asarray(rng.normal(size=(1, 8, 8)), jnp.float32)
    facets = pipe.init_facets(jnp.float32)
    facets = pipe.load_inputs(facets, inputs)
    H = pipe.copy_in(facets, (0, 0, 0))
    want = pipe.execute_tile(H)
    got = execute_tiles("jacobi2d5p", H[None], (4, 4, 4), interpret=True)
    w = prog.widths
    np.testing.assert_allclose(
        np.asarray(got[0]),
        np.asarray(want[w[0]:, w[1]:, w[2]:]),
        rtol=1e-6, atol=1e-6,
    )


# ---------------------------------------------------------------------------
# block (facet-layout) decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Hq,Hkv,D,S,bs", [
    (2, 8, 2, 64, 256, 64),
    (1, 4, 4, 32, 128, 32),   # MHA (no grouping)
    (3, 16, 1, 64, 192, 64),  # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_attention_matches_ref(B, Hq, Hkv, D, S, bs, dtype):
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(B, Hq, D)), dtype)
    kc = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    vc = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    lengths = jnp.asarray(rng.integers(1, S + 1, size=(B,)), jnp.int32)
    got = decode_attention(q, blockify(kc, bs), blockify(vc, bs), lengths)
    want = decode_attention_ref(q, kc, vc, lengths)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_block_attention_partial_final_block():
    """Lengths that do not align with block boundaries must mask correctly."""
    rng = np.random.default_rng(3)
    B, Hq, Hkv, D, S, bs = 2, 4, 2, 32, 128, 32
    q = jnp.asarray(rng.normal(size=(B, Hq, D)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    lengths = jnp.asarray([1, 33], jnp.int32)  # deep in first / second block
    got = decode_attention(q, blockify(kc, bs), blockify(vc, bs), lengths)
    want = decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_blockify_roundtrip_and_append():
    rng = np.random.default_rng(11)
    B, S, H, D, bs = 2, 64, 4, 16, 16
    kc = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    blocks = blockify(kc, bs)
    np.testing.assert_array_equal(np.asarray(deblockify(blocks)), np.asarray(kc))
    k_new = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
    nb2, _ = append_token(blocks, blocks, k_new, k_new, jnp.int32(37))
    back = deblockify(nb2)
    np.testing.assert_array_equal(np.asarray(back[:, 37]), np.asarray(k_new))
    np.testing.assert_array_equal(np.asarray(back[:, :37]), np.asarray(kc[:, :37]))


# ---------------------------------------------------------------------------
# SSD chunk scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,T,H,P,N,chunk", [
    (2, 64, 4, 16, 8, 16),
    (1, 128, 2, 32, 16, 32),
    (2, 96, 8, 8, 4, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_ref(B, T, H, P, N, chunk, dtype):
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(B, T, H, P)), dtype)
    loga = jnp.asarray(-np.abs(rng.normal(size=(B, T, H))) * 0.5, jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, T, N)) / np.sqrt(N), dtype)
    C = jnp.asarray(rng.normal(size=(B, T, N)) / np.sqrt(N), dtype)
    y, s = ssd_scan(x, loga, Bm, C, chunk=chunk)
    y_ref, s_ref = ssd_scan_ref(x, loga, Bm, C)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32), rtol=tol, atol=tol
    )
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-4, atol=1e-4)


def test_ssd_chunk_invariance():
    """The facet decomposition must be invariant to the chunk size."""
    rng = np.random.default_rng(9)
    B, T, H, P, N = 1, 64, 2, 8, 4
    x = jnp.asarray(rng.normal(size=(B, T, H, P)), jnp.float32)
    loga = jnp.asarray(-np.abs(rng.normal(size=(B, T, H))) * 0.3, jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, T, N)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(B, T, N)), jnp.float32)
    y8, s8 = ssd_scan(x, loga, Bm, C, chunk=8)
    y64, s64 = ssd_scan(x, loga, Bm, C, chunk=64)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y64), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(s8), np.asarray(s64), rtol=2e-5, atol=2e-5)


def test_ssd_decode_step_consistent_with_scan():
    """Token-by-token decode must follow the scan trajectory."""
    rng = np.random.default_rng(13)
    B, T, H, P, N = 2, 16, 2, 8, 4
    x = jnp.asarray(rng.normal(size=(B, T, H, P)), jnp.float32)
    loga = jnp.asarray(-np.abs(rng.normal(size=(B, T, H))) * 0.3, jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, T, N)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(B, T, N)), jnp.float32)
    y_ref, s_ref = ssd_scan_ref(x, loga, Bm, C)
    S = jnp.zeros((B, H, P, N), jnp.float32)
    for t in range(T):
        y_t, S = ssd_decode_step(S, x[:, t], loga[:, t], Bm[:, t], C[:, t])
        np.testing.assert_allclose(
            np.asarray(y_t), np.asarray(y_ref[:, t]), rtol=1e-5, atol=1e-5
        )
    np.testing.assert_allclose(np.asarray(S), np.asarray(s_ref), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# facet-fetch read engine (paper Fig. 13 'read' stage as BlockSpec DMAs)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,space,tile", [
    ("jacobi2d5p", (8, 8, 8), (4, 4, 4)),
    ("jacobi2d9p", (12, 8, 8), (4, 4, 4)),
    ("gaussian", (4, 16, 16), (2, 8, 8)),
])
def test_facet_fetch_kernel_matches_copy_in(name, space, tile):
    from repro.core.cfa import CFAPipeline, IterSpace, Tiling, get_program
    from repro.kernels.facet_fetch import (fetch_interior_halos,
                                           fetch_interior_halos_ref)

    prog = get_program(name)
    pipe = CFAPipeline(prog, IterSpace(space), Tiling(tile))
    rng = np.random.default_rng(0)
    inputs = jnp.asarray(rng.normal(size=(pipe.specs[0].width, *space[1:])),
                         jnp.float32)
    facets = pipe._sweep(inputs, dtype=jnp.float32)
    got = fetch_interior_halos(name, facets, space, tile, interpret=True)
    want = fetch_interior_halos_ref(name, facets, space, tile)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_facet_fetch_rejects_non_dividing_width():
    from repro.core.cfa import CFAPipeline, IterSpace, Tiling, get_program
    from repro.kernels.facet_fetch import fetch_interior_halos

    prog = get_program("smith-waterman-3seq")  # w0 = 3
    pipe = CFAPipeline(prog, IterSpace((8, 8, 8)), Tiling((4, 4, 4)))
    facets = pipe.init_facets(jnp.float32)
    with pytest.raises(ValueError):
        fetch_interior_halos("smith-waterman-3seq", facets, (8, 8, 8),
                             (4, 4, 4))
