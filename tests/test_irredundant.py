"""The irredundant & compressed facet storage subsystem (Ferry 2024).

Acceptance criteria pinned here:

* every Table I program plus ``heat1d``/``heat3d`` runs **bit-exact** under
  ``storage="irredundant"`` vs the redundant layout, on every applicable
  backend, through ``repro.cfa.compile`` (rehydration bridges the payloads);
* the irredundant storage map has ``redundancy == 1.0`` (no duplicate
  storage) and a **strictly smaller footprint** than the redundant layout —
  pinned for ``jacobi2d5p`` and ``heat3d``;
* the fixed-ratio block codec round-trips exactly on data that fits its
  ratio, and the compressed discipline is modeled as reduced bytes/burst;
* the autotuner's storage axis (schema v4) caches, ranks and round-trips;
* ``allocation.pack_all``/``unpack_into`` understand the deduplicated map,
  and the w | t restriction raises the documented ``ValueError`` from every
  public entry point (the tile-dependent case routes to the sweep executor);
* the ``facet_fetch`` Pallas read engine fetches via the owner-facet
  indirection.
"""
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import cfa
from repro.core.cfa import (
    AXI_ZC706,
    CFAPipeline,
    IterSpace,
    Tiling,
    build_facet_specs,
    build_storage_map,
    cfa_plan,
    dedup_facets,
    get_program,
    owner_of,
    rehydrate_facets,
)
from repro.core.cfa.autotune import LayoutDecision, autotune
from repro.core.cfa.compress import CODECS, get_codec
from repro.core.cfa.irredundant import (
    STORAGE_MODES,
    CompressedPipeline,
    IrredundantPipeline,
)
from repro.core.cfa.plans import TransferPlan
from repro.core.cfa.spaces import facet_points

# (program, space, tile): the same test-size corners test_api.py pins.
CASES = [
    ("jacobi2d5p", (8, 8, 8), (4, 4, 4)),
    ("jacobi2d9p", (8, 8, 8), (4, 4, 4)),
    ("jacobi2d9p-gol", (8, 8, 8), (4, 4, 4)),
    ("gaussian", (4, 16, 16), (2, 8, 8)),
    ("smith-waterman-3seq", (9, 8, 8), (3, 4, 4)),
    ("heat1d", (8, 8), (4, 4)),
    ("heat3d", (4, 4, 4, 4), (2, 2, 2, 2)),
]


def _inputs(space, name, seed=0):
    prog = get_program(name)
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(prog.widths[0], *space[1:])))


# ---------------------------------------------------------------------------
# storage map: single assignment + footprint (the acceptance pins)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,space,tile", CASES,
                         ids=[c[0] for c in CASES])
def test_storage_map_single_assignment_and_footprint(name, space, tile):
    prog = get_program(name)
    specs = build_facet_specs(IterSpace(space), prog.deps, Tiling(tile))
    smap = build_storage_map(specs)
    # no duplicate storage: stored slots / distinct values == 1.0, exactly
    assert smap.redundancy == 1.0
    # the owned masks partition each tile's facet union
    pts = np.concatenate([
        facet_points(Tiling(tile), prog.widths, k, (0,) * len(space))
        for k in specs
    ])
    uniq = np.unique(pts, axis=0)
    own = owner_of(specs, uniq)
    assert (own >= 0).all(), "facet-union point with no owner"
    for k in specs:
        assert smap.owned_per_block[k] == int((own == k).sum())
    n_tiles = int(np.prod([n // t for n, t in zip(space, tile)]))
    assert smap.stored_elems == len(uniq) * n_tiles
    # dedup strictly shrinks whenever facets overlap at all
    assert smap.stored_elems <= smap.redundant_elems


@pytest.mark.parametrize("name,space,tile", [
    ("jacobi2d5p", (8, 8, 8), (4, 4, 4)),
    ("heat3d", (4, 4, 4, 4), (2, 2, 2, 2)),
])
def test_footprint_strictly_smaller_pinned(name, space, tile):
    """Acceptance pin: irredundant footprint < redundant footprint."""
    prog = get_program(name)
    red = cfa_plan(IterSpace(space), prog.deps, Tiling(tile))
    irr = cfa_plan(IterSpace(space), prog.deps, Tiling(tile),
                   storage="irredundant")
    assert irr.storage == "irredundant" and red.storage == "redundant"
    assert irr.footprint < red.footprint
    assert irr.stored_elems < red.stored_elems
    specs = build_facet_specs(IterSpace(space), prog.deps, Tiling(tile))
    smap = build_storage_map(specs)
    assert irr.footprint == smap.stored_elems
    assert red.footprint == smap.redundant_elems
    assert smap.savings > 0


def test_heat3d_savings_dominates():
    """The d >= 4 regime duplicates the most — dedup recovers the most."""
    prog = get_program("heat3d")
    specs = build_facet_specs(IterSpace((4, 4, 4, 4)), prog.deps,
                              Tiling((2, 2, 2, 2)))
    smap = build_storage_map(specs)
    assert smap.savings > 0.5  # 71.4% at the 2^4 tile


# ---------------------------------------------------------------------------
# TransferPlan storage fields: strict validation (PR 3-style hardening)
# ---------------------------------------------------------------------------

def _plan(**kw):
    return TransferPlan("x", (4,), (4,), 4, 4, **kw)


def test_transfer_plan_storage_validation():
    assert _plan().footprint is None and _plan().stored_elems is None
    assert _plan(storage="irredundant", footprint=8, stored_elems=8,
                 ).footprint == 8
    with pytest.raises(ValueError, match="storage"):
        _plan(storage="deduplicated")
    with pytest.raises(ValueError, match="stored_elems"):
        _plan(stored_elems=0)
    with pytest.raises(ValueError, match="stored_elems"):
        _plan(stored_elems=-3)
    with pytest.raises(ValueError, match="footprint"):
        _plan(footprint=0)
    with pytest.raises(ValueError, match="footprint"):
        _plan(footprint=-1)
    with pytest.raises(ValueError, match="codec_bits"):
        _plan(codec_bits=0)


def test_cfa_plan_rejects_codec_without_compressed():
    prog = get_program("jacobi2d5p")
    with pytest.raises(ValueError, match="compressed"):
        cfa_plan(IterSpace((8, 8, 8)), prog.deps, Tiling((4, 4, 4)),
                 storage="irredundant", codec="deltapack16")
    with pytest.raises(ValueError, match="storage"):
        cfa_plan(IterSpace((8, 8, 8)), prog.deps, Tiling((4, 4, 4)),
                 storage="nope")


def test_baseline_plans_carry_canonical_footprint():
    from repro.core.cfa import bounding_box_plan, original_layout_plan

    prog = get_program("jacobi2d5p")
    sp, til = IterSpace((8, 8, 8)), Tiling((4, 4, 4))
    assert original_layout_plan(sp, prog.deps, til).footprint == 8 ** 3
    assert bounding_box_plan(sp, prog.deps, til).footprint == 8 ** 3


# ---------------------------------------------------------------------------
# bit-exactness: every program x backend, irredundant vs redundant
# ---------------------------------------------------------------------------

def _exact_params():
    out = []
    for name, space, tile in CASES:
        for b in ("sweep", "wavefront", "pallas", "sharded"):
            if b == "pallas" and len(space) != 3:
                continue  # the pallas backend is declared 3-D only
            # repo convention: one fast sharded representative in tier-1,
            # the rest on the CI slow leg
            marks = ([pytest.mark.slow]
                     if b == "sharded" and name != "jacobi2d5p" else [])
            out.append(pytest.param(name, space, tile, b,
                                    marks=marks, id=f"{name}-{b}"))
    return out


@pytest.mark.parametrize("name,space,tile,backend", _exact_params())
def test_irredundant_bit_exact_vs_redundant(name, space, tile, backend):
    """rehydrate(irredundant payload) == redundant payload, same backend."""
    n_ports = 2 if backend == "sharded" else 1
    x = _inputs(space, name)
    red = cfa.compile(name, space, layout=tile, backend=backend,
                      n_ports=n_ports)(x, dtype=jnp.float64)
    c = cfa.compile(name, space, layout=tile, backend=backend,
                    n_ports=n_ports, storage="irredundant")
    assert c.storage == "irredundant" and c.pipeline.storage == "irredundant"
    got = c(x, dtype=jnp.float64)
    # the raw payload is deduplicated: exactly the redundant payload with
    # non-owned slots zeroed
    dd = dedup_facets(red, c.pipeline.storage_map)
    for k in red:
        assert (np.asarray(got[k]) == np.asarray(dd[k])).all(), f"facet {k}"
    # and rehydration reconstructs the redundant payload bit-for-bit
    rh = c.rehydrate(got)
    for k in red:
        assert (np.asarray(rh[k]) == np.asarray(red[k])).all(), f"facet {k}"


@pytest.mark.parametrize("name,space,tile", [CASES[0], CASES[-1]],
                         ids=["jacobi2d5p", "heat3d"])
def test_irredundant_reference_backend_matches_sweep(name, space, tile):
    x = _inputs(space, name)
    ref = cfa.compile(name, space, layout=tile, backend="reference",
                      storage="irredundant")(x, dtype=jnp.float64)
    swp = cfa.compile(name, space, layout=tile, backend="sweep",
                      storage="irredundant")(x, dtype=jnp.float64)
    for k in swp:
        assert (np.asarray(ref[k]) == np.asarray(swp[k])).all(), f"facet {k}"


def test_rehydrate_is_identity_for_redundant():
    c = cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                    backend="sweep")
    x = _inputs((8, 8, 8), "jacobi2d5p")
    facets = c(x)
    assert c.rehydrate(facets) is facets
    assert c.storage_map is None


# ---------------------------------------------------------------------------
# compressed storage: codec exactness + modeled bytes/burst
# ---------------------------------------------------------------------------

def _truncated(x, bits):
    """Zero the low (width - bits) bits of every word: data the fixed-ratio
    codec preserves exactly."""
    w = 8 * np.dtype(x.dtype).itemsize
    u = {4: jnp.uint32, 8: jnp.uint64}[np.dtype(x.dtype).itemsize]
    raw = jax.lax.bitcast_convert_type(x, u)
    return jax.lax.bitcast_convert_type((raw >> (w - bits)) << (w - bits),
                                        x.dtype)


@pytest.mark.parametrize("codec", sorted(CODECS))
def test_codec_roundtrip(codec):
    c = CODECS[codec]
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(5, 7, 3)), jnp.float32)
    rt = c.roundtrip(x)
    assert rt.shape == x.shape and rt.dtype == x.dtype
    if not c.bits:
        assert c.exact(x)  # raw is the identity
    else:
        xt = _truncated(x, min(c.bits, 32))
        assert c.exact(xt), "bit-truncated data must survive the ratio"
        assert c.ratio(x.size, 32) <= 1.0
    # jit-compatible (shape-static encode/decode)
    assert jax.jit(c.roundtrip)(x).shape == x.shape


def test_codec_registry():
    assert get_codec(None).name == "deltapack16"
    assert get_codec("raw").bits == 0
    assert get_codec(CODECS["deltapack8"]) is CODECS["deltapack8"]
    with pytest.raises(ValueError, match="unknown codec"):
        get_codec("zstd")
    with pytest.raises(ValueError, match="bits"):
        type(CODECS["raw"])("bad", bits=12)


def test_compressed_raw_codec_bit_exact_vs_irredundant():
    x = _inputs((8, 8, 8), "jacobi2d5p")
    irr = cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                      backend="sweep", storage="irredundant")(x, dtype=jnp.float64)
    cmp_ = cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                       backend="sweep", storage="compressed", codec="raw")
    got = cmp_(x, dtype=jnp.float64)
    for k in irr:
        assert (np.asarray(got[k]) == np.asarray(irr[k])).all(), f"facet {k}"


def test_compressed_pipeline_quantises_through_codec():
    """With a lossy ratio the payload holds what compression preserved —
    close to, but not necessarily identical to, the irredundant payload."""
    x = _inputs((8, 8, 8), "jacobi2d5p")
    irr = cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                      backend="sweep", storage="irredundant")(x, dtype=jnp.float32)
    cp = cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                     backend="sweep", storage="compressed",
                     codec="deltapack16")
    got = cp(x, dtype=jnp.float32)
    for k in irr:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(irr[k]),
                                   rtol=2e-2, atol=2e-2)


def test_compressed_bursts_modeled_faster():
    """Same burst structure, fewer bytes: compressed plan time < irredundant
    plan time, and effective bandwidth rises accordingly."""
    prog = get_program("jacobi2d5p")
    sp, til = IterSpace((32, 32, 32)), Tiling((16, 16, 16))
    irr = cfa_plan(sp, prog.deps, til, storage="irredundant")
    cmp_ = cfa_plan(sp, prog.deps, til, storage="compressed",
                    codec="deltapack16")
    assert cmp_.codec_bits == 16 and irr.codec_bits is None
    assert cmp_.read_runs == irr.read_runs  # structure unchanged
    assert cmp_.write_runs == irr.write_runs
    assert AXI_ZC706.time(cmp_) < AXI_ZC706.time(irr)
    from repro.core.cfa import BandwidthReport

    r_i = BandwidthReport.evaluate(irr, AXI_ZC706)
    r_c = BandwidthReport.evaluate(cmp_, AXI_ZC706)
    assert r_c.effective_bw > r_i.effective_bw
    assert r_c.peak_fraction_raw <= 1.0 + 1e-12  # wire bytes never above peak
    assert r_c.storage == "compressed" and r_c.footprint == cmp_.footprint
    # "raw" models as uncompressed
    raw = cfa_plan(sp, prog.deps, til, storage="compressed", codec="raw")
    assert raw.codec_bits is None
    assert AXI_ZC706.time(raw) == AXI_ZC706.time(irr)


def test_compressed_ported_plan_carries_codec():
    from repro.core.cfa import best_repartition

    prog = get_program("jacobi2d5p")
    plan = cfa_plan(IterSpace((32, 32, 32)), prog.deps, Tiling((16, 16, 16)),
                    storage="compressed", codec="deltapack16")
    pp = best_repartition(plan, 4, AXI_ZC706)
    assert pp.codec_bits == 16 and pp.storage == "compressed"
    assert AXI_ZC706.time(pp) <= AXI_ZC706.time(plan)


# ---------------------------------------------------------------------------
# compile() surface: gating, auto-selection, describe
# ---------------------------------------------------------------------------

def test_storage_mode_validation():
    with pytest.raises(ValueError, match="storage"):
        cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                    storage="dedup")
    with pytest.raises(ValueError, match="compressed"):
        cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                    backend="sweep", codec="deltapack16")


def test_pallas_rejects_compressed_and_auto_avoids_it():
    with pytest.raises(cfa.BackendError, match="compressed"):
        cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                    backend="pallas", storage="compressed")
    # auto: 3-D would pick pallas, but compressed falls back to wavefront
    assert cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                       storage="compressed").backend == "wavefront"
    assert cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                       storage="irredundant").backend == "pallas"
    j = get_program("jacobi2d5p")
    assert cfa.select_backend(j, IterSpace((8, 8, 8)),
                              storage="compressed") == "wavefront"


def test_lower_revalidates_storage():
    c = cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                    backend="sweep", storage="compressed")
    assert c.lower("wavefront").backend == "wavefront"
    with pytest.raises(cfa.BackendError, match="compressed"):
        c.lower("pallas")


def test_available_backends_storage_axis():
    j = get_program("jacobi2d5p")
    have = cfa.available_backends(j, IterSpace((8, 8, 8)),
                                  storage="compressed")
    assert "pallas" not in have and "sweep" in have
    assert "pallas" in cfa.available_backends(j, IterSpace((8, 8, 8)),
                                              storage="irredundant")


def test_describe_and_report_mention_storage():
    c = cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                    backend="sweep", storage="irredundant")
    assert "irredundant" in c.describe()
    rep = c.report()
    assert rep.storage == "irredundant"
    assert rep.footprint == c.plan.footprint


def test_autotuned_storage_compile_end_to_end(tmp_path):
    c = cfa.compile("jacobi2d5p", (8, 8, 8), backend="sweep",
                    storage="irredundant",
                    autotune_kwargs=dict(budget=16, cache_dir=tmp_path))
    assert c.decision.storage == "irredundant"
    assert c.decision.best_cfa().footprint is not None
    x = _inputs((8, 8, 8), "jacobi2d5p")
    got = c(x, dtype=jnp.float64)
    ref = c.lower("reference")(x, dtype=jnp.float64)
    for k in ref:
        assert (np.asarray(got[k]) == np.asarray(ref[k])).all()


# ---------------------------------------------------------------------------
# autotune: the storage/footprint axis + cache schema v4
# ---------------------------------------------------------------------------

def test_autotune_storage_axis_and_cache(tmp_path):
    dec = autotune("jacobi2d5p", (32, 32, 32), AXI_ZC706, budget=24,
                   storage="irredundant", cache_dir=tmp_path)
    assert dec.storage == "irredundant"
    best = dec.best_cfa()
    assert best.storage == "irredundant"
    assert best.footprint is not None and best.stored_elems is not None
    # cache round-trip preserves the storage axis
    again = autotune("jacobi2d5p", (32, 32, 32), AXI_ZC706, budget=24,
                     storage="irredundant", cache_dir=tmp_path)
    assert again.from_cache and again.storage == "irredundant"
    assert again.best_cfa() == best
    # a different storage mode is a different cache key
    red = autotune("jacobi2d5p", (32, 32, 32), AXI_ZC706, budget=24,
                   cache_dir=tmp_path)
    assert not red.from_cache and red.storage == "redundant"
    # JSON round-trip carries the v4 fields
    rt = LayoutDecision.from_json(dec.to_json())
    assert rt.storage == "irredundant" and rt.ranked[0] == dec.ranked[0]


def test_autotune_footprint_weight_trades_speed_for_size(tmp_path):
    fast = autotune("jacobi2d5p", (32, 32, 32), AXI_ZC706, budget=24,
                    storage="irredundant", cache_dir=tmp_path)
    small = autotune("jacobi2d5p", (32, 32, 32), AXI_ZC706, budget=24,
                     storage="irredundant", footprint_weight=1.0,
                     cache_dir=tmp_path)
    assert small.footprint_weight == 1.0
    assert small.best_cfa().footprint <= fast.best_cfa().footprint
    assert small.best_cfa().effective_bw <= fast.best_cfa().effective_bw


def test_autotune_storage_validation():
    with pytest.raises(ValueError, match="storage"):
        autotune("jacobi2d5p", (8, 8, 8), AXI_ZC706, storage="zip",
                 cache=False)
    with pytest.raises(ValueError, match="compressed"):
        autotune("jacobi2d5p", (8, 8, 8), AXI_ZC706, codec="deltapack8",
                 cache=False)


def test_cache_schema_v3_rejected():
    import json

    from repro.core.cfa.autotune import CacheSchemaError

    dec = autotune("jacobi2d5p", (8, 8, 8), AXI_ZC706, budget=8, cache=False)
    blob = json.loads(dec.to_json())
    blob["version"] = 3
    with pytest.raises(CacheSchemaError, match="v3"):
        LayoutDecision.from_json(json.dumps(blob))


# ---------------------------------------------------------------------------
# allocation: deduplicated pack/unpack + the w | t error-path satellite
# ---------------------------------------------------------------------------

def test_pack_unpack_with_storage_map():
    from repro.core.cfa import pack_all, unpack_into

    prog = get_program("jacobi2d5p")  # w = (1, 2, 2)
    space, tile = (8, 8, 8), (2, 4, 4)  # w | t on every axis
    specs = build_facet_specs(IterSpace(space), prog.deps, Tiling(tile))
    smap = build_storage_map(specs)
    rng = np.random.default_rng(0)
    V = jnp.asarray(rng.normal(size=space))
    facets = pack_all(V, specs, storage_map=smap)
    # dead slots are zeroed by the dedup-aware pack
    for k in specs:
        dead = ~np.broadcast_to(
            smap.owned[k], facets[k].shape)
        assert (np.asarray(facets[k])[dead] == 0).all()
    # masked unpack restores every facet-union point exactly once
    out = jnp.full(space, jnp.nan)
    for k, spec in specs.items():
        out = unpack_into(out, facets[k], spec, owned=smap.owned[k])
    mask = ~jnp.isnan(out)
    assert bool(mask.any())
    np.testing.assert_array_equal(np.asarray(out)[np.asarray(mask)],
                                  np.asarray(V)[np.asarray(mask)])
    # without the owned masks, the dead zeros would clobber owned values
    out2 = jnp.full(space, jnp.nan)
    for k, spec in specs.items():
        out2 = unpack_into(out2, facets[k], spec)
    assert not np.array_equal(np.asarray(out2)[np.asarray(mask)],
                              np.asarray(V)[np.asarray(mask)])


def test_pack_unpack_w_divides_t_error_paths():
    """Satellite: the documented ValueError comes from *every* public entry
    point, up front (not just the _modulo_perm internals mid-computation)."""
    from repro.core.cfa import pack_all, pack_facet, unpack_into

    prog = get_program("jacobi2d5p")  # w = (1, 2, 2)
    space, tile = (9, 9, 9), (3, 3, 3)  # w=2 does not divide t=3
    specs = build_facet_specs(IterSpace(space), prog.deps, Tiling(tile))
    V = jnp.zeros(space)
    with pytest.raises(ValueError, match="sweep executor"):
        pack_facet(V, specs[1])
    with pytest.raises(ValueError, match="sweep executor"):
        pack_all(V, specs)
    with pytest.raises(ValueError, match="sweep executor"):
        unpack_into(V, jnp.zeros(specs[2].shape), specs[2])


@pytest.mark.parametrize("storage", ["redundant", "irredundant"])
def test_tile_dependent_modulo_routes_to_sweep_executor(storage):
    """Regression: a w-does-not-divide-t layout is exactly the case the
    pack/unpack error message routes to the sweep executor — and that
    executor must actually handle it (tile-dependent modulo labelling),
    bit-exact against the oracle-scatter reference backend."""
    name, space, tile = "jacobi2d5p", (9, 9, 9), (3, 3, 3)
    x = _inputs(space, name)
    swp = cfa.compile(name, space, layout=tile, backend="sweep",
                      storage=storage)
    ref = swp.lower("reference")
    got, want = swp(x, dtype=jnp.float64), ref(x, dtype=jnp.float64)
    for k in want:
        assert (np.asarray(got[k]) == np.asarray(want[k])).all(), f"facet {k}"


# ---------------------------------------------------------------------------
# the facet_fetch read engine: owner-facet indirection
# ---------------------------------------------------------------------------

def test_facet_fetch_owner_indirection_bit_exact():
    from repro.kernels.facet_fetch import fetch_interior_halos

    name, space, tile = "jacobi2d5p", (8, 8, 8), (4, 4, 4)
    pipe = CFAPipeline(get_program(name), IterSpace(space), Tiling(tile))
    facets = pipe._sweep(_inputs(space, name), jnp.float32)
    smap = build_storage_map(pipe.specs)
    dd = dedup_facets(facets, smap)
    h_red = fetch_interior_halos(name, facets, space, tile)
    h_irr = fetch_interior_halos(name, dd, space, tile,
                                 storage="irredundant")
    assert (np.asarray(h_irr) == np.asarray(h_red)).all()
    # the indirection is load-bearing: the redundant fetch over deduplicated
    # arrays reads dead zeros
    h_wrong = fetch_interior_halos(name, dd, space, tile)
    assert not (np.asarray(h_wrong) == np.asarray(h_red)).all()


def test_facet_fetch_rejects_compressed():
    from repro.kernels.facet_fetch import fetch_interior_halos

    name, space, tile = "jacobi2d5p", (8, 8, 8), (4, 4, 4)
    pipe = CFAPipeline(get_program(name), IterSpace(space), Tiling(tile))
    facets = pipe.init_facets(jnp.float32)
    with pytest.raises(ValueError, match="decode"):
        fetch_interior_halos(name, facets, space, tile, storage="compressed")


@pytest.mark.parametrize("name,space,tile", [
    ("jacobi2d9p", (8, 8, 8), (4, 4, 4)),
    ("gaussian", (4, 16, 16), (2, 8, 8)),
])
def test_facet_fetch_owner_indirection_matches_copy_in(name, space, tile):
    """The irredundant kernel fetch equals the irredundant pipeline's own
    copy_in (the jnp owner-resolved gather) on interior tiles."""
    from repro.kernels.facet_fetch import fetch_interior_halos

    prog = get_program(name)
    red = CFAPipeline(prog, IterSpace(space), Tiling(tile))
    irr = IrredundantPipeline(prog, IterSpace(space), Tiling(tile))
    facets = red._sweep(_inputs(space, name), jnp.float32)
    dd = dedup_facets(facets, irr.storage_map)
    H = fetch_interior_halos(name, dd, space, tile, storage="irredundant")
    nt = red.num_tiles
    for q0 in range(1, nt[0]):
        for q1 in range(1, nt[1]):
            for q2 in range(1, nt[2]):
                want = irr.copy_in(dd, (q0, q1, q2))
                got = H[q0 - 1, q1 - 1, q2 - 1]
                assert (np.asarray(got) == np.asarray(want)).all(), (q0, q1, q2)


# ---------------------------------------------------------------------------
# pipelines: construction + payload structure
# ---------------------------------------------------------------------------

def test_storage_modes_constant():
    assert STORAGE_MODES == ("redundant", "irredundant", "compressed")
    assert CFAPipeline.storage == "redundant"
    assert IrredundantPipeline.storage == "irredundant"
    assert CompressedPipeline.storage == "compressed"


def test_compressed_pipeline_resolves_codec():
    prog = get_program("heat1d")
    p = CompressedPipeline(prog, IterSpace((8, 8)), Tiling((4, 4)))
    assert p.codec.name == "deltapack16"  # the default
    p2 = CompressedPipeline(prog, IterSpace((8, 8)), Tiling((4, 4)),
                            codec="raw")
    assert p2.codec.bits == 0


def test_rehydrate_with_virtual_row_untouched():
    """facet_0 (with its virtual live-in row) is fully owned: rehydration
    must never touch it, only refill other facets' dead slots."""
    name, space, tile = "heat1d", (8, 8), (4, 4)
    prog = get_program(name)
    irr = IrredundantPipeline(prog, IterSpace(space), Tiling(tile))
    facets = irr._sweep(_inputs(space, name), jnp.float64)
    rh = rehydrate_facets(facets, irr.storage_map)
    assert rh[0] is facets[0]  # fully owned -> passed through
    assert rh[1].shape == facets[1].shape
