"""Runtime burst telemetry (core/cfa/obs.py).

Spans from every executor, counters reconciling exactly against the plan
accounting, Chrome trace export + schema validation, the dataflow
backend's overlapped lanes, zero-overhead-off, and the measured-vs-
modeled RuntimeReport with its CFA3xx fixit vocabulary.
"""
import json
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro import cfa
from repro.core.cfa import AXI_ZC706, IterSpace, Tiling, get_program
from repro.core.cfa.obs import (
    Counters,
    RuntimeReport,
    Span,
    TraceRecorder,
    runtime_report,
    trace_enabled_by_env,
    validate_chrome_trace,
)
from repro.core.cfa.plans import original_layout_plan

SPACE, TILE = (8, 8, 8), (4, 4, 4)
N_TILES = 8  # (8/4)^3


def _inputs(space, name="jacobi2d5p", seed=0):
    w0 = get_program(name).widths[0]
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(w0, *space[1:])))


def _traced(backend, *, name="jacobi2d5p", space=SPACE, tile=TILE, **kw):
    c = cfa.compile(name, space, layout=tile, backend=backend, trace=True,
                    **kw)
    c(_inputs(space, name), dtype=jnp.float64)
    return c, c.last_trace()


# ---------------------------------------------------------------------------
# span emission per executor
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["sweep", "wavefront", "dataflow"])
def test_per_tile_spans(backend):
    _, rec = _traced(backend)
    assert len(rec.find("copy_in")) == N_TILES
    assert len(rec.find("copy_out")) == N_TILES
    assert len(rec.find("halo_resolve")) == N_TILES
    # every runtime span carries its tile's wave id
    waves = sorted({s.arg("wave") for s in rec.find("copy_in")})
    assert waves == [0, 1, 2, 3]


def test_sweep_executes_per_tile():
    _, rec = _traced("sweep")
    ex = rec.find("execute_tile")
    assert len(ex) == N_TILES
    assert rec.counters["waves"] == 4
    assert all(s.track == "port0/compute" for s in ex)


def test_wavefront_executes_per_wave():
    _, rec = _traced("wavefront")
    ex = rec.find("execute_wave")
    assert len(ex) == 4  # one batched span per wave
    assert [s.arg("n_tiles") for s in ex] == [1, 3, 3, 1]
    assert sum(s.arg("n_tiles") for s in ex) == N_TILES
    assert not rec.find("execute_tile")


def test_sharded_attributes_ports():
    pipe = cfa.compile("jacobi2d5p", SPACE, layout=TILE,
                       backend="sharded", n_ports=2, trace=True)
    pipe(_inputs(SPACE), dtype=jnp.float64)
    rec = pipe.last_trace()
    assert rec.reconcile(pipe.pipeline)["ok"]
    # the mesh folds ports onto however many devices exist (1 on a
    # laptop CPU), so derive the expected shard set from the trace itself
    waves = rec.find("execute_wave")
    assert len(waves) == 4
    n_shards = {s.arg("n_ports") for s in waves}.pop()
    ports = {s.arg("port") for s in rec.find("copy_in")}
    assert ports == set(range(n_shards))
    assert ({s.track for s in rec.find("copy_in")}
            == {f"port{p}/fetch" for p in range(n_shards)})


def test_halo_indirections_only_when_not_redundant():
    _, rec_red = _traced("sweep")
    assert rec_red.counters["halo_indirections"] == 0
    _, rec_irr = _traced("sweep", storage="irredundant")
    assert rec_irr.counters["halo_indirections"] > 0
    assert rec_irr.counters["halo_indirections"] <= rec_irr.counters["halo_points"]


# ---------------------------------------------------------------------------
# reconciliation (the acceptance criterion: exact, not approximate)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend,storage", [
    ("sweep", "redundant"),
    ("wavefront", "redundant"),
    ("dataflow", "redundant"),
    ("sweep", "irredundant"),
])
def test_reconcile_exact(backend, storage):
    c, rec = _traced(backend, storage=storage)
    r = rec.reconcile(c.pipeline)
    assert r["ok"], r["mismatches"]
    # counters' total wire bytes == BurstModel.plan_bytes over all tiles
    wire = rec.counters["wire_bytes_read"] + rec.counters["wire_bytes_write"]
    assert wire == r["expected"]["plan_bytes"]
    assert r["observed"]["tiles"] == N_TILES


def test_reconcile_catches_skipped_tile():
    c, rec = _traced("sweep")
    # forge a recorder that "missed" one tile's commit
    rec.counters.add("tiles", -1)
    rec.counters.add("bursts_write", -1)
    r = rec.reconcile(c.pipeline)
    assert not r["ok"]
    assert "tiles" in r["mismatches"] and "bursts_write" in r["mismatches"]


def test_reconcile_catches_missing_span():
    c, rec = _traced("sweep")
    victim = rec.find("copy_out")[0]
    rec.spans.remove(victim)
    r = rec.reconcile(c.pipeline)
    assert any(m.startswith("spans:copy_out@wave") for m in r["mismatches"])


# ---------------------------------------------------------------------------
# dataflow overlap (acceptance: prefetch/compute/commit as concurrent lanes)
# ---------------------------------------------------------------------------


def test_dataflow_overlapping_lanes():
    _, rec = _traced("dataflow")
    compute = rec.find("execute_tile")
    assert len(compute) == N_TILES
    fetch = rec.find("copy_in")
    commit = rec.find("copy_out")
    # lanes are distinct tracks
    assert {s.track for s in compute} == {"port0/compute"}
    assert {s.track for s in fetch} == {"port0/fetch"}
    assert {s.track for s in commit} == {"port0/commit"}

    def inside(inner, outer):
        return (outer.t0 <= inner.t0 and
                inner.t0 + inner.dur <= outer.t0 + outer.dur)

    # while tile j is in flight, j+1's prefetch and j-1's commit land
    # inside its compute span on their own lanes — the Fig. 13 overlap.
    # The pipeline drains at wave boundaries, so the structural floor is
    # (wave length - 1) overlapped neighbors per wave: 0+2+2+0 = 4 here.
    expected = sum(len(w) - 1
                   for w in cfa.compile("jacobi2d5p", SPACE, layout=TILE,
                                        backend="dataflow")
                   .pipeline.wavefronts())
    assert expected == 4
    fetched_inside = sum(
        any(inside(f, c) for c in compute) for f in fetch)
    committed_inside = sum(
        any(inside(w, c) for c in compute) for w in commit)
    assert fetched_inside >= expected
    assert committed_inside >= expected


def test_dataflow_matches_sweep_while_traced():
    """Tracing must not perturb results: dataflow traced == sweep untraced."""
    c_df = cfa.compile("jacobi2d5p", SPACE, layout=TILE, backend="dataflow",
                       trace=True)
    c_sw = cfa.compile("jacobi2d5p", SPACE, layout=TILE, backend="sweep")
    x = _inputs(SPACE)
    got = c_df(x, dtype=jnp.float64)
    want = c_sw(x, dtype=jnp.float64)
    for k in want:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(want[k]))


# ---------------------------------------------------------------------------
# zero overhead when off
# ---------------------------------------------------------------------------


def test_tracing_off_allocates_nothing():
    c = cfa.compile("jacobi2d5p", SPACE, layout=TILE, backend="sweep")
    assert not c.trace_enabled
    c(_inputs(SPACE))
    assert c.last_trace() is None
    assert c.pipeline.recorder is None


def test_per_call_trace_override():
    c = cfa.compile("jacobi2d5p", SPACE, layout=TILE, backend="sweep")
    c(_inputs(SPACE), trace=True)
    rec1 = c.last_trace()
    assert rec1 is not None and rec1.counters["tiles"] == N_TILES
    # trace=False leaves the previous recorder in place, records nothing new
    c(_inputs(SPACE), trace=False)
    assert c.last_trace() is rec1
    assert c.pipeline.recorder is None


# ---------------------------------------------------------------------------
# compile-span folding + env knobs
# ---------------------------------------------------------------------------


def test_pass_traces_fold_before_runtime():
    _, rec = _traced("sweep")
    passes = rec.find(cat="compile")
    assert {s.track for s in passes} == {"compile"}
    names = [s.name for s in passes]
    assert "pass:resolve_program" in names and "pass:lower_backend" in names
    # compile spans sit before the runtime epoch, runtime spans after
    assert all(s.t0 < 0 or math.isclose(s.t0 + s.dur, 0.0, abs_tol=1e-9)
               for s in passes)
    assert all(s.t0 >= 0 for s in rec.find(cat="runtime"))


def test_repro_trace_env_enables(monkeypatch):
    monkeypatch.setenv("REPRO_TRACE", "1")
    c = cfa.compile("jacobi2d5p", SPACE, layout=TILE, backend="sweep")
    assert c.trace_enabled
    monkeypatch.setenv("REPRO_TRACE", "0")
    assert not trace_enabled_by_env()
    # an explicit trace= beats the env
    c2 = cfa.compile("jacobi2d5p", SPACE, layout=TILE, backend="sweep",
                     trace=False)
    assert not c2.trace_enabled


def test_repro_trace_dir_autosaves(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TRACE_DIR", str(tmp_path))
    c = cfa.compile("jacobi2d5p", SPACE, layout=TILE, backend="sweep",
                    trace=True)
    c(_inputs(SPACE))
    files = list(tmp_path.glob("*.json"))
    assert len(files) == 1
    assert not validate_chrome_trace(json.loads(files[0].read_text()))


# ---------------------------------------------------------------------------
# chrome export + schema validation
# ---------------------------------------------------------------------------


def test_chrome_trace_valid_and_lanes_named():
    _, rec = _traced("dataflow")
    obj = rec.to_chrome()
    assert validate_chrome_trace(obj) == []
    names = {e["args"]["name"] for e in obj["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"compile", "port0/fetch", "port0/compute",
            "port0/commit"} <= names
    # counters travel with the trace
    assert obj["otherData"]["counters"]["tiles"] == N_TILES
    assert obj["otherData"]["backend"] == "dataflow"
    # timestamps are non-negative microseconds (compile spans shifted in)
    xs = [e for e in obj["traceEvents"] if e["ph"] == "X"]
    assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in xs)
    # round-trips through JSON text
    assert validate_chrome_trace(json.loads(json.dumps(obj))) == []


def test_validate_rejects_malformed():
    _, rec = _traced("sweep")
    good = rec.to_chrome()
    assert validate_chrome_trace({"traceEvents": []})
    bad_ph = json.loads(json.dumps(good))
    bad_ph["traceEvents"][-1]["ph"] = "Q"
    assert any("unknown ph" in p for p in validate_chrome_trace(bad_ph))
    orphan = json.loads(json.dumps(good))
    for e in orphan["traceEvents"]:
        if e["ph"] == "X":
            e["tid"] = 999
    assert any("thread_name" in p for p in validate_chrome_trace(orphan))
    no_counters = json.loads(json.dumps(good))
    del no_counters["otherData"]["counters"]
    assert any("counters" in p for p in validate_chrome_trace(no_counters))


def test_span_and_counters_validation():
    with pytest.raises(ValueError):
        Span(name="x", cat="nope", track="t", t0=0.0, dur=0.0, args=())
    with pytest.raises(ValueError):
        Span(name="x", cat="runtime", track="t", t0=0.0, dur=-1.0, args=())
    c = Counters()
    c.add("a", 2)
    c.add("a", 3)
    assert c["a"] == 5 and "a" in c and c.get("missing") == 0
    assert c.as_dict() == {"a": 5}


# ---------------------------------------------------------------------------
# measurement spans through the shared recorder
# ---------------------------------------------------------------------------


def test_measure_runs_emits_spans():
    from repro.core.cfa.calibrate import measure_runs

    rec = TraceRecorder(model=AXI_ZC706, label="measure-test")
    t = measure_runs((64, 64), 8, warmup=0, repeats=3, recorder=rec,
                     label="grid")
    assert t > 0.0
    passes = rec.find("measure_pass", cat="measure")
    assert len(passes) == 3
    assert {s.track for s in passes} == {"measure/grid"}
    summary, = rec.find("measure", cat="measure")
    assert summary.arg("median_s") == t
    assert rec.counters["measure_passes"] == 3
    assert rec.counters["measure_schedules"] == 1
    assert validate_chrome_trace(rec.to_chrome()) == []


# ---------------------------------------------------------------------------
# measured-vs-modeled attribution
# ---------------------------------------------------------------------------


def test_runtime_report_original_baseline_fixit(monkeypatch):
    """Acceptance: the burst-hostile original layout ranks >= 1 deviation
    with a fixit hint (contiguity — its runs sit below the burst knee)."""
    monkeypatch.setenv("REPRO_MEASURE_WARMUP", "0")
    monkeypatch.setenv("REPRO_MEASURE_REPEATS", "1")
    prog = get_program("jacobi2d5p")
    plan = original_layout_plan(IterSpace(SPACE), prog.deps, Tiling(TILE))
    rep = runtime_report(plan, AXI_ZC706)
    assert isinstance(rep, RuntimeReport) and rep.rows
    assert rep.worst.fixit == "contiguity"
    assert rep.worst.observed_s > 0 and rep.worst.modeled_s > 0
    assert "fixit" in rep.summary()
    d = rep.to_dict()
    assert d["rows"][0]["fixit"] == "contiguity"


def test_runtime_report_facet_rows(monkeypatch):
    monkeypatch.setenv("REPRO_MEASURE_WARMUP", "0")
    monkeypatch.setenv("REPRO_MEASURE_REPEATS", "1")
    c = cfa.compile("jacobi2d5p", SPACE, layout=TILE, backend="sweep")
    rec = TraceRecorder(model=AXI_ZC706)
    rep = c.runtime_report(recorder=rec)
    keys = [r.key for r in rep.rows]
    assert any(k.startswith("plan:") for k in keys)
    assert any(k.startswith("facet:") for k in keys)
    # rows rank worst deviation first
    devs = [abs(r.deviation) for r in rep.rows]
    assert devs == sorted(devs, reverse=True)
    # the samples were routed through the shared recorder
    assert rec.find("measure_pass", cat="measure")
