"""Multi-port repartition (§VII): scheduling quality, model monotonicity,
the paper-facing speedup claims, the port-aware autotune stage, and the
sharded wavefront executor's exactness against the single-port oracle."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.cfa import (
    AXI_ZC706,
    TPU_V5E_HBM,
    CFAPipeline,
    Deps,
    IterSpace,
    PROGRAMS,
    PortedPlan,
    Tiling,
    assign_ports,
    autotune,
    best_repartition,
    cfa_plan,
    get_program,
    original_layout_plan,
    port_speedup,
    repartition,
)
from repro.core.cfa.autotune import LayoutDecision


def _default_setup(name):
    prog = get_program(name)
    tiling = Tiling(prog.default_tile)
    space = IterSpace(tuple(3 * t for t in prog.default_tile))
    return prog, space, tiling


# ---------------------------------------------------------------------------
# scheduling quality: LPT vs round-robin, balance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(PROGRAMS))
@pytest.mark.parametrize("n_ports", [2, 3])
def test_facet_lpt_never_worse_than_round_robin(name, n_ports):
    prog, space, tiling = _default_setup(name)
    plan = cfa_plan(space, prog.deps, tiling)
    t_lpt = AXI_ZC706.time(repartition(plan, n_ports, "facet-lpt", model=AXI_ZC706))
    t_rr = AXI_ZC706.time(repartition(plan, n_ports, "facet-rr", model=AXI_ZC706))
    assert t_lpt <= t_rr + 1e-15


def test_balance_is_one_on_symmetric_facet_traffic():
    """A fully symmetric dependence pattern on a cubic tiling gives every
    facet identical traffic, so the 3-facet/3-port LPT split is perfect.
    (Axis-aligned deps: no multi-axis crossings, whose corner points must be
    hosted by a single facet and would skew the loads by one element.)"""
    deps = Deps(((-1, 0, 0), (0, -1, 0), (0, 0, -1)))  # w = (1, 1, 1)
    space, tiling = IterSpace((32, 32, 32)), Tiling((8, 8, 8))
    pa = assign_ports(space, deps, tiling, 3)
    assert pa.balance == pytest.approx(1.0)
    assert sorted(pa.facet_to_port.values()) == [0, 1, 2]  # one facet per port


def test_assign_ports_is_lpt_on_facet_traffic():
    from repro.core.cfa.multiport import _facet_traffic

    prog, space, tiling = _default_setup("jacobi2d5p")
    pa = assign_ports(space, prog.deps, tiling, 2)
    assert pa.n_ports == 2 and set(pa.facet_to_port) == set(range(3))
    traffic = _facet_traffic(space, prog.deps, tiling)
    # nothing lost, and the LPT makespan beats (or ties) round-robin's
    assert sum(pa.port_bytes) == pytest.approx(sum(traffic.values()))
    rr_loads = [0.0, 0.0]
    for i, k in enumerate(sorted(traffic)):
        rr_loads[i % 2] += traffic[k]
    assert max(pa.port_bytes) <= max(rr_loads) + 1e-12
    # and it genuinely split the facets (not everything on one port)
    assert max(pa.port_bytes) < sum(traffic.values())


# ---------------------------------------------------------------------------
# repartition invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ["facet-lpt", "facet-rr", "burst-lpt", "stripe"])
def test_repartition_conserves_traffic(strategy):
    prog, space, tiling = _default_setup("jacobi2d9p")
    plan = cfa_plan(space, prog.deps, tiling)
    pp = repartition(plan, 4, strategy, model=AXI_ZC706)
    assert isinstance(pp, PortedPlan) and pp.n_ports == 4
    assert pp.transferred == plan.transferred  # no element lost or duplicated
    assert pp.useful == plan.useful
    if strategy != "stripe":  # stripe splits runs; the others move them whole
        got = sorted(sum(pp.read_runs_by_port, ()) + sum(pp.write_runs_by_port, ()))
        want = sorted(plan.read_runs + plan.write_runs)
        assert got == want


def test_facet_strategy_requires_attribution():
    prog, space, tiling = _default_setup("jacobi2d5p")
    plan = original_layout_plan(space, prog.deps, tiling)  # no facet hosts
    with pytest.raises(ValueError, match="attribution"):
        repartition(plan, 2, "facet-lpt")
    # burst-granular strategies still apply, so best_repartition succeeds
    pp = best_repartition(plan, 2, AXI_ZC706)
    assert AXI_ZC706.time(pp) <= AXI_ZC706.time(plan) + 1e-15
    # facet-only strategies on an attribution-less plan degrade to the
    # trivial single-port schedule instead of aborting the search
    fb = best_repartition(plan, 2, AXI_ZC706, strategies=("facet-lpt", "facet-rr"))
    assert fb.strategy == "single-port" and fb.n_ports == 2
    assert AXI_ZC706.time(fb) == pytest.approx(AXI_ZC706.time(plan))


def test_autotune_with_facet_only_strategies_completes(tmp_path):
    """n_ports > 1 with facet-granular strategies only must not abort on the
    single-array baseline seeds (they carry no facet attribution)."""
    dec = autotune("jacobi2d5p", (48, 48, 48), AXI_ZC706, budget=12,
                   n_ports=2, port_strategies=("facet-lpt", "facet-rr"),
                   cache_dir=tmp_path)
    assert dec.n_ports == 2 and dec.evaluated > 0
    baselines = [s for s in dec.ranked if s.candidate.scheme != "cfa"]
    assert baselines and all(s.port_strategy == "single-port" for s in baselines)


def test_balance_ignores_idle_padded_ports():
    """A repartition that uses fewer ports than available reports the
    balance of the ports it actually loads, not of the idle padding."""
    prog, space, tiling = _default_setup("jacobi2d5p")
    plan = cfa_plan(space, prog.deps, tiling)
    pp = best_repartition(plan, 8, AXI_ZC706, strategies=("facet-lpt",))
    loaded = [l for l in pp.port_elems if l > 0]
    assert len(loaded) <= 3  # only 3 facets exist
    assert pp.balance == pytest.approx(max(loaded) / (sum(loaded) / len(loaded)))


def test_ported_plan_rejects_ragged_port_schedules():
    """Regression: a read/write port-list length mismatch used to be
    silently truncated by the unstrict zip in ``BurstModel.time``, dropping
    ports from the max and under-reporting transfer time.  Construction now
    validates, and the zips are strict."""
    kw = dict(scheme="cfa", n_ports=2, strategy="facet-lpt",
              read_useful=4, write_useful=4)
    with pytest.raises(ValueError, match="read_runs_by_port"):
        PortedPlan(read_runs_by_port=((4,),),  # 1 entry, n_ports=2
                   write_runs_by_port=((4,), (4,)), **kw)
    with pytest.raises(ValueError, match="write_runs_by_port"):
        PortedPlan(read_runs_by_port=((4,), (4,)),
                   write_runs_by_port=((4,), (4,), (4,)), **kw)
    # even a plan corrupted after construction (bypassing __post_init__)
    # must fail loudly in the model, not drop the trailing port
    pp = PortedPlan(read_runs_by_port=((8,), (2,)),
                    write_runs_by_port=((1,), (16,)), **kw)
    object.__setattr__(pp, "read_runs_by_port", ((8,),))
    with pytest.raises(ValueError):
        AXI_ZC706.time(pp)
    with pytest.raises(ValueError):
        pp.port_elems


def test_ported_time_is_max_over_ports():
    prog, space, tiling = _default_setup("jacobi2d5p")
    plan = cfa_plan(space, prog.deps, tiling)
    pp = repartition(plan, 3, "facet-lpt", model=AXI_ZC706)
    per_port = [
        AXI_ZC706.time_s(rr) + AXI_ZC706.time_s(wr)
        for rr, wr in zip(pp.read_runs_by_port, pp.write_runs_by_port)
    ]
    assert AXI_ZC706.time(pp) == pytest.approx(max(per_port))


# ---------------------------------------------------------------------------
# speedup: monotone in n_ports + the §VII headline numbers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", [AXI_ZC706, TPU_V5E_HBM], ids=lambda m: m.name)
def test_port_speedup_monotone_in_n_ports(model):
    prog, space, tiling = _default_setup("jacobi2d5p")
    speedups = [
        port_speedup(space, prog.deps, tiling, n, model)["speedup"]
        for n in range(1, 9)
    ]
    assert speedups[0] == pytest.approx(1.0)
    for a, b in zip(speedups, speedups[1:]):
        assert b >= a - 1e-12, speedups


def test_jacobi2d5p_axi_headline_speedups():
    """The acceptance numbers the benchmark reports (interior-tile plan at
    the default tile under AXI_ZC706): >= 1.7x @ 2 ports, >= 3x @ 4."""
    prog, space, tiling = _default_setup("jacobi2d5p")
    r2 = port_speedup(space, prog.deps, tiling, 2, AXI_ZC706)
    r4 = port_speedup(space, prog.deps, tiling, 4, AXI_ZC706)
    assert r2["speedup"] >= 1.7, r2
    assert r4["speedup"] >= 3.0, r4


# ---------------------------------------------------------------------------
# port-aware autotune stage
# ---------------------------------------------------------------------------

def test_autotune_ports_beats_single_port(tmp_path):
    dec1 = autotune("jacobi2d5p", (64, 64, 64), AXI_ZC706, budget=24,
                    cache_dir=tmp_path)
    dec4 = autotune("jacobi2d5p", (64, 64, 64), AXI_ZC706, budget=24,
                    n_ports=4, cache_dir=tmp_path)
    assert dec1.n_ports == 1 and dec4.n_ports == 4
    assert dec4.best.n_ports == 4 and dec4.best.port_strategy is not None
    assert dec4.best.port_speedup_vs_single >= 1.0
    # co-tuned 4-port effective bandwidth dominates the single-port winner
    assert dec4.best.effective_bw >= dec1.best.effective_bw - 1e-9


def test_autotune_ports_cache_round_trip(tmp_path):
    dec = autotune("jacobi2d9p", (48, 48, 48), AXI_ZC706, budget=16,
                   n_ports=2, cache_dir=tmp_path)
    rt = LayoutDecision.from_json(dec.to_json())
    assert rt.n_ports == dec.n_ports and rt.ranked == dec.ranked
    hit = autotune("jacobi2d9p", (48, 48, 48), AXI_ZC706, budget=16,
                   n_ports=2, cache_dir=tmp_path)
    assert hit.from_cache and hit.ranked == dec.ranked
    # a different port count is a different cache entry, not a stale hit
    other = autotune("jacobi2d9p", (48, 48, 48), AXI_ZC706, budget=16,
                     n_ports=4, cache_dir=tmp_path)
    assert not other.from_cache and other.n_ports == 4


# ---------------------------------------------------------------------------
# sharded wavefront executor == single-port oracle (acceptance criterion)
# ---------------------------------------------------------------------------

def test_sweep_wavefront_sharded_smoke():
    """Fast tier-1 representative of the sharded executor: small problem,
    waves of uneven size (so the padding path runs).  The full program
    matrix below is `slow` and runs on the CI slow leg."""
    prog = get_program("jacobi2d5p")
    pipe = CFAPipeline(prog, IterSpace((4, 4, 4)), Tiling((4, 2, 2)))
    rng = np.random.default_rng(0)
    inputs = jnp.asarray(rng.normal(size=(1, 4, 4)))
    ref = pipe._sweep(inputs, dtype=jnp.float64)
    got = pipe._sweep_wavefront_sharded(inputs, dtype=jnp.float64, n_ports=2)
    for k in ref:
        assert (np.asarray(ref[k]) == np.asarray(got[k])).all(), f"facet {k}"


@pytest.mark.slow
@pytest.mark.parametrize(
    "name,space,tile",
    [
        ("jacobi2d5p", (8, 8, 8), (4, 4, 4)),
        ("jacobi2d9p", (8, 8, 8), (4, 4, 4)),
        ("jacobi2d9p-gol", (8, 8, 8), (4, 4, 4)),
        ("gaussian", (4, 16, 16), (2, 8, 8)),
        ("smith-waterman-3seq", (9, 8, 8), (3, 4, 4)),
        ("heat1d", (12, 12), (4, 4)),
        ("heat3d", (4, 4, 4, 4), (2, 2, 2, 2)),
    ],
)
def test_sweep_wavefront_sharded_bit_exact(name, space, tile):
    """Every program (Table I + the N-D additions): the multi-port
    executor's facet storage is bit-identical to the single-port ``sweep``'s."""
    prog = get_program(name)
    pipe = CFAPipeline(prog, IterSpace(space), Tiling(tile))
    w0 = pipe.specs[0].width
    rng = np.random.default_rng(0)
    inputs = jnp.asarray(rng.normal(size=(w0, *space[1:])))
    ref = pipe._sweep(inputs, dtype=jnp.float64)
    got = pipe._sweep_wavefront_sharded(inputs, dtype=jnp.float64, n_ports=2)
    for k in ref:
        assert (np.asarray(ref[k]) == np.asarray(got[k])).all(), f"facet {k}"


@pytest.mark.slow
def test_sweep_wavefront_sharded_pads_odd_waves():
    """3 ports over waves whose sizes are not multiples of 3 (padding path)."""
    prog = get_program("jacobi2d5p")
    pipe = CFAPipeline(prog, IterSpace((8, 8, 8)), Tiling((4, 4, 4)))
    rng = np.random.default_rng(1)
    inputs = jnp.asarray(rng.normal(size=(1, 8, 8)))
    ref = pipe._sweep(inputs, dtype=jnp.float64)
    got = pipe._sweep_wavefront_sharded(inputs, dtype=jnp.float64, n_ports=3)
    for k in ref:
        assert (np.asarray(ref[k]) == np.asarray(got[k])).all()


def test_sweep_wavefront_sharded_kernel_path():
    """The Pallas executor path matches to interpreter-rounding tolerance
    (same tolerance class as the existing ``sweep_wavefront(use_kernel)``)."""
    prog = get_program("jacobi2d5p")
    pipe = CFAPipeline(prog, IterSpace((8, 8, 8)), Tiling((4, 4, 4)))
    rng = np.random.default_rng(2)
    inputs = jnp.asarray(rng.normal(size=(1, 8, 8)))
    ref = pipe._sweep(inputs, dtype=jnp.float64)
    got = pipe._sweep_wavefront_sharded(inputs, dtype=jnp.float64, n_ports=2,
                                       use_kernel=True)
    for k in ref:
        np.testing.assert_allclose(np.asarray(ref[k]), np.asarray(got[k]),
                                   rtol=1e-12, atol=1e-12)


def test_sharded_fetch_matches_plain_fetch():
    """Port-resident facets feed the fetch kernel unchanged (placement moves
    the DMAs to the owning port; the gathered halos are identical)."""
    from repro.kernels.facet_fetch import (fetch_interior_halos,
                                           fetch_interior_halos_sharded)

    prog = get_program("jacobi2d5p")
    space, tile = (12, 12, 12), (4, 4, 4)
    pipe = CFAPipeline(prog, IterSpace(space), Tiling(tile))
    rng = np.random.default_rng(3)
    inputs = jnp.asarray(rng.normal(size=(1, 12, 12)))
    facets = pipe._sweep(inputs, dtype=jnp.float64)
    pa = assign_ports(IterSpace(space), prog.deps, Tiling(tile), 2)
    plain = fetch_interior_halos("jacobi2d5p", facets, space, tile)
    sharded = fetch_interior_halos_sharded("jacobi2d5p", facets, space, tile, pa)
    assert (np.asarray(plain) == np.asarray(sharded)).all()
