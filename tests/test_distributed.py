"""Distributed features: sharding rules, compression, pipeline parallelism.

Multi-device behaviour is verified in subprocesses with forced host devices
(the main test process must keep the single real CPU device).

(The hypothesis-based property tests live in
``test_distributed_properties.py`` so this module collects without the
optional ``hypothesis`` extra.)
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.distributed.compression import (dequantize_int8, ef_compress,
                                           ef_init, quantize_int8)
from repro.distributed.sharding import P, sanitize_spec

REPO = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# sharding rule fallbacks
# ---------------------------------------------------------------------------

class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_sanitize_drops_non_dividing_axes():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # batch=1 cannot shard over data -> replicated
    assert sanitize_spec(P(("pod", "data"), None), (1, 128), mesh) == P(None, None)
    # 'pod' absent on single-pod mesh -> silently dropped
    assert sanitize_spec(P(("pod", "data"), None), (32, 128), mesh) == P("data", None)
    # divisible dims keep their axes, missing trailing dims pad with None
    assert sanitize_spec(P("model"), (32, 64, 7), mesh) == P("model", None, None)
    assert sanitize_spec(P(None, "model"), (3, 48), mesh) == P(None, "model")


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_error_feedback_recovers_gradient_sum():
    """Sum of compressed grads -> sum of true grads (EF property)."""
    rng = np.random.default_rng(0)
    grads = [jnp.asarray(rng.normal(size=(32,)), jnp.float32) for _ in range(50)]
    state = ef_init(grads[0])
    total_true = sum(np.asarray(g) for g in grads)
    total_comp = np.zeros(32)
    for g in grads:
        cg, state = ef_compress(g, state)
        total_comp += np.asarray(cg)
    resid = np.abs(total_comp + np.asarray(state) - total_true).max()
    assert resid < 1e-3  # compressed + carried error == exact sum


def test_compression_payload_is_4x_smaller():
    x = jnp.zeros((1024,), jnp.float32)
    q, s = quantize_int8(x)
    assert q.dtype == jnp.int8 and q.nbytes * 4 == x.nbytes


# ---------------------------------------------------------------------------
# pipeline parallelism (subprocess: forced host devices)
# ---------------------------------------------------------------------------

_PIPE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.pipeline import pipeline_apply
    mesh = jax.make_mesh((4,), ("pipe",))
    S, M, B, D = 4, 8, 2, 16
    key = jax.random.PRNGKey(0)
    W = jax.random.normal(key, (S, D, D)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (M, B, D))
    stage = lambda w, h: jnp.tanh(h @ w)
    got = pipeline_apply(stage, W, x, mesh)
    want = x
    for s in range(S):
        want = jnp.tanh(want @ W[s])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
    print("PIPE_OK")
""")

_SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_smoke_config
    from repro.distributed.sharding import use_mesh, sanitize_tree
    from repro.models.lm import init_lm, spec_lm
    from repro.optim import make_optimizer, opt_state_specs
    from repro.train.steps import TrainHParams, make_train_step

    cfg = dataclasses.replace(get_smoke_config("qwen3-0.6b"), n_layers=2,
                              compute_dtype="float32")
    hp = TrainHParams(remat=False, warmup=1)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    opt_init, _ = make_optimizer(cfg.optimizer)
    opt = opt_init(params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
    batch = {"tokens": tokens}

    # single-device reference
    p1, o1, m1 = jax.jit(make_train_step(cfg, hp))(params, opt, batch)

    # 4x2 (data x model) SPMD
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    pspec = spec_lm(cfg)
    psh = sanitize_tree(pspec, params, mesh)
    osh = sanitize_tree(opt_state_specs(pspec, params, cfg.optimizer), opt, mesh)
    from jax.sharding import NamedSharding, PartitionSpec as P
    bsh = {"tokens": NamedSharding(mesh, P("data", None))}
    with use_mesh(mesh):
        step = jax.jit(make_train_step(cfg, hp), in_shardings=(psh, osh, bsh),
                       out_shardings=(psh, osh, None))
        p2, o2, m2 = step(jax.device_put(params, psh), jax.device_put(opt, osh),
                          jax.device_put(batch, bsh))
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=2e-3, atol=3e-4)
    print("SPMD_OK")
""")


def _run_sub(script: str, marker: str):
    from conftest import multidevice_emulation_reason

    reason = multidevice_emulation_reason()
    if reason is not None:
        pytest.skip(f"multi-device emulation unavailable: {reason}")
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, timeout=600)
    assert marker in res.stdout, f"stdout={res.stdout}\nstderr={res.stderr[-3000:]}"


def test_pipeline_parallel_four_stages_subprocess():
    _run_sub(_PIPE_SCRIPT, "PIPE_OK")


def test_spmd_train_step_matches_single_device_subprocess():
    """FSDP+TP sharded train step == single-device train step (f32)."""
    _run_sub(_SPMD_SCRIPT, "SPMD_OK")
