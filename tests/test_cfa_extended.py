"""Extended CFA coverage: 1-D/2-D/4-D spaces, §J (non-mergeable k-th-level
neighbours), bandwidth model properties, and analyzer sanity.

(The hypothesis-based property tests live in ``test_cfa_properties.py`` so
this module collects without the optional ``hypothesis`` extra.)
"""
import numpy as np
import pytest

from repro.core.cfa import (
    AXI_ZC706,
    BandwidthReport,
    Deps,
    IterSpace,
    Tiling,
    build_facet_specs,
    cfa_plan,
    count_runs,
    facet_widths,
    flow_in_points,
    original_layout_plan,
)


def test_1d_cfa_single_burst():
    space, deps, tiling = IterSpace((32,)), Deps(((-2,),)), Tiling((8,))
    plan = cfa_plan(space, deps, tiling, (2,))
    assert plan.n_read_bursts == 1
    assert plan.n_write_bursts == 1
    assert plan.read_useful == 2  # w = 2


def test_2d_cfa_two_read_bursts():
    """d=2: corner merges into the extension read -> 2 bursts total."""
    space = IterSpace((32, 32))
    deps = Deps(((-1, 0), (0, -1), (-1, -1)))
    tiling = Tiling((8, 8))
    plan = cfa_plan(space, deps, tiling, (1, 1))
    assert plan.n_read_bursts == 2, plan.read_runs
    assert plan.n_write_bursts == 2


def test_4d_cfa_counts_extra_bursts_not_crash():
    """Paper §J: in d >= 4 some k-th-level neighbours cannot merge; the
    planner must still cover every flow-in point, with a few more bursts."""
    space = IterSpace((8, 8, 8, 8))
    deps = Deps(((-1, -1, -1, -1), (-1, 0, 0, 0), (0, 0, -1, -1)))
    tiling = Tiling((4, 4, 4, 4))
    plan = cfa_plan(space, deps, tiling, (1, 1, 1, 1))
    assert plan.n_write_bursts == 4  # one per facet
    assert 4 <= plan.n_read_bursts <= 16  # d reads + non-mergeable corners
    orig = original_layout_plan(space, deps, tiling, (1, 1, 1, 1))
    assert plan.n_read_bursts < orig.n_read_bursts


def test_bandwidth_monotonic_in_burst_length():
    """Same bytes in fewer/longer bursts is never slower."""
    short = AXI_ZC706.time_s(tuple([16] * 64))
    long_ = AXI_ZC706.time_s((1024,))
    assert long_ < short


def test_count_runs_exact():
    assert count_runs(np.array([5, 6, 7, 10, 11, 20])) == (3, 2, 1)
    assert count_runs(np.array([], dtype=np.int64)) == ()
    assert count_runs(np.array([3, 3, 4])) == (2,)  # dedup


def test_flow_in_boundary_tiles_partial_facets():
    """Boundary tiles have truncated flow-in; plans must not crash or
    over-read outside the space."""
    from repro.core.cfa import get_program

    prog = get_program("jacobi2d5p")
    space, tiling = IterSpace((8, 8, 8)), Tiling((4, 4, 4))
    for tile in [(0, 0, 0), (0, 1, 1), (1, 0, 1)]:
        plan = cfa_plan(space, prog.deps, tiling, tile)
        fin = flow_in_points(space, prog.deps, tiling, tile)
        assert plan.read_useful == len(fin)


def test_hlo_analyzer_on_synthetic_module():
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks.hlo_analysis import analyze_hlo

    hlo = """\
HloModule test

%body.1 (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,128] get-tuple-element(%p), index=1
  %d = f32[8,128] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,128] all-reduce(%d), replica_groups={}
  ROOT %t = (s32[], f32[8,128]) tuple(%i, %ar)
}

%cond.1 (p: (s32[], f32[8,128])) -> pred[] {
  %p = (s32[], f32[8,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[8,128]) -> f32[8,128] {
  %a = f32[8,128] parameter(0)
  %init = (s32[], f32[8,128]) tuple(%zero, %a)
  %w = (s32[], f32[8,128]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[8,128] get-tuple-element(%w), index=1
}
"""
    s = analyze_hlo(hlo)
    # 12 trips x one AR of 8*128*4 bytes
    assert s.collective_bytes["all-reduce"] == 12 * 8 * 128 * 4
    assert s.collective_counts["all-reduce"] == 12
    assert s.while_trips.get("body.1") == 12


# ---------------------------------------------------------------------------
# wavefront-parallel sweep + multi-port distribution (paper §VII future work)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,space,tile,kernel", [
    ("jacobi2d5p", (8, 8, 8), (4, 4, 4), False),
    ("jacobi2d5p", (8, 8, 8), (4, 4, 4), True),
    ("smith-waterman-3seq", (6, 8, 8), (3, 4, 4), False),
])
def test_wavefront_sweep_matches_sequential(name, space, tile, kernel):
    import jax.numpy as jnp
    from repro.core.cfa import CFAPipeline, get_program

    prog = get_program(name)
    pipe = CFAPipeline(prog, IterSpace(space), Tiling(tile))
    rng = np.random.default_rng(0)
    inputs = jnp.asarray(rng.normal(size=(pipe.specs[0].width, *space[1:])),
                         jnp.float32)
    seq = pipe._sweep(inputs)
    wav = pipe._sweep_wavefront(inputs, use_kernel=kernel)
    for k in pipe.specs:
        np.testing.assert_allclose(np.asarray(seq[k]), np.asarray(wav[k]),
                                   rtol=1e-5, atol=1e-5)


def test_wavefront_independence():
    """Tiles in one wave must not depend on each other."""
    from repro.core.cfa import CFAPipeline, get_program

    prog = get_program("jacobi2d9p")
    pipe = CFAPipeline(prog, IterSpace((12, 12, 12)), Tiling((4, 4, 4)))
    for wave in pipe.wavefronts():
        sums = {sum(t) for t in wave}
        assert len(sums) == 1
    total = sum(len(w) for w in pipe.wavefronts())
    assert total == 27


def test_multiport_balance_and_speedup():
    from repro.core.cfa import AXI_ZC706, get_program
    from repro.core.cfa.multiport import assign_ports, port_speedup

    prog = get_program("jacobi2d5p")
    space, tiling = IterSpace((64, 64, 64)), Tiling((16, 16, 16))
    pa = assign_ports(space, prog.deps, tiling, 3)
    assert set(pa.facet_to_port) == {0, 1, 2}  # every facet assigned
    assert pa.balance < 2.0
    r1 = port_speedup(space, prog.deps, tiling, 1, AXI_ZC706)
    r3 = port_speedup(space, prog.deps, tiling, 3, AXI_ZC706)
    assert r1["speedup"] == pytest.approx(1.0, abs=1e-9)
    assert r3["speedup"] > 1.5  # three facets -> near-3x at balance ~1
