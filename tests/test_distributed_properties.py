"""Property tests for the distributed features (sharding sanitiser, int8
gradient compression).

Requires the optional ``hypothesis`` test extra; the module is skipped when
it is absent so tier-1 collection never breaks on a minimal install.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")

import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.distributed.compression import dequantize_int8, quantize_int8
from repro.distributed.sharding import P, sanitize_spec


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


@given(
    dims=st.lists(st.integers(1, 64), min_size=1, max_size=4),
    axes=st.lists(st.sampled_from([None, "data", "model", ("pod", "data")]),
                  min_size=1, max_size=4),
)
@settings(max_examples=100, deadline=None)
def test_sanitize_never_produces_invalid_spec(dims, axes):
    mesh = _FakeMesh({"data": 4, "model": 2})
    spec = sanitize_spec(P(*axes[: len(dims)]), tuple(dims), mesh)
    for size, ax in zip(dims, list(spec)):
        if ax is None:
            continue
        n = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            assert a in mesh.shape
            n *= mesh.shape[a]
        assert size % n == 0


@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-3, 1e3))
@settings(max_examples=40, deadline=None)
def test_int8_quantization_error_bound(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64,)) * scale, jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6  # half-ulp rounding bound
