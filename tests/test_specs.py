"""Cell/spec construction: input_specs shapes, applicability rules, and a
full lower+compile of one smoke cell on a forced-device mesh (subprocess)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.launch.specs import SHAPE_CELLS, cell_applicable, input_specs

REPO = Path(__file__).resolve().parents[1]


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("cell", list(SHAPE_CELLS))
def test_input_specs_shapes(arch, cell):
    cfg = get_config(arch)
    ok, why = cell_applicable(cfg, cell)
    if not ok:
        assert cell == "long_500k" and not cfg.supports_long_context
        assert why
        return
    ins = input_specs(cfg, cell)
    info = SHAPE_CELLS[cell]
    if info["kind"] == "train":
        assert ins["tokens"].shape == (info["batch"], info["seq"])
        if cfg.family in ("vlm", "encdec"):
            assert "context" in ins
            assert ins["context"].shape[0] == info["batch"]
            assert ins["context"].shape[2] == cfg.d_model
    elif info["kind"] == "prefill":
        assert ins["tokens"].shape[0] == info["batch"]
        if cfg.is_encdec:
            assert ins["context"].shape[1] == info["seq"]  # frames carry seq
            assert ins["tokens"].shape[1] == max(info["seq"] // 8, 128)
        else:
            assert ins["tokens"].shape[1] == info["seq"]
    else:
        assert ins["token"].shape == (info["batch"],)
        assert ins["position"].shape == ()


def test_long_500k_applicability_matches_design():
    eligible = {a for a in ARCH_NAMES if cell_applicable(get_config(a), "long_500k")[0]}
    assert eligible == {"mamba2-370m", "jamba-1.5-large-398b"}


def test_every_cell_count_is_40():
    cells = 0
    for a in ARCH_NAMES:
        for c in SHAPE_CELLS:
            cells += 1
    assert cells == 40


_CELL_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, jax
    from repro.configs import get_smoke_config
    from repro.distributed.sharding import use_mesh
    from repro.launch.specs import build_cell, policy_for, SHAPE_CELLS
    import repro.launch.specs as S

    # shrink the cells so smoke configs lower quickly
    S.SHAPE_CELLS = {
        "train_4k": dict(seq=64, batch=8, kind="train"),
        "decode_32k": dict(seq=64, batch=8, kind="decode"),
    }
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    for arch in ("qwen3-0.6b", "jamba-1.5-large-398b"):
        cfg = get_smoke_config(arch)
        for cell in ("train_4k", "decode_32k"):
            with use_mesh(mesh, **policy_for(cfg, cell)):
                c = build_cell(cfg, cell, mesh)
                jax.jit(c.step, in_shardings=c.in_shardings,
                        out_shardings=c.out_shardings).lower(*c.args).compile()
            print(f"CELL_OK {arch} {cell}")
""")


@pytest.mark.slow
def test_build_cell_compiles_on_small_mesh_subprocess():
    from conftest import multidevice_emulation_reason

    reason = multidevice_emulation_reason()
    if reason is not None:
        pytest.skip(f"multi-device emulation unavailable: {reason}")
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    res = subprocess.run([sys.executable, "-c", _CELL_SCRIPT],
                         capture_output=True, text=True, env=env, timeout=900)
    assert res.stdout.count("CELL_OK") == 4, (
        f"stdout={res.stdout}\nstderr={res.stderr[-3000:]}")
