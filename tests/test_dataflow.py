"""``backend="dataflow"``: overlapped fetch/compute/commit, proven harmless.

Differential harness for the software-pipelined executor (Fig. 13 DATAFLOW
made a schedule): every Table I program (plus the 2-D/4-D additions) run
through ``backend="dataflow"`` must land the *exact* facet storage the
sequential ``sweep`` backend lands, on every storage discipline —
prefetching tile j+1 and deferring tile j-1's commit while j executes is a
pure reordering, because all halo reads come from strictly earlier waves.

The host path is pinned bit-exact (``==``, facet for facet); the kernel
path (``use_kernel=True``, the jitted Pallas tile executor) is allowed
float-rounding differences only — the same convention ``test_api.py`` uses
for the pallas backend.
"""
import json
from pathlib import Path

import numpy as np
import jax.numpy as jnp
import pytest

from repro import cfa
from repro.core.cfa import get_program
from repro.core.cfa.executors import EXECUTORS, BackendError

# The Table I suite at test-size spaces + the 2-D and 4-D programs — the
# same corners test_api.py pins (kept in sync by the shared shapes).
CASES = [
    ("jacobi2d5p", (8, 8, 8), (4, 4, 4)),
    ("jacobi2d9p", (8, 8, 8), (4, 4, 4)),
    ("jacobi2d9p-gol", (8, 8, 8), (4, 4, 4)),
    ("gaussian", (4, 16, 16), (2, 8, 8)),
    ("smith-waterman-3seq", (9, 8, 8), (3, 4, 4)),
    ("heat1d", (8, 8), (4, 4)),
    ("heat3d", (4, 4, 4, 4), (2, 2, 2, 2)),
]


def _inputs(space, name, seed=0):
    prog = get_program(name)
    w0 = prog.widths[0]
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(w0, *space[1:])))


def _run(name, space, tile, backend, storage, **opts):
    compiled = cfa.compile(name, space, layout=tile, backend=backend,
                           storage=storage)
    return compiled(_inputs(space, name), dtype=jnp.float64, **opts)


def _host_params():
    out = []
    for name, space, tile in CASES:
        for storage in ("redundant", "irredundant"):
            out.append(pytest.param(name, space, tile, storage,
                                    id=f"{name}-{storage}"))
    # the compressed discipline is storage-layer-heavy; one 3-D and the
    # 2-D/4-D corners keep tier-1 fast while covering every dimensionality
    for name, space, tile in (CASES[0], CASES[-2], CASES[-1]):
        out.append(pytest.param(name, space, tile, "compressed",
                                id=f"{name}-compressed"))
    return out


@pytest.mark.parametrize("name,space,tile,storage", _host_params())
def test_dataflow_host_path_bit_exact_vs_sweep(name, space, tile, storage):
    """dataflow == sweep, facet for facet, on the eager host path."""
    got = _run(name, space, tile, "dataflow", storage)
    ref = _run(name, space, tile, "sweep", storage)
    assert set(got) == set(ref)
    for k in ref:
        assert (np.asarray(got[k]) == np.asarray(ref[k])).all(), f"facet {k}"


def _kernel_params():
    out = []
    for name, space, tile in CASES:
        if len(space) != 3:
            continue  # the Pallas tile executor is declared 3-D only
        for storage in (("redundant", "irredundant")
                        if name == "jacobi2d5p" else ("redundant",)):
            out.append(pytest.param(name, space, tile, storage,
                                    id=f"{name}-{storage}"))
    return out


@pytest.mark.parametrize("name,space,tile,storage", _kernel_params())
def test_dataflow_kernel_path_matches_sweep(name, space, tile, storage):
    """dataflow(use_kernel=True) == sweep within float32 kernel rounding."""
    got = _run(name, space, tile, "dataflow", storage, use_kernel=True)
    ref = _run(name, space, tile, "sweep", storage)
    assert set(got) == set(ref)
    for k in ref:
        assert np.allclose(np.asarray(got[k]), np.asarray(ref[k]),
                           rtol=1e-5, atol=1e-5), f"facet {k}"


def test_dataflow_matches_wavefront_and_reference():
    """Three-way agreement: dataflow == wavefront == reference oracle."""
    name, space, tile = CASES[0]
    df = _run(name, space, tile, "dataflow", "redundant")
    wf = _run(name, space, tile, "wavefront", "redundant")
    ref = _run(name, space, tile, "reference", "redundant")
    for k in ref:
        assert (np.asarray(df[k]) == np.asarray(wf[k])).all(), f"facet {k}"
        assert (np.asarray(df[k]) == np.asarray(ref[k])).all(), f"facet {k}"


# --------------------------------------------------------------------------
# Capability gating
# --------------------------------------------------------------------------


def test_dataflow_declares_overlap_cap():
    caps = EXECUTORS["dataflow"].caps
    assert caps.overlap
    assert caps.kernels
    assert not caps.multiport
    # the only backend whose modeled time composes with overlap=True
    assert [n for n, ex in EXECUTORS.items() if ex.caps.overlap] == ["dataflow"]


def test_dataflow_kernel_path_rejects_non_3d():
    name, space, tile = ("heat1d", (8, 8), (4, 4))
    compiled = cfa.compile(name, space, layout=tile, backend="dataflow")
    with pytest.raises(BackendError, match=r"3-D.*2-D"):
        compiled(_inputs(space, name), dtype=jnp.float64, use_kernel=True)


def test_dataflow_kernel_path_rejects_compressed():
    name, space, tile = CASES[0]
    compiled = cfa.compile(name, space, layout=tile, backend="dataflow",
                           storage="compressed")
    with pytest.raises(BackendError, match="decode"):
        compiled(_inputs(space, name), dtype=jnp.float64, use_kernel=True)


def test_dataflow_rejects_unknown_options():
    name, space, tile = CASES[0]
    compiled = cfa.compile(name, space, layout=tile, backend="dataflow")
    with pytest.raises(TypeError, match="does not accept"):
        compiled(_inputs(space, name), dtype=jnp.float64, mesh=None)


# --------------------------------------------------------------------------
# The modeled counterpart rides along
# --------------------------------------------------------------------------


def test_dataflow_report_defaults_to_overlap():
    """report() on a dataflow-bound stencil models the pipelined schedule."""
    name, space, tile = CASES[0]
    compiled = cfa.compile(name, space, layout=tile, backend="dataflow")
    c = 1e-4
    ovl = compiled.report(compute_s=c)            # overlap defaults to caps
    seq = compiled.report(compute_s=c, overlap=False)
    assert ovl.overlap and not seq.overlap
    assert ovl.compute_s == seq.compute_s == c
    # the report's bandwidths divide by the composed time, so the
    # overlapped report can only look faster, never slower
    assert ovl.raw_bw >= seq.raw_bw
    assert ovl.effective_bw >= seq.effective_bw
    model = compiled.target.model
    t_ovl = model.time(compiled.plan, compute_s=c, overlap=True)
    t_seq = model.time(compiled.plan, compute_s=c, overlap=False)
    t = model.transfer_time_s(compiled.plan)
    assert max(t, c) <= t_ovl <= t_seq == t + c
    # a sequential backend's default report stays sequential
    assert not cfa.compile(name, space, layout=tile,
                           backend="sweep").report().overlap


# --------------------------------------------------------------------------
# The committed benchmark record stays honest
# --------------------------------------------------------------------------

RESULTS = Path(__file__).resolve().parent.parent / "benchmarks" / "results" / "dataflow"


@pytest.mark.parametrize("model", ["axi-zc706", "tpu-v5e-hbm"])
def test_committed_suite_record_demonstrates_overlap(model):
    """The shipped suite artifact records a real measured overlap win.

    Regenerate with ``PYTHONPATH=src python benchmarks/dataflow_bench.py``;
    this test fails if a regeneration ships a record where no transfer-bound
    program measured faster overlapped than sequential.
    """
    record = json.loads((RESULTS / f"suite_{model}.json").read_text())
    head = record["headline"]
    assert head["transfer_bound_overlap_demonstrated"] is True
    assert head["best_transfer_bound"]["measured_speedup"] > 1.0
    assert {r["program"] for r in record["rows"]} == {c[0] for c in CASES}
    for row in record["rows"]:
        assert row["wave_factor"] >= 1
        for reg in row["regimes"]:
            assert reg["rel_err_modeled_overlap"] >= 0.0
            assert reg["rel_err_fitted_overlap"] >= 0.0
            assert reg["modeled"]["speedup"] <= reg["modeled"]["bound"] + 1e-9
