"""N-dimensional CFA: the executor, plans, autotuner and kernels for d != 3.

The paper's construction (§IV-F..J) is dimension-generic; these tests pin it
for a 2-D program (``heat1d``: the 1-D heat equation as a time x space tiled
plane) and a 4-D program (``heat3d``: the 3-D heat equation, the §IV-J
regime where some mid-level neighbour pieces cannot merge into one burst).

Burst-count pins are hand-derived:

* heat1d, tile (t0, t1), widths (1, 2): flow-in is the time-halo row
  (w0*t1 = t1 elements, one facet_0 run) plus the spatial slab w1*t0 with
  the level-2 corner merged into it (one facet_1 run, the corner is hosted
  by facet_1 because its extension axis — time — has the thinnest width,
  §IV-I) -> **2 read bursts**, runs (t1, w1*t0).  Writes: one full block
  per facet -> **2 write bursts**.
* heat3d, widths (1, 2, 2, 2): 4 level-1 reads, 6 level-2 + 4 level-3
  pieces of which 2 find no host whose extension direction is crossed
  (§IV-J, `cfa_piece_census`), plus the level-4 corner ->
  **7 read bursts** = (d + 1) + 2 unmergeable.  Writes: 4 facets -> 4.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.cfa import (
    AXI_ZC706,
    CFAPipeline,
    Deps,
    FacetSpec,
    IterSpace,
    Tiling,
    autotune,
    best_repartition,
    build_facet_specs,
    cfa_plan,
    cfa_piece_census,
    extension_dir,
    facet_widths,
    get_program,
    pack_facet,
    repartition,
)


# ---------------------------------------------------------------------------
# program specs
# ---------------------------------------------------------------------------

def test_nd_facet_widths():
    assert facet_widths(get_program("heat1d").deps) == (1, 2)
    assert facet_widths(get_program("heat3d").deps) == (1, 2, 2, 2)


def test_pipeline_rejects_dimension_mismatch():
    prog = get_program("heat1d")  # 2-D program
    with pytest.raises(ValueError, match="2-D"):
        CFAPipeline(prog, IterSpace((8, 8, 8)), Tiling((4, 4, 4)))
    with pytest.raises(ValueError, match="d >= 2"):
        CFAPipeline(prog, IterSpace((8,)), Tiling((4,)))
    with pytest.raises(ValueError, match="not divisible"):
        CFAPipeline(prog, IterSpace((8, 10)), Tiling((4, 4)))


# ---------------------------------------------------------------------------
# tiled sweep through facets == untiled oracle (2-D and 4-D)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "name,space,tile",
    [
        ("heat1d", (16, 16), (4, 4)),
        ("heat1d", (12, 8), (3, 4)),  # non-square, t0 not a multiple of t1
        ("heat3d", (4, 4, 4, 4), (2, 2, 2, 2)),
        ("heat3d", (4, 8, 8, 8), (2, 4, 4, 4)),
    ],
)
def test_nd_sweep_matches_oracle(name, space, tile):
    prog = get_program(name)
    pipe = CFAPipeline(prog, IterSpace(space), Tiling(tile))
    rng = np.random.default_rng(0)
    inputs = jnp.asarray(rng.normal(size=(pipe.specs[0].width, *space[1:])))
    facets = pipe._sweep(inputs, dtype=jnp.float64)
    V = pipe.reference_volume(inputs)
    for k, spec in pipe.specs.items():
        got = facets[k]
        if k == 0:
            got = got[1:]  # drop the virtual live-in row
        want = pack_facet(V, spec)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("name,space,tile", [
    ("heat1d", (8, 8), (4, 4)),
    ("heat3d", (4, 4, 4, 4), (2, 2, 2, 2)),
])
def test_nd_wavefront_and_kernel_path(name, space, tile):
    """The wavefront executor and the Pallas tile kernel are N-D too."""
    prog = get_program(name)
    pipe = CFAPipeline(prog, IterSpace(space), Tiling(tile))
    rng = np.random.default_rng(1)
    inputs = jnp.asarray(rng.normal(size=(pipe.specs[0].width, *space[1:])))
    seq = pipe._sweep(inputs, dtype=jnp.float64)
    for kernel in (False, True):
        wav = pipe._sweep_wavefront(inputs, dtype=jnp.float64, use_kernel=kernel)
        for k in seq:
            np.testing.assert_allclose(np.asarray(seq[k]), np.asarray(wav[k]),
                                       rtol=1e-12, atol=1e-12)


def test_2d_sharded_sweep_bit_exact():
    """Multi-port wavefront execution repartitions N-D facets too."""
    prog = get_program("heat1d")
    pipe = CFAPipeline(prog, IterSpace((8, 8)), Tiling((4, 4)))
    rng = np.random.default_rng(2)
    inputs = jnp.asarray(rng.normal(size=(1, 8)))
    ref = pipe._sweep(inputs, dtype=jnp.float64)
    got = pipe._sweep_wavefront_sharded(inputs, dtype=jnp.float64, n_ports=2)
    for k in ref:
        assert (np.asarray(ref[k]) == np.asarray(got[k])).all(), f"facet {k}"


def test_nd_stencil_kernel_matches_ref():
    """The generalized Pallas executor == the jnp reference, both N-D."""
    from repro.kernels.stencil import execute_tiles, execute_tiles_ref

    for name, tile in [("heat1d", (4, 4)), ("heat3d", (2, 2, 2, 2))]:
        prog = get_program(name)
        w = prog.widths
        hshape = tuple(wa + ta for wa, ta in zip(w, tile))
        rng = np.random.default_rng(3)
        halos = jnp.asarray(rng.normal(size=(3, *hshape)))
        got = execute_tiles(name, halos, tile, interpret=True)
        want = execute_tiles_ref(name, halos, tile)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-12, atol=1e-12)
    with pytest.raises(ValueError, match="3-D, tile is 2-D"):
        execute_tiles("jacobi2d5p", jnp.zeros((1, 5, 6)), (4, 4))


# ---------------------------------------------------------------------------
# burst counts, pinned (incl. the d >= 4 unmergeable-corner accounting)
# ---------------------------------------------------------------------------

def test_heat1d_burst_counts_pinned():
    prog = get_program("heat1d")
    sp, tl = IterSpace((16, 16)), Tiling((4, 4))
    plan = cfa_plan(sp, prog.deps, tl)
    assert plan.read_runs == (4, 8)  # (w0*t1, w1*t0 incl. merged corner)
    assert plan.n_read_bursts == 2
    assert plan.n_write_bursts == 2
    assert plan.read_transferred == plan.read_useful  # zero redundancy
    census = cfa_piece_census(sp, prog.deps, tl)
    assert census["pieces_by_level"] == {1: 2, 2: 1}
    assert census["unmergeable"] == 0  # d <= 3: everything merges


@pytest.mark.parametrize("space,tile", [
    ((8, 8, 8, 8), (4, 4, 4, 4)),
    ((4, 8, 8, 8), (2, 4, 4, 4)),
])
def test_heat3d_burst_counts_pinned(space, tile):
    """§IV-J: in d = 4 two mid-level pieces find no facet whose extension
    direction is a crossed axis; each starts an extra burst beyond the
    d + 1 = 5 the d <= 3 construction would reach."""
    prog = get_program("heat3d")
    sp, tl = IterSpace(space), Tiling(tile)
    plan = cfa_plan(sp, prog.deps, tl)
    census = cfa_piece_census(sp, prog.deps, tl)
    assert census["pieces_by_level"] == {1: 4, 2: 6, 3: 4, 4: 1}
    assert census["unmergeable"] == 2
    assert plan.n_read_bursts == (4 + 1) + census["unmergeable"]  # == 7
    assert plan.n_write_bursts == 4  # one full block per facet, any d


@pytest.mark.parametrize("name", ["jacobi2d5p", "jacobi2d9p", "gaussian",
                                  "smith-waterman-3seq"])
def test_3d_census_has_no_unmergeable_pieces(name):
    """d = 3 is below the §IV-J regime: every piece merges (4 read bursts)."""
    prog = get_program(name)
    t = prog.default_tile
    sp = IterSpace(tuple(4 * x for x in t))
    census = cfa_piece_census(sp, prog.deps, Tiling(t))
    assert census["unmergeable"] == 0


# ---------------------------------------------------------------------------
# autotune + multiport over N-D spaces
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,space", [
    ("heat1d", (16, 16)),
    ("heat3d", (8, 8, 8, 8)),
])
def test_nd_autotune_valid_decision(name, space, tmp_path):
    prog = get_program(name)
    dec = autotune(prog, space, AXI_ZC706, budget=24, seed=0,
                   cache_dir=tmp_path)
    assert dec.evaluated > 0
    best = dec.best_cfa()
    assert best.candidate.scheme == "cfa"
    assert len(best.candidate.tile) == len(space)
    # the decision instantiates and stays exact end-to-end
    from repro import cfa
    pipe = cfa.compile(prog.name, space, layout=dec,
                       backend="sweep").pipeline
    rng = np.random.default_rng(4)
    inputs = jnp.asarray(rng.normal(size=(pipe.specs[0].width, *space[1:])))
    facets = pipe._sweep(inputs, dtype=jnp.float64)
    V = pipe.reference_volume(inputs)
    spec = pipe.specs[0]
    if spec.tile_sizes[0] % spec.width == 0:
        err = float(jnp.abs(facets[0][1:] - pack_facet(V, spec)).max())
        assert err < 1e-12


def test_nd_kernel_compatible_requires_3d(tmp_path):
    dec = autotune("heat1d", (16, 16), AXI_ZC706, budget=8, seed=0,
                   cache_dir=tmp_path)
    with pytest.raises(LookupError, match="3-D"):
        dec.best_cfa(kernel_compatible=True)


def test_4d_repartition_conserves_traffic():
    prog = get_program("heat3d")
    sp, tl = IterSpace((12, 12, 12, 12)), Tiling((4, 4, 4, 4))
    plan = cfa_plan(sp, prog.deps, tl)
    pp = repartition(plan, 4, "facet-lpt", model=AXI_ZC706)
    assert pp.transferred == plan.transferred
    assert set(dict(pp.facet_to_port)) == {0, 1, 2, 3}  # all 4 facets placed
    best = best_repartition(plan, 4, AXI_ZC706)
    assert AXI_ZC706.time(best) <= AXI_ZC706.time(plan)


# ---------------------------------------------------------------------------
# extension-direction degenerate/2-D behaviour (explicit, validated)
# ---------------------------------------------------------------------------

def test_extension_dir_degenerate_and_2d():
    # 1-D: c == k is the explicit "no extension direction" marker
    assert extension_dir(0, 1) == 0
    # 2-D: forced to the single other axis
    assert extension_dir(0, 2) == 1
    assert extension_dir(1, 2) == 0
    with pytest.raises(ValueError, match="out of range"):
        extension_dir(3, 2)


def test_build_facet_specs_validates_ext_dirs():
    deps2 = Deps(((-1, -1),))
    sp, tl = IterSpace((8, 8)), Tiling((4, 4))
    # c == k is rejected for d >= 2 ...
    with pytest.raises(ValueError, match="invalid extension direction"):
        build_facet_specs(sp, deps2, tl, ext_dirs={0: 0})
    # ... and is the only legal value for d == 1
    specs1 = build_facet_specs(IterSpace((8,)), Deps(((-2,),)), Tiling((4,)))
    assert specs1[0].ext_dir == 0
    with pytest.raises(ValueError, match="1-D"):
        build_facet_specs(IterSpace((8,)), Deps(((-2,),)), Tiling((4,)),
                          ext_dirs={0: 1})


def test_facet_spec_validates_ext_dir():
    with pytest.raises(ValueError, match="degenerate"):
        FacetSpec(axis=0, width=1, tile_sizes=(4, 4), num_tiles=(2, 2),
                  outer_axes=(0, 1), inner_axes=(0, 1), ext_dir=0)
    with pytest.raises(ValueError, match="out of range"):
        FacetSpec(axis=0, width=1, tile_sizes=(4, 4), num_tiles=(2, 2),
                  outer_axes=(0, 1), inner_axes=(0, 1), ext_dir=5)
