"""The staged lowering (repro.core.cfa.passes).

Covers the pass-pipeline acceptance bar:

* *differential equivalence* — an explicitly assembled ``default_pipeline()``
  run over a ``CompileState`` produces facets bit-exact against
  ``cfa.compile()`` for every Table I program (plus heat1d/heat3d) across
  the storage x backend matrix (comparisons are same-backend: the pallas
  interpret kernel is not bit-exact against sweep in float64, and that
  pre-dates the pipeline);
* *pass-order validation* — a missing, duplicated or mis-ordered stage is
  rejected loudly at pipeline assembly, never mid-lowering;
* *trace* — every compile records a per-pass artifact diff retrievable as
  ``CompiledStencil.trace()``;
* *distribute* — a space exceeding ``host_budget`` lowers to sharded
  execution bit-exact against the single-host sweep, and a budget even the
  target's full port complement cannot satisfy raises.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro import cfa
from repro.core.cfa import get_program
from repro.core.cfa.passes import (
    DEFAULT_PASSES,
    CompileState,
    PassPipeline,
    PassTrace,
    PipelineError,
    compiler_pass,
    default_pass_fingerprint,
    default_pipeline,
    estimate_facet_bytes,
)
from repro.core.cfa.spaces import IterSpace

# (program, space, tile): the Table I suite at test-size spaces + the N-D
# additions (pinned tiles keep the matrix out of the autotuner)
CASES = [
    ("jacobi2d5p", (8, 8, 8), (4, 4, 4)),
    ("jacobi2d9p", (8, 8, 8), (4, 4, 4)),
    ("jacobi2d9p-gol", (8, 8, 8), (4, 4, 4)),
    ("gaussian", (4, 16, 16), (2, 8, 8)),
    ("smith-waterman-3seq", (9, 8, 8), (3, 4, 4)),
    ("heat1d", (8, 8), (4, 4)),
    ("heat3d", (4, 4, 4, 4), (2, 2, 2, 2)),
]
STORAGES = ("redundant", "irredundant", "compressed")
BACKENDS = ("sweep", "wavefront", "pallas", "sharded", "dataflow")


def _inputs(name, space, seed=0):
    w0 = get_program(name).widths[0]
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(w0, *space[1:])))


def _eligible(name, space, storage, backend):
    if backend == "pallas":
        return len(space) == 3 and storage != "compressed"
    return True


def _matrix_params():
    out = []
    for name, space, tile in CASES:
        for storage in STORAGES:
            for backend in BACKENDS:
                if not _eligible(name, space, storage, backend):
                    continue
                # fast subset: the full matrix on jacobi2d5p, plus every
                # program's redundant sweep; the rest rides the CI slow leg
                fast = (name == "jacobi2d5p"
                        or (storage == "redundant" and backend == "sweep"))
                out.append(pytest.param(
                    name, space, tile, storage, backend,
                    marks=[] if fast else [pytest.mark.slow],
                    id=f"{name}-{storage}-{backend}"))
    return out


@pytest.mark.parametrize("name,space,tile,storage,backend", _matrix_params())
def test_pipeline_differential_bit_exact(name, space, tile, storage, backend):
    """compile() and a hand-assembled default pipeline agree, facet for
    facet, across the program x storage x backend matrix."""
    n_ports = 2 if backend == "sharded" else 1
    compiled = cfa.compile(name, space, layout=tile, backend=backend,
                           storage=storage, n_ports=n_ports)
    state = CompileState(program=name, space=space, layout=tile,
                         backend=backend, storage=storage, n_ports=n_ports)
    final = default_pipeline().run(state)
    manual = final.compiled
    assert manual.backend == compiled.backend == backend
    assert manual.layout.key == compiled.layout.key
    x = _inputs(name, space)
    got = compiled(x, dtype=jnp.float64)
    ref = manual(x, dtype=jnp.float64)
    assert set(got) == set(ref)
    for k in ref:
        assert (np.asarray(got[k]) == np.asarray(ref[k])).all(), f"facet {k}"


def test_explicit_passes_kwarg_is_the_same_lowering():
    pipe = default_pipeline()
    a = cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                    backend="sweep")
    b = cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                    backend="sweep", passes=pipe)
    x = _inputs("jacobi2d5p", (8, 8, 8))
    ga, gb = a(x, dtype=jnp.float64), b(x, dtype=jnp.float64)
    for k in ga:
        assert (np.asarray(ga[k]) == np.asarray(gb[k])).all()
    # and the explicit pipeline retains its own trace
    assert tuple(t.name for t in pipe.trace()) == pipe.names


# ---------------------------------------------------------------------------
# pass-order validation: assembly-time, loud
# ---------------------------------------------------------------------------

def test_missing_stage_rejected_at_assembly():
    with pytest.raises(PipelineError, match="requires"):
        default_pipeline().without("layout_search")  # lower_backend starves
    with pytest.raises(PipelineError, match="requires"):
        default_pipeline().without("resolve_program")


def test_missing_lower_backend_rejected():
    with pytest.raises(PipelineError, match="compiled"):
        default_pipeline().without("lower_backend")


def test_duplicated_stage_rejected():
    with pytest.raises(PipelineError, match="duplicate"):
        PassPipeline(DEFAULT_PASSES + (DEFAULT_PASSES[0],))


def test_misordered_stage_rejected():
    shuffled = (DEFAULT_PASSES[1],) + (DEFAULT_PASSES[0],) + DEFAULT_PASSES[2:]
    with pytest.raises(PipelineError, match="mis-ordered|requires"):
        PassPipeline(shuffled)


def test_without_unknown_stage_rejected():
    with pytest.raises(PipelineError, match="no pass named"):
        default_pipeline().without("not_a_stage")


def test_replaced_swaps_a_stage():
    @compiler_pass("select_backend", version="2",
                   requires=("program", "target"), provides=("backend",))
    def always_sweep(state):
        import dataclasses

        from repro.core.cfa.executors import get_executor
        return dataclasses.replace(state, executor=get_executor("sweep"))

    pipe = default_pipeline().replaced("select_backend", always_sweep)
    assert pipe.names == default_pipeline().names
    assert ("select_backend", "2") in pipe.fingerprint()
    compiled = cfa.compile("heat3d", (4, 4, 4, 4), layout=(2, 2, 2, 2),
                           passes=pipe)
    assert compiled.backend == "sweep"  # auto would have picked wavefront


def test_fingerprint_is_ordered_names_and_versions():
    fp = default_pipeline().fingerprint()
    assert fp == default_pass_fingerprint()
    assert [n for n, _ in fp] == list(default_pipeline().names)
    assert all(isinstance(n, str) and isinstance(v, str) for n, v in fp)


# ---------------------------------------------------------------------------
# the trace artifact
# ---------------------------------------------------------------------------

def test_trace_shape_and_artifact_diffs():
    compiled = cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                           backend="sweep", storage="irredundant")
    tr = compiled.trace()
    assert tuple(t.name for t in tr) == default_pipeline().names
    assert all(isinstance(t, PassTrace) for t in tr)
    assert all(t.wall_s >= 0 for t in tr)
    by_name = {t.name: t for t in tr}
    assert dict(by_name["resolve_program"].changed).keys() >= {"program",
                                                               "space"}
    assert "candidate" in dict(by_name["layout_search"].changed)
    assert "storage_map" in dict(by_name["storage_map"].changed)
    assert "compiled" in dict(by_name["lower_backend"].changed)
    d = tr[0].to_dict()
    assert set(d) == {"pass", "version", "wall_s", "changed"}
    assert d["pass"] == "resolve_program"


def test_noop_passes_trace_empty_diffs():
    compiled = cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                           backend="sweep")
    by_name = {t.name: t for t in compiled.trace()}
    # single-port, redundant, no budget: these stages have nothing to do
    assert by_name["distribute"].changed == ()
    assert by_name["storage_map"].changed == ()
    assert by_name["port_repartition"].changed == ()


# ---------------------------------------------------------------------------
# the distribute pass
# ---------------------------------------------------------------------------

def _budget_for_shards(name, space, shards):
    """A per-host byte budget that forces exactly ``shards`` shards."""
    target = cfa.get_target("axi-zc706")
    prog = get_program(name)
    est = estimate_facet_bytes(prog, IterSpace(space),
                               elem_bytes=target.model.elem_bytes)
    return -(-est // shards)


def test_distribute_lowers_to_sharded_bit_exact():
    name, space = "jacobi2d5p", (8, 8, 8)
    budget = _budget_for_shards(name, space, 2)
    dist = cfa.compile(name, space, layout=(4, 4, 4), host_budget=budget)
    assert dist.distributed
    assert dist.backend == "sharded"
    single = cfa.compile(name, space, layout=(4, 4, 4), backend="sweep")
    assert not single.distributed
    x = _inputs(name, space)
    got = dist(x, dtype=jnp.float64)
    ref = single(x, dtype=jnp.float64)
    for k in ref:
        assert (np.asarray(got[k]) == np.asarray(ref[k])).all(), f"facet {k}"
    # the decision shows up in the trace
    by_name = {t.name: t for t in dist.trace()}
    changed = dict(by_name["distribute"].changed)
    assert changed.keys() >= {"n_ports", "distributed"}


def test_distribute_noop_when_space_fits():
    compiled = cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                           backend="sweep", host_budget=10**12)
    assert not compiled.distributed
    assert compiled.backend == "sweep"


def test_distribute_budget_beyond_port_complement_raises():
    with pytest.raises(ValueError, match="host_budget|port"):
        cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4), host_budget=8)


def test_distribute_budget_must_be_positive():
    with pytest.raises(ValueError, match="positive"):
        cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4), host_budget=0)


def test_estimate_facet_bytes_scales_with_space_and_width():
    prog = get_program("jacobi2d5p")
    small = estimate_facet_bytes(prog, IterSpace((8, 8, 8)))
    big = estimate_facet_bytes(prog, IterSpace((8, 32, 32)))
    assert 0 < small < big
    assert estimate_facet_bytes(prog, IterSpace((8, 8, 8)),
                                elem_bytes=8) == 2 * small


@pytest.mark.slow
def test_distribute_quantized_halos_are_lossy_but_close():
    name, space = "jacobi2d5p", (8, 8, 8)
    budget = _budget_for_shards(name, space, 2)
    x = _inputs(name, space)
    exact = cfa.compile(name, space, layout=(4, 4, 4),
                        host_budget=budget)(x, dtype=jnp.float64)
    quant = cfa.compile(name, space, layout=(4, 4, 4), host_budget=budget,
                        halo_quantize=True)(x, dtype=jnp.float64)
    bitwise = all(
        (np.asarray(exact[k]) == np.asarray(quant[k])).all() for k in exact
    )
    assert not bitwise, "int8 halo quantization should be lossy"
    for k in exact:
        np.testing.assert_allclose(np.asarray(quant[k]), np.asarray(exact[k]),
                                   atol=5e-2, rtol=5e-2)
