"""The ``repro.cfa`` front-end: compile() over every program x backend.

Covers the acceptance criteria of the API redesign:

* ``cfa.compile(...)(inputs)`` is bit-exact against the hand-wired
  ``CFAPipeline`` internals it drives, for every Table I program
  (plus the N-D additions) on every eligible backend;
* backend auto-selection follows the documented rules and the capability
  gate rejects ineligible (backend, program, space, n_ports) combinations
  with a clear error;
* the ``Target`` registry resolves names/models and enforces port budgets;
* the legacy shims (deprecated through PR 4-6) are really gone;
* ``repro.cfa.__all__`` is pinned — accidental public-surface changes fail.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro import cfa
from repro.core.cfa import CFAPipeline, IterSpace, Tiling, get_program
from repro.core.cfa.executors import EXECUTORS

# (program, space, tile): the Table I suite at test-size spaces, plus the
# 2-D and 4-D programs (same corners the legacy pipeline tests pin).
CASES = [
    ("jacobi2d5p", (8, 8, 8), (4, 4, 4)),
    ("jacobi2d9p", (8, 8, 8), (4, 4, 4)),
    ("jacobi2d9p-gol", (8, 8, 8), (4, 4, 4)),
    ("gaussian", (4, 16, 16), (2, 8, 8)),
    ("smith-waterman-3seq", (9, 8, 8), (3, 4, 4)),
    ("heat1d", (8, 8), (4, 4)),
    ("heat3d", (4, 4, 4, 4), (2, 2, 2, 2)),
]

# backend -> the CFAPipeline internal the executor drives
LEGACY = {
    "sweep": lambda p, x: p._sweep(x, dtype=jnp.float64),
    "wavefront": lambda p, x: p._sweep_wavefront(x, dtype=jnp.float64),
    "pallas": lambda p, x: p._sweep_wavefront(x, dtype=jnp.float64,
                                             use_kernel=True),
    "sharded": lambda p, x: p._sweep_wavefront_sharded(x, dtype=jnp.float64,
                                                      n_ports=2),
}


def _inputs(space, tile, name, seed=0):
    prog = get_program(name)
    w0 = prog.widths[0]
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(w0, *space[1:])))


def _exact_params():
    out = []
    for name, space, tile in CASES:
        for b in ("sweep", "wavefront", "pallas", "sharded"):
            if b == "pallas" and len(space) != 3:
                continue  # the pallas backend is declared 3-D only
            # one fast sharded representative stays in tier-1; the rest of
            # the sharded matrix runs on the CI slow leg (repo convention)
            marks = ([pytest.mark.slow]
                     if b == "sharded" and name != "jacobi2d5p" else [])
            out.append(pytest.param(name, space, tile, b,
                                    marks=marks, id=f"{name}-{b}"))
    return out


@pytest.mark.parametrize("name,space,tile,backend", _exact_params())
def test_compile_bit_exact_vs_legacy(name, space, tile, backend):
    """compiled(inputs) == the legacy entry point, facet for facet."""
    n_ports = 2 if backend == "sharded" else 1
    compiled = cfa.compile(name, space, layout=tile, backend=backend,
                           n_ports=n_ports)
    assert compiled.backend == backend
    x = _inputs(space, tile, name)
    got = compiled(x, dtype=jnp.float64)
    legacy_pipe = CFAPipeline(get_program(name), IterSpace(space), Tiling(tile))
    ref = LEGACY[backend](legacy_pipe, x)
    assert set(got) == set(ref)
    for k in ref:
        assert (np.asarray(got[k]) == np.asarray(ref[k])).all(), f"facet {k}"


@pytest.mark.parametrize("name,space,tile", [CASES[0], CASES[-1]])
def test_reference_backend_matches_sweep(name, space, tile):
    """The oracle-scatter backend lands the same facet storage as sweep."""
    x = _inputs(space, tile, name)
    ref = cfa.compile(name, space, layout=tile, backend="reference")(
        x, dtype=jnp.float64)
    swp = cfa.compile(name, space, layout=tile, backend="sweep")(
        x, dtype=jnp.float64)
    for k in swp:
        assert (np.asarray(ref[k]) == np.asarray(swp[k])).all(), f"facet {k}"


# ---------------------------------------------------------------------------
# backend selection + the capability gate
# ---------------------------------------------------------------------------

def test_auto_backend_selection_rules():
    j, h1, h3 = (get_program(n) for n in ("jacobi2d5p", "heat1d", "heat3d"))
    assert cfa.select_backend(j, IterSpace((8, 8, 8))) == "pallas"
    assert cfa.select_backend(h1, IterSpace((8, 8))) == "wavefront"
    assert cfa.select_backend(h3, IterSpace((4, 4, 4, 4))) == "wavefront"
    assert cfa.select_backend(j, IterSpace((8, 8, 8)), n_ports=2) == "sharded"
    # overlap=True routes to the dataflow backend (any dimensionality);
    # the multiport rule still wins (dataflow is single-port)
    assert cfa.select_backend(j, IterSpace((8, 8, 8)), overlap=True) == "dataflow"
    assert cfa.select_backend(h3, IterSpace((4, 4, 4, 4)),
                              overlap=True) == "dataflow"
    assert cfa.select_backend(j, IterSpace((8, 8, 8)), n_ports=2,
                              overlap=True) == "sharded"
    # compile(backend="auto") applies exactly these rules
    assert cfa.compile(j, (8, 8, 8), layout=(4, 4, 4)).backend == "pallas"
    assert cfa.compile(h1, (8, 8), layout=(4, 4)).backend == "wavefront"
    assert cfa.compile(j, (8, 8, 8), layout=(4, 4, 4),
                       n_ports=2).backend == "sharded"
    assert cfa.compile(j, (8, 8, 8), layout=(4, 4, 4),
                       overlap=True).backend == "dataflow"
    # overlap=True with an explicitly sequential backend is rejected loudly
    with pytest.raises(cfa.BackendError, match="sequentially"):
        cfa.compile(j, (8, 8, 8), layout=(4, 4, 4), backend="sweep",
                    overlap=True)


def test_pallas_backend_is_3d_only():
    with pytest.raises(cfa.BackendError, match="3-D"):
        cfa.compile("heat3d", (4, 4, 4, 4), layout=(2, 2, 2, 2),
                    backend="pallas")
    with pytest.raises(cfa.BackendError, match="3-D"):
        cfa.compile("heat1d", (8, 8), layout=(4, 4), backend="pallas")


def test_single_port_backends_reject_multiport():
    for backend in ("reference", "sweep", "wavefront", "pallas", "dataflow"):
        with pytest.raises(cfa.BackendError, match="single-port"):
            cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                        backend=backend, n_ports=2)


def test_unknown_backend_lists_registered():
    with pytest.raises(cfa.BackendError, match="registered"):
        cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                    backend="turbo")


def test_capability_gate_error_lists_backends_sorted():
    """check_backend's BackendError spells the eligible alternatives out in
    sorted order — stable regardless of executor registration order (the
    same convention get_executor's unknown-name error already follows)."""
    with pytest.raises(cfa.BackendError) as ei:
        cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                    backend="pallas", n_ports=2)
    msg = str(ei.value)
    eligible = cfa.available_backends(
        get_program("jacobi2d5p"), IterSpace((8, 8, 8)), n_ports=2)
    assert f"eligible backends: {sorted(eligible)}" in msg


def test_available_backends():
    j, h3 = get_program("jacobi2d5p"), get_program("heat3d")
    assert cfa.available_backends(j, IterSpace((8, 8, 8))) == [
        "reference", "sweep", "wavefront", "pallas", "sharded", "dataflow"]
    h3_avail = cfa.available_backends(h3, IterSpace((4, 4, 4, 4)))
    assert "pallas" not in h3_avail
    assert "dataflow" in h3_avail  # the host dataflow path is N-D
    assert cfa.available_backends(j, IterSpace((8, 8, 8)), n_ports=2) == [
        "sharded"]


def test_lower_rebinds_and_revalidates():
    compiled = cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                           backend="sweep")
    assert compiled.lower("wavefront").backend == "wavefront"
    assert compiled.backend == "sweep"  # lower() does not mutate
    nd = cfa.compile("heat3d", (4, 4, 4, 4), layout=(2, 2, 2, 2),
                     backend="sweep")
    with pytest.raises(cfa.BackendError):
        nd.lower("pallas")


# ---------------------------------------------------------------------------
# Target registry
# ---------------------------------------------------------------------------

def test_target_resolution():
    t = cfa.get_target("axi-zc706")
    assert t.model == cfa.AXI_ZC706 and t.max_ports == 4
    assert cfa.get_target(cfa.AXI_ZC706) is t  # registered model -> entry
    assert cfa.get_target(t) is t
    custom = cfa.BurstModel(name="lab-bench", peak_bytes_per_s=1e9,
                            setup_s=1e-7, elem_bytes=4)
    wrapped = cfa.get_target(custom)
    assert wrapped.model == custom and wrapped.max_ports is None
    with pytest.raises(ValueError, match="unknown target"):
        cfa.get_target("fpga-9000")


def test_port_budget_enforced():
    with pytest.raises(ValueError, match="port"):
        cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                    target="axi-zc706", n_ports=8)
    with pytest.raises(ValueError, match="n_ports"):
        cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4), n_ports=0)
    # an unvalidated custom model accepts any port count the backend takes
    custom = cfa.BurstModel(name="lab-bench", peak_bytes_per_s=1e9,
                            setup_s=1e-7, elem_bytes=4)
    c = cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                    target=custom, n_ports=8)
    assert c.n_ports == 8 and c.backend == "sharded"


def test_register_target_rejects_duplicates():
    with pytest.raises(ValueError, match="already registered"):
        cfa.register_target(cfa.Target(name="axi-zc706", model=cfa.AXI_ZC706))


def test_recalibrated_model_keeps_platform_port_budget():
    """Tweaking a registered platform's model parameters (a calibration
    workflow) must not silently forfeit the port-budget validation."""
    import dataclasses

    refit = dataclasses.replace(cfa.AXI_ZC706, peak_bytes_per_s=1e9)
    t = cfa.get_target(refit)
    assert t.model == refit and t.max_ports == 4
    with pytest.raises(ValueError, match="port"):
        cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                    target=refit, n_ports=16)


def test_unknown_call_options_rejected():
    """A typo'd or inapplicable call option fails loudly instead of being
    silently ignored (e.g. interpret= on a kernel-less backend)."""
    c = cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                    backend="sweep")
    x = _inputs((8, 8, 8), (4, 4, 4), "jacobi2d5p")
    with pytest.raises(TypeError, match="does not accept"):
        c(x, interpret=False)
    p = c.lower("pallas")
    with pytest.raises(TypeError, match="does not accept"):
        p(x, interpert=False)  # typo'd 'interpret'
    assert isinstance(p(x, interpret=True), dict)  # the real knob works


# ---------------------------------------------------------------------------
# layout resolution
# ---------------------------------------------------------------------------

def test_layout_default_uses_program_tile():
    c = cfa.compile("jacobi2d5p", (32, 32, 32), layout="default",
                    backend="sweep")
    assert c.layout.tile == get_program("jacobi2d5p").default_tile


def test_layout_rejects_non_cfa_candidate():
    bad = cfa.LayoutCandidate("bbox", (4, 4, 4))
    with pytest.raises(ValueError, match="cfa"):
        cfa.compile("jacobi2d5p", (8, 8, 8), layout=bad, backend="sweep")


def test_layout_rejects_unknown_string_and_type():
    with pytest.raises(ValueError, match="layout"):
        cfa.compile("jacobi2d5p", (8, 8, 8), layout="best-effort",
                    backend="sweep")
    with pytest.raises(TypeError):
        cfa.compile("jacobi2d5p", (8, 8, 8), layout=3.14, backend="sweep")


def test_layout_autotune_and_decision_reuse(tmp_path):
    c = cfa.compile("jacobi2d5p", (8, 8, 8), backend="sweep",
                    autotune_kwargs=dict(budget=16, cache_dir=tmp_path))
    assert c.decision is not None
    assert c.layout == c.decision.best_cfa().candidate
    # a decision object is itself a valid layout= argument
    again = cfa.compile("jacobi2d5p", (8, 8, 8), layout=c.decision,
                        backend="sweep")
    assert again.layout == c.layout
    # ... but only for the (program, space) it was searched for
    with pytest.raises(ValueError, match="decision is for"):
        cfa.compile("jacobi2d9p", (8, 8, 8), layout=c.decision,
                    backend="sweep")
    x = _inputs((8, 8, 8), c.layout.tile, "jacobi2d5p")
    got = c(x, dtype=jnp.float64)
    ref = c.lower("reference")(x, dtype=jnp.float64)
    for k in ref:
        assert (np.asarray(got[k]) == np.asarray(ref[k])).all()


def test_compile_validates_ndim():
    with pytest.raises(ValueError, match="-D"):
        cfa.compile("jacobi2d5p", (8, 8), layout=(4, 4), backend="sweep")


# ---------------------------------------------------------------------------
# the compiled artifact: plan / report / describe
# ---------------------------------------------------------------------------

def test_compiled_plan_and_report():
    c = cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                    backend="sweep")
    plan = c.plan
    assert isinstance(plan, cfa.TransferPlan) and plan.n_bursts > 0
    rep = c.report()
    assert rep.model == "axi-zc706" and rep.effective_bw > 0
    assert rep.n_ports == 1
    assert "jacobi2d5p" in c.describe()
    # multi-port report: repartitioned, aggregate bandwidth over ports
    c2 = cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                     backend="sharded", n_ports=2)
    rep2 = c2.report()
    assert rep2.n_ports == 2
    assert rep2.effective_bw >= rep.effective_bw


# ---------------------------------------------------------------------------
# legacy shims: removed for good (deprecated through PR 4-6, deleted here)
# ---------------------------------------------------------------------------

def test_legacy_shims_are_gone():
    for name in ("sweep", "sweep_wavefront", "sweep_wavefront_sharded",
                 "from_autotuned"):
        assert not hasattr(CFAPipeline, name), (
            f"CFAPipeline.{name} was deleted; use cfa.compile() "
            f"(or the _-prefixed internal from the executors)"
        )
    import repro.kernels.facet_fetch as facet_fetch
    import repro.kernels.stencil as stencil
    assert not hasattr(stencil, "execute_tiles_from_autotuned")
    assert not hasattr(facet_fetch, "fetch_interior_halos_from_autotuned")
    # the deprecation machinery itself left with its last clients
    with pytest.raises(ImportError):
        import repro.core.cfa.deprecation  # noqa: F401


# ---------------------------------------------------------------------------
# public-surface snapshot
# ---------------------------------------------------------------------------

# The public API of repro.cfa.  A failure here means the surface changed:
# update this list (and the docs) deliberately, or revert the accident.
PUBLIC_API = [
    "AXI_ZC706",
    "AnalysisReport",
    "BackendError",
    "BandwidthReport",
    "BlockCodec",
    "BurstModel",
    "CFAPipeline",
    "CODECS",
    "CacheSchemaError",
    "CalibratedModel",
    "Calibration",
    "CompileState",
    "CompiledStencil",
    "Counters",
    "DEFAULT_PASSES",
    "Deps",
    "Diagnostic",
    "EXECUTORS",
    "Executor",
    "ExecutorCaps",
    "IterSpace",
    "LayoutCandidate",
    "LayoutDecision",
    "PROGRAMS",
    "Pass",
    "PassPipeline",
    "PassTrace",
    "PipelineError",
    "PortedPlan",
    "RuntimeReport",
    "SCORE_MODES",
    "STORAGE_MODES",
    "ScoredLayout",
    "Span",
    "StencilProgram",
    "StorageMap",
    "TARGETS",
    "TPU_V5E_HBM",
    "Target",
    "Tiling",
    "TraceRecorder",
    "TransferPlan",
    "TransferSample",
    "VerificationError",
    "autotune",
    "available_backends",
    "build_storage_map",
    "calibrate",
    "chrome_trace",
    "compile",
    "dedup_facets",
    "default_pass_fingerprint",
    "default_pipeline",
    "estimate_facet_bytes",
    "fit_burst_model",
    "get_codec",
    "get_executor",
    "get_program",
    "get_target",
    "measure_plan",
    "measure_runs",
    "overlap_speedup",
    "register_executor",
    "register_target",
    "rehydrate_facets",
    "runtime_report",
    "select_backend",
    "validate_chrome_trace",
    "verify",
]


def test_public_api_snapshot():
    assert sorted(cfa.__all__) == sorted(set(cfa.__all__)), "duplicate names"
    assert sorted(cfa.__all__) == PUBLIC_API
    for name in cfa.__all__:
        assert hasattr(cfa, name), f"repro.cfa.__all__ names missing {name}"


def test_builtin_backends_registered():
    assert list(EXECUTORS) == ["reference", "sweep", "wavefront", "pallas",
                               "sharded", "dataflow"]
    # only the dataflow backend declares the Fig. 13 phase overlap
    assert [n for n, ex in EXECUTORS.items() if ex.caps.overlap] == ["dataflow"]
