"""Elastic fault tolerance: checkpoints restore across mesh changes, and the
data pipeline survives stragglers."""
import os
import subprocess
import sys
import textwrap
import time
from pathlib import Path

import numpy as np

from repro.data.pipeline import SyntheticTokens

REPO = Path(__file__).resolve().parents[1]

_ELASTIC_SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, jax, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint.manager import CheckpointManager
    from repro.configs import get_smoke_config
    from repro.distributed.sharding import sanitize_tree
    from repro.models.lm import init_lm, spec_lm

    cfg = dataclasses.replace(get_smoke_config("qwen3-0.6b"), n_layers=2)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    pspec = spec_lm(cfg)

    with tempfile.TemporaryDirectory() as d:
        # save while sharded on a 4x2 mesh
        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        sh_a = sanitize_tree(pspec, params, mesh_a)
        params_a = jax.device_put(params, sh_a)
        m = CheckpointManager(d)
        m.save(7, params_a, blocking=True)

        # restore onto a 2x4 mesh (different pod shape after elastic event)
        mesh_b = jax.make_mesh((2, 4), ("data", "model"))
        sh_b = sanitize_tree(pspec, params, mesh_b)
        restored = m.restore(7, params, shardings=sh_b)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # and the restored tree really lives on mesh_b
        leaf = jax.tree.leaves(restored)[0]
        assert leaf.sharding.mesh.shape == {"data": 2, "model": 4}
    print("ELASTIC_OK")
""")


def test_checkpoint_elastic_resharding_subprocess():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    res = subprocess.run([sys.executable, "-c", _ELASTIC_SCRIPT],
                         capture_output=True, text=True, env=env, timeout=600)
    assert "ELASTIC_OK" in res.stdout, res.stderr[-3000:]


class _Slow(SyntheticTokens):
    """Every 3rd batch takes far longer than the step deadline."""

    def batch_at(self, step):
        if step % 3 == 2:
            time.sleep(0.5)
        return super().batch_at(step)


def test_straggler_deadline_skips_not_stalls():
    d = _Slow(vocab=64, batch=2, seq=8, prefetch=1)
    t0 = time.time()
    batches = [d.next(deadline_s=0.2) for _ in range(6)]
    dt = time.time() - t0
    d.close()
    assert all(b["tokens"].shape == (2, 8) for b in batches)
    # without mitigation: >= 2 stalls x 0.5 s on the critical path; with the
    # deadline fallback the six steps finish quickly and skips are counted
    assert dt < 2.5
    assert d.stats["skipped"] >= 1


def test_data_determinism_across_seek():
    a = SyntheticTokens(vocab=100, batch=2, seq=8, seed=5)
    first = [a.next() for _ in range(4)]
    a.seek(0)
    second = [a.next() for _ in range(4)]
    a.close()
    for x, y in zip(first, second):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
