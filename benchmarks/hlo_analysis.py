"""Static analyzer for post-SPMD-partitioning HLO text.

``compiled.cost_analysis()`` does NOT multiply ``while``-loop bodies by their
trip count, so for scan-over-layers models it reports ~one layer of FLOPs.
This module re-derives the per-device totals the roofline needs by walking
the HLO call graph:

* computations are parsed into (name -> ops) with a value-name -> byte-size map;
* every computation gets an execution multiplier: entry = 1, while body/cond =
  caller_mult x trip_count (trip count recovered from the loop condition's
  comparison constant), fusion/call/conditional bodies = caller_mult;
* FLOPs: ``dot`` ops contribute 2 * prod(result_shape) * prod(contracted dims)
  (parsed from dimension numbers + operand shapes); convolutions analogous.
* collective bytes: operand bytes of all-reduce / all-gather / reduce-scatter /
  all-to-all / collective-permute, times the multiplier;
* HBM traffic model: for every *top-level* op of non-fusion computations
  (fusion internals stay in registers/VMEM), operand + result bytes — an
  upper-bound-ish proxy for HBM bytes touched, again times multipliers.

This is the "profile" of the dry-run container: exact static counts, no
wall-clock.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

__all__ = ["analyze_hlo", "HloStats"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class _Op:
    name: str
    result_type: str
    opcode: str
    operands: list[str]
    attrs: str


@dataclasses.dataclass
class HloStats:
    flops: float
    collective_bytes: dict
    collective_counts: dict
    hbm_traffic_bytes: float
    while_trips: dict


def _parse_ops(body_lines: list[str]) -> list[_Op]:
    ops = []
    for ln in body_lines:
        m = _OP_RE.match(ln)
        if not m:
            continue
        name, rhs = m.groups()
        # result type: leading tuple-parenthesised or single token
        if rhs.startswith("("):
            depth = 0
            for i, ch in enumerate(rhs):
                depth += ch == "("
                depth -= ch == ")"
                if depth == 0:
                    break
            rtype, rest = rhs[: i + 1], rhs[i + 1:].strip()
        else:
            parts = rhs.split(" ", 1)
            rtype, rest = parts[0], parts[1] if len(parts) > 1 else ""
        om = re.match(r"([\w\-]+)\((.*)$", rest)
        if not om:
            continue
        opcode, tail = om.groups()
        # operands: up to matching close paren
        depth = 1
        args = []
        cur = ""
        for ch in tail:
            if ch == "(":
                depth += 1
            if ch == ")":
                depth -= 1
                if depth == 0:
                    args.append(cur)
                    break
            if ch == "," and depth == 1:
                args.append(cur)
                cur = ""
            else:
                cur += ch
        operands = [a.strip().lstrip("%") for a in args if a.strip()]
        attrs = tail[len("".join(args)) :]
        ops.append(_Op(name, rtype, opcode, operands, tail))
    return ops


def _dot_flops(op: _Op, sizes_types: dict) -> float:
    """2 * prod(result) * prod(contracted lhs dims)."""
    res = _shape_dims(op.result_type)
    if not res:
        return 0.0
    out_elems = 1
    for d in res[0][1]:
        out_elems *= d
    lhs = op.operands[0] if op.operands else None
    lhs_type = sizes_types.get(lhs, "")
    ldims = _shape_dims(lhs_type)
    if not ldims:
        return 0.0
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    contract = 1
    if m:
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(ldims[0][1]):
                contract *= ldims[0][1][int(idx)]
    else:
        contract = ldims[0][1][-1] if ldims[0][1] else 1
    return 2.0 * out_elems * contract


def analyze_hlo(hlo_text: str) -> HloStats:
    # ---- split into computations --------------------------------------
    comps: dict[str, list[str]] = {}
    cur = None
    for ln in hlo_text.splitlines():
        m = _COMP_HEADER.match(ln.strip()) if ln.rstrip().endswith("{") else None
        if m and "=" not in ln.split("(")[0]:
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if ln.strip() == "}":
                cur = None
                continue
            comps[cur].append(ln)
    parsed = {name: _parse_ops(lines) for name, lines in comps.items()}
    types = {
        name: {op.name: op.result_type for op in ops} for name, ops in parsed.items()
    }

    # ---- call graph multipliers ----------------------------------------
    entry = None
    for ln in hlo_text.splitlines():
        if ln.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", ln)
            if m:
                entry = m.group(1)
    if entry is None:  # fall back: computation named like main
        entry = next((n for n in comps if "main" in n), next(iter(comps), None))

    def trip_count(cond_name: str) -> int:
        best = 1
        for op in parsed.get(cond_name, []):
            if op.opcode == "constant":
                m = re.search(r"constant\((\d+)\)", "constant(" + op.attrs)
                if m:
                    best = max(best, int(m.group(1)))
        # constants may be hoisted: also scan raw lines
        for ln in comps.get(cond_name, []):
            for m in re.finditer(r"constant\((\d+)\)", ln):
                best = max(best, int(m.group(1)))
        return best

    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    while order:
        caller = order.pop(0)
        cmult = mult[caller]
        for op in parsed.get(caller, []):
            callees: list[tuple[str, float]] = []
            wm = re.search(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)", op.attrs)
            if op.opcode == "while" and wm:
                cond, body = wm.groups()
                t = trip_count(cond)
                callees += [(cond, cmult * (t + 1)), (body, cmult * t)]
            else:
                for m in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", op.attrs):
                    callees.append((m.group(1), cmult))
                m = re.search(r"branch_computations=\{([^}]*)\}", op.attrs)
                if m:  # conditional: assume each branch runs (upper bound)
                    for br in m.group(1).split(","):
                        callees.append((br.strip().lstrip("%"), cmult))
            for cn, cm in callees:
                if cn in comps:
                    mult[cn] += cm
                    if cn not in seen:
                        seen.add(cn)
                        order.append(cn)

    # ---- effective read size of a fusion/call operand -------------------
    # A fusion whose parameter is only consumed by dynamic-slice ops reads
    # just the slice, not the whole operand (scan-over-layers reads one
    # layer's weights from the stacked array per trip).
    def _called_comp(op: _Op) -> str | None:
        m = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", op.attrs)
        return m.group(1) if m else None

    def _operand_read_bytes(op: _Op, idx: int, full: int) -> int:
        cn = _called_comp(op)
        if cn is None or cn not in parsed:
            return full
        # find parameter idx inside the called computation
        pname = None
        for cop in parsed[cn]:
            if cop.opcode == "parameter" and cop.operands == [str(idx)]:
                pname = cop.name
                break
        if pname is None:
            return full
        # chase aliases (bitcast/copy/reshape/gte) transitively: if every
        # real consumer is a slice-like read (or in-place DUS target), the
        # effective bytes are the slice windows, not the whole operand.
        aliases = {pname}
        frontier = [pname]
        while frontier:
            a = frontier.pop()
            for cop in parsed[cn]:
                if a in cop.operands and cop.opcode in (
                        "bitcast", "copy", "reshape", "get-tuple-element",
                        "transpose"):
                    if cop.name not in aliases:
                        aliases.add(cop.name)
                        frontier.append(cop.name)
        consumer_sizes = []
        for cop in parsed[cn]:
            if cop.name in aliases:
                continue
            hit = [o for o in cop.operands if o in aliases]
            if not hit:
                continue
            if cop.opcode in ("dynamic-slice", "slice", "gather"):
                consumer_sizes.append(_type_bytes(cop.result_type))
            elif cop.opcode == "dynamic-update-slice" and cop.operands and \
                    cop.operands[0] in aliases:
                upd = cop.operands[1] if len(cop.operands) > 1 else None
                consumer_sizes.append(
                    _type_bytes(types[cn].get(upd, "")) if upd else 0)
            else:
                return full
        if consumer_sizes:
            return min(sum(consumer_sizes), full)
        return 0  # unused (or alias-only) parameter

    def _result_write_bytes(op: _Op) -> int:
        full = _type_bytes(op.result_type)
        cn = _called_comp(op)
        if cn is None or cn not in parsed:
            return full
        # root = last op of the computation body
        body = parsed[cn]
        if body and body[-1].opcode == "dynamic-update-slice" and \
                len(body[-1].operands) > 1:
            upd = body[-1].operands[1]
            return min(_type_bytes(types[cn].get(upd, "")), full)
        return full

    # ---- accumulate ------------------------------------------------------
    flops = 0.0
    coll_bytes = {k: 0.0 for k in COLLECTIVES}
    coll_counts = {k: 0.0 for k in COLLECTIVES}
    traffic = 0.0
    for name, ops in parsed.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        fusion_comp = name.startswith("fused_") or ".fused" in name
        sizes = types[name]
        for op in ops:
            if op.opcode in ("dot", "convolution"):
                flops += m * _dot_flops(op, sizes)
            base = op.opcode.replace("-start", "")
            if base in COLLECTIVES:
                ob = sum(_type_bytes(sizes.get(o, "")) for o in op.operands)
                coll_bytes[base] += m * ob
                coll_counts[base] += m
            if not fusion_comp and (
                op.opcode in ("fusion", "dot", "convolution", "copy",
                              "scatter", "gather", "custom-call")
                or base in COLLECTIVES
            ):
                ob = sum(
                    _operand_read_bytes(op, i, _type_bytes(sizes.get(o, "")))
                    for i, o in enumerate(op.operands)
                )
                traffic += m * (ob + _result_write_bytes(op))
    trips = {}
    for name, ops in parsed.items():
        for op in ops:
            wm = re.search(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)", op.attrs)
            if op.opcode == "while" and wm:
                trips[wm.group(2)] = trip_count(wm.group(1))
    return HloStats(
        flops=flops,
        collective_bytes=coll_bytes,
        collective_counts=coll_counts,
        hbm_traffic_bytes=traffic,
        while_trips=trips,
    )
