"""CFA applied to serving: facet(block)-layout KV cache vs canonical layout.

Two measurements:
 1. DMA-model transfer plan for one decode step's cache reads: the canonical
    (B, S, Hkv, D) layout reads each head's keys strided by Hkv*D per token
    (S short bursts per head), the block layout reads (bs, D) contiguous
    extents (S/bs long bursts per head) — the paper's burst-count argument,
    on real cache shapes.
 2. Wall-clock of the two jnp decode-attention paths on CPU (small shapes) —
    a sanity check, not the score.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.cfa import TPU_V5E_HBM, BurstModel
from repro.kernels.block_attention import blockify, decode_attention_ref


def decode_read_plan(B, S, Hkv, D, bs, elem=2):
    """Burst runs for one decode step's full cache read, per layout."""
    # canonical (B, S, Hkv, D): per (b, s, h): D contiguous, then stride.
    canonical = [D] * (B * S * Hkv)
    # facet/block (B, nb, Hkv, bs, D): per (b, blk, h): bs*D contiguous.
    blocks = [bs * D] * (B * (S // bs) * Hkv)
    return canonical, blocks


def run_kvcache_bench():
    rows = []
    model = TPU_V5E_HBM
    for (B, S, Hkv, D, bs) in [
        (8, 4096, 8, 128, 256),
        (8, 32768, 8, 128, 256),
        (1, 524288, 16, 128, 512),
    ]:
        canonical, blocks = decode_read_plan(B, S, Hkv, D, bs)
        t_canon = model.time_s(tuple(canonical))
        t_block = model.time_s(tuple(blocks))
        bytes_total = B * S * Hkv * D * model.elem_bytes
        rows.append({
            "shape": f"B{B}_S{S}_H{Hkv}_D{D}_bs{bs}",
            "canonical_bursts": len(canonical),
            "block_bursts": len(blocks),
            "canonical_eff_frac": bytes_total / model.peak_bytes_per_s / t_canon,
            "block_eff_frac": bytes_total / model.peak_bytes_per_s / t_block,
            "speedup": t_canon / t_block,
        })
    return rows


def run_kvcache_walltime(repeat: int = 5):
    """CPU wall-time sanity check of both layouts' attention math."""
    B, S, Hq, Hkv, D, bs = 2, 2048, 8, 4, 64, 128
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, Hq, D)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    lengths = jnp.full((B,), S, jnp.int32)
    kb, vb = blockify(kc, bs), blockify(vc, bs)

    @jax.jit
    def canon(q, kc, vc, lengths):
        return decode_attention_ref(q, kc, vc, lengths)

    @jax.jit
    def block(q, kb, vb, lengths):
        from repro.kernels.block_attention.ref import deblockify
        return decode_attention_ref(q, deblockify(kb), deblockify(vb), lengths)

    canon(q, kc, vc, lengths).block_until_ready()
    block(q, kb, vb, lengths).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(repeat):
        canon(q, kc, vc, lengths).block_until_ready()
    t1 = time.perf_counter()
    for _ in range(repeat):
        block(q, kb, vb, lengths).block_until_ready()
    t2 = time.perf_counter()
    return {
        "canonical_us": 1e6 * (t1 - t0) / repeat,
        "block_us": 1e6 * (t2 - t1) / repeat,
    }
