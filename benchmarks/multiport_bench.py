"""Multi-port sweep (paper §VII): modeled speedup of 1/2/4/8 memory ports
over the Table I suite, on both BurstModel presets.

For every (program, model, n_ports) the interior-tile CFA plan at the
program's default tile is repartitioned with the best strategy
(``repro.core.cfa.multiport.best_repartition``: facet-LPT / facet round-robin
/ burst-LPT / striping, over any number of ports up to n) and the modeled
tile time — the slowest port — is compared against the single-port plan.
A small port-aware autotune run is recorded alongside so the co-tuned
(layout x repartition) winner is visible next to the fixed-layout speedup.

Headline numbers (checked by tests/test_multiport.py): on jacobi2d5p under
``AXI_ZC706`` the repartition reaches >= 1.7x at 2 ports and >= 3x at 4.

    PYTHONPATH=src python benchmarks/multiport_bench.py            # full suite
    PYTHONPATH=src python benchmarks/multiport_bench.py --smoke    # CI leg
    PYTHONPATH=src python benchmarks/multiport_bench.py \
        --program jacobi2d5p --model axi-zc706 --ports 1 2 4 8 16

Writes one JSON per model to benchmarks/results/multiport/ (schema in
benchmarks/results/README.md); ``--smoke`` prints but writes nothing.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.cfa import (
    AXI_ZC706,
    TPU_V5E_HBM,
    IterSpace,
    PROGRAMS,
    Tiling,
    autotune,
    get_program,
    port_speedup,
)

OUT = Path(__file__).parent / "results" / "multiport"
MODELS = {m.name: m for m in (AXI_ZC706, TPU_V5E_HBM)}
DEFAULT_PORTS = (1, 2, 4, 8)


def run_one(name: str, model, ports, args) -> dict:
    """Port sweep + a co-tuned autotune run for one (program, model)."""
    prog = get_program(name)
    space = tuple(args.space) if args.space else tuple(
        3 * t for t in prog.default_tile)
    tiling = Tiling(prog.default_tile)
    sp = IterSpace(space)

    sweep = []
    print(f"{name} @ space {space}  tile {prog.default_tile}  model={model.name}")
    print(f"{'ports':>6} {'speedup':>8} {'balance':>8} {'t_multi':>10}  strategy")
    for n in ports:
        r = port_speedup(sp, prog.deps, tiling, n, model)
        sweep.append(r)
        print(f"{n:>6} {r['speedup']:>7.2f}x {r['balance']:>8.3f} "
              f"{r['t_multi_us']:>8.2f}us  {r['strategy']}")

    # co-tuned: the layout search itself scored at the largest port count
    n_max = max(ports)
    cotuned = None
    if not args.no_autotune:
        decision = autotune(prog, sp, model, budget=args.budget,
                            n_ports=n_max, cache=not args.no_cache,
                            cache_dir=args.cache_dir)
        best = decision.best
        cotuned = {
            "n_ports": n_max,
            "winner": best.candidate.key,
            "port_strategy": best.port_strategy,
            "port_assignment": (
                dict(best.port_assignment)
                if best.port_assignment is not None else None),
            "port_speedup_vs_single": best.port_speedup_vs_single,
            "eff_frac": best.peak_fraction_effective,
            "evaluated": decision.evaluated,
        }
        print(f"  co-tuned x{n_max}: {best.candidate.key} "
              f"[{best.port_strategy}] eff={best.peak_fraction_effective:.1%} "
              f"of one port's peak\n")
    return {
        "program": name,
        "space": list(space),
        "tile": list(prog.default_tile),
        "model": model.name,
        "ports": sweep,
        "cotuned": cotuned,
    }


def verify_sharded_exec() -> None:
    """Tiny end-to-end check: the sharded wavefront backend is bit-exact
    against the single-port ``sweep`` backend (the full Table I matrix is in
    tests/test_api.py; this keeps the CI smoke leg self-contained)."""
    import numpy as np
    import jax.numpy as jnp

    from repro import cfa

    sharded = cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                          backend="sharded", n_ports=2)
    # the single-port reference is its own compile: lower() keeps n_ports,
    # and the capability gate rightly rejects a 2-port sweep backend
    single = cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                         backend="sweep")
    rng = np.random.default_rng(0)
    inputs = jnp.asarray(rng.normal(size=(1, 8, 8)), jnp.float32)
    ref = single(inputs)
    got = sharded(inputs)
    for k in ref:
        assert (np.asarray(ref[k]) == np.asarray(got[k])).all(), f"facet {k}"
    print("sharded backend == sweep backend (bit-exact) on jacobi2d5p 8^3")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--program", choices=sorted(PROGRAMS), default=None,
                    help="one benchmark (default: the whole Table I suite)")
    ap.add_argument("--model", choices=sorted(MODELS), default=None,
                    help="one preset (default: both)")
    ap.add_argument("--ports", type=int, nargs="+", default=list(DEFAULT_PORTS))
    ap.add_argument("--space", type=int, nargs="+", default=None,
                    help="iteration-space sizes (default: 3x the default tile)")
    ap.add_argument("--budget", type=int, default=32,
                    help="autotune evaluations for the co-tuned record")
    ap.add_argument("--no-autotune", action="store_true",
                    help="skip the co-tuned autotune record")
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--cache-dir", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: jacobi2d5p, AXI, 1/2/4 ports, no files")
    args = ap.parse_args()

    if args.smoke:
        args.program = args.program or "jacobi2d5p"
        args.model = args.model or "axi-zc706"
        args.ports = [1, 2, 4]
        args.budget = min(args.budget, 16)

    names = [args.program] if args.program else sorted(PROGRAMS)
    models = [MODELS[args.model]] if args.model else [AXI_ZC706, TPU_V5E_HBM]

    for model in models:
        records = [run_one(name, model, tuple(args.ports), args)
                   for name in names]
        if args.smoke:
            continue
        OUT.mkdir(parents=True, exist_ok=True)
        tag = args.program or "suite"
        out = OUT / f"{tag}_{model.name}.json"
        out.write_text(json.dumps(records, indent=1))
        print(f"wrote {out}")

    if args.smoke:
        verify_sharded_exec()
        # the §VII headline the docs quote; keep the smoke leg honest
        r2 = port_speedup(IterSpace((48, 48, 48)), get_program("jacobi2d5p").deps,
                          Tiling((16, 16, 16)), 2, AXI_ZC706)
        r4 = port_speedup(IterSpace((48, 48, 48)), get_program("jacobi2d5p").deps,
                          Tiling((16, 16, 16)), 4, AXI_ZC706)
        assert r2["speedup"] >= 1.7, r2
        assert r4["speedup"] >= 3.0, r4
        print(f"smoke OK: jacobi2d5p AXI speedups "
              f"{r2['speedup']:.2f}x @2, {r4['speedup']:.2f}x @4")


if __name__ == "__main__":
    main()
