"""Footprint/bandwidth trade-off curves for the facet storage disciplines.

The paper's burst-friendly layout duplicates halo data (`TransferPlan.
redundancy` measures the transfer tax; the storage tax is the facet arrays'
footprint).  The Ferry-2024 follow-up removes the duplicates (irredundant
storage) and compresses the blocks at a fixed ratio; this benchmark sweeps
both axes over the Table I suite (+ `heat1d`/`heat3d`):

* per (program, model): the interior-tile plan at the default tile under
  ``redundant`` / ``irredundant`` / ``compressed`` (deltapack16 + deltapack8)
  storage — footprint in elements and modeled bytes, per-tile stored slots,
  burst counts, transfer redundancy, modeled time and effective bandwidth;
* a trade-off curve: ``autotune(storage="irredundant",
  footprint_weight=...)`` at several weights, recording each winner's
  (footprint, effective-bandwidth) point — the knob a footprint-constrained
  deployment turns.

    PYTHONPATH=src python benchmarks/footprint_bench.py            # full suite
    PYTHONPATH=src python benchmarks/footprint_bench.py --smoke    # CI leg
    PYTHONPATH=src python benchmarks/footprint_bench.py \
        --program heat3d --model axi-zc706 --weights 0 0.5 1

Writes one JSON per model to benchmarks/results/footprint/ (schema in
benchmarks/results/README.md); ``--smoke`` prints, asserts the headline
invariants (storage redundancy 1.0, strictly smaller footprint, compressed
bursts modeled faster, bit-exact execution) and writes nothing.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.cfa import (
    AXI_ZC706,
    TPU_V5E_HBM,
    BandwidthReport,
    IterSpace,
    PROGRAMS,
    Tiling,
    autotune,
    build_facet_specs,
    build_storage_map,
    cfa_plan,
    get_codec,
    get_program,
)

OUT = Path(__file__).parent / "results" / "footprint"
MODELS = {m.name: m for m in (AXI_ZC706, TPU_V5E_HBM)}
#: (storage, codec) sweep points; codec only meaningful for "compressed".
STORAGES = (
    ("redundant", None),
    ("irredundant", None),
    ("compressed", "deltapack16"),
    ("compressed", "deltapack8"),
)
DEFAULT_WEIGHTS = (0.0, 0.5, 1.0)


def _footprint_bytes(smap, storage, codec, model) -> float:
    """Resident bytes of the whole layout under a discipline: redundant
    counts every slot, irredundant only owned slots, compressed packs each
    facet's owned block at the codec's fixed ratio."""
    elem_bits = 8 * model.elem_bytes
    if storage == "redundant":
        return smap.redundant_elems * model.elem_bytes
    if storage == "irredundant" or codec is None:
        return smap.stored_elems * model.elem_bytes
    cdc = get_codec(codec)
    bits = 0
    for k, spec in smap.specs.items():
        n_blocks = spec.size // spec.block_elems
        bits += n_blocks * cdc.stored_bits(smap.owned_per_block[k], elem_bits)
    return bits / 8


def sweep_one(name: str, model, args) -> dict:
    prog = get_program(name)
    space = tuple(args.space) if args.space else tuple(
        3 * t for t in prog.default_tile)
    sp, tiling = IterSpace(space), Tiling(prog.default_tile)
    specs = build_facet_specs(sp, prog.deps, tiling)
    smap = build_storage_map(specs)

    print(f"{name} @ space {space}  tile {prog.default_tile}  model={model.name}")
    print(f"{'storage':>22} {'fp-elems':>9} {'fp-bytes':>10} {'bursts':>6} "
          f"{'redun':>6} {'t_us':>8} {'eff':>7}")
    rows = []
    for storage, codec in STORAGES:
        plan = cfa_plan(sp, prog.deps, tiling, storage=storage, codec=codec)
        rep = BandwidthReport.evaluate(plan, model)
        t_us = 1e6 * model.time(plan)
        fp_bytes = _footprint_bytes(smap, storage, codec, model)
        label = storage if codec is None else f"{storage}/{codec}"
        rows.append({
            "storage": storage,
            "codec": codec,
            "footprint_elems": plan.footprint,
            "footprint_bytes": fp_bytes,
            "stored_per_tile": plan.stored_elems,
            "storage_redundancy": (1.0 if storage != "redundant"
                                   else smap.redundant_elems / smap.stored_elems),
            "n_bursts": plan.n_bursts,
            "transfer_redundancy": plan.redundancy,
            "t_us": t_us,
            "eff_frac": rep.peak_fraction_effective,
        })
        print(f"{label:>22} {plan.footprint:>9} {fp_bytes:>10.0f} "
              f"{plan.n_bursts:>6} {plan.redundancy:>6.1%} {t_us:>8.2f} "
              f"{rep.peak_fraction_effective:>6.1%}")

    curve = []
    if not args.no_autotune:
        for wgt in args.weights:
            dec = autotune(prog, sp, model, budget=args.budget,
                           storage="irredundant", footprint_weight=wgt,
                           cache=not args.no_cache, cache_dir=args.cache_dir)
            best = dec.best_cfa()
            curve.append({
                "footprint_weight": wgt,
                "winner": best.candidate.key,
                "footprint_elems": best.footprint,
                "eff_frac": best.peak_fraction_effective,
                "evaluated": dec.evaluated,
            })
            print(f"  weight {wgt:>4}: {best.candidate.key}  "
                  f"footprint {best.footprint}  "
                  f"eff {best.peak_fraction_effective:.1%}")
    print()
    return {
        "program": name,
        "space": list(space),
        "tile": list(prog.default_tile),
        "model": model.name,
        "savings": smap.savings,
        "storages": rows,
        "tradeoff_curve": curve,
    }


def verify_exactness() -> None:
    """Tiny end-to-end check for the CI smoke leg: the irredundant pipeline
    is bit-exact against the redundant one (the full matrix lives in
    tests/test_irredundant.py)."""
    import numpy as np
    import jax.numpy as jnp

    from repro import cfa

    x = jnp.asarray(np.random.default_rng(0).normal(size=(1, 8, 8)),
                    jnp.float32)
    red = cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                      backend="sweep")(x)
    irr = cfa.compile("jacobi2d5p", (8, 8, 8), layout=(4, 4, 4),
                      backend="sweep", storage="irredundant")
    rh = irr.rehydrate(irr(x))
    for k in red:
        assert (np.asarray(rh[k]) == np.asarray(red[k])).all(), f"facet {k}"
    print("irredundant backend == redundant backend (bit-exact) "
          "on jacobi2d5p 8^3")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--program", choices=sorted(PROGRAMS), default=None,
                    help="one benchmark (default: the whole suite)")
    ap.add_argument("--model", choices=sorted(MODELS), default=None,
                    help="one preset (default: both)")
    ap.add_argument("--space", type=int, nargs="+", default=None,
                    help="iteration-space sizes (default: 3x the default tile)")
    ap.add_argument("--weights", type=float, nargs="+",
                    default=list(DEFAULT_WEIGHTS),
                    help="footprint_weight points on the trade-off curve")
    ap.add_argument("--budget", type=int, default=32,
                    help="autotune evaluations per trade-off point")
    ap.add_argument("--no-autotune", action="store_true",
                    help="skip the trade-off curve")
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--cache-dir", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: jacobi2d5p + heat3d, AXI, no files")
    args = ap.parse_args()

    if args.smoke:
        args.model = args.model or "axi-zc706"
        args.budget = min(args.budget, 16)
        args.weights = [0.0, 1.0]

    if args.smoke:
        names = [args.program] if args.program else ["jacobi2d5p", "heat3d"]
    else:
        names = [args.program] if args.program else sorted(PROGRAMS)
    models = [MODELS[args.model]] if args.model else [AXI_ZC706, TPU_V5E_HBM]

    for model in models:
        records = [sweep_one(name, model, args) for name in names]
        if args.smoke:
            # the acceptance headlines, kept honest on every CI run
            for r in records:
                by = {(row["storage"], row["codec"]): row
                      for row in r["storages"]}
                red = by[("redundant", None)]
                irr = by[("irredundant", None)]
                cmp16 = by[("compressed", "deltapack16")]
                assert irr["storage_redundancy"] == 1.0, r["program"]
                assert irr["footprint_elems"] < red["footprint_elems"], r["program"]
                assert cmp16["t_us"] < irr["t_us"], r["program"]
                assert cmp16["footprint_bytes"] < irr["footprint_bytes"], r["program"]
            continue
        OUT.mkdir(parents=True, exist_ok=True)
        tag = args.program or "suite"
        out = OUT / f"{tag}_{model.name}.json"
        out.write_text(json.dumps(records, indent=1))
        print(f"wrote {out}")

    if args.smoke:
        verify_exactness()
        print("smoke OK: redundancy 1.0, smaller footprint, faster "
              "compressed bursts on jacobi2d5p + heat3d")


if __name__ == "__main__":
    main()
