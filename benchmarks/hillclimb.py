"""Layout hillclimbing CLI — a thin front-end over ``repro.cfa.autotune``.

The search itself (candidate tilings x extension directions x contiguity
levels, scored by the BurstModel, persistently cached) lives in the library
(``repro.cfa.autotune``, which ``cfa.compile(layout="autotune")`` drives);
this script only parses arguments, runs decisions, prints the ranked tables
and writes one JSON per (program, model) to benchmarks/results/autotune/.

    PYTHONPATH=src python benchmarks/hillclimb.py                     # whole suite
    PYTHONPATH=src python benchmarks/hillclimb.py --program jacobi2d5p \
        --space 64 64 64 --model tpu-v5e-hbm --budget 128 --seed 3
    PYTHONPATH=src python benchmarks/hillclimb.py --no-cache --top 12
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.cfa import (
    AXI_ZC706,
    TPU_V5E_HBM,
    IterSpace,
    PROGRAMS,
    autotune,
    get_program,
)
from repro.core.cfa import hand_coded_baselines

OUT = Path(__file__).parent / "results" / "autotune"
MODELS = {m.name: m for m in (AXI_ZC706, TPU_V5E_HBM)}


def run_one(name: str, space: tuple[int, ...], model, args) -> dict:
    # decision-only search: cfa.autotune is the documented direct route
    # (cfa.compile(layout="autotune") drives the same machinery when an
    # executable stencil is wanted too)
    prog = get_program(name)
    decision = autotune(
        prog,
        space,
        model,
        seed=args.seed,
        budget=args.budget,
        cache=not args.no_cache,
        cache_dir=args.cache_dir,
    )
    print(decision.summary(top=args.top))
    # compare against the hand-coded plans at the default tile when it is
    # legal for this space, else at the winning tile
    base_tile = prog.default_tile
    if any(n % t or t < max(1, w)
           for n, t, w in zip(space, base_tile, prog.widths)):
        base_tile = decision.best_cfa().candidate.tile
    base = hand_coded_baselines(prog, IterSpace(space), model, tile=base_tile)
    gain = decision.best.effective_bw / max(
        s.effective_bw for s in base.values()
    )
    print(f"     best hand-coded plan beaten by {gain:.2f}x "
          f"(winner: {decision.best.candidate.key})\n")
    return {
        "program": name,
        "space": list(space),
        "model": model.name,
        "seed": decision.seed,
        "evaluated": decision.evaluated,
        "from_cache": decision.from_cache,
        "gain_vs_hand_coded": gain,
        "winner": decision.best.candidate.key,
        "winner_eff_frac": decision.best.peak_fraction_effective,
        "ranked": json.loads(decision.to_json())["ranked"][: args.top],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--program", choices=sorted(PROGRAMS), default=None,
                    help="one benchmark (default: the whole Table I suite)")
    ap.add_argument("--space", type=int, nargs="+", default=None,
                    help="iteration-space sizes (default: 3x the default tile)")
    ap.add_argument("--model", choices=sorted(MODELS), default="axi-zc706")
    ap.add_argument("--budget", type=int, default=96,
                    help="max candidate evaluations per program")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--top", type=int, default=8, help="rows to print/record")
    ap.add_argument("--no-cache", action="store_true",
                    help="ignore and do not write the on-disk decision cache")
    ap.add_argument("--cache-dir", default=None,
                    help="override the decision cache directory")
    args = ap.parse_args()

    model = MODELS[args.model]
    names = [args.program] if args.program else sorted(PROGRAMS)
    records = []
    for name in names:
        space = (
            tuple(args.space)
            if args.space
            else tuple(3 * t for t in PROGRAMS[name].default_tile)
        )
        records.append(run_one(name, space, model, args))

    OUT.mkdir(parents=True, exist_ok=True)
    tag = args.program or "suite"
    out = OUT / f"{tag}_{model.name}.json"
    out.write_text(json.dumps(records, indent=1))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
