"""Perf hillclimbing driver (EXPERIMENTS.md §Perf).

Lowers + compiles variants of the three chosen cells on the single-pod mesh,
re-derives the roofline terms from the HLO, and writes one JSON per variant
to benchmarks/results/perf/.  Each variant is a (hypothesis, change) pair —
the log in EXPERIMENTS.md quotes these numbers directly.

    PYTHONPATH=src python benchmarks/hillclimb.py [--only NAME]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax

from benchmarks.hlo_analysis import analyze_hlo
from benchmarks.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                 analytic_bytes_per_device,
                                 model_flops_per_device)
from repro.configs import get_config
from repro.distributed.sharding import use_mesh
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell, policy_for
from repro.train.steps import TrainHParams

OUT = Path(__file__).parent / "results" / "perf"


def measure(tag: str, arch: str, cell: str, cfg, hp=None) -> dict:
    mesh = make_production_mesh(multi_pod=False)
    t0 = time.time()
    with use_mesh(mesh, **policy_for(cfg, cell)):
        c = build_cell(cfg, cell, mesh, hp=hp)
        jitted = jax.jit(c.step, in_shardings=c.in_shardings,
                         out_shardings=c.out_shardings)
        lowered = jitted.lower(*c.args)
    compiled = lowered.compile()
    stats = analyze_hlo(compiled.as_text())
    mem = compiled.memory_analysis()
    nd = mesh.devices.size
    coll = sum(stats.collective_bytes.values())
    hbm_lb = analytic_bytes_per_device(cfg, cell, nd)
    terms = {
        "compute": stats.flops / PEAK_FLOPS,
        "memory": hbm_lb / HBM_BW,
        "collective": coll / ICI_BW,
    }
    mf = model_flops_per_device(cfg, cell, nd)
    rec = {
        "tag": tag, "arch": arch, "cell": cell,
        "flops": stats.flops,
        "collective_bytes": stats.collective_bytes,
        "collective_counts": {k: int(v) for k, v in stats.collective_counts.items()},
        "hbm_analytic_bytes": hbm_lb,
        "hbm_parsed_bytes": stats.hbm_traffic_bytes,
        "terms_s": terms,
        "dominant": max(terms, key=terms.get),
        "roofline_fraction": (mf / PEAK_FLOPS) / max(terms.values()),
        "temp_gib": mem.temp_size_in_bytes / 2**30,
        "compile_s": round(time.time() - t0, 1),
    }
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / f"{tag}.json").write_text(json.dumps(rec, indent=1))
    t = terms
    print(f"{tag}: frac={rec['roofline_fraction']:.4f} dominant={rec['dominant']} "
          f"compute={t['compute']:.3f}s mem={t['memory']:.3f}s "
          f"coll={t['collective']:.3f}s coll_GiB={coll/2**30:.1f} "
          f"temp={rec['temp_gib']:.1f}GiB", flush=True)
    return rec


def h1_deepseek_train(only=None):
    """Collective-bound cell: gradient reduce-scatter + remat policy."""
    arch, cell = "deepseek-67b", "train_4k"
    cfg = get_config(arch)
    base_hp = TrainHParams(accum=4, shard_grads=False)
    variants = [
        ("h1_baseline", base_hp),
        ("h1_shard_grads", dataclasses.replace(base_hp, shard_grads=True)),
        ("h1_remat_dots", dataclasses.replace(base_hp, shard_grads=True,
                                              remat_policy="dots")),
    ]
    for tag, hp in variants:
        if only and only not in tag:
            continue
        measure(tag, arch, cell, cfg, hp)


def h2_deepseek_decode(only=None):
    """Memory-bound decode: fp8 KV cache."""
    arch, cell = "deepseek-67b", "decode_32k"
    base = get_config(arch)
    variants = [
        ("h2_baseline_bf16", base),
        ("h2_fp8_cache", dataclasses.replace(base, kv_cache_dtype="float8_e4m3fn")),
    ]
    for tag, cfg in variants:
        if only and only not in tag:
            continue
        measure(tag, arch, cell, cfg)


def h3_mamba_chunk(only=None):
    """Paper-representative: SSD chunk (facet/tile) size sweep."""
    arch, cell = "mamba2-370m", "train_4k"
    base = get_config(arch)
    for chunk in (64, 128, 256):
        tag = f"h3_chunk{chunk}"
        if only and only not in tag:
            continue
        cfg = dataclasses.replace(base, ssm_chunk=chunk)
        measure(tag, arch, cell, cfg, TrainHParams(accum=1, shard_grads=False)
                if chunk == -1 else None)


def h2b_serving_sharding(only=None):
    """Serving weights without FSDP (no per-layer param all-gathers) +
    fp8 cache — the combined decode configuration."""
    if only and "h2b" not in only:
        return
    arch, cell = "deepseek-67b", "decode_32k"
    cfg = dataclasses.replace(get_config(arch), kv_cache_dtype="float8_e4m3fn")
    measure("h2b_serving_params_fp8", arch, cell, cfg)


def h4_parallelism_policy(only=None):
    """Small-d_model archs: pure DP (model axis folded into batch) vs TP."""
    for arch in ("qwen3-0.6b", "mamba2-370m"):
        for mode in ("tp", "dp"):
            tag = f"h4_{arch.split('-')[0]}_{mode}"
            if only and only not in tag:
                continue
            cfg = dataclasses.replace(get_config(arch), parallelism=mode)
            measure(tag, arch, "train_4k", cfg)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    h1_deepseek_train(args.only)
    h2_deepseek_decode(args.only)
    h3_mamba_chunk(args.only)
    h2b_serving_sharding(args.only)
    h4_parallelism_policy(args.only)


if __name__ == "__main__":
    main()
