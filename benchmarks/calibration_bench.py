"""Measured-vs-modeled calibration sweep over the Table I suite.

The paper validates its burst model against *measured* throughput (§VI);
Zohouri & Matsuoka 2019 show how far analytic controller models drift from
silicon.  This benchmark runs the ``repro.core.cfa.calibrate`` harness on
this host: it times real facet transfers per (burst length, burst count)
grid point and per interior-tile plan (program x storage discipline x port
count), fits the ``BurstModel`` parameters to the samples, and records the
per-plan modeled-vs-measured and fitted-vs-measured relative errors.

    PYTHONPATH=src python benchmarks/calibration_bench.py            # full suite
    PYTHONPATH=src python benchmarks/calibration_bench.py --smoke    # CI leg
    PYTHONPATH=src python benchmarks/calibration_bench.py \
        --program heat3d --model axi-zc706 --ports 1 2 4

Writes one JSON per (tag, model) to benchmarks/results/calibration/
(schema in benchmarks/results/README.md).  ``--smoke`` shrinks the sweep
to jacobi2d5p + heat3d on the AXI preset, asserts the headline invariants
(physical fit, every plan row carries its relative errors, JSON
round-trip) and STILL writes the JSON — CI uploads it as the error-report
artifact.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.cfa import AXI_ZC706, PROGRAMS, TPU_V5E_HBM
from repro.core.cfa.calibrate import (Calibration, calibrate,
                                      timing_unusable_reason)

OUT = Path(__file__).parent / "results" / "calibration"
MODELS = {m.name: m for m in (AXI_ZC706, TPU_V5E_HBM)}
STORAGES = ("redundant", "irredundant", "compressed")
#: smoke keeps the synthetic grid small but still spanning both regressors
SMOKE_LENGTHS = (1, 64, 4096)
SMOKE_COUNTS = (1, 8)


def run_one(model, names, args) -> Calibration:
    cal = calibrate(
        model,
        programs=tuple(names),
        storages=tuple(args.storages),
        ports=tuple(args.ports),
        lengths=tuple(args.lengths),
        counts=tuple(args.counts),
        warmup=args.warmup,
        repeats=args.repeats,
    )
    print(cal.summary())
    print(f"{'program':>18} {'storage':>12} {'ports':>5} {'bursts':>6} "
          f"{'measured':>10} {'modeled':>10} {'fitted':>10} "
          f"{'err_mod':>8} {'err_fit':>8}")
    for r in cal.plan_errors:
        def pct(x):
            return "n/a" if x is None else f"{x:.1%}"
        print(f"{r['program']:>18} {r['storage']:>12} {r['n_ports']:>5} "
              f"{r['n_bursts']:>6} {r['measured_s']:>10.3e} "
              f"{r['modeled_s']:>10.3e} {r['fitted_s']:>10.3e} "
              f"{pct(r['rel_err_modeled']):>8} {pct(r['rel_err_fitted']):>8}")
    print()
    return cal


def check_smoke(cal: Calibration) -> None:
    """The acceptance headlines, kept honest on every CI run.  Structural
    invariants only — never wall-clock tolerances, so the job cannot flake
    on a noisy runner."""
    f = cal.fitted
    assert f.setup_s >= 0.0, f"unphysical fitted setup {f.setup_s}"
    assert f.peak_bytes_per_s > 0.0, f"unphysical fitted peak {f.peak_bytes_per_s}"
    assert cal.samples, "calibration produced no samples"
    assert all(s.measured_s >= 0.0 for s in cal.samples)
    assert cal.plan_errors, "calibration recorded no plan error rows"
    for r in cal.plan_errors:
        # every plan row records modeled-vs-measured relative error —
        # the per-plan accountability the ISSUE requires of results JSON
        assert r["measured_s"] > 0.0, r
        assert r["rel_err_modeled"] is not None, r
        assert r["rel_err_fitted"] is not None, r
    # the artifact round-trips: what CI uploads can be reloaded and audited
    back = Calibration.from_json(cal.to_json())
    assert back == cal, "Calibration JSON round-trip drifted"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--program", choices=sorted(PROGRAMS), default=None,
                    help="one benchmark (default: the whole suite)")
    ap.add_argument("--model", choices=sorted(MODELS), default=None,
                    help="one preset (default: both)")
    ap.add_argument("--storages", nargs="+", choices=STORAGES,
                    default=list(STORAGES))
    ap.add_argument("--ports", type=int, nargs="+", default=[1, 2],
                    help="port counts for the multi-port samples")
    ap.add_argument("--lengths", type=int, nargs="+",
                    default=[1, 8, 64, 512, 4096, 32768],
                    help="synthetic-grid burst lengths (elements)")
    ap.add_argument("--counts", type=int, nargs="+", default=[1, 4, 16],
                    help="synthetic-grid burst counts")
    ap.add_argument("--warmup", type=int, default=None,
                    help="warmup passes per measurement (default: env/1)")
    ap.add_argument("--repeats", type=int, default=None,
                    help="median-of-k repeats (default: env/5)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: jacobi2d5p + heat3d, AXI, asserts "
                         "the invariants and still writes the JSON artifact")
    args = ap.parse_args()

    reason = timing_unusable_reason()
    if reason is not None:
        print(f"WARNING: host timing looks unreliable ({reason}); "
              f"measurements will be noisy but the sweep still runs")

    if args.smoke:
        args.model = args.model or "axi-zc706"
        args.lengths = list(SMOKE_LENGTHS)
        args.counts = list(SMOKE_COUNTS)
        names = [args.program] if args.program else ["jacobi2d5p", "heat3d"]
    else:
        names = [args.program] if args.program else sorted(PROGRAMS)
    models = [MODELS[args.model]] if args.model else [AXI_ZC706, TPU_V5E_HBM]

    OUT.mkdir(parents=True, exist_ok=True)
    tag = args.program or ("smoke" if args.smoke else "suite")
    for model in models:
        cal = run_one(model, names, args)
        if args.smoke:
            check_smoke(cal)
        out = OUT / f"{tag}_{model.name}.json"
        cal.save(out)
        print(f"wrote {out}")

    if args.smoke:
        print("smoke OK: physical fit, per-plan relative errors recorded, "
              "artifact round-trips")


if __name__ == "__main__":
    main()
