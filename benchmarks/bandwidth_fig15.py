"""Fig. 15 reproduction: raw + effective bandwidth, CFA vs the three
baselines, per benchmark x tile size, on the paper's AXI model and on the
TPU DMA model (the adaptation target).

The paper's qualitative claims to validate:
 * CFA reaches close to 100 % of bus bandwidth (raw AND effective);
 * bounding-box reaches high raw bandwidth but loses effective bandwidth to
   redundancy; data tiling sits between; original layout has no redundancy
   but many short bursts;
 * CFA stays efficient at small tile sizes (gaussian 4x64x64 > 80 %).
"""
from __future__ import annotations

import dataclasses

from repro.core.cfa import (
    AXI_ZC706,
    TPU_V5E_HBM,
    BandwidthReport,
    IterSpace,
    Tiling,
    bounding_box_plan,
    cfa_plan,
    data_tiling_plan,
    get_program,
    interior_tile,
    original_layout_plan,
    PROGRAMS,
)

__all__ = ["run_fig15", "SCHEMES"]

SCHEMES = ("cfa", "original", "bbox", "data-tiling")


def best_data_tiling(space, deps, tiling, tile):
    """The paper reports the best block size <= the iteration tile."""
    best = None
    t = tiling.sizes
    candidates = [t, tuple(max(1, x // 2) for x in t),
                  tuple(max(1, x // 4) for x in t)]
    for blk in candidates:
        plan = data_tiling_plan(space, deps, tiling, tile, block=blk)
        rep = BandwidthReport.evaluate(plan, AXI_ZC706)
        if best is None or rep.effective_bw > best[1].effective_bw:
            best = (plan, rep)
    return best[0]


def run_fig15(tile_sizes: dict | None = None):
    rows = []
    for name, prog in PROGRAMS.items():
        tiles = tile_sizes.get(name) if tile_sizes else prog.paper_tiles[:3]
        for t in tiles:
            tiling = Tiling(t)
            space = IterSpace(tuple(3 * x for x in t))
            tile = interior_tile(space, tiling)
            plans = {
                "cfa": cfa_plan(space, prog.deps, tiling, tile),
                "original": original_layout_plan(space, prog.deps, tiling, tile),
                "bbox": bounding_box_plan(space, prog.deps, tiling, tile),
                "data-tiling": best_data_tiling(space, prog.deps, tiling, tile),
            }
            for scheme, plan in plans.items():
                for model in (AXI_ZC706, TPU_V5E_HBM):
                    rep = BandwidthReport.evaluate(plan, model)
                    rows.append({
                        "benchmark": name,
                        "tile": "x".join(map(str, t)),
                        "scheme": scheme,
                        "model": model.name,
                        "n_bursts": plan.n_bursts,
                        "raw_frac": rep.peak_fraction_raw,
                        "eff_frac": rep.peak_fraction_effective,
                        "redundancy": rep.redundancy,
                        "time_us": 1e6 * (model.time_s(plan.read_runs)
                                          + model.time_s(plan.write_runs)),
                    })
    return rows
