"""Fig. 16 analogue: address-generation cost.

The paper measures FPGA slices/DSP for the read/write engines and finds CFA
costs no more than the baselines (address generators are small either way).
The TPU analogue of "address generator logic" is the *index/copy computation*
the compiler must emit: we report (a) the number of jaxpr primitives in the
pack/copy path per scheme and (b) the number of burst descriptors per tile
(DMA-issue work).  The claim to validate is relative: CFA's addressing cost
is the same order as the baselines'.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.cfa import (
    CFAPipeline,
    IterSpace,
    Tiling,
    build_facet_specs,
    cfa_plan,
    bounding_box_plan,
    data_tiling_plan,
    original_layout_plan,
    get_program,
    interior_tile,
    pack_all,
    PROGRAMS,
)


def _jaxpr_ops(fn, *args) -> int:
    jaxpr = jax.make_jaxpr(fn)(*args)
    count = 0

    def walk(j):
        nonlocal count
        for eq in j.eqns:
            count += 1
            for sub in eq.params.values():
                if hasattr(sub, "jaxpr"):
                    walk(sub.jaxpr)
    walk(jaxpr.jaxpr)
    return count


def run_fig16():
    rows = []
    for name, t in (("jacobi2d5p", (8, 8, 8)), ("smith-waterman-3seq", (6, 6, 6))):
        prog = get_program(name)
        tiling = Tiling(t)
        space = IterSpace(tuple(3 * x for x in t))
        tile = interior_tile(space, tiling)
        specs = build_facet_specs(space, prog.deps, tiling)
        V = jnp.zeros(space.sizes, jnp.float32)

        cfa_ops = _jaxpr_ops(lambda v: pack_all(v, specs), V)
        canon_ops = _jaxpr_ops(lambda v: v.reshape(-1), V)  # original: identity
        blk = tiling.sizes
        dt_ops = _jaxpr_ops(
            lambda v: v.reshape(3, blk[0], 3, blk[1], 3, blk[2])
            .transpose(0, 2, 4, 1, 3, 5), V)

        plans = {
            "cfa": cfa_plan(space, prog.deps, tiling, tile),
            "original": original_layout_plan(space, prog.deps, tiling, tile),
            "bbox": bounding_box_plan(space, prog.deps, tiling, tile),
            "data-tiling": data_tiling_plan(space, prog.deps, tiling, tile),
        }
        addr_ops = {"cfa": cfa_ops, "original": canon_ops,
                    "bbox": canon_ops, "data-tiling": dt_ops}
        for scheme, plan in plans.items():
            rows.append({
                "benchmark": name,
                "scheme": scheme,
                "layout_ops": addr_ops[scheme],
                "descriptors_per_tile": plan.n_bursts,
            })
    return rows
