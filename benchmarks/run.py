"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per the repo convention, then a
readable report.  Roofline terms come from the dry-run records
(benchmarks/results/dryrun) when present.
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

RESULTS = Path(__file__).parent / "results"


def _csv(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.2f},{derived}")


def table1_suite() -> None:
    """Table I: the benchmark suite runs end-to-end through facet storage."""
    import jax.numpy as jnp
    import numpy as np
    from repro import cfa

    for name, prog in cfa.PROGRAMS.items():
        t = tuple(min(x, 4) for x in prog.default_tile)
        space = tuple(2 * x for x in t)
        compiled = cfa.compile(prog, space, layout=t, backend="sweep")
        rng = np.random.default_rng(0)
        spec = compiled.pipeline.specs[0]
        inputs = jnp.asarray(rng.normal(size=(spec.width, *space[1:])))
        t0 = time.perf_counter()
        facets = compiled(inputs)
        us = 1e6 * (time.perf_counter() - t0)
        V = compiled.reference(inputs)
        from repro.core.cfa import pack_facet
        ok = "n/a"
        if spec.tile_sizes[0] % spec.width == 0:
            want = pack_facet(V.astype(jnp.float32), spec)
            err = float(jnp.abs(facets[0][1:] - want).max())
            ok = f"max_err={err:.2e}"
        _csv(f"table1/{name}", us, f"deps={len(prog.deps.vectors)};{ok}")


def fig15_bandwidth() -> None:
    from benchmarks.bandwidth_fig15 import run_fig15

    rows = run_fig15()
    (RESULTS / "fig15.json").write_text(json.dumps(rows, indent=1))
    for r in rows:
        if r["model"] == "axi-zc706":
            _csv(
                f"fig15/{r['benchmark']}/{r['tile']}/{r['scheme']}",
                r["time_us"],
                f"raw={r['raw_frac']:.3f};eff={r['eff_frac']:.3f};"
                f"bursts={r['n_bursts']}",
            )


def fig16_area() -> None:
    from benchmarks.area_fig16 import run_fig16

    rows = run_fig16()
    (RESULTS / "fig16.json").write_text(json.dumps(rows, indent=1))
    for r in rows:
        _csv(f"fig16/{r['benchmark']}/{r['scheme']}", 0.0,
             f"layout_ops={r['layout_ops']};descriptors={r['descriptors_per_tile']}")


def fig17_vmem() -> None:
    from benchmarks.vmem_fig17 import run_fig17

    rows = run_fig17()
    (RESULTS / "fig17.json").write_text(json.dumps(rows, indent=1))
    for r in rows:
        _csv(f"fig17/{r['benchmark']}/{r['tile']}", 0.0,
             f"cfa={r['cfa_vmem_frac']:.4f};bbox={r['bbox_vmem_frac']:.4f};"
             f"dt={r['data_tiling_vmem_frac']:.4f}")


def kvcache() -> None:
    from benchmarks.kvcache_bench import run_kvcache_bench, run_kvcache_walltime

    rows = run_kvcache_bench()
    (RESULTS / "kvcache.json").write_text(json.dumps(rows, indent=1))
    for r in rows:
        _csv(f"kvcache/{r['shape']}", 0.0,
             f"block_eff={r['block_eff_frac']:.3f};"
             f"canon_eff={r['canonical_eff_frac']:.3f};speedup={r['speedup']:.1f}x")
    wt = run_kvcache_walltime()
    _csv("kvcache/walltime_block", wt["block_us"], "jnp-cpu-sanity")
    _csv("kvcache/walltime_canonical", wt["canonical_us"], "jnp-cpu-sanity")


def multiport() -> None:
    """Paper §VII future work: facet distribution over HBM ports."""
    from repro.core.cfa import AXI_ZC706, TPU_V5E_HBM, IterSpace, Tiling, get_program
    from repro.core.cfa.multiport import port_speedup

    rows = []
    prog = get_program("jacobi2d5p")
    space, tiling = IterSpace((64, 64, 64)), Tiling((16, 16, 16))
    for model in (AXI_ZC706, TPU_V5E_HBM):
        for n in (1, 2, 3):
            r = port_speedup(space, prog.deps, tiling, n, model)
            rows.append(dict(r, model=model.name))
            _csv(f"multiport/{model.name}/{n}ports", r["t_multi_us"],
                 f"speedup={r['speedup']:.2f};balance={r['balance']:.2f}")
    out = RESULTS / "multiport" / "quick.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rows, indent=1))


def autotune_table() -> None:
    """Layout autotuner: winning layout per benchmark vs the hand-coded plans."""
    from repro import cfa
    from repro.core.cfa import IterSpace, hand_coded_baselines

    rows = []
    for name, prog in cfa.PROGRAMS.items():
        space = tuple(2 * t for t in prog.default_tile)
        # decision-only: the front door's cfa.autotune, no executor needed
        d = cfa.autotune(prog, space, cfa.AXI_ZC706, seed=0, budget=64)
        base = hand_coded_baselines(prog, IterSpace(space), cfa.AXI_ZC706)
        gain = d.best.effective_bw / max(s.effective_bw for s in base.values())
        rows.append({
            "benchmark": name,
            "space": list(space),
            "winner": d.best.candidate.key,
            "eff_frac": d.best.peak_fraction_effective,
            "gain_vs_hand_coded": gain,
            "evaluated": d.evaluated,
            "from_cache": d.from_cache,
        })
        _csv(f"autotune/{name}", 0.0,
             f"winner={d.best.candidate.key};"
             f"eff={d.best.peak_fraction_effective:.3f};gain={gain:.2f}x")
    out = RESULTS / "autotune" / "quick.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rows, indent=1))


def roofline_table() -> None:
    from benchmarks.roofline import build_table

    rows = build_table("single")
    if not rows:
        print("# roofline: no dry-run records found (run repro.launch.dryrun)")
        return
    (RESULTS / "roofline_single.json").write_text(json.dumps(rows, indent=1))
    for r in rows:
        _csv(
            f"roofline/{r['arch']}/{r['cell']}", 0.0,
            f"dominant={r['dominant']};frac={r['roofline_fraction']:.4f};"
            f"useful={r['useful_ratio']:.2f}",
        )


def main() -> None:
    RESULTS.mkdir(parents=True, exist_ok=True)
    print("name,us_per_call,derived")
    table1_suite()
    fig15_bandwidth()
    fig16_area()
    fig17_vmem()
    kvcache()
    multiport()
    autotune_table()
    roofline_table()


if __name__ == "__main__":
    main()
