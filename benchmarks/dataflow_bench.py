"""Overlapped (dataflow) vs sequential tile schedules, measured + modeled.

The dataflow backend pipelines fetch/compute/commit (Fig. 13 DATAFLOW);
its modeled counterpart is ``BurstModel.time(..., overlap=True)``.  This
benchmark pins the *measured* overlapped-vs-sequential speedup per Table I
program on this host, against the modeled and host-fitted predictions,
with modeled-vs-measured relative error recorded through the calibration
layer (``fit_burst_model``).

Per program the interior-tile CFA plan is taken at a scaled tile and
*wave-coalesced*: consecutive tiles' facet blocks are adjacent in memory
along the extension direction (§IV-H inter-tile contiguity), so a wave of
R tiles prefetches R-times-*longer* bursts, not R-times-*more* bursts —
this is the burst-merging the layout exists for, and it keeps the measured
schedule copy-bound rather than python-dispatch-bound.  Each plan is then
timed sequentially (transfer then compute) and overlapped (compute spun
while the copies are in flight) across three compute regimes:
transfer-bound (compute = T/2), balanced (= T, where the modeled gain
peaks at 2x) and compute-bound (= 5T).

    PYTHONPATH=src python benchmarks/dataflow_bench.py            # full suite
    PYTHONPATH=src python benchmarks/dataflow_bench.py --smoke    # CI leg
    PYTHONPATH=src python benchmarks/dataflow_bench.py \
        --program jacobi2d5p --model axi-zc706

Writes one JSON per (tag, model) to benchmarks/results/dataflow/ (schema
in benchmarks/results/README.md).  ``--smoke`` shrinks the sweep to
jacobi2d5p + heat3d on the AXI preset, asserts the structural invariants
(never wall-clock tolerances — a noisy runner must not flake the job) and
STILL writes the JSON as the CI artifact.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.cfa import (AXI_ZC706, IterSpace, PROGRAMS, TPU_V5E_HBM,
                            Tiling, overlap_speedup)
from repro.core.cfa.calibrate import (TransferSample, fit_burst_model,
                                      measure_plan, measurement_noise,
                                      timing_unusable_reason)
from repro.core.cfa.executors import host_fingerprint
from repro.core.cfa.plans import TransferPlan, cfa_plan, interior_tile

OUT = Path(__file__).parent / "results" / "dataflow"
MODELS = {m.name: m for m in (AXI_ZC706, TPU_V5E_HBM)}
#: (regime label, compute as a fraction of the measured transfer time)
REGIMES = (("transfer-bound", 0.5), ("balanced", 1.0), ("compute-bound", 5.0))
#: tile = default_tile * SCALE[ndim] — big enough for copy-bound bursts,
#: small enough that the exact burst enumeration stays a few seconds
SCALE = {2: 16, 3: 4, 4: 2}
#: synthetic grid the host fit is trained on (copy-bound sizes included:
#: the fit must see the regime the wave schedules run in)
FIT_GRID = ((4096,), (1 << 20,), (1 << 22,), (1 << 23,), (1 << 22,) * 2)


def wave_plan(prog, *, bytes_target: float, elem_bytes: int):
    """The program's interior-tile plan at the scaled tile, wave-coalesced
    to ~``bytes_target`` wire bytes.  Returns (plan, tile, space, R)."""
    tile = tuple(t * SCALE[len(prog.default_tile)] for t in prog.default_tile)
    sp = IterSpace(tuple(2 * t for t in tile))
    tiling = Tiling(tile)
    p = cfa_plan(sp, prog.deps, tiling, interior_tile(sp, tiling))
    per_tile = (sum(p.read_runs) + sum(p.write_runs)) * elem_bytes
    R = max(1, min(1024, int(bytes_target // per_tile)))
    coalesced = TransferPlan(
        scheme=p.scheme,
        read_runs=tuple(r * R for r in p.read_runs),
        write_runs=tuple(r * R for r in p.write_runs),
        read_useful=p.read_useful * R,
        write_useful=p.write_useful * R,
        storage=p.storage,
    )
    return coalesced, tile, sp.sizes, R


def grid_samples(model, mkw):
    """Measured synthetic-grid samples (the fit's anchors)."""
    from repro.core.cfa.calibrate import measure_runs

    return [
        TransferSample(runs_by_port=(s,), elem_bytes=model.elem_bytes,
                       measured_s=measure_runs(s, model.elem_bytes, **mkw),
                       label=f"grid/{len(s)}x{s[0]}")
        for s in FIT_GRID
    ]


def rel_err(predicted: float, measured: float) -> float:
    return abs(predicted - measured) / measured


def run_program(name, model, fitted, plan, tile, space, R, t_meas,
                args) -> dict:
    mkw = dict(warmup=args.warmup, repeats=args.repeats)
    row = {
        "program": name,
        "space": list(space),
        "tile": list(tile),
        "model": model.name,
        "storage": plan.storage,
        "wave_factor": R,
        "n_bursts": plan.n_bursts,
        "wire_bytes": (sum(plan.read_runs) + sum(plan.write_runs))
        * model.elem_bytes,
        "transfer": {
            "modeled_s": model.time(plan),
            "fitted_s": fitted.time(plan),
            "measured_s": t_meas,
            "rel_err_modeled": rel_err(model.time(plan), t_meas),
            "rel_err_fitted": rel_err(fitted.time(plan), t_meas),
        },
        "regimes": [],
    }
    for regime, ratio in REGIMES:
        c = ratio * t_meas  # regime fidelity on THIS host, not the model's
        t_seq = measure_plan(plan, model, compute_s=c, overlap=False, **mkw)
        t_ovl = measure_plan(plan, model, compute_s=c, overlap=True, **mkw)
        modeled = overlap_speedup(plan, model, compute_s=c)
        fit_ovl = fitted.time(plan, compute_s=c, overlap=True)
        fit_seq = fitted.time(plan, compute_s=c, overlap=False)
        row["regimes"].append({
            "regime": regime,
            "compute_ratio": ratio,
            "compute_s": c,
            "measured": {"t_seq_s": t_seq, "t_ovl_s": t_ovl,
                         "speedup": t_seq / t_ovl},
            "modeled": {"t_seq_s": modeled["t_sequential_s"],
                        "t_ovl_s": modeled["t_overlapped_s"],
                        "speedup": modeled["speedup"],
                        "bound": modeled["bound"]},
            "fitted": {"t_seq_s": fit_seq, "t_ovl_s": fit_ovl,
                       "speedup": fit_seq / fit_ovl},
            "rel_err_modeled_overlap": rel_err(modeled["t_overlapped_s"],
                                               t_ovl),
            "rel_err_fitted_overlap": rel_err(fit_ovl, t_ovl),
        })
    return row


def headline(rows) -> dict:
    """The acceptance pin: measured overlapped-vs-sequential speedup on the
    transfer-bound regime, best program forward."""
    tb = [(r["program"],
           next(g for g in r["regimes"] if g["regime"] == "transfer-bound"))
          for r in rows]
    best_name, best = max(tb, key=lambda ng: ng[1]["measured"]["speedup"])
    return {
        "transfer_bound_overlap_demonstrated":
            best["measured"]["speedup"] > 1.0,
        "best_transfer_bound": {
            "program": best_name,
            "measured_speedup": best["measured"]["speedup"],
            "modeled_speedup": best["modeled"]["speedup"],
        },
        "max_rel_err_fitted_overlap": max(
            g["rel_err_fitted_overlap"] for r in rows for g in r["regimes"]),
    }


def check_smoke(record: dict) -> None:
    """Structural invariants only — never wall-clock tolerances."""
    assert record["rows"], "no program rows"
    for r in record["rows"]:
        assert r["n_bursts"] > 0 and r["wave_factor"] >= 1
        assert r["transfer"]["measured_s"] > 0.0
        assert r["transfer"]["rel_err_modeled"] >= 0.0
        assert r["transfer"]["rel_err_fitted"] >= 0.0
        assert [g["regime"] for g in r["regimes"]] == [n for n, _ in REGIMES]
        for g in r["regimes"]:
            assert g["measured"]["t_seq_s"] > 0.0
            assert g["measured"]["t_ovl_s"] > 0.0
            # the modeled gain obeys its own bounds by construction
            assert 1.0 - 1e-12 <= g["modeled"]["speedup"]
            assert g["modeled"]["speedup"] <= g["modeled"]["bound"] + 1e-12
            assert g["rel_err_modeled_overlap"] >= 0.0
            assert g["rel_err_fitted_overlap"] >= 0.0
    assert set(record["headline"]) == {
        "transfer_bound_overlap_demonstrated", "best_transfer_bound",
        "max_rel_err_fitted_overlap"}
    # what CI uploads must be reloadable
    assert json.loads(json.dumps(record)) == record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--program", choices=sorted(PROGRAMS), default=None,
                    help="one benchmark (default: the whole suite)")
    ap.add_argument("--model", choices=sorted(MODELS), default=None,
                    help="one preset (default: both)")
    ap.add_argument("--bytes-target", type=float, default=48e6,
                    help="wave-coalesced wire bytes per schedule (default 48M)")
    ap.add_argument("--warmup", type=int, default=1,
                    help="warmup passes per measurement")
    ap.add_argument("--repeats", type=int, default=5,
                    help="median-of-k repeats per measurement")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: jacobi2d5p + heat3d, AXI, asserts the "
                         "structural invariants and still writes the JSON")
    args = ap.parse_args()

    reason = timing_unusable_reason()
    if reason is not None:
        print(f"WARNING: host timing looks unreliable ({reason}); "
              f"measurements will be noisy but the sweep still runs")

    if args.smoke:
        # the wave must stay larger than the host's LLC, like the fit grid,
        # or the fitted peak misses the cache tier the wave runs in — the
        # byte target is NOT shrunk for smoke, only the program set is
        args.model = args.model or "axi-zc706"
        args.repeats = min(args.repeats, 3)
        names = [args.program] if args.program else ["jacobi2d5p", "heat3d"]
    else:
        names = [args.program] if args.program else sorted(PROGRAMS)
    models = [MODELS[args.model]] if args.model else [AXI_ZC706, TPU_V5E_HBM]

    OUT.mkdir(parents=True, exist_ok=True)
    tag = args.program or ("smoke" if args.smoke else "suite")
    for model in models:
        mkw = dict(warmup=args.warmup, repeats=args.repeats)
        # measure every wave's plain transfer FIRST and feed those points
        # into the fit alongside the synthetic grid (calibrate() does the
        # same): the fitted model must see the burst-size mix the regime
        # measurements actually run in, or a cache-tier mismatch between
        # grid and wave sizes dominates the recorded errors
        samples = grid_samples(model, mkw)
        waves = {}
        for n in names:
            plan, tile, space, R = wave_plan(
                PROGRAMS[n], bytes_target=args.bytes_target,
                elem_bytes=model.elem_bytes)
            t_meas = measure_plan(plan, model, **mkw)
            waves[n] = (plan, tile, space, R, t_meas)
            samples.append(TransferSample(
                runs_by_port=(plan.read_runs + plan.write_runs,),
                elem_bytes=model.elem_bytes, measured_s=t_meas,
                label=f"plan/{n}"))
        fitted = fit_burst_model(samples, model)
        rows = [run_program(n, model, fitted, *waves[n], args) for n in names]
        record = {
            "model": model.name,
            "base": {k: v for k, v in dataclasses.asdict(model).items()},
            "fitted": {"setup_s": fitted.setup_s,
                       "peak_bytes_per_s": fitted.peak_bytes_per_s},
            "host": host_fingerprint(),
            "noise": measurement_noise(),
            "bytes_target": args.bytes_target,
            "rows": rows,
            "headline": headline(rows),
        }
        print(f"== {model.name} ==")
        print(f"{'program':>20} {'regime':>15} {'measured':>9} "
              f"{'modeled':>8} {'bound':>6} {'err_fit':>8}")
        for r in rows:
            for g in r["regimes"]:
                print(f"{r['program']:>20} {g['regime']:>15} "
                      f"{g['measured']['speedup']:>8.2f}x "
                      f"{g['modeled']['speedup']:>7.2f}x "
                      f"{g['modeled']['bound']:>5.2f}x "
                      f"{g['rel_err_fitted_overlap']:>8.1%}")
        h = record["headline"]
        print(f"headline: transfer-bound overlap "
              f"{'demonstrated' if h['transfer_bound_overlap_demonstrated'] else 'NOT demonstrated'} "
              f"(best {h['best_transfer_bound']['program']}: "
              f"{h['best_transfer_bound']['measured_speedup']:.2f}x measured, "
              f"{h['best_transfer_bound']['modeled_speedup']:.2f}x modeled)")
        if args.smoke:
            check_smoke(record)
        out = OUT / f"{tag}_{model.name}.json"
        out.write_text(json.dumps(record, indent=2) + "\n")
        print(f"wrote {out}\n")

    if args.smoke:
        print("smoke OK: per-regime measured/modeled/fitted rows recorded, "
              "modeled gain within bounds, artifact round-trips")


if __name__ == "__main__":
    main()
