"""Fig. 17 analogue: on-chip memory (BRAM -> VMEM) per benchmark x tile size.

The paper's claim: CFA does not change the on-chip allocation, so its BRAM
cost equals the original layout's; bounding-box/data-tiling baselines pay
extra for their redundant footprints.  Here: VMEM working set of the tile
executor = halo buffer + output tile (+ the over-approximated footprint for
the redundant baselines), against a 128 MiB VMEM budget.
"""
from __future__ import annotations

import math

from repro.core.cfa import (
    IterSpace,
    Tiling,
    bounding_box_plan,
    data_tiling_plan,
    facet_widths,
    get_program,
    PROGRAMS,
)

VMEM_BYTES = 128 * 2**20
ELEM = 4  # f32 on-chip


def run_fig17():
    rows = []
    for name, prog in PROGRAMS.items():
        w = facet_widths(prog.deps)
        for t in prog.paper_tiles:
            halo = math.prod(wi + ti for wi, ti in zip(w, t))
            tile = math.prod(t)
            cfa = (halo + tile) * ELEM
            # original layout needs the same on-chip tile (paper's point)
            original = cfa
            space = IterSpace(tuple(3 * x for x in t))
            tiling = Tiling(t)
            bb = bounding_box_plan(space, prog.deps, tiling)
            dt = data_tiling_plan(space, prog.deps, tiling)
            bbox = (bb.read_transferred + tile) * ELEM
            dtil = (dt.read_transferred + tile) * ELEM
            rows.append({
                "benchmark": name,
                "tile": "x".join(map(str, t)),
                "cfa_vmem_frac": cfa / VMEM_BYTES,
                "original_vmem_frac": original / VMEM_BYTES,
                "bbox_vmem_frac": bbox / VMEM_BYTES,
                "data_tiling_vmem_frac": dtil / VMEM_BYTES,
            })
    return rows
