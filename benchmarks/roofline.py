"""Roofline analysis from the dry-run records (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape) on the single-pod mesh (per assignment):

    compute    = HLO_FLOPs / (chips * 197 TFLOP/s bf16)
    memory     = HLO_bytes / (chips * 819 GB/s HBM)
    collective = collective_bytes / (chips * 50 GB/s/link ICI)

HLO_FLOPs / bytes / collective bytes come from the loop-aware static HLO
analysis (benchmarks/hlo_analysis.py) — the records store them *per device*
(the SPMD program is per-device), so dividing by per-chip peaks directly
yields seconds.  MODEL_FLOPS is 6*N*D for training (N = active params,
D = tokens) and 2*N*D for inference, giving the useful-work ratio
MODEL_FLOPS / HLO_FLOPs that catches remat/padding/redundancy waste, and the
roofline fraction = useful-compute time / dominant term.
"""
from __future__ import annotations

import json
from pathlib import Path

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link

RESULTS = Path(__file__).parent / "results" / "dryrun"


def model_flops_per_device(cfg, cell_name: str, n_devices: int) -> float:
    from repro.launch.specs import SHAPE_CELLS

    info = SHAPE_CELLS[cell_name]
    n_active = cfg.active_param_count()
    if info["kind"] == "train":
        tokens = info["batch"] * info["seq"]
        total = 6.0 * n_active * tokens
    elif info["kind"] == "prefill":
        seq = info["seq"] if not cfg.is_encdec else max(info["seq"] // 8, 128)
        tokens = info["batch"] * seq
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * info["batch"]
    return total / n_devices


def analytic_bytes_per_device(cfg, cell_name: str, n_devices: int) -> float:
    """First-order HBM traffic (napkin math, per device per step).

    Exact for decode (params + whole KV/state cache read once per token);
    first-order for train/prefill (weights per pass, activation block
    boundaries, optimizer state, logits).  The HLO-parsed number is an
    upper bound (fusion operands it cannot prove sliced); the truth lies
    between — both are reported.
    """
    from repro.launch.specs import SHAPE_CELLS

    info = SHAPE_CELLS[cell_name]
    kind, seq, batch = info["kind"], info["seq"], info["batch"]
    n_params = cfg.param_count()
    p_bytes = n_params * 4 / n_devices  # f32 master weights, sharded
    d = cfg.d_model
    L = cfg.n_layers
    bloc_tokens = batch * (seq if kind != "decode" else 1) / n_devices

    act_block = bloc_tokens * d * 2  # bf16 activations at one boundary
    logits = bloc_tokens * cfg.padded_vocab * 2 / cfg.tp  # vocab-sharded

    # KV/state cache bytes per device (decode reads all of it each step)
    cache = 0.0
    n_attn = sum(1 for k in cfg.period if k in ("attn", "dec")) * cfg.n_periods
    if kind != "train" and n_attn:
        import numpy as _np
        kv_elem = _np.dtype(cfg.kv_cache_dtype).itemsize
        kvb = (batch * seq * cfg.stored_kv_heads * cfg.head_dim * 2 * kv_elem)
        cache += n_attn * kvb / n_devices
    n_mamba = sum(1 for k in cfg.period if k == "mamba") * cfg.n_periods
    if kind != "train" and n_mamba:
        cache += n_mamba * batch * cfg.ssm_heads * cfg.ssm_head_dim * \
            cfg.ssm_state * 4 / n_devices

    if kind == "train":
        # 3 weight passes (fwd, remat fwd, bwd) at bf16-read each, grads f32
        # r/w, adam m/v r/w, params r/w + ~6 activation touches per layer
        # boundary + logits fwd/bwd
        opt_mult = 10.0 if cfg.optimizer == "adamw" else 6.0
        return (3 * p_bytes / 2 + opt_mult * p_bytes
                + 6 * L * act_block + 3 * logits)
    if kind == "prefill":
        return p_bytes / 2 + 2 * L * act_block + cache + logits
    # decode
    return p_bytes / 2 + cache + 2 * act_block * L + logits


def load_records(mesh: str = "single") -> list[dict]:
    out = []
    for p in sorted(RESULTS.glob(f"*__{mesh}.json")):
        rec = json.loads(p.read_text())
        out.append(rec)
    return out


def roofline_row(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    from repro.configs import get_config

    la = rec.get("loop_aware", {})
    if "flops" not in la:
        return None
    cfg = get_config(rec["arch"])
    nd = rec["n_devices"]
    flops = la["flops"]  # per device
    hbm_ub = la["hbm_traffic_bytes"]  # HLO-parsed upper bound
    hbm_lb = analytic_bytes_per_device(cfg, rec["cell"], nd)  # napkin math
    coll = sum(la["collective_bytes"].values())
    t_compute = flops / PEAK_FLOPS
    t_memory = hbm_lb / HBM_BW  # dominant-term decisions use the analytic
    t_memory_ub = hbm_ub / HBM_BW  # ...with the parsed bound alongside
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(cfg, rec["cell"], nd)
    t_useful = mf / PEAK_FLOPS
    frac = t_useful / max(terms.values()) if max(terms.values()) > 0 else 0.0
    mem = rec.get("memory", {})
    perdev_gib = (mem.get("argument_size_in_bytes", 0)
                  + mem.get("temp_size_in_bytes", 0)) / 2**30
    return {
        "arch": rec["arch"],
        "cell": rec["cell"],
        "mesh": rec["mesh"],
        "n_devices": nd,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_memory_ub_s": t_memory_ub,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_dev": mf,
        "hlo_flops_dev": flops,
        "useful_ratio": mf / flops if flops else 0.0,
        "roofline_fraction": frac,
        "perdev_mem_gib": perdev_gib,
        "collective_detail_gib": {
            k: v / 2**30 for k, v in la["collective_bytes"].items() if v
        },
    }


def build_table(mesh: str = "single") -> list[dict]:
    rows = []
    for rec in load_records(mesh):
        r = roofline_row(rec)
        if r:
            rows.append(r)
    return rows


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | cell | compute s | memory s | collective s | dominant | "
           "MODEL/HLO | roofline frac | mem GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in sorted(rows, key=lambda r: (r["arch"], r["cell"])):
        body += (
            f"| {r['arch']} | {r['cell']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {r['perdev_mem_gib']:.1f} |\n"
        )
    return hdr + body


def main() -> None:
    rows = build_table("single")
    out = Path(__file__).parent / "results" / "roofline_single.json"
    out.write_text(json.dumps(rows, indent=1))
    print(to_markdown(rows))
    worst = sorted(rows, key=lambda r: r["roofline_fraction"])[:5]
    print("\nworst roofline fractions:")
    for r in worst:
        print(f"  {r['arch']} {r['cell']}: {r['roofline_fraction']:.4f} "
              f"(dominant {r['dominant']})")
    collb = sorted(rows, key=lambda r: -(r["t_collective_s"]
                                         / max(r["t_compute_s"], 1e-12)))[:5]
    print("most collective-bound (collective/compute):")
    for r in collb:
        print(f"  {r['arch']} {r['cell']}: "
              f"{r['t_collective_s'] / max(r['t_compute_s'], 1e-12):.2f}")


if __name__ == "__main__":
    main()
