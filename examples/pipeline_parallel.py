"""Pipeline parallelism demo: 4 stages, 8 microbatches, GPipe schedule.

Runs in a subprocess with forced host devices so the parent interpreter's
single-device state is untouched.

    PYTHONPATH=src python examples/pipeline_parallel.py
"""
import os
import subprocess
import sys

SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import pipeline_apply

mesh = jax.make_mesh((4,), ("pipe",))
S, M, B, D = 4, 8, 2, 64
W = jax.random.normal(jax.random.PRNGKey(0), (S, D, D)) * 0.3
x = jax.random.normal(jax.random.PRNGKey(1), (M, B, D))
stage = lambda w, h: jnp.tanh(h @ w)

out = pipeline_apply(stage, W, x, mesh)
want = x
for s in range(S):
    want = jnp.tanh(want @ W[s])
err = float(jnp.abs(out - want).max())
bubble = (S - 1) / (M + S - 1)
print(f"4-stage pipeline over {M} microbatches: err={err:.2e}, "
      f"bubble fraction={bubble:.0%}")
assert err < 1e-5
print("OK")
"""

if __name__ == "__main__":
    env = dict(os.environ)
    sys.exit(subprocess.call([sys.executable, "-c", SCRIPT], env=env))
