"""Stencil application through the compiled CFA pipeline + Pallas executor.

Runs a gaussian blur (the paper's 5x5 benchmark) over a 2-D grid for several
time steps through ``cfa.compile(..., backend="pallas")``: flow-in gathered
from facet arrays (contiguous block reads), tiles executed by the Pallas
tile kernel (interpret mode on CPU; MXU-tiled on TPU), flow-out written as
single-burst facet blocks.

    PYTHONPATH=src python examples/stencil_pipeline.py
"""
import numpy as np
import jax.numpy as jnp

from repro import cfa

compiled = cfa.compile("gaussian", (4, 32, 32), layout=(2, 16, 16),
                       backend="pallas")
print(compiled.describe())

rng = np.random.default_rng(0)
image = rng.normal(size=(32, 32)).astype(np.float32)
inputs = jnp.asarray(np.stack([image] * compiled.pipeline.specs[0].width))

facets = compiled(inputs)  # every tile runs through the Pallas executor
n_tiles = int(np.prod(compiled.pipeline.num_tiles))

V = compiled.reference(inputs)
from repro.core.cfa import pack_facet
err = float(jnp.abs(facets[0][1:] - pack_facet(V, compiled.pipeline.specs[0])).max())
print(f"{n_tiles} tiles through the Pallas executor; oracle err {err:.2e}")
assert err < 1e-4

# the jnp wavefront backend produces the same facet storage (the jitted
# kernel agrees to float rounding)
wave = compiled.lower("wavefront")(inputs)
for k in facets:
    np.testing.assert_allclose(np.asarray(facets[k]), np.asarray(wave[k]),
                               rtol=1e-5, atol=1e-5)
print("pallas == wavefront (to rounding)")
print("OK")
