"""Stencil application through the full CFA pipeline + Pallas tile executor.

Runs a gaussian blur (the paper's 5x5 benchmark) over a 2-D grid for several
time steps: flow-in gathered from facet arrays (contiguous block reads),
tiles executed by the Pallas kernel (interpret mode on CPU; MXU-tiled on
TPU), flow-out written as single-burst facet blocks.

    PYTHONPATH=src python examples/stencil_pipeline.py
"""
import itertools

import numpy as np
import jax.numpy as jnp

from repro.core.cfa import CFAPipeline, IterSpace, Tiling, get_program
from repro.kernels.stencil import execute_tiles

prog = get_program("gaussian")
space, tiling = IterSpace((4, 32, 32)), Tiling((2, 16, 16))
pipe = CFAPipeline(prog, space, tiling)

rng = np.random.default_rng(0)
image = rng.normal(size=(32, 32)).astype(np.float32)
inputs = jnp.asarray(np.stack([image] * pipe.specs[0].width))

facets = pipe.init_facets(jnp.float32)
facets = pipe.load_inputs(facets, inputs)

n_kernel_tiles = 0
for tile in itertools.product(*(range(n) for n in pipe.num_tiles)):
    H = pipe.copy_in(facets, tile)  # contiguous facet-block reads
    out = execute_tiles("gaussian", H[None], tiling.sizes, interpret=True)[0]
    H = H.at[prog.widths[0]:, prog.widths[1]:, prog.widths[2]:].set(out)
    facets = pipe.copy_out(facets, tile, H)  # single-burst facet writes
    n_kernel_tiles += 1

V = pipe.reference_volume(inputs)
from repro.core.cfa import pack_facet
err = float(jnp.abs(facets[0][1:] - pack_facet(V, pipe.specs[0])).max())
print(f"{n_kernel_tiles} tiles through the Pallas executor; oracle err {err:.2e}")
assert err < 1e-4
print("OK")
