"""End-to-end driver: train a ~100M-parameter LM with the full stack —
synthetic packed data, AdamW + cosine schedule, remat, async fault-tolerant
checkpointing.

    PYTHONPATH=src python examples/train_lm.py --steps 300

On this CPU container a step takes O(10 s); pass --steps 10 for a quick run.
Kill it mid-run and rerun: it resumes from the latest checkpoint.
"""
import argparse

from repro.models.config import ArchConfig
from repro.data.pipeline import PackedDocs
from repro.train.loop import Trainer
from repro.train.steps import TrainHParams

# ~114M parameters: a llama-family dense config
CFG_100M = ArchConfig(
    name="demo-100m",
    family="dense",
    n_layers=10,
    d_model=640,
    n_heads=10,
    n_kv_heads=5,
    d_ff=2560,
    vocab=50304,
    head_dim=64,
    rope_theta=10_000.0,
    period=("attn",),
    tp=1,
    kv_block=64,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_demo_100m")
    args = ap.parse_args()

    print(f"params ~= {CFG_100M.param_count()/1e6:.0f}M")
    hp = TrainHParams(peak_lr=3e-4, warmup=20, total_steps=args.steps,
                      remat=True)
    data = PackedDocs(vocab=CFG_100M.vocab, batch=args.batch, seq=args.seq)
    tr = Trainer(CFG_100M, batch=args.batch, seq=args.seq,
                 ckpt_dir=args.ckpt_dir, hp=hp, data=data, ckpt_every=50)
    log = tr.run(args.steps, log_every=5)
    for m in log:
        print(f"step {m['step']:4d}  loss {m['loss']:.4f}  "
              f"lr {m['lr']:.2e}  {m['dt']:.2f}s")
    tr.data.close()


if __name__ == "__main__":
    main()
