"""Quickstart: Canonical Facet Allocation in five minutes.

One call — ``cfa.compile`` — picks a burst-friendly layout for the paper's
running example (a 3-D skewed jacobi iteration space), builds the
read->execute->write schedule and binds an execution backend.  The compiled
stencil then runs the tiled computation entirely through facet storage,
verifies against the untiled oracle, and prints the burst statistics that
are the paper's whole point.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro import cfa

prog = cfa.get_program("jacobi2d5p")
space = (16, 32, 32)

# 1. compile: layout search + planning + backend selection in one call ------
compiled = cfa.compile(prog, space, target="axi-zc706",
                       autotune_kwargs=dict(seed=0, budget=64))
print(f"dependence pattern ({len(prog.deps.vectors)} vectors): "
      f"{prog.deps.vectors}")
print(f"facet widths w_k = {prog.widths}")
for k, s in compiled.pipeline.specs.items():
    print(f"  facet_{k}: shape {s.shape}  outer={s.outer_axes} inner={s.inner_axes}")
print(f"autotuned layout: {compiled.layout.key}  "
      f"({compiled.decision.evaluated} candidates scored"
      f"{', cached' if compiled.decision.from_cache else ''})")
print(f"backend: {compiled.backend}  (auto rule: sharded if n_ports > 1, "
      f"pallas on 3-D, wavefront otherwise)")

# 2. the compiled plan: burst statistics vs the paper's baselines -----------
from repro.core.cfa import (IterSpace, Tiling, bounding_box_plan,
                            original_layout_plan)

tiling = Tiling(compiled.layout.tile)
rep = compiled.report()
print(f"\n{'CFA (compiled)':>14}: {compiled.plan.n_bursts:5d} bursts/tile, "
      f"redundancy {compiled.plan.redundancy:5.1%}, "
      f"effective bw {rep.peak_fraction_effective:6.1%} (AXI) "
      f"{compiled.report(cfa.TPU_V5E_HBM).peak_fraction_effective:6.1%} (TPU DMA)")
for name, plan in [
    ("original", original_layout_plan(IterSpace(space), prog.deps, tiling)),
    ("bounding-box", bounding_box_plan(IterSpace(space), prog.deps, tiling)),
]:
    axi = cfa.BandwidthReport.evaluate(plan, cfa.AXI_ZC706)
    tpu = cfa.BandwidthReport.evaluate(plan, cfa.TPU_V5E_HBM)
    print(f"{name:>14}: {plan.n_bursts:5d} bursts/tile, "
          f"redundancy {plan.redundancy:5.1%}, "
          f"effective bw {axi.peak_fraction_effective:6.1%} (AXI) "
          f"{tpu.peak_fraction_effective:6.1%} (TPU DMA)")

# 3. run it: the whole computation through facet storage --------------------
rng = np.random.default_rng(0)
inputs = jnp.asarray(rng.normal(size=(1, 32, 32)), jnp.float32)
facets = compiled(inputs)

V = compiled.reference(inputs)  # the untiled oracle
from repro.core.cfa import pack_facet
spec = compiled.pipeline.specs[0]
err = float(jnp.abs(facets[0][1:] - pack_facet(V, spec)).max())
print(f"\ncompiled stencil == untiled oracle: max err {err:.2e}")
assert err < 1e-5

# 4. rebind backends: same layout, different executors ----------------------
# (sweep and wavefront are bit-identical to each other; the Pallas kernel
# backend above is jitted, so it agrees to float rounding, not bitwise)
sweep = compiled.lower("sweep")(inputs)
wave = compiled.lower("wavefront")(inputs)
assert all(bool(jnp.array_equal(sweep[k], wave[k])) for k in facets)
for k in facets:
    np.testing.assert_allclose(np.asarray(facets[k]), np.asarray(sweep[k]),
                               rtol=1e-5, atol=1e-5)
print("backends sweep == wavefront (bit-exact), pallas == both (to rounding)")
print("OK")
