"""Quickstart: Canonical Facet Allocation in five minutes.

Builds the paper's running example (a 3-D skewed jacobi iteration space),
derives the facet layout from the dependence pattern, runs the tiled
computation entirely through facet storage, verifies it against the untiled
oracle, prints the burst statistics that are the paper's whole point, and
lets the layout autotuner pick an even better layout for the workload.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.cfa import (
    AXI_ZC706, TPU_V5E_HBM, BandwidthReport, CFAPipeline, IterSpace, Tiling,
    autotune, bounding_box_plan, build_facet_specs, cfa_plan, get_program,
    original_layout_plan,
)

prog = get_program("jacobi2d5p")
space, tiling = IterSpace((16, 32, 32)), Tiling((8, 8, 8))

# 1. the facet layout, derived from the dependence pattern ------------------
specs = build_facet_specs(space, prog.deps, tiling)
print(f"dependence pattern ({len(prog.deps.vectors)} vectors): "
      f"{prog.deps.vectors}")
print(f"facet widths w_k = {prog.widths}")
for k, s in specs.items():
    print(f"  facet_{k}: shape {s.shape}  outer={s.outer_axes} inner={s.inner_axes}")

# 2. burst plans: CFA vs baselines -----------------------------------------
for name, plan in [
    ("CFA", cfa_plan(space, prog.deps, tiling)),
    ("original", original_layout_plan(space, prog.deps, tiling)),
    ("bounding-box", bounding_box_plan(space, prog.deps, tiling)),
]:
    axi = BandwidthReport.evaluate(plan, AXI_ZC706)
    tpu = BandwidthReport.evaluate(plan, TPU_V5E_HBM)
    print(f"{name:>13}: {plan.n_bursts:5d} bursts/tile, "
          f"redundancy {plan.redundancy:5.1%}, "
          f"effective bw {axi.peak_fraction_effective:6.1%} (AXI) "
          f"{tpu.peak_fraction_effective:6.1%} (TPU DMA)")

# 3. run the whole computation through facet storage ------------------------
pipe = CFAPipeline(prog, space, tiling)
rng = np.random.default_rng(0)
inputs = jnp.asarray(rng.normal(size=(1, 32, 32)), jnp.float32)
facets = pipe.sweep(inputs)
V = pipe.reference_volume(inputs)

from repro.core.cfa import pack_facet
err = float(jnp.abs(facets[0][1:] - pack_facet(V, pipe.specs[0])).max())
print(f"\ntiled-through-facets sweep == untiled oracle: max err {err:.2e}")
assert err < 1e-5

# 4. let the autotuner pick the layout instead of hard-coding one ----------
decision = autotune(prog, space, AXI_ZC706, seed=0, budget=64)
best = decision.best
hand = BandwidthReport.evaluate(cfa_plan(space, prog.deps, tiling), AXI_ZC706)
print(f"\nautotuned layout: {best.candidate.key}")
print(f"  effective bandwidth {best.peak_fraction_effective:6.1%} of peak "
      f"(hand-coded tiling above: {hand.peak_fraction_effective:6.1%}), "
      f"{decision.evaluated} candidates scored"
      f"{', cached' if decision.from_cache else ''}")

tuned = CFAPipeline.from_autotuned(prog, space, decision=decision)
facets = tuned.sweep(inputs)
err = float(jnp.abs(
    facets[0][1:] - pack_facet(tuned.reference_volume(inputs), tuned.specs[0])
).max())
print(f"autotuned sweep == untiled oracle: max err {err:.2e}")
assert err < 1e-5
print("OK")
