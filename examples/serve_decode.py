"""Serving example: batched requests through prefill + facet-layout KV-cache
decode, with per-phase throughput accounting.

    PYTHONPATH=src python examples/serve_decode.py --arch qwen3-0.6b
"""
import argparse
import subprocess
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    # delegate to the launcher (same public API a cluster deployment uses)
    sys.exit(subprocess.call([
        sys.executable, "-m", "repro.launch.serve", "--arch", args.arch,
        "--smoke", "--batch", str(args.batch), "--gen", str(args.gen),
    ]))


if __name__ == "__main__":
    main()
