"""Learning-rate schedules."""
from __future__ import annotations

import math

import jax.numpy as jnp


def cosine_warmup(step, *, peak_lr: float, warmup: int, total: int,
                  floor_frac: float = 0.1):
    """Linear warmup then cosine decay to ``floor_frac * peak_lr``."""
    t = jnp.asarray(step, jnp.float32)
    warm = peak_lr * (t + 1.0) / max(warmup, 1)  # step 0 must have lr > 0
    prog = jnp.clip((t - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(math.pi * prog)))
    return jnp.where(t < warmup, warm, cos)
