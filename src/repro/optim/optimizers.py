"""Optimizers: AdamW and Adafactor (factored second moment), functional style.

Optimizer state shards exactly like the parameters (ZeRO-style: the FSDP
'data'-axis sharding of a param applies to its moments), so ``opt_state_specs``
simply mirrors the param spec tree.  Adafactor exists because AdamW state for
a 398B-param model (jamba-1.5-large) cannot fit a single v5e pod — see
EXPERIMENTS.md §Memory.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.distributed.sharding import P

__all__ = [
    "OptState", "adamw_init", "adafactor_init", "make_optimizer",
    "opt_state_specs", "global_norm", "clip_by_global_norm",
]


@dataclasses.dataclass
class OptState:
    step: jnp.ndarray
    mu: Any  # first moment tree (AdamW) or None-tree (Adafactor)
    nu: Any  # second moment tree; Adafactor: dict(row=, col=) for >=2D leaves


jax.tree_util.register_dataclass(OptState, ["step", "mu", "nu"], [])


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros_like(p, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def _adamw_update(grads, state: OptState, params, lr, *, b1=0.9, b2=0.95,
                  eps=1e-8, weight_decay=0.1):
    step = state.step + 1
    t = step.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        mhat = m / (1 - b1 ** t)
        vhat = v / (1 - b2 ** t)
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(step=step, mu=mu, nu=nu)


# ---------------------------------------------------------------------------
# Adafactor (no momentum, factored second moment for >=2D params)
# ---------------------------------------------------------------------------

def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor_init(params) -> OptState:
    def nu0(p):
        if _factored(p):
            return {
                "row": jnp.zeros(p.shape[:-1], jnp.float32),
                "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return jnp.zeros_like(p, jnp.float32)

    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params),  # stub
        nu=jax.tree.map(nu0, params),
    )


def _adafactor_update(grads, state: OptState, params, lr, *, decay=0.8,
                      eps=1e-30, weight_decay=0.0, clip_threshold=1.0):
    step = state.step + 1
    t = step.astype(jnp.float32)
    beta = 1.0 - t ** -decay

    def upd(g, v, p):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + eps
        if _factored(p):
            row = beta * v["row"] + (1 - beta) * g2.mean(axis=-1)
            col = beta * v["col"] + (1 - beta) * g2.mean(axis=-2)
            denom = jnp.maximum(row.mean(axis=-1, keepdims=True), eps)
            rfac = jax.lax.rsqrt(row / denom)[..., None]  # (..., rows, 1)
            cfac = jax.lax.rsqrt(col)[..., None, :]  # (..., 1, cols)
            update = gf * rfac * cfac
            v_new = {"row": row, "col": col}
        else:
            v_new = beta * v + (1 - beta) * g2
            update = gf * jax.lax.rsqrt(v_new)
        rms = jnp.sqrt(jnp.mean(update * update))
        update = update / jnp.maximum(1.0, rms / clip_threshold)
        if weight_decay:
            update = update + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), v_new

    # nu has dict sub-structure for factored leaves: flatten up to param leaves
    flat_g, tdef = jax.tree.flatten(grads)
    flat_p = jax.tree.leaves(params)
    flat_v = tdef.flatten_up_to(state.nu)
    news = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
    new_params = tdef.unflatten([n[0] for n in news])
    nu = tdef.unflatten([n[1] for n in news])
    return new_params, OptState(step=step, mu=state.mu, nu=nu)


# ---------------------------------------------------------------------------

def opt_state_specs(param_specs, params_shapes, optimizer: str) -> OptState:
    """Spec tree mirroring the parameter sharding (ZeRO: moments shard like
    their params; Adafactor factored moments drop the reduced dim's axis)."""
    if optimizer == "adamw":
        return OptState(step=P(), mu=param_specs, nu=param_specs)

    def nu_spec(spec, shp):
        shape = shp.shape if hasattr(shp, "shape") else shp
        if len(shape) >= 2:
            dims = list(spec) + [None] * (len(shape) - len(spec))
            return {"row": P(*dims[:-1]), "col": P(*(dims[:-2] + dims[-1:]))}
        return spec

    flat_specs, tdef = jax.tree.flatten(
        param_specs, is_leaf=lambda s: isinstance(s, P)
    )
    flat_shapes = jax.tree.leaves(params_shapes)
    nu = tdef.unflatten([nu_spec(s, sh) for s, sh in zip(flat_specs, flat_shapes)])
    mu = tdef.unflatten([P() for _ in flat_specs])
    return OptState(step=P(), mu=mu, nu=nu)


def make_optimizer(name: str) -> tuple[Callable, Callable]:
    """Returns (init_fn(params) -> state, update_fn(grads, state, params, lr))."""
    if name == "adamw":
        return adamw_init, _adamw_update
    if name == "adafactor":
        return adafactor_init, _adafactor_update
    raise ValueError(f"unknown optimizer {name!r}")
