from .optimizers import (
    OptState,
    adamw_init,
    adafactor_init,
    make_optimizer,
    opt_state_specs,
    global_norm,
    clip_by_global_norm,
)
from .schedule import cosine_warmup

__all__ = [
    "OptState", "adamw_init", "adafactor_init", "make_optimizer",
    "opt_state_specs", "global_norm", "clip_by_global_norm", "cosine_warmup",
]
