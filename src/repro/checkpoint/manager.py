"""Fault-tolerant checkpointing: async, step-atomic, elastically resharded.

Design (1000+-node posture, single-process emulation documented):

* **step-atomic commit**: a checkpoint is written to ``step_N.tmp/`` and
  atomically renamed to ``step_N/``; a crash mid-write never corrupts the
  latest checkpoint.
* **async**: ``save`` snapshots to host memory synchronously (cheap) and
  writes to disk on a background thread, overlapping I/O with the next
  training steps (the paper's read/execute/write overlap, applied to
  checkpoints).
* **elastic resharding**: the manifest stores only *logical* shapes; restore
  takes the target abstract tree + the *new* mesh/shardings and
  ``device_put``s each leaf — restarting on a different pod count or mesh
  shape reshards transparently.
* **keep-last-k GC** bounds disk usage.

On a real multi-host cluster each host writes its local shards (same layout,
one subdirectory per host) — the manifest/commit protocol is unchanged; this
container's single process writes full arrays.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from pathlib import Path

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str | Path, *, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: Future | None = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------------ save

    def save(self, step: int, tree, *, blocking: bool = False) -> None:
        """Snapshot now, write asynchronously (unless blocking)."""
        host_leaves = [np.asarray(l) for l in jax.tree.leaves(tree)]
        self.wait()  # one outstanding write at a time
        self._pending = self._pool.submit(self._write, step, host_leaves)
        if blocking:
            self.wait()

    def _write(self, step: int, leaves: list[np.ndarray]) -> None:
        tmp = self.dir / f"step_{step:010d}.tmp"
        final = self.dir / f"step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "leaves.npz", **{f"l{i}": a for i, a in enumerate(leaves)})
        manifest = {
            "step": step,
            "time": time.time(),
            "n_leaves": len(leaves),
            "shapes": [list(a.shape) for a in leaves],
            "dtypes": [str(a.dtype) for a in leaves],
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic commit
        self._gc()

    def _gc(self) -> None:
        with self._lock:
            steps = sorted(self.all_steps())
            for s in steps[: -self.keep]:
                shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    # --------------------------------------------------------------- restore

    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree, shardings=None):
        """Rebuild ``target_tree``'s structure from disk; ``shardings`` (an
        optional matching tree of NamedShardings for the *current* mesh)
        reshards elastically."""
        path = self.dir / f"step_{step:010d}"
        data = np.load(path / "leaves.npz")
        leaves, treedef = _flatten(target_tree)
        if len(leaves) != len(data.files):
            raise ValueError(
                f"checkpoint has {len(data.files)} leaves, target {len(leaves)} — "
                "architecture mismatch"
            )
        shard_leaves = (
            jax.tree.leaves(shardings, is_leaf=lambda x: x is None)
            if shardings is not None else [None] * len(leaves)
        )
        out = []
        for i, (tgt, sh) in enumerate(zip(leaves, shard_leaves)):
            arr = data[f"l{i}"]
            if tuple(arr.shape) != tuple(tgt.shape):
                raise ValueError(f"leaf {i}: shape {arr.shape} != {tgt.shape}")
            arr = arr.astype(tgt.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
        return treedef.unflatten(out)
