"""GPipe-style pipeline parallelism over a 'pipe' mesh axis via shard_map.

The assigned production mesh is (pod, data, model) — PP is the optional
fourth axis for depth-dominated models (deepseek-67b at 95 layers is the
natural customer).  Each pipeline stage owns one slice of the layer stack;
microbatches rotate through stages with ``jax.lax.ppermute`` on the classic
bubble schedule (S + M - 1 ticks for S stages / M microbatches; bubble
fraction (S-1)/(M+S-1)).

Microbatch m is processed by stage s at tick m + s and retires from the
last stage at tick m + S - 1.  Inputs are replicated to the pipe group
(stage 0 injects), outputs are psum-collected from the last stage.

Exercised by tests/test_distributed.py (single-stage identity inline + a
4-stage subprocess run on forced host devices) — the 40-cell dry-run mesh
has no pipe axis, by assignment.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .sharding import shard_map_compat

__all__ = ["pipeline_apply"]


def pipeline_apply(
    stage_fn: Callable,  # (stage_params, x) -> x (same shape)
    stage_params,  # leaves with leading dim n_stages (sharded over 'pipe')
    x: jnp.ndarray,  # (n_micro, micro_batch, ...) microbatched input
    mesh: Mesh,
    *,
    axis: str = "pipe",
) -> jnp.ndarray:
    n_stages = int(mesh.shape[axis])
    n_micro = x.shape[0]

    @functools.partial(
        shard_map_compat,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_vma=False,
    )
    def run(params, xs):
        params = jax.tree.map(lambda a: a[0], params)  # this stage's slice
        stage = jax.lax.axis_index(axis)
        cur = jnp.zeros_like(xs[0])
        buf = jnp.zeros_like(xs)
        ticks = n_micro + n_stages - 1
        fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(t, state):
            cur, buf = state
            x_in = xs[jnp.minimum(t, n_micro - 1)]
            inject = (stage == 0) & (t < n_micro)
            cur = jnp.where(inject, x_in, cur)
            out = stage_fn(params, cur)
            retire_idx = t - (n_stages - 1)
            do_retire = (stage == n_stages - 1) & (retire_idx >= 0)
            upd = jax.lax.dynamic_update_index_in_dim(
                buf, out, jnp.clip(retire_idx, 0, n_micro - 1), 0)
            buf = jnp.where(do_retire, upd, buf)
            cur = jax.lax.ppermute(out, axis, fwd)
            return cur, buf

        cur, buf = jax.lax.fori_loop(0, ticks, tick, (cur, buf))
        mask = (stage == n_stages - 1).astype(buf.dtype)
        return jax.lax.psum(buf * mask, axis)

    return run(stage_params, x)
