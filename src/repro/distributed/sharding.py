"""Sharding rules and constraint helpers (DP / TP / EP / SP / pod).

Axis conventions (launch/mesh.py):

* ``pod``   — outer data-parallel axis across pods (DCN-connected);
* ``data``  — intra-pod data parallelism + FSDP parameter sharding;
* ``model`` — tensor / expert parallelism (ICI-connected).

All model code expresses shardings as logical `PartitionSpec`s built from
the helpers here.  Two robustness rules keep the 40-cell dry-run matrix
green:

1. ``constrain`` / ``sanitize_spec`` silently drop a mesh axis from a dim
   whose size it does not divide (e.g. batch=1 long-context cells cannot
   shard batch; the spec degrades to replication on that dim instead of a
   compile error) — mirroring MaxText's logical-axis fallback.
2. A ``None`` mesh (unit tests, single-device smoke) turns every constraint
   into a no-op, so model code is mesh-agnostic.
"""
from __future__ import annotations

import math
import threading
from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "P",
    "set_mesh",
    "get_mesh",
    "use_mesh",
    "constrain",
    "sanitize_spec",
    "sanitize_tree",
    "named",
    "DP_AXES",
    "batch_spec",
    "shard_map_compat",
    "port_mesh",
    "shard_facets",
]

_STATE = threading.local()

# logical data-parallel axes; ``pod`` is silently absent on single-pod meshes
DP_AXES = ("pod", "data")


def set_mesh(mesh: Mesh | None) -> None:
    _STATE.mesh = mesh


def get_mesh() -> Mesh | None:
    return getattr(_STATE, "mesh", None)


def get_dp_axes() -> tuple:
    return getattr(_STATE, "dp_axes", DP_AXES)


def get_drop_axes() -> frozenset:
    return getattr(_STATE, "drop_axes", frozenset())


class use_mesh:
    """Install the active mesh + parallelism policy for model constraints.

    ``dp_axes``: mesh axes carrying the batch dimension (per-arch policy:
    small models fold 'model' into DP — §Perf H4).
    ``drop_axes``: axes erased from activation constraints (pure-DP mode
    replicates what TP would shard)."""

    def __init__(self, mesh: Mesh | None, *, dp_axes: tuple = DP_AXES,
                 drop_axes=frozenset()):
        self.mesh = mesh
        self.dp_axes = tuple(dp_axes)
        self.drop_axes = frozenset(drop_axes)

    def __enter__(self):
        self.prev = (get_mesh(), get_dp_axes(), get_drop_axes())
        _STATE.mesh = self.mesh
        _STATE.dp_axes = self.dp_axes
        _STATE.drop_axes = self.drop_axes
        return self.mesh

    def __exit__(self, *exc):
        _STATE.mesh, _STATE.dp_axes, _STATE.drop_axes = self.prev
        return False


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape.get(a, 1)
    return size


def _present(mesh: Mesh, axes):
    """Drop mesh axes that do not exist in this mesh (e.g. 'pod' single-pod)."""
    if axes is None:
        return None
    if isinstance(axes, str):
        return axes if axes in mesh.shape else None
    kept = tuple(a for a in axes if a in mesh.shape)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def sanitize_spec(spec: P, shape: Sequence[int], mesh: Mesh | None) -> P:
    """Adapt a logical spec to a concrete (mesh, shape): drop absent axes;
    for multi-axis dims keep the longest prefix whose product divides the
    dim (e.g. batch=128 over ('data','model')=256 degrades to 'data'=16)."""
    if mesh is None:
        return P()
    dims = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim_size, axes in zip(shape, dims):
        axes = _present(mesh, axes)
        if axes is None:
            out.append(None)
            continue
        tup = axes if isinstance(axes, tuple) else (axes,)
        while tup and dim_size % _axis_size(mesh, tup) != 0:
            tup = tup[:-1]
        if not tup:
            out.append(None)
        else:
            out.append(tup if len(tup) > 1 else tup[0])
    return P(*out)


def named(spec: P, shape: Sequence[int], mesh: Mesh | None) -> NamedSharding | None:
    if mesh is None:
        return None
    return NamedSharding(mesh, sanitize_spec(spec, shape, mesh))


def constrain(x: jax.Array, *spec_dims) -> jax.Array:
    """with_sharding_constraint against the active mesh (no-op without one).

    Accepts either a ready PartitionSpec (``constrain(x, batch_spec(...))``)
    or bare dims (``constrain(x, 'data', None)``)."""
    mesh = get_mesh()
    if mesh is None:
        return x
    if len(spec_dims) == 1 and isinstance(spec_dims[0], P):
        spec = spec_dims[0]
    else:
        spec = P(*spec_dims)
    drop = get_drop_axes()
    if drop:
        spec = P(*[_drop(a, drop) for a in spec])
    spec = sanitize_spec(spec, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _drop(axes, drop: frozenset):
    if axes is None:
        return None
    tup = axes if isinstance(axes, tuple) else (axes,)
    kept = tuple(a for a in tup if a not in drop)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def sanitize_tree(specs: Any, shapes: Any, mesh: Mesh | None) -> Any:
    """Map sanitize_spec over parallel (spec, shape) pytrees -> NamedShardings."""
    return jax.tree.map(
        lambda s, shp: named(s, shp.shape if hasattr(shp, "shape") else shp, mesh),
        specs,
        shapes,
        is_leaf=lambda s: isinstance(s, P),
    )


def batch_spec(*trailing) -> P:
    """Spec with the batch dim over the policy's data-parallel axes."""
    return P(get_dp_axes(), *trailing)


def translate_specs(tree, *, drop=("model",)):
    """Erase mesh axes from a spec tree (serving weights: no FSDP; pure-DP
    weights: no TP)."""
    dropset = frozenset(drop)
    return jax.tree.map(
        lambda s: P(*[_drop(a, dropset) for a in s]),
        tree, is_leaf=lambda s: isinstance(s, P))


def shard_map_compat(f, *, mesh, in_specs, out_specs, check_vma=True):
    """``shard_map`` across the jax versions this repo supports.

    Recent jax exposes ``jax.shard_map`` (with ``check_vma``); the pinned
    0.4.x series only has ``jax.experimental.shard_map.shard_map`` (with the
    older ``check_rep`` spelling of the same knob).  All multi-port / pipeline
    executors go through this shim so they run on either.  The default keeps
    jax's own replication check on; callers whose bodies the checker cannot
    analyse (Pallas calls) pass ``check_vma=False`` explicitly.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def port_mesh(n_ports: int, axis: str = "port") -> Mesh:
    """1-D mesh standing in for ``n_ports`` memory ports.

    This is the device fabric behind the ``sharded`` backend of
    ``repro.cfa.compile`` (port-count validation against the *platform*
    budget happens there, in the ``Target`` registry; this helper only
    maps ports onto whatever devices exist — pass ``mesh=`` through the
    compiled stencil's call options to supply a custom mesh instead).

    Uses up to ``n_ports`` local devices; with fewer devices than ports the
    mesh folds ports onto the available devices (port p -> device p mod size),
    so the same code runs on a laptop CPU, forced host devices, or a real
    multi-chip slice.
    """
    if n_ports <= 0:
        raise ValueError(f"n_ports must be positive: {n_ports}")
    devs = jax.devices()
    return Mesh(np.asarray(devs[: min(n_ports, len(devs))]), (axis,))


def shard_facets(facets: dict, facet_to_port: dict, mesh: Mesh,
                 axis: str = "port") -> dict:
    """Place each facet array on its assigned port's device.

    The facet array is CFA's unit of contiguity, so a port repartition at
    facet granularity is realised by whole-array placement: facet ``k`` lives
    on the device at mesh coordinate ``facet_to_port[k] mod axis size``.
    Ports beyond the mesh size fold back (see ``port_mesh``).
    """
    n = int(mesh.shape[axis])
    devs = list(mesh.devices.reshape(-1))
    out = {}
    for k, arr in facets.items():
        p = int(facet_to_port.get(k, 0)) % n
        dev = devs[p]
        if getattr(arr, "devices", None) is not None and arr.devices() == {dev}:
            out[k] = arr  # already resident on its port
        else:
            out[k] = jax.device_put(arr, dev)
    return out


def constrain_tree(tree, spec_tree):
    """Constrain every leaf of ``tree`` to the matching spec (active mesh).

    Used to pin gradients to the parameters' FSDP sharding *before* the
    optimizer, which turns the data-parallel gradient sync into a
    reduce-scatter instead of an all-reduce + dynamic-slice (ZeRO; measured
    in EXPERIMENTS.md §Perf H1)."""
    mesh = get_mesh()
    if mesh is None:
        return tree

    def one(x, s):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, sanitize_spec(s, x.shape, mesh)))

    return jax.tree.map(one, tree, spec_tree,
                        is_leaf=lambda s: isinstance(s, P))
