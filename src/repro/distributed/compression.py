"""Error-feedback gradient compression (int8) for the DP all-reduce.

At 1000+-node scale the data-parallel gradient all-reduce crosses DCN
between pods; int8 quantization cuts those bytes 4x (vs f32).  Error
feedback (Karimireddy et al., 2019) accumulates the quantization residual
locally and re-injects it the next step, preserving convergence.

Usage in the train step (compression wraps the *gradient values* before the
optimizer; under pjit the all-reduce itself is implicit, so quantizing the
summand is equivalent to an int8-payload reduce up to the reduction order):

    comp_state = ef_init(params)
    grads, comp_state = ef_compress(grads, comp_state)

Property-tested in tests/test_distributed.py: idempotent shapes, bounded
per-step error, and error-feedback recovering the exact gradient sum over
time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ef_init", "ef_compress", "quantize_int8", "dequantize_int8"]


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization: returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress(grads, error_state):
    """Quantize (grad + carried error); carry the new residual."""

    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = quantize_int8(target)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), target - deq

    out = jax.tree.map(one, grads, error_state)
    new_grads = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda o: o[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_err
