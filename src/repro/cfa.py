"""``repro.cfa`` — the public front door to the CFA stack.

One declarative entry point over layout search, burst planning and the
execution backends:

    from repro import cfa

    compiled = cfa.compile("jacobi2d5p", (16, 32, 32))   # autotuned layout
    facets   = compiled(inputs)                          # run it
    print(compiled.report())                             # bandwidth stats
    sharded  = cfa.compile("jacobi2d5p", (16, 32, 32), n_ports=4)
    dedup    = cfa.compile("jacobi2d5p", (16, 32, 32),   # Ferry-2024 storage
                           storage="irredundant")

Everything here re-exports from :mod:`repro.core.cfa`; the curated
``__all__`` below *is* the public API surface — ``tests/test_api.py`` pins
it with a snapshot test, so additions and removals are deliberate, reviewed
events rather than accidents.  Lower-level machinery (point sets, packing,
baseline plans, repartition strategies) stays importable from
``repro.core.cfa`` for tooling and tests.
"""
from repro.core.cfa import (
    # the front door
    compile,
    CompiledStencil,
    # platform registry
    Target,
    TARGETS,
    register_target,
    get_target,
    AXI_ZC706,
    TPU_V5E_HBM,
    # execution backends + the capability gate
    Executor,
    ExecutorCaps,
    EXECUTORS,
    register_executor,
    get_executor,
    available_backends,
    select_backend,
    BackendError,
    # layout machinery a compile() caller sees
    IterSpace,
    Deps,
    Tiling,
    StencilProgram,
    PROGRAMS,
    get_program,
    LayoutCandidate,
    ScoredLayout,
    LayoutDecision,
    autotune,
    CacheSchemaError,
    SCORE_MODES,
    # measured-vs-modeled calibration (autotune(score="measured"),
    # report(measured=True), the calibration bench)
    TransferSample,
    CalibratedModel,
    Calibration,
    measure_runs,
    measure_plan,
    fit_burst_model,
    calibrate,
    # plans / bandwidth carried on CompiledStencil
    TransferPlan,
    BurstModel,
    PortedPlan,
    BandwidthReport,
    overlap_speedup,
    # facet storage disciplines (compile(storage=...), Ferry 2024)
    STORAGE_MODES,
    StorageMap,
    build_storage_map,
    dedup_facets,
    rehydrate_facets,
    BlockCodec,
    CODECS,
    get_codec,
    # the underlying pipeline (CompiledStencil.pipeline)
    CFAPipeline,
    # runtime burst telemetry (compile(trace=True),
    # CompiledStencil.last_trace(), tools/cfa_trace.py)
    TraceRecorder,
    Span,
    Counters,
    RuntimeReport,
    runtime_report,
    chrome_trace,
    validate_chrome_trace,
    # static verification (compile(verify=True), cfa.verify,
    # CompiledStencil.diagnostics(), tools/cfa_lint.py)
    verify,
    Diagnostic,
    AnalysisReport,
    VerificationError,
    # the staged lowering behind compile (CompiledStencil.trace(),
    # compile(passes=...), the autotune cache's pipeline fingerprint)
    CompileState,
    Pass,
    PassPipeline,
    PassTrace,
    PipelineError,
    DEFAULT_PASSES,
    default_pipeline,
    default_pass_fingerprint,
    estimate_facet_bytes,
)

__all__ = [
    "compile",
    "CompiledStencil",
    "Target",
    "TARGETS",
    "register_target",
    "get_target",
    "AXI_ZC706",
    "TPU_V5E_HBM",
    "Executor",
    "ExecutorCaps",
    "EXECUTORS",
    "register_executor",
    "get_executor",
    "available_backends",
    "select_backend",
    "BackendError",
    "IterSpace",
    "Deps",
    "Tiling",
    "StencilProgram",
    "PROGRAMS",
    "get_program",
    "LayoutCandidate",
    "ScoredLayout",
    "LayoutDecision",
    "autotune",
    "CacheSchemaError",
    "SCORE_MODES",
    "TransferSample",
    "CalibratedModel",
    "Calibration",
    "measure_runs",
    "measure_plan",
    "fit_burst_model",
    "calibrate",
    "TransferPlan",
    "BurstModel",
    "PortedPlan",
    "BandwidthReport",
    "overlap_speedup",
    "STORAGE_MODES",
    "StorageMap",
    "build_storage_map",
    "dedup_facets",
    "rehydrate_facets",
    "BlockCodec",
    "CODECS",
    "get_codec",
    "CFAPipeline",
    "TraceRecorder",
    "Span",
    "Counters",
    "RuntimeReport",
    "runtime_report",
    "chrome_trace",
    "validate_chrome_trace",
    "verify",
    "Diagnostic",
    "AnalysisReport",
    "VerificationError",
    "CompileState",
    "Pass",
    "PassPipeline",
    "PassTrace",
    "PipelineError",
    "DEFAULT_PASSES",
    "default_pipeline",
    "default_pass_fingerprint",
    "estimate_facet_bytes",
]
