"""Period blocks: assemble layer kinds into the repeating unit scanned by
``lax.scan`` (HLO size stays O(one period) regardless of depth).

Layer kinds:
* ``attn``  — causal self-attention (+ FFN),
* ``mamba`` — SSD mixer (+ optional FFN; none for pure-SSM LMs),
* ``cross`` — cross-attention to a static context (VLM image layers),
* ``dec``   — self-attention + cross-attention (enc-dec decoder layers).

FFN kinds: ``mlp`` (SwiGLU), ``moe`` (top-k experts), ``none``.
Every position is pre-norm residual.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import (
    KVCache,
    attention,
    decode_attention_blocks,
    decode_cross_attention,
    init_attention,
    init_mlp,
    init_norm,
    mlp,
    rms_norm,
    spec_attention,
    spec_mlp,
    spec_norm,
)
from .mamba2 import MambaCache, init_mamba, mamba_decode, mamba_train, spec_mamba
from .moe import init_moe, moe, spec_moe

__all__ = [
    "ffn_kind",
    "init_position",
    "spec_position",
    "cache_position",
    "apply_position",
]


def ffn_kind(cfg: ArchConfig, pos: int) -> str:
    if pos in cfg.moe_positions:
        return "moe"
    if cfg.period[pos] == "mamba" and cfg.family == "ssm":
        return "none"
    return "mlp"


def init_position(key, kind: str, fk: str, cfg: ArchConfig) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: dict[str, Any] = {"norm1": init_norm(cfg.d_model)}
    if kind == "attn":
        p["mixer"] = init_attention(k1, cfg)
    elif kind == "mamba":
        p["mixer"] = init_mamba(k1, cfg)
    elif kind == "cross":
        p["mixer"] = init_attention(k1, cfg)
        p["gate"] = jnp.zeros((), jnp.float32)
    elif kind == "dec":
        p["mixer"] = init_attention(k1, cfg)
        p["norm_x"] = init_norm(cfg.d_model)
        p["cross"] = init_attention(k4, cfg)
    else:
        raise ValueError(kind)
    if fk != "none":
        p["norm2"] = init_norm(cfg.d_model)
        p["ffn"] = init_moe(k2, cfg) if fk == "moe" else init_mlp(k3, cfg)
    return p


def spec_position(kind: str, fk: str, cfg: ArchConfig) -> dict:
    from repro.distributed.sharding import P

    s: dict[str, Any] = {"norm1": spec_norm()}
    if kind == "mamba":
        s["mixer"] = spec_mamba(cfg)
    else:
        s["mixer"] = spec_attention(cfg)
    if kind == "cross":
        s["gate"] = P()
    if kind == "dec":
        s["norm_x"] = spec_norm()
        s["cross"] = spec_attention(cfg)
    if fk != "none":
        s["norm2"] = spec_norm()
        s["ffn"] = spec_moe() if fk == "moe" else spec_mlp()
    return s


def cache_position(kind: str, cfg: ArchConfig, batch: int, seq: int, src_len: int,
                   dtype=jnp.bfloat16):
    """Zero-initialised decode cache slot for one period position."""
    slot: dict[str, Any] = {}
    if kind in ("attn", "dec"):
        slot["kv"] = KVCache.zeros(cfg, batch, seq, dtype)
    if kind == "mamba":
        slot["ssm"] = MambaCache.zeros(cfg, batch, dtype)
    if kind in ("cross", "dec"):
        shape = (batch, src_len, cfg.stored_kv_heads, cfg.head_dim)
        slot["cross_k"] = jnp.zeros(shape, dtype)
        slot["cross_v"] = jnp.zeros(shape, dtype)
    return slot


def _cross_kv(p_attn: dict, src: jnp.ndarray, cfg: ArchConfig):
    cd = jnp.dtype(cfg.compute_dtype)
    k = jnp.einsum("bsd,dhk->bshk", src.astype(cd), p_attn["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", src.astype(cd), p_attn["wv"].astype(cd))
    if cfg.qk_norm:
        k = rms_norm(k, p_attn["k_norm"])
    return k, v


def apply_position(
    p: dict,
    x: jnp.ndarray,
    kind: str,
    fk: str,
    cfg: ArchConfig,
    mode: str,  # train | prefill | decode
    cache: dict | None,
    ctx: dict,
) -> tuple[jnp.ndarray, dict | None, jnp.ndarray]:
    """Apply one period position.  Returns (x, new_cache_slot, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["norm1"])
    new_cache: dict[str, Any] = {}

    if kind == "attn":
        if mode == "decode":
            y, kv = decode_attention_blocks(p["mixer"], h, cache["kv"],
                                            ctx["decode_pos"], cfg)
            new_cache["kv"] = kv
        else:
            template = None
            if mode == "prefill":
                template = cache["kv"]
            y, kv = attention(p["mixer"], h, cfg, positions=ctx.get("positions"),
                              cache=template)
            if mode == "prefill":
                new_cache["kv"] = kv
        x = x + y

    elif kind == "mamba":
        if mode == "decode":
            y, ssm = mamba_decode(p["mixer"], h, cache["ssm"], cfg)
            new_cache["ssm"] = ssm
        else:
            y = mamba_train(p["mixer"], h, cfg)
            if mode == "prefill":
                # re-run stateful tail for the cache (cheap closed form)
                new_cache["ssm"] = _mamba_prefill_cache(
                    p["mixer"], h, cfg, dtype=cache["ssm"].conv_x.dtype
                )
        x = x + y

    elif kind == "cross":
        if mode == "decode":
            y = decode_cross_attention(p["mixer"], h, cache["cross_k"],
                                       cache["cross_v"], cfg)
            new_cache["cross_k"] = cache["cross_k"]
            new_cache["cross_v"] = cache["cross_v"]
        else:
            y, _ = attention(p["mixer"], h, cfg, kv_x=ctx["cross_src"],
                             causal=False, rope=False)
            if mode == "prefill":
                ck, cv = _cross_kv(p["mixer"], ctx["cross_src"], cfg)
                new_cache["cross_k"] = ck.astype(cache["cross_k"].dtype)
                new_cache["cross_v"] = cv.astype(cache["cross_v"].dtype)
        x = x + jnp.tanh(p["gate"]).astype(y.dtype) * y

    elif kind == "dec":
        if mode == "decode":
            y, kv = decode_attention_blocks(p["mixer"], h, cache["kv"],
                                            ctx["decode_pos"], cfg)
            new_cache["kv"] = kv
            hx = rms_norm(x + y, p["norm_x"])
            y2 = decode_cross_attention(p["cross"], hx, cache["cross_k"],
                                        cache["cross_v"], cfg)
            new_cache["cross_k"] = cache["cross_k"]
            new_cache["cross_v"] = cache["cross_v"]
        else:
            template = cache["kv"] if mode == "prefill" else None
            y, kv = attention(p["mixer"], h, cfg, positions=ctx.get("positions"),
                              cache=template)
            if mode == "prefill":
                new_cache["kv"] = kv
            hx = rms_norm(x + y, p["norm_x"])
            y2, _ = attention(p["cross"], hx, cfg, kv_x=ctx["cross_src"],
                              causal=False, rope=False)
            if mode == "prefill":
                ck, cv = _cross_kv(p["cross"], ctx["cross_src"], cfg)
                new_cache["cross_k"] = ck.astype(cache["cross_k"].dtype)
                new_cache["cross_v"] = cv.astype(cache["cross_v"].dtype)
        x = x + y + y2
    else:
        raise ValueError(kind)

    if fk != "none":
        h2 = rms_norm(x, p["norm2"])
        if fk == "moe":
            y2, aux = moe(p["ffn"], h2, cfg)
        else:
            y2 = mlp(p["ffn"], h2, cfg)
        x = x + y2

    return x, (new_cache if mode != "train" else None), aux


def _mamba_prefill_cache(p: dict, x: jnp.ndarray, cfg: ArchConfig,
                         dtype=jnp.bfloat16) -> MambaCache:
    """Build the decode cache after a prefill pass (final conv tails + state)."""
    from .mamba2 import _causal_conv, _decays, _projections, _ssd_chunked

    B, S, _ = x.shape
    h, pd = cfg.ssm_heads, cfg.ssm_head_dim
    K = cfg.ssm_conv
    xi, z, Bm, Cm, dt = _projections(p, x, cfg)
    tails = (
        xi[:, S - (K - 1):, :].astype(dtype),
        Bm[:, S - (K - 1):, :].astype(dtype),
        Cm[:, S - (K - 1):, :].astype(dtype),
    )
    xi = _causal_conv(xi, p["conv_x"].astype(xi.dtype))
    Bm = _causal_conv(Bm, p["conv_B"].astype(Bm.dtype))
    Cm = _causal_conv(Cm, p["conv_C"].astype(Cm.dtype))
    loga, dtp = _decays(p, dt)
    xh = xi.reshape(B, S, h, pd) * dtp[..., None].astype(xi.dtype)
    _, S_fin = _ssd_chunked(xh, loga, Bm, Cm, cfg.ssm_chunk)
    return MambaCache(*tails, S_fin)
