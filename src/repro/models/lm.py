"""Decoder-only language model (dense / MoE / SSM / hybrid / VLM) and the
encoder-decoder variant, with scan-over-periods and the three lowerable
entry points: train forward, prefill, decode.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import P, batch_spec, constrain
from .blocks import apply_position, cache_position, ffn_kind, init_position, spec_position
from .config import ArchConfig
from .layers import embed, init_embedding, init_norm, rms_norm, spec_embedding, spec_norm, unembed

__all__ = [
    "init_lm", "spec_lm", "lm_forward", "lm_prefill", "lm_decode",
    "init_caches", "init_encoder", "spec_encoder", "encode",
]


# ---------------------------------------------------------------------------
# AD-safe optimization barrier
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _barrier(x: jnp.ndarray) -> jnp.ndarray:
    """``jax.lax.optimization_barrier`` with a differentiation rule.

    The pinned jax (0.4.x) has no AD rule for ``optimization_barrier``, so the
    bare primitive inside a ``jax.checkpoint``-wrapped scan body raises
    ``NotImplementedError`` during the backward trace.  The barrier is
    semantically the identity; the cotangent passes through its own barrier so
    the anti-CSE effect also holds on the recomputed forward of the remat
    backward pass (where the hoisting this barrier exists to stop happens).
    """
    return jax.lax.optimization_barrier(x)


def _barrier_fwd(x):
    return jax.lax.optimization_barrier(x), None


def _barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_barrier.defvjp(_barrier_fwd, _barrier_bwd)


# ---------------------------------------------------------------------------
# init / specs
# ---------------------------------------------------------------------------

def _stack_specs(spec_tree: Any) -> Any:
    """Prepend the period-stack dim (replicated) to every leaf spec."""
    return jax.tree.map(
        lambda s: P(None, *s), spec_tree, is_leaf=lambda s: isinstance(s, P)
    )


def init_lm(key, cfg: ArchConfig) -> dict:
    k_embed, k_periods, k_enc = jax.random.split(key, 3)
    period_keys = jax.random.split(k_periods, cfg.n_periods)

    def one_period(k):
        ks = jax.random.split(k, len(cfg.period))
        return {
            f"pos{i}": init_position(ks[i], kind, ffn_kind(cfg, i), cfg)
            for i, kind in enumerate(cfg.period)
        }

    params = {
        "embed": init_embedding(k_embed, cfg),
        "periods": jax.vmap(one_period)(period_keys),
        "final_norm": init_norm(cfg.d_model),
    }
    if cfg.is_encdec:
        params["encoder"] = init_encoder(k_enc, cfg)
    return params


def spec_lm(cfg: ArchConfig) -> dict:
    period_spec = {
        f"pos{i}": spec_position(kind, ffn_kind(cfg, i), cfg)
        for i, kind in enumerate(cfg.period)
    }
    s = {
        "embed": spec_embedding(),
        "periods": _stack_specs(period_spec),
        "final_norm": spec_norm(),
    }
    if cfg.is_encdec:
        s["encoder"] = spec_encoder(cfg)
    return s


def init_caches(cfg: ArchConfig, batch: int, seq: int, src_len: int = 0,
                dtype=jnp.bfloat16) -> dict:
    """Zero decode caches, stacked over periods (leading n_periods dim)."""
    def one():
        return {
            f"pos{i}": cache_position(kind, cfg, batch, seq, src_len, dtype)
            for i, kind in enumerate(cfg.period)
        }

    slots = one()
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (cfg.n_periods, *x.shape)), slots)


# ---------------------------------------------------------------------------
# encoder (enc-dec archs): non-causal self-attention stack over frame embeddings
# ---------------------------------------------------------------------------

def init_encoder(key, cfg: ArchConfig) -> dict:
    keys = jax.random.split(key, cfg.enc_layers)

    def one(k):
        return init_position(k, "attn", "mlp", cfg)

    return {
        "layers": jax.vmap(one)(keys),
        "final_norm": init_norm(cfg.d_model),
    }


def spec_encoder(cfg: ArchConfig) -> dict:
    return {
        "layers": _stack_specs(spec_position("attn", "mlp", cfg)),
        "final_norm": spec_norm(),
    }


def encode(params: dict, frames: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Bidirectional encoder over (stub) frame embeddings (B, T, d)."""
    x = constrain(frames.astype(jnp.dtype(cfg.compute_dtype)), batch_spec(None, None))

    def body(x, layer_p):
        h = rms_norm(x, layer_p["norm1"])
        from .layers import attention
        y, _ = attention(layer_p["mixer"], h, cfg, causal=False, rope=True)
        x = x + y
        h2 = rms_norm(x, layer_p["norm2"])
        from .layers import mlp
        x = x + mlp(layer_p["ffn"], h2, cfg)
        return x, None

    x, _ = jax.lax.scan(body, x, params["layers"])
    return rms_norm(x, params["final_norm"])


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _period_fn(cfg: ArchConfig, mode: str, *, inner_remat: bool = False):
    def body(x, period_params, cache_slots, ctx):
        new_slots = {}
        aux = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(cfg.period):
            slot = cache_slots[f"pos{i}"] if cache_slots is not None else None

            def pos_fn(x, pp, i=i, kind=kind, slot=slot):
                return apply_position(pp, x, kind, ffn_kind(cfg, i), cfg,
                                      mode, slot, ctx)

            if inner_remat and mode == "train" and len(cfg.period) > 1:
                # nested remat: keeps only per-position boundaries live during
                # the backward recompute of a long period body (jamba: 8
                # unrolled layers would otherwise hold ~100 GiB of activations
                # per device — measured, EXPERIMENTS.md §Dry-run)
                pos_fn = jax.checkpoint(pos_fn)
            x, new_slot, a = pos_fn(x, period_params[f"pos{i}"])
            aux = aux + a
            if new_slot is not None:
                new_slots[f"pos{i}"] = new_slot
        return x, new_slots, aux

    return body


def lm_forward(
    params: dict,
    tokens: jnp.ndarray,  # (B, S) int32
    cfg: ArchConfig,
    *,
    cross_src: jnp.ndarray | None = None,  # (B, S_src, d) context embeddings
    remat: bool = True,
    remat_policy=None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Training forward: logits (B, S, padded_vocab) + MoE aux loss."""
    x = embed(params["embed"], tokens, cfg)
    if cfg.is_encdec:
        cross_src = encode(params["encoder"], cross_src, cfg)
    ctx = {"positions": jnp.arange(tokens.shape[1])[None, :], "cross_src": cross_src}
    body = _period_fn(cfg, "train", inner_remat=remat)

    def scan_fn(carry, period_params):
        x, aux = carry
        # barrier: stops XLA hoisting the (CSE'd) f32 upcast of x out of the
        # rematted body — without it the scan saves an f32 copy of every
        # period boundary (2x activation-stack memory; measured on jamba)
        x = _barrier(x)
        x, _, a = body(x, period_params, None, ctx)
        return (x, aux + a), None

    if remat:
        scan_fn = jax.checkpoint(scan_fn, policy=remat_policy)
    (x, aux), _ = jax.lax.scan(scan_fn, (x, jnp.zeros((), jnp.float32)),
                               params["periods"])
    x = rms_norm(x, params["final_norm"])
    logits = unembed(params["embed"], x, cfg)
    return logits, aux


def lm_prefill(
    params: dict,
    tokens: jnp.ndarray,  # (B, S)
    cfg: ArchConfig,
    *,
    cross_src: jnp.ndarray | None = None,
    cache_dtype=jnp.bfloat16,
    max_seq: int | None = None,  # cache capacity (>= S + decode budget)
) -> tuple[jnp.ndarray, dict]:
    """Prefill: last-position logits + filled decode caches."""
    B, S = tokens.shape
    src_len = 0
    if cross_src is not None or cfg.is_encdec:
        if cfg.is_encdec:
            cross_src = encode(params["encoder"], cross_src, cfg)
        src_len = cross_src.shape[1]
    caches = init_caches(cfg, B, max_seq or S, src_len, cache_dtype)
    x = embed(params["embed"], tokens, cfg)
    ctx = {"positions": jnp.arange(S)[None, :], "cross_src": cross_src}
    body = _period_fn(cfg, "prefill")

    def scan_fn(x, xs):
        period_params, slots = xs
        x, new_slots, _ = body(x, period_params, slots, ctx)
        return x, new_slots

    x, filled = jax.lax.scan(scan_fn, x, (params["periods"], caches))
    x = rms_norm(x[:, -1:], params["final_norm"])
    logits = unembed(params["embed"], x, cfg)
    return logits[:, 0], filled


def lm_decode(
    params: dict,
    caches: dict,
    token: jnp.ndarray,  # (B,) int32 — current token
    position: jnp.ndarray,  # scalar int32 — its index in the sequence
    cfg: ArchConfig,
) -> tuple[jnp.ndarray, dict]:
    """One decode step over the facet-layout caches."""
    x = embed(params["embed"], token[:, None], cfg)
    ctx = {"decode_pos": position}
    body = _period_fn(cfg, "decode")

    def scan_fn(x, xs):
        period_params, slots = xs
        x, new_slots, _ = body(x, period_params, slots, ctx)
        return x, new_slots

    x, new_caches = jax.lax.scan(scan_fn, x, (params["periods"], caches))
    x = rms_norm(x, params["final_norm"])
    logits = unembed(params["embed"], x, cfg)
    return logits[:, 0], new_caches
