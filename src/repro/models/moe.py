"""Mixture-of-Experts layer: top-k routing with grouped, capacity-bounded
einsum dispatch (t5x-style), expert-parallel over the 'model' axis.

CFA connection (DESIGN.md §3): the per-expert dispatch buffers
``(groups, E, capacity, d)`` are the facet analogue for routed computation —
tokens destined for one expert are materialised as one dense, contiguous
block per expert (full-tile contiguity), so the all-to-all moves a few long
extents instead of per-token scatters.  Tokens over capacity are dropped
(standard; capacity_factor controls the trade — the paper's bounding-box
redundancy trade-off in routing clothes).

Routing groups keep the dispatch tensor linear in sequence length:
memory = T * group_size * top_k * cf elements instead of the naive
T^2 * k / E.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import P, batch_spec, constrain
from .config import ArchConfig
from .layers import _normal

__all__ = ["init_moe", "spec_moe", "moe"]


def init_moe(key, cfg: ArchConfig) -> dict:
    d, f, e = cfg.d_model, cfg.expert_d_ff, cfg.moe_experts
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "router": _normal(ks[0], (d, e), d ** -0.5, jnp.float32),
        "w1": _normal(ks[1], (e, d, f), d ** -0.5, dt),
        "w3": _normal(ks[2], (e, d, f), d ** -0.5, dt),
        "w2": _normal(ks[3], (e, f, d), f ** -0.5, dt),
    }


def spec_moe() -> dict:
    return {
        "router": P("data", None),
        "w1": P("model", "data", None),
        "w3": P("model", "data", None),
        "w2": P("model", None, "data"),
    }


def moe(p: dict, x: jnp.ndarray, cfg: ArchConfig) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,S,d), load-balance aux loss (scalar))."""
    B, S, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    cd = jnp.dtype(cfg.compute_dtype)
    T = B * S
    gs = min(cfg.moe_group_size, T)
    pad = (-T) % gs
    xt = x.reshape(T, d)
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    G = xt.shape[0] // gs
    xg = xt.reshape(G, gs, d)
    xg = constrain(xg, batch_spec(None, None))
    # padded tokens must not eat expert capacity
    valid = (jnp.arange(G * gs) < T).astype(jnp.float32).reshape(G, gs)

    logits = xg.astype(jnp.float32) @ p["router"]  # (G, gs, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, k)  # (G, gs, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    cap = max(1, int(gs * k * cfg.moe_capacity_factor / e))
    cap = -(-cap // 4) * 4  # pad capacity for lane alignment

    counts = jnp.zeros((G, 1, e), jnp.float32)
    dispatch = None
    combine = None
    for j in range(k):  # k is small and static: unrolled priority assignment
        oh = jax.nn.one_hot(top_idx[..., j], e, dtype=jnp.float32)  # (G,gs,E)
        oh = oh * valid[..., None]
        pos = counts + jnp.cumsum(oh, axis=1) - oh  # position if admitted
        admitted = (pos < cap) * oh
        counts = counts + oh.sum(axis=1, keepdims=True)
        slot = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
        disp_j = admitted[..., None] * slot  # (G, gs, E, C)
        dispatch = disp_j if dispatch is None else dispatch + disp_j
        comb_j = disp_j * top_w[..., j][..., None, None]
        combine = comb_j if combine is None else combine + comb_j

    dispatch = constrain(dispatch.astype(cd), batch_spec(None, "model", None))
    combine = constrain(combine.astype(cd), batch_spec(None, "model", None))

    # expert-facet buffers: one contiguous block per expert (EP over 'model')
    ein = jnp.einsum("gsec,gsd->gecd", dispatch, xg.astype(cd))
    ein = constrain(ein, batch_spec("model", None, None))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", ein, p["w1"].astype(cd)))
    h = h * jnp.einsum("gecd,edf->gecf", ein, p["w3"].astype(cd))
    h = constrain(h, batch_spec("model", None, None))
    eout = jnp.einsum("gecf,efd->gecd", h, p["w2"].astype(cd))
    eout = constrain(eout, batch_spec("model", None, None))
    out = jnp.einsum("gsec,gecd->gsd", combine, eout)

    out = out.reshape(G * gs, d)[:T].reshape(B, S, d)

    # Switch-style load-balance loss: E * sum_e f_e * p_e
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_idx[..., 0], e, dtype=jnp.float32), axis=(0, 1)
    )
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return constrain(out, batch_spec(None, None)), aux
