"""Architecture configuration for the model zoo.

One frozen dataclass describes every assigned architecture (dense / MoE /
SSM / hybrid / enc-dec / VLM).  Layer stacks are expressed as repeating
*periods* (a short list of layer kinds) so that ``jax.lax.scan`` can run over
stacked period parameters — keeping compiled HLO size proportional to one
period rather than the full depth, which matters for 95-layer models on a
512-device dry-run.

TPU-shardability adjustments (documented in DESIGN.md and counted honestly
in the roofline's MODEL_FLOPS / HLO_FLOPS ratio):

* ``padded_q_heads`` — query heads padded up to a multiple of the tensor-
  parallel axis (llama4-scout 40->48, phi4 24->32); padded heads have zero
  weights and zero output contribution.
* ``stored_kv_heads`` — KV heads replicated up to the TP degree when
  ``kv < tp`` (MaxText-style), so the KV cache shards exactly.
* ``padded_vocab`` — vocab padded to a multiple of ``tp * 128`` for lane
  alignment and exact vocab-parallel sharding; padded logits are masked.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

__all__ = ["ArchConfig", "LayerKind", "TP_DEGREE"]

# The production mesh's model-parallel degree (launch/mesh.py).
TP_DEGREE = 16

LayerKind = Literal["attn", "mamba", "cross"]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    qk_norm: bool = False
    rope_theta: float = 500_000.0
    # --- layer pattern: one period, repeated n_layers/len(period) times ----
    # kinds: "attn" (self-attention), "mamba" (SSD block), "cross"
    # (self-attention + cross-attention, for VLM/enc-dec periods)
    period: tuple[str, ...] = ("attn",)
    # which positions within the period use MoE instead of a dense FFN
    moe_positions: tuple[int, ...] = ()
    # --- MoE ---------------------------------------------------------------
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0  # expert hidden width (defaults to d_ff)
    moe_capacity_factor: float = 1.25
    moe_group_size: int = 256  # routing group (tokens) for dispatch einsums
    # --- SSM (mamba2) --------------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # --- encoder-decoder ----------------------------------------------------
    enc_layers: int = 0  # encoder depth (decoder depth = n_layers)
    # --- multimodal stub frontend -------------------------------------------
    n_context_tokens: int = 0  # precomputed patch/frame embeddings (B, n, d)
    # --- serving ------------------------------------------------------------
    kv_block: int = 256  # facet (block) size of the KV cache sequence axis
    kv_cache_dtype: str = "bfloat16"  # fp8 halves the decode memory term (§Perf H2)
    # --- numerics -----------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    optimizer: str = "adamw"  # adamw | adafactor (jamba-scale memory relief)
    tp: int = TP_DEGREE
    # --- parallelism policy (§Perf H4) ---------------------------------------
    # "tp": Megatron TP/EP over 'model' + DP/FSDP over 'pod','data'
    # "dp": pure data parallelism — 'model' folds into the batch axes;
    #       right for small-d_model archs where 16-way TP shards are tiny
    #       and the per-layer all-reduces dominate the roofline
    parallelism: str = "tp"

    # ------------------------------------------------------------------ derived

    def __post_init__(self):
        if self.n_layers % len(self.period):
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} must divide by "
                f"period length {len(self.period)}"
            )
        for p in self.moe_positions:
            if not (0 <= p < len(self.period)):
                raise ValueError(f"{self.name}: moe position {p} out of period")

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.period)

    @property
    def padded_q_heads(self) -> int:
        return _round_up(self.n_heads, self.tp)

    @property
    def stored_kv_heads(self) -> int:
        if self.n_kv_heads >= self.tp:
            if self.n_kv_heads % self.tp:
                raise ValueError(f"{self.name}: kv heads {self.n_kv_heads} vs tp")
            return self.n_kv_heads
        if self.tp % self.n_kv_heads:
            raise ValueError(f"{self.name}: kv heads {self.n_kv_heads} vs tp")
        return self.tp

    @property
    def q_per_kv(self) -> int:
        return self.padded_q_heads // self.stored_kv_heads

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab, self.tp * 128)

    # SSM deriveds
    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        assert self.ssm_d_inner % self.ssm_head_dim == 0
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def has_ssm(self) -> bool:
        return "mamba" in self.period

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing (SSM/hybrid) — long_500k eligibility."""
        return self.has_ssm

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs generate tokens (enc-dec included)

    def param_count(self) -> int:
        """Analytic parameter count (unpadded, for 6ND MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab
        total = v * d  # embedding
        total += v * d  # unembed
        per_period = 0
        for i, kind in enumerate(self.period):
            if kind in ("attn", "cross"):
                per_period += d * self.n_heads * self.head_dim * 2  # wq, wo
                per_period += d * self.n_kv_heads * self.head_dim * 2  # wk, wv
                if kind == "cross":
                    per_period += d * self.n_heads * self.head_dim * 2
                    per_period += d * self.n_kv_heads * self.head_dim * 2
            elif kind == "mamba":
                din, n, h = self.ssm_d_inner, self.ssm_state, self.ssm_heads
                per_period += d * din * 2  # w_x, w_z
                per_period += d * n * 2 + d * h  # w_B, w_C, w_dt
                per_period += din * d  # out_proj
            if i in self.moe_positions:
                per_period += self.moe_experts * 3 * d * self.expert_d_ff
                per_period += d * self.moe_experts  # router
            elif kind != "mamba":
                per_period += 3 * d * self.d_ff
        total += self.n_periods * per_period
        if self.is_encdec:  # encoder layers: self-attn + dense FFN
            total += self.enc_layers * (
                d * self.n_heads * self.head_dim * 2
                + d * self.n_kv_heads * self.head_dim * 2
                + 3 * d * self.d_ff
            )
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top-k experts only)."""
        if not self.moe_experts:
            return self.param_count()
        full = self.param_count()
        n_moe = self.n_periods * len(self.moe_positions)
        all_experts = n_moe * self.moe_experts * 3 * self.d_model * self.expert_d_ff
        active = n_moe * self.moe_top_k * 3 * self.d_model * self.expert_d_ff
        return full - all_experts + active
