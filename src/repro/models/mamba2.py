"""Mamba2 (SSD) block: chunked state-space scan with facet state passing.

The sequence is tiled into chunks; the inter-chunk SSM state is the chunk's
CFA flow-out facet (dependence depth 1 along the chunk axis), carried through
``lax.scan``.  The pure-jnp chunked path below is the XLA-compiled model
graph (einsums -> MXU); ``repro.kernels.ssd`` is the hand-tiled Pallas TPU
version of the same math, validated against the sequential oracle.

Decode carries a constant-size cache: the SSM state plus the causal-conv
tail — the SSM's "KV cache of seq_len" is O(1), which is exactly why the
long_500k cell runs for SSM/hybrid archs only.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.sharding import P, batch_spec, constrain
from .config import ArchConfig
from .layers import _normal, init_norm, rms_norm, spec_norm

__all__ = ["init_mamba", "spec_mamba", "mamba_train", "mamba_decode", "MambaCache"]


@dataclasses.dataclass
class MambaCache:
    """Decode cache: conv tails + SSM state (the running facet)."""

    conv_x: jnp.ndarray  # (B, K-1, d_inner)
    conv_B: jnp.ndarray  # (B, K-1, N)
    conv_C: jnp.ndarray  # (B, K-1, N)
    state: jnp.ndarray  # (B, H, P, N) float32

    @staticmethod
    def zeros(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> "MambaCache":
        K, din, n = cfg.ssm_conv, cfg.ssm_d_inner, cfg.ssm_state
        h, pd = cfg.ssm_heads, cfg.ssm_head_dim
        return MambaCache(
            jnp.zeros((batch, K - 1, din), dtype),
            jnp.zeros((batch, K - 1, n), dtype),
            jnp.zeros((batch, K - 1, n), dtype),
            jnp.zeros((batch, h, pd, n), jnp.float32),
        )


jax.tree_util.register_dataclass(
    MambaCache, ["conv_x", "conv_B", "conv_C", "state"], []
)


def init_mamba(key, cfg: ArchConfig) -> dict:
    d, din, n, h = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    K = cfg.ssm_conv
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 9)
    return {
        "w_x": _normal(ks[0], (d, din), d ** -0.5, dt),
        "w_z": _normal(ks[1], (d, din), d ** -0.5, dt),
        "w_B": _normal(ks[2], (d, n), d ** -0.5, dt),
        "w_C": _normal(ks[3], (d, n), d ** -0.5, dt),
        "w_dt": _normal(ks[4], (d, h), d ** -0.5, dt),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.zeros((h,), jnp.float32),  # a = -exp(A_log) = -1
        "D": jnp.ones((h,), jnp.float32),
        "conv_x": _normal(ks[5], (K, din), K ** -0.5, dt),
        "conv_B": _normal(ks[6], (K, n), K ** -0.5, dt),
        "conv_C": _normal(ks[7], (K, n), K ** -0.5, dt),
        "norm": init_norm(din),
        "w_out": _normal(ks[8], (din, d), din ** -0.5, dt),
    }


def spec_mamba(cfg: ArchConfig) -> dict:
    return {
        "w_x": P("data", "model"),
        "w_z": P("data", "model"),
        "w_B": P("data", None),
        "w_C": P("data", None),
        "w_dt": P("data", "model"),
        "dt_bias": P(None),
        "A_log": P(None),
        "D": P(None),
        "conv_x": P(None, "model"),
        "conv_B": P(None, None),
        "conv_C": P(None, None),
        "norm": spec_norm(),
        "w_out": P("model", "data"),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, tail: jnp.ndarray | None = None):
    """Depthwise causal conv via K shifted adds.  x: (B,S,C); w: (K,C).
    ``tail``: (B, K-1, C) history for decode/streaming continuity."""
    K = w.shape[0]
    if tail is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    out = sum(xp[:, j : j + S, :] * w[j][None, None, :] for j in range(K))
    return jax.nn.silu(out)


def _ssd_chunked(x, loga, Bm, C, chunk: int):
    """Chunked SSD scan (pure jnp; same math as kernels/ssd).

    x: (B,T,H,P); loga: (B,T,H) f32; Bm, C: (B,T,N).  Returns y, final state.
    """
    Bb, T, H, Pd = x.shape
    N = Bm.shape[-1]
    L = min(chunk, T)
    pad = (-T) % L
    if pad:  # zero-pad: loga=0 (no decay) and x=0 leave the state untouched
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    T_pad = T + pad
    nc = T_pad // L
    xc = x.astype(jnp.float32).reshape(Bb, nc, L, H, Pd)
    lc = loga.astype(jnp.float32).reshape(Bb, nc, L, H)
    Bc = Bm.astype(jnp.float32).reshape(Bb, nc, L, N)
    Cc = C.astype(jnp.float32).reshape(Bb, nc, L, N)

    ti = jnp.arange(L)[:, None]
    si = jnp.arange(L)[None, :]
    mask = ti >= si

    def chunk_step(S_prev, inp):
        xk, lk, Bk, Ck = inp  # (B,L,H,P), (B,L,H), (B,L,N), (B,L,N)
        lcum = jnp.cumsum(lk, axis=1)  # (B,L,H)
        ltot = lcum[:, -1]  # (B,H)
        # inter-chunk: read the incoming facet
        cs = jnp.einsum("bln,bhpn->blhp", Ck, S_prev)
        y_inter = jnp.exp(lcum)[..., None] * cs
        # intra-chunk: masked decay attention
        G = jnp.einsum("bln,bsn->bls", Ck, Bk)  # (B, L_t, L_s)
        ldiff = lcum[:, :, None, :] - lcum[:, None, :, :]  # (B, Lt, Ls, H)
        W = jnp.where(mask[None, :, :, None], jnp.exp(ldiff) * G[..., None], 0.0)
        y_intra = jnp.einsum("blsh,bshp->blhp", W, xk)
        # flow-out facet: next chunk's state
        wout = jnp.exp(ltot[:, None] - lcum)  # (B,L,H)
        dS = jnp.einsum("blhp,bln->bhpn", xk * wout[..., None], Bk)
        S_new = jnp.exp(ltot)[..., None, None] * S_prev + dS
        return S_new, y_intra + y_inter

    S0 = jnp.zeros((Bb, H, Pd, N), jnp.float32)
    xs = (
        xc.transpose(1, 0, 2, 3, 4),
        lc.transpose(1, 0, 2, 3),
        Bc.transpose(1, 0, 2, 3),
        Cc.transpose(1, 0, 2, 3),
    )
    S_fin, yc = jax.lax.scan(chunk_step, S0, xs)
    y = yc.transpose(1, 0, 2, 3, 4).reshape(Bb, T_pad, H, Pd)[:, :T]
    return y.astype(x.dtype), S_fin


def _projections(p, x, cfg: ArchConfig):
    cd = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cd)
    xi = xc @ p["w_x"].astype(cd)  # (B,S,din)
    z = xc @ p["w_z"].astype(cd)
    Bm = xc @ p["w_B"].astype(cd)
    Cm = xc @ p["w_C"].astype(cd)
    dt = xc @ p["w_dt"].astype(cd)  # (B,S,H)
    return xi, z, Bm, Cm, dt


def _decays(p, dt):
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    return -jnp.exp(p["A_log"])[None, None, :] * dtp, dtp


def mamba_train(p: dict, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Full-sequence SSD block (training / prefill)."""
    B, S, d = x.shape
    h, pd, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    xi, z, Bm, Cm, dt = _projections(p, x, cfg)
    xi = _causal_conv(xi, p["conv_x"].astype(xi.dtype))
    Bm = _causal_conv(Bm, p["conv_B"].astype(Bm.dtype))
    Cm = _causal_conv(Cm, p["conv_C"].astype(Cm.dtype))
    xi = constrain(xi, batch_spec(None, "model"))
    loga, dtp = _decays(p, dt)
    xh = (xi.reshape(B, S, h, pd) * dtp[..., None].astype(xi.dtype))
    y, _ = _ssd_chunked(xh, loga, Bm, Cm, cfg.ssm_chunk)
    y = y + p["D"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(B, S, h * pd)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    cd = jnp.dtype(cfg.compute_dtype)
    out = y.astype(cd) @ p["w_out"].astype(cd)
    return constrain(out, batch_spec(None, None))


def mamba_decode(
    p: dict, x: jnp.ndarray, cache: MambaCache, cfg: ArchConfig
) -> tuple[jnp.ndarray, MambaCache]:
    """One-token SSD step; O(1) state update (the facet, degenerate chunk)."""
    B, _, d = x.shape
    h, pd = cfg.ssm_heads, cfg.ssm_head_dim
    xi, z, Bm, Cm, dt = _projections(p, x, cfg)
    xi_c = _causal_conv(xi, p["conv_x"].astype(xi.dtype), tail=cache.conv_x)
    Bm_c = _causal_conv(Bm, p["conv_B"].astype(Bm.dtype), tail=cache.conv_B)
    Cm_c = _causal_conv(Cm, p["conv_C"].astype(Cm.dtype), tail=cache.conv_C)
    new_cache_tails = (
        jnp.concatenate([cache.conv_x[:, 1:], xi.astype(cache.conv_x.dtype)], axis=1),
        jnp.concatenate([cache.conv_B[:, 1:], Bm.astype(cache.conv_B.dtype)], axis=1),
        jnp.concatenate([cache.conv_C[:, 1:], Cm.astype(cache.conv_C.dtype)], axis=1),
    )
    loga, dtp = _decays(p, dt)  # (B,1,H)
    xh = (xi_c.reshape(B, 1, h, pd) * dtp[..., None].astype(xi_c.dtype))
    a = jnp.exp(loga[:, 0])[:, :, None, None]  # (B,H,1,1)
    S_new = a * cache.state + jnp.einsum(
        "bhp,bn->bhpn", xh[:, 0].astype(jnp.float32), Bm_c[:, 0].astype(jnp.float32)
    )
    y = jnp.einsum("bhpn,bn->bhp", S_new, Cm_c[:, 0].astype(jnp.float32))
    y = y[:, None] + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, 1, h * pd)
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z), p["norm"])
    cd = jnp.dtype(cfg.compute_dtype)
    out = y.astype(cd) @ p["w_out"].astype(cd)
    new_cache = MambaCache(*new_cache_tails, S_new)
    return constrain(out, batch_spec(None, None)), new_cache
