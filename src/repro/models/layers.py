"""Core model layers: norms, RoPE, GQA attention (train/prefill/decode over
the facet-layout KV cache), SwiGLU MLP, embeddings.

Functional style: every layer is an ``init_*`` returning a param dict, a
parallel ``spec_*`` returning logical PartitionSpecs, and an ``apply``
function.  Sharding is expressed through ``repro.distributed.sharding``:

* TP (Megatron): wq/wk/wv column-parallel over 'model' (head dim), wo
  row-parallel; w1/w3 column-, w2 row-parallel — activations between blocks
  are constrained to batch-sharded/replicated, so GSPMD inserts exactly the
  two all-reduces per block;
* FSDP: the non-TP weight dim is sharded over 'data' (gathered per layer by
  the scan);
* attention is computed in query/key chunks (flash-style online softmax) so
  no S x S score tensor is ever materialised — prefill_32k stays O(S.chunk).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import P, batch_spec, constrain
from .config import ArchConfig

__all__ = [
    "rms_norm", "init_norm", "spec_norm",
    "apply_rope",
    "init_attention", "spec_attention", "attention",
    "decode_attention_blocks",
    "init_mlp", "spec_mlp", "mlp",
    "init_embedding", "spec_embedding", "embed", "unembed",
    "KVCache",
]


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------

def init_norm(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def spec_norm() -> dict:
    return {"scale": P(None)}


def rms_norm(x: jnp.ndarray, p: dict, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None, None] * freqs  # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def _normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class KVCache:
    """Facet(block)-layout KV cache: (B, nb, Hkv_stored, block, Dh)."""

    k: jnp.ndarray
    v: jnp.ndarray

    @staticmethod
    def zeros(cfg: ArchConfig, batch: int, seq: int, dtype=jnp.bfloat16) -> "KVCache":
        bs = cfg.kv_block
        nb = -(-seq // bs)
        shape = (batch, nb, cfg.stored_kv_heads, bs, cfg.head_dim)
        return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


jax.tree_util.register_dataclass(KVCache, ["k", "v"], [])


def init_attention(key, cfg: ArchConfig) -> dict:
    d, hq, hkv, dh = cfg.d_model, cfg.padded_q_heads, cfg.stored_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    scale = d ** -0.5
    dt = jnp.dtype(cfg.param_dtype)
    wq = _normal(ks[0], (d, hq, dh), scale, dt)
    # zero the padded query heads: they contribute nothing, exactly
    if cfg.padded_q_heads != cfg.n_heads:
        mask = (np.arange(hq) < cfg.n_heads)[None, :, None]
        wq = wq * jnp.asarray(mask, dt)
    # kv weights are initialised per *real* kv head then replicated so the
    # stored-kv expansion is function-preserving GQA
    rep = cfg.stored_kv_heads // cfg.n_kv_heads
    wk = _normal(ks[1], (d, cfg.n_kv_heads, dh), scale, dt)
    wv = _normal(ks[2], (d, cfg.n_kv_heads, dh), scale, dt)
    wk = jnp.repeat(wk, rep, axis=1)
    wv = jnp.repeat(wv, rep, axis=1)
    wo = _normal(ks[3], (hq, dh, d), (hq * dh) ** -0.5, dt)
    if cfg.padded_q_heads != cfg.n_heads:
        mask = (np.arange(hq) < cfg.n_heads)[:, None, None]
        wo = wo * jnp.asarray(mask, dt)
    p = {"wq": wq, "wk": wk, "wv": wv, "wo": wo}
    if cfg.qk_norm:
        p["q_norm"] = init_norm(dh)
        p["k_norm"] = init_norm(dh)
    return p


def spec_attention(cfg: ArchConfig) -> dict:
    s = {
        "wq": P("data", "model", None),
        "wk": P("data", "model", None),
        "wv": P("data", "model", None),
        "wo": P("model", None, "data"),
    }
    if cfg.qk_norm:
        s["q_norm"] = spec_norm()
        s["k_norm"] = spec_norm()
    return s


def _project_qkv(p, x, kv_x, cfg: ArchConfig, q_positions, kv_positions):
    cd = jnp.dtype(cfg.compute_dtype)
    q = jnp.einsum("bsd,dhk->bshk", x.astype(cd), p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", kv_x.astype(cd), p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", kv_x.astype(cd), p["wv"].astype(cd))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if q_positions is not None:
        q = apply_rope(q, q_positions, cfg.rope_theta)
    if kv_positions is not None:
        k = apply_rope(k, kv_positions, cfg.rope_theta)
    q = constrain(q, batch_spec(None, "model", None))
    k = constrain(k, batch_spec(None, "model", None))
    v = constrain(v, batch_spec(None, "model", None))
    return q, k, v


def _chunked_attention(q, k, v, *, causal: bool, chunk: int, q_offset=0):
    """Flash-style attention in pure jnp: scan over query chunks, inner scan
    over key chunks with online softmax.  No (S, S) tensor materialised."""
    B, Sq, H, Dh = q.shape
    Sk = k.shape[1]
    cq = min(chunk, Sq)
    ck = min(chunk, Sk)
    nq, nk = -(-Sq // cq), -(-Sk // ck)
    qpad, kpad = nq * cq - Sq, nk * ck - Sk
    qf = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0))).astype(jnp.float32)
    kf = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0))).astype(jnp.float32)
    vf = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0))).astype(jnp.float32)
    scale = Dh ** -0.5
    kv_heads = k.shape[2]
    g = H // kv_heads

    qf = qf.reshape(B, nq, cq, kv_heads, g, Dh).transpose(1, 0, 3, 4, 2, 5)
    kf = kf.reshape(B, nk, ck, kv_heads, Dh).transpose(1, 0, 3, 2, 4)
    vf = vf.reshape(B, nk, ck, kv_heads, Dh).transpose(1, 0, 3, 2, 4)

    q_pos = q_offset + jnp.arange(nq * cq).reshape(nq, cq)
    k_pos = jnp.arange(nk * ck).reshape(nk, ck)
    k_valid = k_pos < Sk

    def per_q_chunk(carry, inp):
        qc, qp = inp  # (B, kvh, g, cq, Dh), (cq,)

        def per_k_chunk(state, kin):
            m, l, acc = state
            kc, vc, kp, kval = kin
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qc, kc) * scale
            mask = kval[None, None, None, None, :]
            if causal:
                mask = mask & (qp[None, None, None, :, None] >= kp[None, None, None, None, :])
            s = jnp.where(mask, s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            pexp = jnp.exp(s - m_safe[..., None])
            pexp = jnp.where(mask, pexp, 0.0)
            l_new = l * alpha + pexp.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum("bhgqk,bhkd->bhgqd", pexp, vc)
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((B, kv_heads, g, cq), -jnp.inf),
            jnp.zeros((B, kv_heads, g, cq)),
            jnp.zeros((B, kv_heads, g, cq, Dh)),
        )
        (m, l, acc), _ = jax.lax.scan(per_k_chunk, init, (kf, vf, k_pos, k_valid))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return carry, out

    _, out = jax.lax.scan(per_q_chunk, None, (qf, q_pos))
    # (nq, B, kvh, g, cq, Dh) -> (B, Sq, H, Dh)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * cq, H, Dh)
    return out[:, :Sq].astype(q.dtype)


def attention(
    p: dict,
    x: jnp.ndarray,  # (B, S, d)
    cfg: ArchConfig,
    *,
    positions: jnp.ndarray | None = None,  # (S,) or (B, S)
    kv_x: jnp.ndarray | None = None,  # cross-attention source
    causal: bool = True,
    rope: bool = True,
    chunk: int = 512,
    cache: KVCache | None = None,  # if given (with causal), emit block cache
) -> tuple[jnp.ndarray, KVCache | None]:
    """Self/cross attention over a full sequence (train / prefill)."""
    B, S, d = x.shape
    src = x if kv_x is None else kv_x
    if positions is None:
        positions = jnp.arange(S)[None, :]
    qpos = positions if rope else None
    kpos = (positions if kv_x is None else None) if rope else None
    q, k, v = _project_qkv(p, x, src, cfg, qpos, kpos)
    out = _chunked_attention(q, k, v, causal=causal, chunk=chunk)
    out = constrain(out, batch_spec(None, "model", None))
    new_cache = None
    if cache is not None:
        nb, bs = cache.k.shape[1], cache.k.shape[3]
        kpad = jnp.pad(k, ((0, 0), (0, nb * bs - k.shape[1]), (0, 0), (0, 0)))
        vpad = jnp.pad(v, ((0, 0), (0, nb * bs - v.shape[1]), (0, 0), (0, 0)))
        to_blocks = lambda t: t.reshape(B, nb, bs, t.shape[2], t.shape[3]).transpose(0, 1, 3, 2, 4)
        new_cache = KVCache(
            to_blocks(kpad).astype(cache.k.dtype), to_blocks(vpad).astype(cache.v.dtype)
        )
    cd = jnp.dtype(cfg.compute_dtype)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(cd), p["wo"].astype(cd))
    return constrain(y, batch_spec(None, None)), new_cache


def decode_attention_blocks(
    p: dict,
    x: jnp.ndarray,  # (B, 1, d)
    cache: KVCache,
    position: jnp.ndarray,  # int32: scalar, or (B,) per-lane positions
    cfg: ArchConfig,
    *,
    rope: bool = True,
) -> tuple[jnp.ndarray, KVCache]:
    """One decode step over the facet(block)-layout cache.

    The new token's K/V are appended with a single in-block store (CFA's
    write-one-burst stance); attention reads the cache block-wise (jnp path;
    ``repro.kernels.block_attention`` is the Pallas TPU path).

    ``position`` may be per-lane (continuous batching): each sequence in the
    batch writes and masks at its own offset."""
    B, _, d = x.shape
    pos = jnp.asarray(position, jnp.int32)
    per_lane = pos.ndim == 1
    qpos = (pos[:, None] if per_lane else pos[None, None]) if rope else None
    q, k, v = _project_qkv(p, x, x, cfg, qpos, qpos)
    bs = cache.k.shape[3]
    blk, row = pos // bs, pos % bs
    zero = jnp.int32(0)

    if per_lane:
        def put(blocks, new):  # vmapped per-lane in-block store
            def one(bl, nw, b_, r_):  # bl (nb,H,bs,D); nw (H,1,D)
                return jax.lax.dynamic_update_slice(
                    bl, nw[None].astype(bl.dtype), (b_, zero, r_, zero))
            return jax.vmap(one)(blocks, new[:, 0], blk, row)
    else:
        def put(blocks, new):  # (B, nb, H, bs, D) <- (B, 1, H, 1, D)
            return jax.lax.dynamic_update_slice(
                blocks, new.astype(blocks.dtype), (zero, blk, zero, row, zero)
            )

    cache = KVCache(
        put(cache.k, k.transpose(0, 2, 1, 3)[:, None]),
        put(cache.v, v.transpose(0, 2, 1, 3)[:, None]),
    )
    nb, hkv = cache.k.shape[1], cache.k.shape[2]
    g = q.shape[2] // hkv
    qg = q.reshape(B, hkv, g, cfg.head_dim).astype(jnp.float32)
    kb = cache.k.astype(jnp.float32)
    vb = cache.v.astype(jnp.float32)
    s = jnp.einsum("bhgk,bnhsk->bhgns", qg, kb) * (cfg.head_dim ** -0.5)
    kpos = (jnp.arange(nb)[:, None] * bs + jnp.arange(bs)[None, :])[None, None, None]
    pos_b = pos[:, None, None, None, None] if per_lane else pos
    s = jnp.where(kpos <= pos_b, s, -jnp.inf)
    s = s.reshape(B, hkv, g, nb * bs)
    w = jax.nn.softmax(s, axis=-1).reshape(B, hkv, g, nb, bs)
    out = jnp.einsum("bhgns,bnhsk->bhgk", w, vb).reshape(B, 1, hkv * g, cfg.head_dim)
    cd = jnp.dtype(cfg.compute_dtype)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(cd), p["wo"].astype(cd))
    return constrain(y, batch_spec(None, None)), cache


def decode_cross_attention(
    p: dict,
    x: jnp.ndarray,  # (B, 1, d)
    k: jnp.ndarray,  # (B, S_src, H, Dh) precomputed source K
    v: jnp.ndarray,
    cfg: ArchConfig,
) -> jnp.ndarray:
    cd = jnp.dtype(cfg.compute_dtype)
    q = jnp.einsum("bsd,dhk->bshk", x.astype(cd), p["wq"].astype(cd))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
    hkv = k.shape[2]
    g = q.shape[2] // hkv
    qg = q.reshape(x.shape[0], hkv, g, cfg.head_dim).astype(jnp.float32)
    s = jnp.einsum("bhgk,bshk->bhgs", qg, k.astype(jnp.float32)) * (cfg.head_dim ** -0.5)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshk->bhgk", w, v.astype(jnp.float32))
    out = out.reshape(x.shape[0], 1, hkv * g, cfg.head_dim)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(cd), p["wo"].astype(cd))
    return constrain(y, batch_spec(None, None))


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ArchConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": _normal(k1, (d, f), d ** -0.5, dt),
        "w3": _normal(k2, (d, f), d ** -0.5, dt),
        "w2": _normal(k3, (f, d), f ** -0.5, dt),
    }


def spec_mlp() -> dict:
    return {
        "w1": P("data", "model"),
        "w3": P("data", "model"),
        "w2": P("model", "data"),
    }


def mlp(p: dict, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    cd = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cd)
    h = jax.nn.silu(xc @ p["w1"].astype(cd)) * (xc @ p["w3"].astype(cd))
    h = constrain(h, batch_spec(None, "model"))
    y = h @ p["w2"].astype(cd)
    return constrain(y, batch_spec(None, None))


# ---------------------------------------------------------------------------
# embedding / unembedding (vocab-parallel, padded)
# ---------------------------------------------------------------------------

def init_embedding(key, cfg: ArchConfig) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    vp, d = cfg.padded_vocab, cfg.d_model
    table = _normal(k1, (vp, d), 1.0, dt)
    head = _normal(k2, (d, vp), d ** -0.5, dt)
    return {"table": table, "head": head}


def spec_embedding() -> dict:
    # table: vocab-parallel only — sharding d as well makes the gather's
    # SPMD partitioning degenerate to full-batch all-gathers (measured in
    # the dry-run HLO; see EXPERIMENTS.md §Perf iteration 0).
    return {"table": P("model", None), "head": P(None, "model")}


def embed(p: dict, tokens: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    cd = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(p["table"].astype(cd), tokens, axis=0)
    return constrain(x, batch_spec(None, None))


def unembed(p: dict, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    cd = jnp.dtype(cfg.compute_dtype)
    logits = x.astype(cd) @ p["head"].astype(cd)  # (B, S, padded_vocab)
    return constrain(logits, batch_spec(None, "model"))
