"""Polyhedral-lite integer machinery for Canonical Facet Allocation (CFA).

The paper (Ferry et al., "Increasing FPGA Accelerators Memory Bandwidth with
a Burst-Friendly Memory Layout", 2022) restricts itself to

  * rectangular iteration spaces,
  * rectangular tiles,
  * uniform dependencies whose vectors are backwards in every dimension
    (any skewing required to reach this normal form is assumed to have been
    applied beforehand, §IV-E).

Under those hypotheses full ISL generality is unnecessary: every set we
manipulate is a union of integer boxes.  This module provides exactly that —
boxes, uniform dependence patterns, tiles, and the flow-in / flow-out /
facet point sets of the paper, materialised as ``numpy`` integer point
arrays so that downstream analyses (burst-run counting, coverage proofs,
property tests) are exact rather than asserted.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable, Sequence

import numpy as np

__all__ = [
    "IterSpace",
    "Deps",
    "Tiling",
    "facet_widths",
    "box_points",
    "tile_box",
    "tile_points",
    "flow_in_points",
    "flow_out_points",
    "facet_points",
    "neighbor_offsets",
]


@dataclasses.dataclass(frozen=True)
class IterSpace:
    """Rectangular iteration space ``E = [0,N_1) x ... x [0,N_d)``."""

    sizes: tuple[int, ...]

    def __post_init__(self) -> None:
        if not self.sizes or any(n <= 0 for n in self.sizes):
            raise ValueError(f"iteration space sizes must be positive: {self.sizes}")

    @property
    def ndim(self) -> int:
        return len(self.sizes)

    def contains(self, pts: np.ndarray) -> np.ndarray:
        """Boolean mask of which points (n, d) lie inside the space."""
        pts = np.atleast_2d(pts)
        lo = (pts >= 0).all(axis=1)
        hi = (pts < np.asarray(self.sizes)).all(axis=1)
        return lo & hi


@dataclasses.dataclass(frozen=True)
class Deps:
    """Uniform dependence pattern: iteration ``x`` reads ``x + B_q``.

    All components of every vector must be <= 0 ("backwards in all
    dimensions"), which is the paper's legality condition for rectangular
    tiling (§IV-D/E).
    """

    vectors: tuple[tuple[int, ...], ...]

    def __post_init__(self) -> None:
        if not self.vectors:
            raise ValueError("dependence pattern must be non-empty")
        d = len(self.vectors[0])
        for v in self.vectors:
            if len(v) != d:
                raise ValueError(f"inconsistent dependence arity: {self.vectors}")
            if any(c > 0 for c in v):
                raise ValueError(
                    f"dependence vector {v} is not backwards in all dimensions; "
                    "skew the iteration space first (paper §IV-E)"
                )
        if all(all(c == 0 for c in v) for v in self.vectors):
            raise ValueError("all-zero dependence pattern")

    @property
    def ndim(self) -> int:
        return len(self.vectors[0])

    def as_array(self) -> np.ndarray:
        return np.asarray(self.vectors, dtype=np.int64)


@dataclasses.dataclass(frozen=True)
class Tiling:
    """Rectangular tile sizes ``t_1 .. t_d``.

    The framework requires ``N_k % t_k == 0``; callers pad the space when the
    problem size is not a multiple (mirroring the full-tile codegen of the
    paper's proof-of-concept pass).
    """

    sizes: tuple[int, ...]

    def __post_init__(self) -> None:
        if any(t <= 0 for t in self.sizes):
            raise ValueError(f"tile sizes must be positive: {self.sizes}")

    @property
    def ndim(self) -> int:
        return len(self.sizes)

    def num_tiles(self, space: IterSpace) -> tuple[int, ...]:
        if space.ndim != self.ndim:
            raise ValueError(
                f"tiling {self.sizes} is {self.ndim}-D but the space "
                f"{space.sizes} is {space.ndim}-D"
            )
        for n, t in zip(space.sizes, self.sizes, strict=True):
            if n % t:
                raise ValueError(
                    f"space {space.sizes} not divisible by tiles {self.sizes}; pad first"
                )
        return tuple(n // t for n, t in zip(space.sizes, self.sizes, strict=True))


def facet_widths(deps: Deps) -> tuple[int, ...]:
    """``w_k = max_q |e_k . B_q|`` — facet thickness per canonical axis (§IV-F3).

    ``w_k == 0`` means no dependence crosses faces normal to axis ``k`` and no
    facet array is allocated for that axis.
    """
    b = deps.as_array()
    return tuple(int(w) for w in np.abs(b).max(axis=0))


def box_points(lo: Sequence[int], hi: Sequence[int]) -> np.ndarray:
    """All integer points of the half-open box ``[lo, hi)`` as an (n, d) array."""
    axes = [np.arange(l, h, dtype=np.int64) for l, h in zip(lo, hi)]
    if any(a.size == 0 for a in axes):
        return np.empty((0, len(axes)), dtype=np.int64)
    mesh = np.meshgrid(*axes, indexing="ij")
    return np.stack([m.ravel() for m in mesh], axis=1)


def tile_box(tile: Sequence[int], tiling: Tiling) -> tuple[np.ndarray, np.ndarray]:
    """(lo, hi) corners of the tile with coordinates ``tile``."""
    t = np.asarray(tiling.sizes, dtype=np.int64)
    q = np.asarray(tile, dtype=np.int64)
    return q * t, (q + 1) * t


def tile_points(tile: Sequence[int], tiling: Tiling) -> np.ndarray:
    lo, hi = tile_box(tile, tiling)
    return box_points(lo, hi)


def _unique_rows(pts: np.ndarray) -> np.ndarray:
    if pts.size == 0:
        return pts
    return np.unique(pts, axis=0)


def flow_in_points(
    space: IterSpace, deps: Deps, tiling: Tiling, tile: Sequence[int]
) -> np.ndarray:
    """The iteration-wise flow-in set of a tile (paper appendix):

        phi_i(T) = { y in E \\ T : exists q, y - B_q in T }
                 = union_q (T + B_q) intersect E, minus T.
    """
    lo, hi = tile_box(tile, tiling)
    pieces = []
    for b in deps.as_array():
        pts = box_points(lo + b, hi + b)
        pts = pts[space.contains(pts)]
        pieces.append(pts)
    pts = _unique_rows(np.concatenate(pieces, axis=0)) if pieces else np.empty((0, space.ndim))
    inside = ((pts >= lo) & (pts < hi)).all(axis=1)
    return pts[~inside]


def flow_out_points(
    space: IterSpace, deps: Deps, tiling: Tiling, tile: Sequence[int]
) -> np.ndarray:
    """Iterations of T whose results are consumed by another tile:

        phi_o(T) = { x in T : exists q, x - B_q in E \\ T }.
    """
    pts = tile_points(tile, tiling)
    lo, hi = tile_box(tile, tiling)
    used = np.zeros(len(pts), dtype=bool)
    for b in deps.as_array():
        cons = pts - b  # consumer iteration y = x - B (y + B = x)
        in_space = space.contains(cons)
        in_tile = ((cons >= lo) & (cons < hi)).all(axis=1)
        used |= in_space & ~in_tile
    return pts[used]


def facet_points(
    tiling: Tiling, widths: Sequence[int], axis: int, tile: Sequence[int]
) -> np.ndarray:
    """The k-th facet of tile T (paper appendix):

        S_k(T) = { x in T : t_k - w_k <= x_k mod t_k }.
    """
    w = widths[axis]
    if w <= 0:
        return np.empty((0, tiling.ndim), dtype=np.int64)
    lo, hi = tile_box(tile, tiling)
    lo = lo.copy()
    lo[axis] = hi[axis] - w
    return box_points(lo, hi)


def neighbor_offsets(d: int, *, max_level: int | None = None) -> list[tuple[int, ...]]:
    """All backward neighbor tile offsets delta in {0,-1}^d \\ {0}.

    The number of nonzero components is the neighbor "level" of §IV-D.
    """
    out = []
    for delta in itertools.product((0, -1), repeat=d):
        lvl = sum(1 for c in delta if c)
        if lvl == 0:
            continue
        if max_level is not None and lvl > max_level:
            continue
        out.append(delta)
    return out
