"""The CFA "compiler pass" output: a read -> execute -> write tile pipeline.

Mirrors §V of the paper.  Given a :class:`StencilProgram` (post-skew normal
form), a rectangular space and a tiling, :class:`CFAPipeline` provides

* ``init_facets``  — allocate the facet arrays (plus one virtual leading
  block row on the time facet holding live-in planes),
* ``copy_in``      — gather a tile's flow-in from facets into a local halo
  buffer (the on-chip scratchpad; off-chip side reads facet blocks),
* ``execute_tile`` — run the tile's plane recurrence on the halo buffer,
* ``copy_out``     — write the tile's facet blocks (full-tile contiguity:
  each is one contiguous store),
* ``_sweep``       — the whole accelerator loop over tiles in lexicographic
  order (the legal schedule under backward dependences); the executor
  registry (``repro.core.cfa.executors``) is the public way to run it.

On real hardware the three phases run as a coarse-grain pipeline
(paper Fig. 13, DATAFLOW); in Pallas the same overlap comes for free from
grid pipelining — see ``repro.kernels.stencil``.  This module is the
correctness/reference path and is deliberately written tile-by-tile.

The pipeline is dimension-generic (the paper's construction is, §IV-F..J):
any d >= 2 works — one time axis plus d-1 spatial axes — so 2-D programs
(``heat1d``), the 3-D Table I suite, and 4-D programs (``heat3d``, the
§IV-J regime) all run through the same code path.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import typing
import warnings
from typing import Mapping

import numpy as np
import jax
import jax.numpy as jnp

from .facets import FacetSpec, build_facet_specs, row_major_strides
from .programs import StencilProgram
from .spaces import IterSpace, Tiling, box_points

__all__ = ["CFAPipeline"]


@dataclasses.dataclass
class CFAPipeline:
    #: facet storage discipline this pipeline realises; the irredundant /
    #: compressed variants live in ``repro.core.cfa.irredundant``
    storage: typing.ClassVar[str] = "redundant"

    program: StencilProgram
    space: IterSpace
    tiling: Tiling
    # layout knobs (see repro.core.cfa.facets); defaults = the paper's layout
    ext_dirs: Mapping[int, int] | tuple[tuple[int, int], ...] | None = None
    contiguity: str = "intra-tile"
    # the autotuner decision this pipeline was built from, if any
    decision: object | None = dataclasses.field(default=None, repr=False, compare=False)
    # the compile-time facet->port split (the port_repartition pass); the
    # sharded sweep prefers it over re-deriving one from the decision
    port_assignment: object | None = dataclasses.field(default=None, repr=False, compare=False)
    # round-trip every halo gather through the int8 compression hooks of
    # repro.distributed.compression (lossy halo traffic, the distribute
    # pass's compression knob; False keeps results bit-exact)
    halo_quantize: bool = False
    # runtime telemetry (repro.core.cfa.obs.TraceRecorder); None = tracing
    # off, and the executors pay exactly one `is None` check per phase —
    # no recorder or span allocation on the hot path
    recorder: object | None = dataclasses.field(default=None, repr=False, compare=False)
    specs: Mapping[int, FacetSpec] = dataclasses.field(init=False)
    num_tiles: tuple[int, ...] = dataclasses.field(init=False)

    def __post_init__(self) -> None:
        if self.space.ndim < 2:
            raise ValueError(
                "the executor needs a time axis plus at least one spatial "
                f"axis (d >= 2); got a {self.space.ndim}-D space"
            )
        if self.program.ndim != self.space.ndim:
            raise ValueError(
                f"program {self.program.name!r} is {self.program.ndim}-D but "
                f"the space is {self.space.ndim}-D"
            )
        self.specs = build_facet_specs(
            self.space, self.program.deps, self.tiling,
            ext_dirs=dict(self.ext_dirs) if self.ext_dirs is not None else None,
            contiguity=self.contiguity,
        )
        self.num_tiles = self.tiling.num_tiles(self.space)
        if 0 not in self.specs:
            raise ValueError("time axis must carry a facet (w_0 >= 1)")

    # -- storage -----------------------------------------------------------

    def facet_shape(self, k: int) -> tuple[int, ...]:
        shape = list(self.specs[k].shape)
        if k == 0:
            shape[0] += 1  # virtual leading block row for live-in planes
        return tuple(shape)

    def init_facets(self, dtype=jnp.float32) -> dict[int, jnp.ndarray]:
        return {k: jnp.zeros(self.facet_shape(k), dtype) for k in self.specs}

    def load_inputs(
        self, facets: dict[int, jnp.ndarray], inputs: jnp.ndarray
    ) -> dict[int, jnp.ndarray]:
        """Pack live-in planes (w_0, N_1, .., N_{d-1}) into the virtual
        facet_0 row."""
        spec = self.specs[0]
        w0 = spec.width
        if inputs.shape != (w0, *self.space.sizes[1:]):
            raise ValueError(f"inputs must be {(w0, *self.space.sizes[1:])}")
        f0 = facets[0]
        t = self.tiling.sizes
        for q in itertools.product(*(range(n) for n in self.num_tiles[1:])):
            sl = tuple(
                slice(q[a - 1] * t[a], (q[a - 1] + 1) * t[a])
                for a in range(1, self.space.ndim)
            )
            blk = inputs[(slice(None), *sl)]
            f0 = self._store_block(f0, spec, (-1, *q), blk, virtual=True)
        facets = dict(facets)
        facets[0] = f0
        return facets

    # -- block addressing ----------------------------------------------------

    def _block_index(self, spec: FacetSpec, tile: tuple[int, ...], virtual: bool):
        idx = []
        for a in spec.outer_axes:
            q = tile[a]
            if spec.axis == 0 and a == 0:
                q += 1  # shift for the virtual live-in row
            idx.append(q)
        return tuple(idx)

    def _store_block(self, arr, spec: FacetSpec, tile, slab, *, virtual=False):
        """``slab`` has canonical axis order with axis ``spec.axis`` of size w
        indexed by slab position; store it permuted to the facet block layout
        with the paper's (tile-dependent, in general) modulo labelling."""
        k, w, t_k = spec.axis, spec.width, spec.tile_sizes[spec.axis]
        x0 = tile[k] * t_k + t_k - w if not virtual else -w
        perm = np.argsort([(x0 + j) % w for j in range(w)])  # m -> slab j
        slab = jnp.take(slab, jnp.asarray(perm), axis=k)
        block = slab.transpose([a for a in spec.inner_axes])
        return self._commit_block(arr, self._block_index(spec, tile, virtual),
                                  block, spec)

    def _commit_block(self, arr, idx, block, spec: FacetSpec):
        """Write one laid-out facet block at its outer index.  The storage
        disciplines override only this commit step (owner-masked under
        irredundant storage, codec round-trip under compressed — see
        ``repro.core.cfa.irredundant``)."""
        return arr.at[idx].set(block)

    # -- copy-in -------------------------------------------------------------

    def _halo_maps(self, tile: tuple[int, ...]):
        """Static gather maps: halo point -> (facet id, flat offset).

        Halo = points of [lo - w, hi) with some coordinate below lo.  Points
        with x_0 < 0 come from the virtual live-in row; points outside the
        space elsewhere keep the zero boundary value.
        """
        d = self.space.ndim
        w = np.array([self.specs[a].width if a in self.specs else 0 for a in range(d)])
        lo = np.array(tile) * np.array(self.tiling.sizes)
        hi = lo + np.array(self.tiling.sizes)
        pts = box_points(lo - w, hi)
        below = (pts < lo).any(axis=1)
        pts = pts[below]
        # spatially out-of-space points are zero-boundary; x_0 < 0 is live-in
        in_space = np.ones(len(pts), dtype=bool)
        for a in range(1, d):
            in_space &= (pts[:, a] >= 0) & (pts[:, a] < self.space.sizes[a])
        in_space &= pts[:, 0] < self.space.sizes[0]
        pts = pts[in_space]
        maps = {}
        taken = np.zeros(len(pts), dtype=bool)
        # virtual live-in reads
        virt = pts[:, 0] < 0
        if virt.any():
            maps["virtual"] = pts[virt]
            taken |= virt
        maps.update(self._halo_hosts(pts, lo, taken))
        if not bool(taken.all()):
            raise AssertionError("halo point not covered by any facet — layout bug")
        return maps, lo, w

    def _halo_hosts(self, pts, lo, taken):
        """Assign each non-virtual halo point to the facet it is read from:
        under redundant storage, the first facet crossed along its own axis
        whose domain contains the point (any copy is valid — they are all
        written).  ``taken`` is updated in place.  The irredundant pipeline
        overrides this with the owner-facet indirection."""
        maps = {}
        for k, spec in self.specs.items():
            mask = ~taken & (pts[:, k] < lo[k]) & (pts[:, k] >= 0) & spec.domain_mask(pts)
            if mask.any():
                maps[k] = pts[mask]
                taken |= mask
        return maps

    def copy_in(self, facets: dict[int, jnp.ndarray], tile: tuple[int, ...]) -> jnp.ndarray:
        """Gather the tile's flow-in into a halo buffer of shape (w + t).

        When the facet arrays span several devices (port-resident facets
        under ``sweep_wavefront_sharded``) the scatter goes through a
        host-side buffer — mixing arrays committed to different devices in
        one ``.at[].set`` chain is a jax error — and the combined halo comes
        back as a fresh, uncommitted array.  Single-device facets (the
        ``sweep``/``sweep_wavefront`` hot path) keep the all-on-device path.
        """
        rec = self.recorder
        t_start = rec.now() if rec is not None else 0.0
        maps, lo, w = self._halo_maps(tile)
        if rec is not None:
            rec.add_span("halo_resolve", t_start, rec.now(),
                         track=rec.track("fetch"), tile=list(tile),
                         wave=int(sum(tile)), port=rec.port,
                         **rec.record_halo(self, maps))
        t = np.array(self.tiling.sizes)
        pieces = []
        for key, pts in maps.items():
            if key == "virtual":
                spec = self.specs[0]
                vals = self._gather_virtual(facets[0], spec, pts)
            else:
                spec = self.specs[key]
                flat = facets[key].reshape(-1)
                offs = spec.offsets(pts)
                if key == 0:  # account for the virtual leading row
                    offs = offs + spec.block_elems * math.prod(
                        spec.num_tiles[a] for a in spec.outer_axes[1:]
                    )
                vals = flat[jnp.asarray(offs)]
            if self.halo_quantize:
                # model compressed halo traffic: each gathered message
                # round-trips through the symmetric int8 quantizer (lossy;
                # see repro.distributed.compression)
                from repro.distributed.compression import (
                    dequantize_int8, quantize_int8)

                vals = dequantize_int8(*quantize_int8(vals)).astype(vals.dtype)
            pieces.append((pts - (lo - w), vals))
        devices = set()
        for arr in facets.values():
            devices.update(arr.devices() if hasattr(arr, "devices") else ())
        if len(devices) > 1:
            H = np.zeros(tuple(w + t), dtype=np.dtype(facets[0].dtype))
            for local, vals in pieces:
                H[tuple(local.T)] = np.asarray(vals)
            H = jnp.asarray(H)
        else:
            H = jnp.zeros(tuple(w + t), facets[0].dtype)
            for local, vals in pieces:
                H = H.at[tuple(jnp.asarray(local.T))].set(vals)
        if rec is not None:
            rec.add_span("copy_in", t_start, rec.now(),
                         track=rec.track("fetch"),
                         **rec.record_read(self, tile))
        return H

    def _gather_virtual(self, f0, spec: FacetSpec, pts: np.ndarray):
        """Read live-in points (x_0 < 0) from the virtual facet_0 row."""
        w = spec.width
        idx_cols = []
        shape = self.facet_shape(0)
        for a in spec.outer_axes:
            idx_cols.append(
                np.zeros(len(pts), np.int64) if a == 0 else pts[:, a] // spec.tile_sizes[a]
            )
        for a in spec.inner_axes:
            if a == 0:
                idx_cols.append(pts[:, 0] % w)  # matches the store perm for x0=-w..-1
            else:
                idx_cols.append(pts[:, a] % spec.tile_sizes[a])
        idx = np.stack(idx_cols, axis=1)
        return f0.reshape(-1)[jnp.asarray(idx @ row_major_strides(shape))]

    # -- execute ---------------------------------------------------------------

    @property
    def widths(self) -> tuple[int, ...]:
        """Facet width per axis (0 for axes that carry no facet)."""
        return tuple(
            self.specs[a].width if a in self.specs else 0
            for a in range(self.space.ndim)
        )

    def _interior_slices(self, w: tuple[int, ...]) -> tuple[slice, ...]:
        """Index of the tile interior within a (w + t)-shaped halo buffer."""
        return tuple(slice(w[a], None) for a in range(self.space.ndim))

    def execute_tile(self, H: jnp.ndarray) -> jnp.ndarray:
        """Run the plane recurrence over the halo buffer; returns the filled
        buffer (interior planes computed in place)."""
        w = self.widths
        t = self.tiling.sizes
        depth = w[0]
        spatial = self._interior_slices(w)[1:]
        for s in range(t[0]):
            prev = [H[w[0] + s - m] for m in range(depth, 0, -1)]
            plane = self.program.plane_update(prev, w)
            H = H.at[(w[0] + s, *spatial)].set(plane)
        return H

    # -- copy-out ---------------------------------------------------------------

    def copy_out(
        self, facets: dict[int, jnp.ndarray], tile: tuple[int, ...], H: jnp.ndarray
    ) -> dict[int, jnp.ndarray]:
        rec = self.recorder
        t_start = rec.now() if rec is not None else 0.0
        w = self.widths
        t = self.tiling.sizes
        interior = H[self._interior_slices(w)]
        out = dict(facets)
        for k, spec in self.specs.items():
            sl = [slice(None)] * self.space.ndim
            sl[k] = slice(t[k] - spec.width, t[k])
            out[k] = self._store_block(out[k], spec, tile, interior[tuple(sl)])
        if rec is not None:
            rec.add_span("copy_out", t_start, rec.now(),
                         track=rec.track("commit"),
                         **rec.record_write(self, tile))
        return out

    # -- full sweep ----------------------------------------------------------------

    def _sweep(self, inputs: jnp.ndarray, dtype=jnp.float32) -> dict[int, jnp.ndarray]:
        """Run the whole tiled computation through facet storage (the
        ``backend="sweep"`` executor's entry point)."""
        rec = self.recorder
        facets = self.init_facets(dtype)
        facets = self.load_inputs(facets, inputs.astype(dtype))
        if rec is not None:
            rec.counters.add("waves", len(self.wavefronts()))
        for tile in itertools.product(*(range(n) for n in self.num_tiles)):
            H = self.copy_in(facets, tile)
            if rec is None:
                H = self.execute_tile(H)
            else:
                with rec.span("execute_tile", track=rec.track("compute"),
                              tile=list(tile), wave=int(sum(tile))):
                    H = self.execute_tile(H)
            facets = self.copy_out(facets, tile, H)
        return facets

    # -- wavefront-parallel sweep ------------------------------------------------

    def wavefronts(self) -> list[list[tuple[int, ...]]]:
        """Tiles grouped by wavefront (sum of tile coordinates).

        All backward-neighbour dependencies strictly decrease the coordinate
        sum, so tiles within one wavefront are independent — the tile-level
        parallelism the paper's task pipeline generalises to on a machine
        with many cores/ports."""
        waves: dict[int, list[tuple[int, ...]]] = {}
        for tile in itertools.product(*(range(n) for n in self.num_tiles)):
            waves.setdefault(sum(tile), []).append(tile)
        return [waves[s] for s in sorted(waves)]

    def _sweep_wavefront(self, inputs: jnp.ndarray, dtype=jnp.float32,
                         use_kernel: bool = False,
                         interpret: bool = True) -> dict[int, jnp.ndarray]:
        """Wavefront-parallel sweep: each wave's tiles execute as one batch
        (through the Pallas tile executor when ``use_kernel``) — the
        ``backend="wavefront"``/``"pallas"`` executors' entry point."""
        rec = self.recorder
        facets = self.init_facets(dtype)
        facets = self.load_inputs(facets, inputs.astype(dtype))
        interior = self._interior_slices(self.widths)
        waves = self.wavefronts()
        if rec is not None:
            rec.counters.add("waves", len(waves))
        for wave in waves:
            halos = jnp.stack([self.copy_in(facets, t) for t in wave])
            tok = rec.begin("execute_wave", track=rec.track("compute"),
                            wave=int(sum(wave[0])), n_tiles=len(wave),
                            tiles=[list(t) for t in wave],
                            ) if rec is not None else None
            if use_kernel:
                from repro.kernels.stencil import execute_tiles

                interiors = execute_tiles(self.program.name, halos,
                                          self.tiling.sizes,
                                          interpret=interpret)
                outs = []
                for i in range(len(wave)):
                    H = halos[i].at[interior].set(interiors[i])
                    outs.append(H)
            else:
                outs = [self.execute_tile(halos[i]) for i in range(len(wave))]
            if tok is not None:
                rec.end(tok)
            for tile, H in zip(wave, outs):
                facets = self.copy_out(facets, tile, H)
        return facets

    # -- dataflow (overlapped) sweep ----------------------------------------

    def _sweep_dataflow(self, inputs: jnp.ndarray, dtype=jnp.float32,
                        use_kernel: bool = False,
                        interpret: bool = True) -> dict[int, jnp.ndarray]:
        """Software-pipelined wavefront sweep: fetch, compute and commit of
        consecutive tiles overlap (the host realisation of Fig. 13 DATAFLOW).

        Same plane arithmetic and same facet-commit order as
        ``_sweep_wavefront`` — only the *interleaving* changes: while tile
        ``j``'s execute is in flight (jax dispatches it asynchronously),
        tile ``j+1``'s halo is gathered and tile ``j-1``'s result is
        committed.  This is legal because every halo point a wave-``s``
        tile reads was committed by a strictly earlier wave (backward deps
        decrease the coordinate sum — see :meth:`wavefronts`), so a fetch
        never races a same-wave commit.

        The host path hands each gathered halo to a donated jitted staging
        buffer (``jax.jit(..., donate_argnums=0)``): the previous tile's
        halo memory is reused for the next tile — a ping-pong staging pair
        instead of a fresh allocation per tile — while the plane recurrence
        itself runs through the very same eager ``execute_tile`` the sweep
        executor uses, keeping the host path bit-exact.  The kernel path
        runs each tile through the Pallas executor (``execute_tiles``),
        whose grid pipeline double-buffers HBM<->VMEM copies against
        compute in hardware.
        """
        facets = self.init_facets(dtype)
        facets = self.load_inputs(facets, inputs.astype(dtype))
        interior = self._interior_slices(self.widths)
        if use_kernel:
            from repro.kernels.stencil import execute_tiles

            def _dispatch(H):
                out = execute_tiles(self.program.name, H[None],
                                    self.tiling.sizes, interpret=interpret)
                return H.at[interior].set(out[0])
        else:
            stage = jax.jit(lambda h: h, donate_argnums=0)

            def _dispatch(H):
                with warnings.catch_warnings():
                    # backends without donation support (CPU jax) warn and
                    # fall back to a copy; the staging is then a no-op,
                    # not an error
                    warnings.filterwarnings("ignore", message=r".*[Dd]onat")
                    H = stage(H)
                return self.execute_tile(H)

        rec = self.recorder
        waves = self.wavefronts()
        if rec is not None:
            rec.counters.add("waves", len(waves))
        for wave in waves:
            nxt = self.copy_in(facets, wave[0])
            prev_tile: tuple[int, ...] | None = None
            prev_out = None
            prev_tok: int | None = None
            for j, tile in enumerate(wave):
                # the compute span brackets the whole in-flight window:
                # dispatch here, closed when this tile's commit begins —
                # so the next tile's prefetch (and the previous tile's
                # commit) land *inside* it as concurrent lanes
                tok = rec.begin("execute_tile", track=rec.track("compute"),
                                tile=list(tile), wave=int(sum(tile)),
                                port=rec.port) if rec is not None else None
                H = _dispatch(nxt)  # async: compute in flight from here on
                if j + 1 < len(wave):
                    nxt = self.copy_in(facets, wave[j + 1])  # prefetch
                if prev_tile is not None:
                    if prev_tok is not None:
                        rec.end(prev_tok)
                    facets = self.copy_out(facets, prev_tile, prev_out)
                prev_tile, prev_out, prev_tok = tile, H, tok
            if prev_tok is not None:
                rec.end(prev_tok)
            facets = self.copy_out(facets, prev_tile, prev_out)
        return facets

    # -- multi-port sharded sweep -------------------------------------------

    def _sweep_wavefront_sharded(
        self,
        inputs: jnp.ndarray,
        dtype=jnp.float32,
        *,
        n_ports: int = 2,
        mesh=None,
        axis: str = "port",
        assignment=None,
        use_kernel: bool = False,
    ) -> dict[int, jnp.ndarray]:
        """Multi-port wavefront sweep: facet arrays sharded over a mesh axis
        per the port repartition, anti-diagonal tile waves executed in
        parallel via ``shard_map`` (paper §VII made an execution path) —
        the ``backend="sharded"`` executor's entry point.

        * the facet arrays are placed on their assigned port's device
          (``repro.distributed.sharding.shard_facets``; the facet array is the
          unit of contiguity, so facet-granular repartition == whole-array
          placement — ``assignment`` defaults to this pipeline's compile-time
          ``port_assignment`` (the port_repartition pass), then the autotuned
          decision's split, then the LPT split of ``multiport.assign_ports``);
        * every wavefront's tiles are independent (backward deps strictly
          decrease the coordinate sum), so each wave is batched, padded to a
          multiple of the mesh axis, and executed concurrently — one shard of
          tiles per port — through ``execute_tiles_sharded`` (Pallas kernel
          per shard) when ``use_kernel``, else an inline ``shard_map`` of the
          plane recurrence.

        Bit-exact against the single-port ``_sweep``: device placement and
        shard_map batching change *where* tiles run, never the plane
        arithmetic or the order facet blocks are committed.
        """
        from jax.sharding import NamedSharding

        from repro.core.cfa.multiport import assign_ports
        from repro.distributed.sharding import (
            P, port_mesh, shard_facets, shard_map_compat)

        if assignment is None:
            pa = self.port_assignment
            if pa is not None and getattr(pa, "n_ports", None) == n_ports:
                assignment = pa
        if assignment is None:
            decision = self.decision
            if decision is not None and getattr(decision, "n_ports", 1) == n_ports:
                # only reuse the decision's facet->port split when this
                # pipeline actually instantiates the candidate it was
                # computed for (a kernel-compatible re-pick may have chosen
                # a different, kernel-addressable layout)
                try:
                    best = decision.best_cfa()
                except LookupError:
                    best = None
                if best is not None and tuple(best.candidate.tile) == self.tiling.sizes:
                    assignment = decision.port_assignment  # may still be None
        if assignment is None:
            assignment = assign_ports(self.space, self.program.deps,
                                      self.tiling, n_ports)
        mesh = mesh if mesh is not None else port_mesh(n_ports, axis)
        n_shards = int(mesh.shape[axis])

        facets = self.init_facets(dtype)
        facets = self.load_inputs(facets, inputs.astype(dtype))
        facets = shard_facets(facets, assignment.facet_to_port, mesh, axis)

        interior = self._interior_slices(self.widths)

        def _exec_batch(halos: jnp.ndarray) -> jnp.ndarray:
            # one shard of the wave per port-device; each tile runs the very
            # same execute_tile recurrence as the single-port sweep
            return shard_map_compat(
                jax.vmap(self.execute_tile), mesh=mesh,
                in_specs=P(axis), out_specs=P(axis),
            )(halos)

        batch_sharding = NamedSharding(mesh, P(axis))
        rec = self.recorder
        waves = self.wavefronts()
        if rec is not None:
            rec.counters.add("waves", len(waves))
        for wave in waves:
            # pad the wave to a multiple of the mesh axis by repeating tiles
            # (a wave can be smaller than the axis — e.g. the first wave is
            # always one tile — so slicing the batch itself cannot under-pad)
            target = -(-len(wave) // n_shards) * n_shards
            gathered = []
            for i, t in enumerate(wave):
                if rec is not None:
                    # tile i runs on shard i of the padded batch — group its
                    # spans under that port's lanes
                    rec.port = i * n_shards // target
                gathered.append(self.copy_in(facets, t))
            halos = jnp.stack(gathered)
            if rec is not None:
                rec.port = 0
            if target != len(wave):
                reps = -(-target // len(wave))
                halos = jnp.concatenate([halos] * reps, axis=0)[:target]
            # commit the batch to the port mesh: one shard of tiles per port
            halos = jax.device_put(halos, batch_sharding)
            tok = rec.begin("execute_wave", track=rec.track("compute"),
                            wave=int(sum(wave[0])), n_tiles=len(wave),
                            n_ports=n_shards,
                            ) if rec is not None else None
            if use_kernel:
                from repro.kernels.stencil import execute_tiles_sharded

                interiors = execute_tiles_sharded(
                    self.program.name, halos, self.tiling.sizes, mesh,
                    axis=axis, interpret=True)
                outs = halos.at[(slice(None), *interior)].set(interiors)
            else:
                outs = _exec_batch(halos)
            # pull the executed planes back uncommitted so copy_out's facet
            # updates stay resident on each facet's own port device
            outs = np.asarray(jax.device_get(outs))
            if tok is not None:
                rec.end(tok)
            for i, tile in enumerate(wave):
                if rec is not None:
                    rec.port = i * n_shards // target
                facets = self.copy_out(facets, tile, jnp.asarray(outs[i]))
            if rec is not None:
                rec.port = 0
        return facets

    # -- oracle ----------------------------------------------------------------

    def reference_volume(self, inputs: jnp.ndarray) -> jnp.ndarray:
        """Untiled plane-by-plane sweep over the full space (the oracle)."""
        w = self.widths
        N = self.space.sizes
        depth = w[0]
        pad = [(w[a], 0) for a in range(1, self.space.ndim)]
        hist = [jnp.asarray(inputs[m]) for m in range(depth)]  # planes -w0..-1
        planes = []
        for _ in range(N[0]):
            padded = [jnp.pad(h, pad) for h in hist]
            new = self.program.plane_update(padded, w)
            planes.append(new)
            hist = hist[1:] + [new] if depth > 1 else [new]
        return jnp.stack(planes)
