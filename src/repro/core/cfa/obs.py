"""Runtime burst telemetry: span tracing, counters, attribution.

The paper's thesis is that effective memory bandwidth bounds the
accelerator, and the Memory Controller Wall study (Zohouri & Matsuoka
2019) shows real memory interfaces drifting far from analytic models —
yet until this module the repo could only *model* transfers
(:class:`BurstModel`, the CFA3xx lint) or time them in aggregate
(``calibrate``).  ``obs`` turns every execution into an inspectable,
attributable timeline:

* :class:`Span` / :class:`TraceRecorder` — structured spans
  (``copy_in`` / ``execute_tile`` / ``copy_out`` / ``halo_resolve`` per
  tile, grouped by wave and port, with facet/burst accounting linking
  back to the tile's :class:`TransferPlan`) emitted by every
  ``CFAPipeline._sweep*`` executor; the ``dataflow`` executor's
  overlapped prefetch/compute/commit appear as concurrent per-port lanes.
* :class:`Counters` — a deterministic metrics registry (bursts issued,
  wire vs stored bytes, tiles, waves, halo indirections) whose totals
  :meth:`TraceRecorder.reconcile` checks *exactly* against
  ``BurstModel.plan_bytes`` and the per-tile plans' read/write
  accounting — the runtime counterpart of the CFA1xx static verifier.
* Chrome trace-event JSON (:meth:`TraceRecorder.to_chrome`,
  Perfetto-loadable; ``tools/cfa_trace.py`` is the CLI) with the
  compile-time :class:`PassTrace` stages folded into the same timeline.
* The shared measurement clock: :func:`now`, :func:`burn`,
  :func:`measure_defaults` (``REPRO_MEASURE_WARMUP`` /
  ``REPRO_MEASURE_REPEATS``) and the host noise probe
  (:func:`timing_unusable_reason` / :func:`measurement_noise`,
  ``REPRO_TIMING_TESTS``) — one home for every wall-clock fidelity knob;
  ``calibrate.measure_runs`` / ``measure_plan`` emit their timed passes
  as spans through the same recorder.
* :class:`RuntimeReport` / :func:`runtime_report` — measured-vs-modeled
  attribution: per-facet / per-port observed time against
  ``BurstModel.time``, worst offender first, each row carrying the same
  fixit vocabulary (:data:`~repro.core.cfa.analysis.FIXIT_KNOBS`) as the
  static analysis diagnostics.

Tracing is strictly opt-in: with no recorder attached the executors pay
one ``is None`` check per phase — no recorder, span or context-manager
allocation on the hot path.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import json
import math
import os
import time
from pathlib import Path
from typing import Any, Iterable, Mapping

__all__ = [
    "Span",
    "Counters",
    "TraceRecorder",
    "RuntimeReport",
    "runtime_report",
    "chrome_trace",
    "validate_chrome_trace",
    "now",
    "burn",
    "measure_defaults",
    "timing_unusable_reason",
    "measurement_noise",
]


# --------------------------------------------------------------------------
# The shared clock + measurement fidelity knobs
# --------------------------------------------------------------------------

#: the one wall-clock every timed path in the repo reads (``calibrate``'s
#: measurement passes, ``passes.PassPipeline`` stage timing, the serving
#: scheduler's tick accounting, and every recorded span)
now = time.perf_counter

_DEF_WARMUP = 1
_DEF_REPEATS = 5


def measure_defaults(warmup: int | None, repeats: int | None) -> tuple[int, int]:
    """Resolve warmup/median-of-k, honouring the env-var escape hatches
    ``REPRO_MEASURE_WARMUP`` / ``REPRO_MEASURE_REPEATS``."""
    if warmup is None:
        warmup = int(os.environ.get("REPRO_MEASURE_WARMUP", _DEF_WARMUP))
    if repeats is None:
        repeats = int(os.environ.get("REPRO_MEASURE_REPEATS", _DEF_REPEATS))
    if warmup < 0:
        raise ValueError(f"warmup must be >= 0: {warmup}")
    if repeats < 1:
        raise ValueError(f"repeats must be >= 1: {repeats}")
    return warmup, repeats


def burn(seconds: float) -> None:
    """Occupy ``seconds`` of wall-clock — the stand-in for tile compute.

    Models a *dedicated* compute engine (Fig. 13 DATAFLOW: compute does
    not contend with the DMA engine): the bulk is slept, so the host cores
    stay free for in-flight copy threads, and only a short tail is spun
    for timer precision.  Either way the time cannot be elided by the
    device queue."""
    if seconds <= 0.0:
        return
    end = now() + seconds
    while (remaining := end - now()) > 0.0:
        if remaining > 5e-4:
            time.sleep(remaining - 2e-4)


# --------------------------------------------------------------------------
# Noise probe (the skip-with-reason hook behind the timing tests)
# --------------------------------------------------------------------------

_PROBE_SCHEDULE = (4096,) * 8
_MAX_NOISE = 0.75  # relative spread beyond which timing tests must skip


@functools.lru_cache(maxsize=1)
def _timing_probe() -> tuple[str | None, float]:
    """(why timing is unusable here | None, measured relative noise).

    Probe once, cache, let tests skip with the reason.
    ``REPRO_TIMING_TESTS=skip`` forces the skip (CI escape hatch for
    known-noisy runners); ``=force`` trusts the host unconditionally.
    """
    override = os.environ.get("REPRO_TIMING_TESTS", "").strip().lower()
    if override in ("force", "run", "1"):
        return None, 0.0
    if override in ("skip", "0"):
        return "REPRO_TIMING_TESTS=skip set in the environment", 1.0
    res = time.get_clock_info("perf_counter").resolution
    if res > 1e-4:
        return f"perf_counter resolution too coarse ({res:.1e} s)", 1.0
    from .calibrate import measure_runs  # lazy: calibrate imports obs

    try:
        ts = [measure_runs(_PROBE_SCHEDULE, 8, warmup=1, repeats=3)
              for _ in range(2)]
    except Exception as e:  # no usable jax device, OOM, ...
        return f"measurement harness failed to run ({e!r})", 1.0
    lo = min(ts)
    if lo <= 0.0:
        return "reference schedule measured as zero time", 1.0
    spread = (max(ts) - lo) / lo
    if spread > _MAX_NOISE:
        return (f"host timing too noisy (reference schedule spread "
                f"{spread:.0%} > {_MAX_NOISE:.0%})"), spread
    return None, spread


def timing_unusable_reason() -> str | None:
    """None when wall-clock measurement is trustworthy here, else why not."""
    return _timing_probe()[0]


def measurement_noise() -> float:
    """Relative spread of the reference schedule on this host (probe-
    cached); timing tests scale their tolerances by it."""
    return _timing_probe()[1]


# --------------------------------------------------------------------------
# Spans + counters
# --------------------------------------------------------------------------

#: span categories (the Chrome trace event ``cat`` field)
SPAN_CATS = ("compile", "runtime", "measure", "serve")


@dataclasses.dataclass(frozen=True)
class Span:
    """One timed interval on the trace: a phase of one tile, a lowering
    pass, a measurement pass, or a scheduler tick.

    ``track`` names the lane the span renders on (``port0/fetch``,
    ``port0/compute``, ``port0/commit``, ``compile``, ``measure``,
    ``serve/step``, ...) — concurrent lanes are how the dataflow
    executor's overlap becomes visible.  ``t0`` is seconds since the
    recorder's epoch; compile spans folded from :class:`PassTrace`
    records sit on the negative side of the epoch.  ``args`` carries the
    structured payload (tile, wave, port, facet ids, burst/byte
    accounting from the tile's :class:`TransferPlan`).
    """

    name: str
    cat: str
    track: str
    t0: float
    dur: float
    args: tuple[tuple[str, Any], ...] = ()

    def __post_init__(self) -> None:
        if self.cat not in SPAN_CATS:
            raise ValueError(f"cat must be one of {SPAN_CATS}: {self.cat!r}")
        if not (self.dur >= 0.0 and math.isfinite(self.dur)):
            raise ValueError(f"dur must be finite and >= 0: {self.dur}")

    def arg(self, key: str, default: Any = None) -> Any:
        return dict(self.args).get(key, default)

    def to_dict(self) -> dict:
        return {"name": self.name, "cat": self.cat, "track": self.track,
                "t0": self.t0, "dur": self.dur, "args": dict(self.args)}


class Counters:
    """A deterministic metrics registry: name -> numeric total.

    Totals are exact by construction (integer tile/burst/element counts;
    byte figures from ``BurstModel.burst_bytes`` sums), which is what lets
    :meth:`TraceRecorder.reconcile` compare them *equal*, not close, to
    the plan accounting."""

    def __init__(self) -> None:
        self._vals: dict[str, float] = {}

    def add(self, name: str, value: float = 1) -> None:
        self._vals[name] = self._vals.get(name, 0) + value

    def get(self, name: str, default: float = 0) -> float:
        return self._vals.get(name, default)

    def __getitem__(self, name: str) -> float:
        return self._vals[name]

    def __contains__(self, name: str) -> bool:
        return name in self._vals

    def as_dict(self) -> dict[str, float]:
        return dict(sorted(self._vals.items()))

    def __repr__(self) -> str:
        return f"Counters({self.as_dict()})"


class TraceRecorder:
    """Collects spans, counters and counter-sample events for one run.

    Attach one to a :class:`~repro.core.cfa.transform.CFAPipeline` (the
    ``recorder`` field) and every executor phase records itself; or pass
    one to ``calibrate.measure_runs`` / ``ContinuousBatcher`` for the
    measurement and serving paths.  ``cfa.compile(..., trace=True)``
    wires all of this up and surfaces the recorder as
    ``CompiledStencil.last_trace()``.

    ``model`` (a :class:`BurstModel`) prices the byte counters; without
    one the recorder still collects spans and structural counters but no
    wire-byte totals.  ``port`` is the current lane group — the sharded
    executor sets it per tile so spans land on ``port{n}/...`` tracks.
    """

    def __init__(self, model=None, label: str = "") -> None:
        self.model = model
        self.label = label
        self.epoch = now()
        self.port = 0
        self.spans: list[Span] = []
        self.counters = Counters()
        self.counter_samples: list[tuple[float, str, float]] = []
        self.meta: dict[str, Any] = {}
        self._open: dict[int, tuple[str, str, str, float, tuple]] = {}
        self._next_token = 0
        self._plan_cache: dict[tuple[int, ...], Any] = {}

    # -- clock ------------------------------------------------------------

    def now(self) -> float:
        return now()

    def track(self, phase: str) -> str:
        """The current port's lane for ``phase`` (fetch/compute/commit)."""
        return f"port{self.port}/{phase}"

    # -- span emission ----------------------------------------------------

    def add_span(self, name: str, t0: float, t1: float, *, track: str,
                 cat: str = "runtime", **args: Any) -> Span:
        """Record a closed interval [t0, t1] (absolute clock readings)."""
        span = Span(name=name, cat=cat, track=track, t0=t0 - self.epoch,
                    dur=max(0.0, t1 - t0), args=tuple(args.items()))
        self.spans.append(span)
        return span

    def begin(self, name: str, *, track: str, cat: str = "runtime",
              **args: Any) -> int:
        """Open a span now; close it with :meth:`end`.  Open/close pairs
        are how the dataflow executor brackets a tile's in-flight compute
        (dispatch -> commit) across loop iterations."""
        token = self._next_token
        self._next_token += 1
        self._open[token] = (name, track, cat, now(), tuple(args.items()))
        return token

    def end(self, token: int) -> Span:
        name, track, cat, t0, args = self._open.pop(token)
        span = Span(name=name, cat=cat, track=track, t0=t0 - self.epoch,
                    dur=max(0.0, now() - t0), args=args)
        self.spans.append(span)
        return span

    @contextlib.contextmanager
    def span(self, name: str, *, track: str, cat: str = "runtime",
             **args: Any):
        token = self.begin(name, track=track, cat=cat, **args)
        try:
            yield
        finally:
            self.end(token)

    def instant(self, name: str, *, track: str, cat: str = "runtime",
                **args: Any) -> Span:
        t = now()
        return self.add_span(name, t, t, track=track, cat=cat, **args)

    def counter_event(self, name: str, value: float) -> None:
        """A time-stamped counter sample (occupancy, queue depth, ...);
        exported as a Chrome ``"C"`` event so Perfetto plots it."""
        self.counter_samples.append((now() - self.epoch, name, float(value)))

    # -- query ------------------------------------------------------------

    def find(self, name: str | None = None, *, cat: str | None = None,
             track: str | None = None, wave: int | None = None) -> list[Span]:
        out = []
        for s in self.spans:
            if name is not None and s.name != name:
                continue
            if cat is not None and s.cat != cat:
                continue
            if track is not None and s.track != track:
                continue
            if wave is not None and s.arg("wave") != wave:
                continue
            out.append(s)
        return out

    # -- compile-trace folding -------------------------------------------

    def add_pass_traces(self, traces: Iterable) -> None:
        """Fold :class:`~repro.core.cfa.passes.PassTrace` records into the
        timeline.  A PassTrace has a duration but no start time, so the
        stages are laid end-to-end on the ``compile`` track immediately
        *before* the runtime epoch — the timeline reads compile -> run."""
        traces = list(traces)
        total = sum(float(t.wall_s) for t in traces)
        at = -total
        for t in traces:
            self.spans.append(Span(
                name=f"pass:{t.name}", cat="compile", track="compile",
                t0=at, dur=float(t.wall_s),
                args=(("version", t.version), ("changed", list(t.changed))),
            ))
            at += float(t.wall_s)

    # -- plan-linked tile accounting -------------------------------------

    def tile_plan(self, pipeline, tile: tuple[int, ...]):
        """The exact :class:`TransferPlan` of ``tile`` under the
        pipeline's layout knobs (cached per tile; boundary tiles have
        smaller flow-in than the interior plan)."""
        key = tuple(int(x) for x in tile)
        plan = self._plan_cache.get(key)
        if plan is None:
            plan = _pipeline_tile_plan(pipeline, key)
            self._plan_cache[key] = plan
        return plan

    def record_read(self, pipeline, tile: tuple[int, ...]) -> dict:
        """Bump the read-side counters for one tile's ``copy_in`` and
        return the span args linking it to the tile's plan."""
        plan = self.tile_plan(pipeline, tile)
        c = self.counters
        c.add("bursts_read", plan.n_read_bursts)
        c.add("read_elems", sum(plan.read_runs))
        args = {"tile": list(tile), "wave": int(sum(tile)),
                "port": self.port, "n_read_bursts": plan.n_read_bursts,
                "facets": sorted(set(plan.read_run_hosts or ()))}
        if self.model is not None:
            b = sum(self.model.burst_bytes(r, plan.codec_bits)
                    for r in plan.read_runs)
            c.add("wire_bytes_read", b)
            args["read_bytes"] = b
        return args

    def record_write(self, pipeline, tile: tuple[int, ...]) -> dict:
        """Bump the write-side + per-tile counters for one ``copy_out``."""
        plan = self.tile_plan(pipeline, tile)
        c = self.counters
        c.add("tiles", 1)
        c.add("bursts_write", plan.n_write_bursts)
        c.add("write_elems", sum(plan.write_runs))
        if plan.stored_elems is not None and self.model is not None:
            c.add("stored_bytes", plan.stored_elems * self.model.elem_bytes)
        args = {"tile": list(tile), "wave": int(sum(tile)),
                "port": self.port, "n_write_bursts": plan.n_write_bursts,
                "facets": sorted(set(plan.write_run_hosts or ()))}
        if self.model is not None:
            b = sum(self.model.burst_bytes(r, plan.codec_bits)
                    for r in plan.write_runs)
            c.add("wire_bytes_write", b)
            args["write_bytes"] = b
        return args

    def record_halo(self, pipeline, maps: Mapping) -> dict:
        """Bump the halo counters from one tile's resolved gather maps."""
        pts = sum(len(v) for k, v in maps.items() if k != "virtual")
        virt = len(maps.get("virtual", ()))
        c = self.counters
        c.add("halo_points", pts)
        c.add("virtual_points", virt)
        indirect = pts if pipeline.storage != "redundant" else 0
        c.add("halo_indirections", indirect)
        return {"points": pts, "virtual": virt, "indirections": indirect,
                "facets": sorted(k for k in maps if k != "virtual")}

    # -- reconciliation (runtime counterpart of the CFA1xx verifier) ------

    def reconcile(self, pipeline, model=None) -> dict:
        """Check the accumulated counters and span population against an
        independent enumeration of the pipeline's per-tile plans.

        Expected totals are recomputed from scratch (fresh ``cfa_plan``
        per tile — no reuse of the recorder's cache), so a sweep that
        skipped a tile, double-committed one, or mispriced a burst shows
        up as an exact mismatch.  Checks, per the plan accounting:

        * ``tiles`` / ``waves`` — every tile visited exactly once, waves
          counted once per executor run;
        * ``bursts_read`` / ``bursts_write`` and ``read_elems`` /
          ``write_elems`` — sums of each tile plan's run counts/lengths;
        * ``wire_bytes_read + wire_bytes_write`` — equals the sum of
          ``model.plan_bytes(tile_plan)`` over all tiles, exactly;
        * span population — one ``copy_in`` and one ``copy_out`` span per
          tile, grouped per wave.

        Returns ``{"ok": bool, "expected": {...}, "observed": {...},
        "mismatches": [...]}``.
        """
        import itertools

        model = model if model is not None else self.model
        exp: dict[str, float] = {
            "tiles": 0, "bursts_read": 0, "bursts_write": 0,
            "read_elems": 0, "write_elems": 0,
        }
        if model is not None:
            exp["wire_bytes_read"] = 0.0
            exp["wire_bytes_write"] = 0.0
            exp["plan_bytes"] = 0.0
        per_wave: dict[int, int] = {}
        for tile in itertools.product(*(range(n) for n in pipeline.num_tiles)):
            plan = _pipeline_tile_plan(pipeline, tile)
            exp["tiles"] += 1
            exp["bursts_read"] += plan.n_read_bursts
            exp["bursts_write"] += plan.n_write_bursts
            exp["read_elems"] += sum(plan.read_runs)
            exp["write_elems"] += sum(plan.write_runs)
            per_wave[sum(tile)] = per_wave.get(sum(tile), 0) + 1
            if model is not None:
                exp["wire_bytes_read"] += sum(
                    model.burst_bytes(r, plan.codec_bits) for r in plan.read_runs)
                exp["wire_bytes_write"] += sum(
                    model.burst_bytes(r, plan.codec_bits) for r in plan.write_runs)
                exp["plan_bytes"] += model.plan_bytes(plan)
        exp["waves"] = len(per_wave)

        obs = {k: self.counters.get(k) for k in exp}
        obs["plan_bytes"] = (self.counters.get("wire_bytes_read")
                            + self.counters.get("wire_bytes_write")) \
            if model is not None else 0.0

        mismatches = [k for k in exp if obs[k] != exp[k]]
        # span population: one copy_in + one copy_out per tile, per wave
        for wave, n in sorted(per_wave.items()):
            for name in ("copy_in", "copy_out"):
                got = len(self.find(name, wave=wave))
                if got != n:
                    mismatches.append(f"spans:{name}@wave{wave}:{got}!={n}")
        return {"ok": not mismatches, "expected": exp, "observed": obs,
                "mismatches": mismatches}

    # -- Chrome trace-event export ---------------------------------------

    def to_chrome(self) -> dict:
        """The run as Chrome trace-event JSON (load in Perfetto or
        ``chrome://tracing``).  Schema: ``docs/tracing.md``.

        Every span becomes one complete (``"ph": "X"``) event; tracks map
        to thread ids (named via ``"M"`` metadata events) so concurrent
        lanes — the dataflow executor's fetch/compute/commit — render as
        parallel rows.  Timestamps are microseconds from the earliest
        span (compile spans included), counters ride in ``otherData``
        plus per-sample ``"C"`` events.
        """
        tracks: list[str] = []
        for s in self.spans:
            if s.track not in tracks:
                tracks.append(s.track)
        tid = {t: i + 1 for i, t in enumerate(sorted(tracks))}
        t_min = min((s.t0 for s in self.spans), default=0.0)
        events: list[dict] = [{
            "name": "process_name", "ph": "M", "pid": 1, "tid": 0,
            "args": {"name": self.label or "repro.cfa"},
        }]
        for t, i in sorted(tid.items(), key=lambda kv: kv[1]):
            events.append({"name": "thread_name", "ph": "M", "pid": 1,
                           "tid": i, "args": {"name": t}})
        for s in self.spans:
            events.append({
                "name": s.name, "cat": s.cat, "ph": "X",
                "ts": (s.t0 - t_min) * 1e6, "dur": s.dur * 1e6,
                "pid": 1, "tid": tid[s.track], "args": dict(s.args),
            })
        for t, name, value in self.counter_samples:
            events.append({
                "name": name, "cat": "counter", "ph": "C",
                "ts": (t - t_min) * 1e6, "pid": 1,
                "args": {"value": value},
            })
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {
                "label": self.label,
                "model": getattr(self.model, "name", None),
                "counters": self.counters.as_dict(),
                **self.meta,
            },
        }

    def save_chrome(self, path: Path | str) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_chrome(), indent=1))
        return path


def chrome_trace(recorder: TraceRecorder) -> dict:
    """Module-level alias for :meth:`TraceRecorder.to_chrome`."""
    return recorder.to_chrome()


def validate_chrome_trace(obj: Mapping) -> list[str]:
    """Check a trace object against the schema in ``docs/tracing.md``.

    Returns a list of problems (empty = valid).  This is what the CI
    ``trace`` job and ``tools/cfa_trace.py --validate`` run against the
    emitted JSON."""
    problems: list[str] = []
    if not isinstance(obj, Mapping):
        return ["trace must be a JSON object"]
    events = obj.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["traceEvents must be a non-empty list"]
    tids_named: set[int] = set()
    for i, ev in enumerate(events):
        if not isinstance(ev, Mapping):
            problems.append(f"traceEvents[{i}] is not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "M", "C"):
            problems.append(f"traceEvents[{i}]: unknown ph {ph!r}")
            continue
        if "name" not in ev or "pid" not in ev:
            problems.append(f"traceEvents[{i}]: missing name/pid")
        if ph == "M":
            if ev.get("name") == "thread_name":
                tids_named.add(ev.get("tid"))
            continue
        if ph == "X":
            ts, dur = ev.get("ts"), ev.get("dur")
            if not isinstance(ts, (int, float)) or ts < 0:
                problems.append(f"traceEvents[{i}]: bad ts {ts!r}")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"traceEvents[{i}]: bad dur {dur!r}")
            if ev.get("cat") not in SPAN_CATS:
                problems.append(f"traceEvents[{i}]: cat must be one of "
                                f"{SPAN_CATS}: {ev.get('cat')!r}")
            if ev.get("tid") not in tids_named:
                problems.append(f"traceEvents[{i}]: tid {ev.get('tid')!r} "
                                f"has no thread_name metadata")
            if not isinstance(ev.get("args", {}), Mapping):
                problems.append(f"traceEvents[{i}]: args must be an object")
        if ph == "C" and "value" not in ev.get("args", {}):
            problems.append(f"traceEvents[{i}]: counter event without value")
    other = obj.get("otherData")
    if not isinstance(other, Mapping) or not isinstance(
            other.get("counters"), Mapping):
        problems.append("otherData.counters must be an object")
    return problems


def _pipeline_tile_plan(pipeline, tile: tuple[int, ...]):
    """One tile's :func:`cfa_plan` under a pipeline's layout knobs."""
    from .plans import cfa_plan

    ext = pipeline.ext_dirs
    return cfa_plan(
        pipeline.space, pipeline.program.deps, pipeline.tiling, tile,
        ext_dirs=dict(ext) if ext is not None else None,
        contiguity=pipeline.contiguity,
        storage=pipeline.storage,
        codec=getattr(pipeline, "codec", None),
    )


# --------------------------------------------------------------------------
# Measured-vs-modeled attribution
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Attribution:
    """One attribution row: a schedule slice (whole plan, one facet's
    runs, or one port's schedule), its observed vs modeled seconds, and
    the fixit knob (:data:`~repro.core.cfa.analysis.FIXIT_KNOBS`) the
    static lint proposes for it."""

    key: str  # "plan:cfa" | "facet:0/read" | "port:1" ...
    observed_s: float
    modeled_s: float
    n_bursts: int
    fixit: str | None = None
    hint: str | None = None

    @property
    def deviation(self) -> float | None:
        """|observed - modeled| / modeled (None when modeled is 0)."""
        if self.modeled_s <= 0.0:
            return None
        return abs(self.observed_s - self.modeled_s) / self.modeled_s

    def to_dict(self) -> dict:
        return {"key": self.key, "observed_s": self.observed_s,
                "modeled_s": self.modeled_s, "n_bursts": self.n_bursts,
                "deviation": self.deviation, "fixit": self.fixit,
                "hint": self.hint}


@dataclasses.dataclass(frozen=True)
class RuntimeReport:
    """Measured-vs-modeled attribution for one plan: rows ranked worst
    deviation first, each carrying the static lint's fixit vocabulary —
    the runtime face of the CFA3xx burst-efficiency diagnostics."""

    scheme: str
    rows: tuple[Attribution, ...]
    noise: float

    @property
    def worst(self) -> Attribution:
        if not self.rows:
            raise ValueError("empty report has no worst offender")
        return self.rows[0]

    def to_dict(self) -> dict:
        return {"scheme": self.scheme, "noise": self.noise,
                "rows": [r.to_dict() for r in self.rows]}

    def summary(self) -> str:
        lines = [f"runtime report for plan:{self.scheme} "
                 f"(host noise {self.noise:.0%})"]
        for r in self.rows:
            dev = f"{r.deviation:+.0%}" if r.deviation is not None else "n/a"
            fix = f" (fixit: {r.fixit})" if r.fixit else ""
            lines.append(
                f"  {r.key}: observed {r.observed_s:.3e} s vs modeled "
                f"{r.modeled_s:.3e} s, deviation {dev}{fix}")
        return "\n".join(lines)


def runtime_report(
    plan,
    model,
    *,
    n_ports: int = 1,
    contiguity: str | None = None,
    compute_s: float = 0.0,
    overlap: bool = False,
    warmup: int | None = None,
    repeats: int | None = None,
    recorder: TraceRecorder | None = None,
) -> RuntimeReport:
    """Measure a plan's schedule slices, compare each against
    ``BurstModel.time``, and rank the deviations.

    Rows:

    * ``plan:{scheme}`` — the whole schedule (a ported plan when
      ``n_ports > 1``; ``overlap`` / ``compute_s`` compose the Fig. 13
      pipelined time exactly as ``BurstModel.time`` does);
    * ``port:{p}`` — each port's schedule, when ported;
    * ``facet:{k}/read`` / ``facet:{k}/write`` — per-facet run groups,
      when the plan attributes runs to facet hosts (CFA plans do;
      single-array baselines have no host axis to split on);

    each measured with the ``calibrate`` harness (spans emitted through
    ``recorder`` when given).  Every row carries the fixit knob of the
    matching ``lint_plan`` diagnostic — per-facet rows prefer a
    diagnostic located at that facet, any row falls back to the
    plan-level worst — so a deviation always arrives with the same
    actionable vocabulary the static analysis uses.
    """
    from .analysis import lint_plan
    from .calibrate import measure_plan, measure_runs
    from .multiport import best_repartition
    from .bandwidth import PortedPlan

    diags = lint_plan(plan, model, n_ports=n_ports, contiguity=contiguity)
    plan_fix = next(((d.fixit, d.message) for d in diags if d.fixit), (None, None))

    def facet_fix(k: int) -> tuple[str | None, str | None]:
        for d in diags:
            if d.fixit and d.facet == k:
                return d.fixit, d.message
        return plan_fix

    target = plan
    if n_ports > 1 and not isinstance(plan, PortedPlan):
        target = best_repartition(plan, n_ports, model,
                                  compute_s=compute_s, overlap=overlap)
    kw = dict(warmup=warmup, repeats=repeats)
    cb = getattr(plan, "codec_bits", None)
    rows: list[Attribution] = []

    obs_total = measure_plan(target, model, compute_s=compute_s,
                             overlap=overlap, recorder=recorder,
                             label=f"plan:{plan.scheme}", **kw)
    rows.append(Attribution(
        key=f"plan:{plan.scheme}", observed_s=obs_total,
        modeled_s=model.time(target, compute_s=compute_s, overlap=overlap),
        n_bursts=int(target.n_bursts), fixit=plan_fix[0], hint=plan_fix[1]))

    if isinstance(target, PortedPlan):
        for p, (rr, wr) in enumerate(zip(target.read_runs_by_port,
                                         target.write_runs_by_port)):
            sched = tuple(rr) + tuple(wr)
            if not sched:
                continue
            rows.append(Attribution(
                key=f"port:{p}",
                observed_s=measure_runs(sched, model.elem_bytes,
                                        codec_bits=cb, recorder=recorder,
                                        label=f"port:{p}", **kw),
                modeled_s=model.time_s(sched, cb), n_bursts=len(sched),
                fixit=plan_fix[0], hint=plan_fix[1]))
    else:
        for side in ("read", "write"):
            runs = getattr(plan, f"{side}_runs")
            hosts = getattr(plan, f"{side}_run_hosts")
            if hosts is None:
                continue
            by_facet: dict[int, list[int]] = {}
            for r, h in zip(runs, hosts):
                by_facet.setdefault(int(h), []).append(int(r))
            for k, sched in sorted(by_facet.items()):
                fix, hint = facet_fix(k)
                rows.append(Attribution(
                    key=f"facet:{k}/{side}",
                    observed_s=measure_runs(tuple(sched), model.elem_bytes,
                                            codec_bits=cb, recorder=recorder,
                                            label=f"facet:{k}/{side}", **kw),
                    modeled_s=model.time_s(tuple(sched), cb),
                    n_bursts=len(sched), fixit=fix, hint=hint))

    rows.sort(key=lambda r: (r.deviation is not None, r.deviation or 0.0),
              reverse=True)
    return RuntimeReport(scheme=plan.scheme, rows=tuple(rows),
                         noise=measurement_noise())


def _env_flag(name: str) -> bool:
    return os.environ.get(name, "").strip().lower() in ("1", "true", "on", "yes")


def trace_enabled_by_env() -> bool:
    """``REPRO_TRACE=1`` turns tracing on for every ``cfa.compile``."""
    return _env_flag("REPRO_TRACE")


def trace_export_dir() -> Path | None:
    """``REPRO_TRACE_DIR=<dir>`` auto-saves each traced run's Chrome
    trace JSON under that directory."""
    d = os.environ.get("REPRO_TRACE_DIR", "").strip()
    return Path(d) if d else None
