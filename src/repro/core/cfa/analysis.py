"""Static plan verification and burst lint — compile-time diagnostics.

The paper's whole argument is that element-wise access patterns silently
destroy effective bandwidth; until now a burst-hostile or even *incorrect*
plan (a double-written facet slot, an unresolved halo owner, an illegal
overlap schedule) was only caught dynamically, by running the differential
test matrix.  Iris (Soldavini et al., 2022) pairs layout generation with
automated efficiency analysis, and the Memory Controller Wall study
(Zohouri & Matsuoka, 2019) quantifies how sub-burst-length accesses degrade
real memory controllers; this module turns both into *static* diagnostics
that run inside the pass pipeline, before any executor is invoked.

It adds a second pass category to :class:`~repro.core.cfa.passes.
PassPipeline`: **analysis passes** (:class:`AnalysisPass` /
:func:`analysis_pass`) are read-only — they consume a ``CompileState`` and
append :class:`Diagnostic` records to ``state.diagnostics`` instead of
mutating lowering artifacts.  Four ship by default (:data:`DEFAULT_ANALYSES`):

* ``verify_single_assignment`` (**CFA1xx**) — the single-assignment /
  coverage verifier: every facet-family element is written exactly once
  (per-facet address injectivity), under ``storage="irredundant"`` the
  owner masks partition the family and every halo read resolves to exactly
  one owner — statically proving what ``tests/test_cfa_properties.py``
  samples — plus ``TransferPlan`` accounting (writes vs stored slots,
  reads vs needed elements).
* ``verify_overlap`` (**CFA2xx**) — the overlap race detector: a static
  wave-dependence check that the dataflow backend's prefetch-of-``j+1`` /
  deferred-commit-of-``j-1`` schedule never aliases tile ``j``'s reads or
  writes (every tile dependence must point strictly to an earlier wave).
* ``lint_bursts`` (**CFA3xx**) — the burst-efficiency lint: runs shorter
  than the bound target's efficient-burst knee, contiguity breaks,
  redundancy above threshold, port-load imbalance — each priced in modeled
  seconds via :class:`~repro.core.cfa.bandwidth.BurstModel`.
* ``verify_contracts`` (**CFA4xx**) — capability/contract checks: backend
  caps vs the lowered state, codec exactness preconditions, port budgets.

Every :class:`Diagnostic` carries a stable code, a severity
(``ERROR``/``WARN``/``INFO``), an optional facet/run location, a human
message, a machine-readable ``fixit`` naming the layout knob to turn
(``ext_dirs``, ``contiguity``, ``storage``, ``n_ports``), and — for the
priced lints — ``cost_s``, the modeled seconds the flagged inefficiency
costs per tile.  The full code table lives in ``docs/analysis.md``.

Entry points: :func:`verify` checks a :class:`~repro.core.cfa.api.
CompiledStencil` post-hoc (``plan=``/``waves=`` inject corrupted artifacts
for mutation testing); ``cfa.compile(..., verify=True)`` appends
:func:`verify_pipeline`'s analysis stages to the lowering and raises
:class:`VerificationError` on any ERROR; ``autotune`` discards candidates
whose plans fail :func:`plan_accounting`; ``tools/cfa_lint.py`` runs the
program x storage x backend matrix from the command line.
"""
from __future__ import annotations

import dataclasses
import inspect
import itertools
import json
from typing import Any, Callable, Iterable, Mapping, Sequence

import numpy as np

from .bandwidth import BurstModel
from .facets import build_facet_specs
from .irredundant import build_storage_map, owner_of
from .passes import CompileState
from .plans import TransferPlan, cfa_piece_census, interior_tile
from .spaces import (
    Deps,
    IterSpace,
    Tiling,
    facet_points,
    facet_widths,
    flow_in_points,
)

__all__ = [
    "SEVERITIES",
    "FIXIT_KNOBS",
    "Diagnostic",
    "AnalysisReport",
    "VerificationError",
    "AnalysisPass",
    "analysis_pass",
    "DEFAULT_ANALYSES",
    "verify_single_assignment",
    "verify_overlap",
    "lint_bursts",
    "verify_contracts",
    "check_facet_family",
    "check_overlap_schedule",
    "plan_accounting",
    "lint_plan",
    "run_analyses",
    "verify",
    "verify_pipeline",
]

#: Diagnostic severities, weakest first (``max_severity`` compares by index).
SEVERITIES = ("INFO", "WARN", "ERROR")

#: The layout knobs a ``fixit`` may name — each is a ``cfa.compile`` /
#: ``LayoutCandidate`` parameter the user can actually turn.
FIXIT_KNOBS = ("ext_dirs", "contiguity", "storage", "n_ports")

# -- lint thresholds (CFA3xx) ------------------------------------------------
#: CFA301 fires when burst-setup time exceeds this share of the modeled
#: transfer time — the plan is descriptor-bound, not bandwidth-bound.
SETUP_SHARE_WARN = 0.5
#: CFA303 fires when more than this fraction of transferred elements are
#: redundant (duplicated halo traffic the irredundant discipline removes).
REDUNDANCY_WARN = 0.5
#: CFA304 fires when the best repartition's max/mean port load exceeds this.
BALANCE_WARN = 1.5


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One static finding: a stable code, a severity, a located message.

    ``analysis`` names the emitting analysis pass (filled by the pass
    wrapper); ``facet``/``run`` locate the finding inside the layout when
    applicable; ``fixit`` is the machine-readable remediation — one of
    :data:`FIXIT_KNOBS`, the compile knob whose change addresses the
    finding; ``cost_s`` prices the inefficiency in modeled seconds per tile
    (CFA3xx lints only).
    """

    code: str
    severity: str
    message: str
    analysis: str = ""
    facet: int | None = None
    run: int | None = None
    fixit: str | None = None
    cost_s: float | None = None

    def __post_init__(self) -> None:
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity must be one of {SEVERITIES}: {self.severity!r}"
            )
        if self.fixit is not None and self.fixit not in FIXIT_KNOBS:
            raise ValueError(
                f"fixit must be one of {FIXIT_KNOBS}: {self.fixit!r}"
            )

    def to_dict(self) -> dict:
        """JSON-ready record; location/fixit/cost keys appear only when set."""
        out = {
            "code": self.code,
            "severity": self.severity,
            "analysis": self.analysis,
            "message": self.message,
        }
        for key in ("facet", "run", "fixit", "cost_s"):
            v = getattr(self, key)
            if v is not None:
                out[key] = v
        return out

    def __str__(self) -> str:
        loc = f" [facet {self.facet}]" if self.facet is not None else ""
        fix = f" (fixit: {self.fixit})" if self.fixit else ""
        return f"{self.severity} {self.code}{loc}: {self.message}{fix}"


@dataclasses.dataclass(frozen=True)
class AnalysisReport:
    """The aggregate of one verification run: every diagnostic, plus the
    (name, version) fingerprint of the analyses that produced them."""

    diagnostics: tuple[Diagnostic, ...]
    analyses: tuple[tuple[str, str], ...] = ()

    def _with_severity(self, severity: str) -> tuple[Diagnostic, ...]:
        return tuple(d for d in self.diagnostics if d.severity == severity)

    @property
    def errors(self) -> tuple[Diagnostic, ...]:
        return self._with_severity("ERROR")

    @property
    def warnings(self) -> tuple[Diagnostic, ...]:
        return self._with_severity("WARN")

    @property
    def infos(self) -> tuple[Diagnostic, ...]:
        return self._with_severity("INFO")

    @property
    def ok(self) -> bool:
        """True when no ERROR diagnostic fired (WARN/INFO are advisory)."""
        return not self.errors

    @property
    def max_severity(self) -> str | None:
        """The worst severity present, ``None`` on a clean report."""
        if not self.diagnostics:
            return None
        return max((d.severity for d in self.diagnostics),
                   key=SEVERITIES.index)

    @property
    def codes(self) -> tuple[str, ...]:
        """The distinct diagnostic codes present, sorted."""
        return tuple(sorted({d.code for d in self.diagnostics}))

    def by_code(self, code: str) -> tuple[Diagnostic, ...]:
        return tuple(d for d in self.diagnostics if d.code == code)

    def to_dict(self) -> dict:
        return {
            "analyses": [list(a) for a in self.analyses],
            "max_severity": self.max_severity,
            "diagnostics": [d.to_dict() for d in self.diagnostics],
        }

    def to_json(self, *, indent: int | None = None) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    def summary(self) -> str:
        """Human one-or-more-line rendering (what ``cfa_lint`` prints)."""
        if not self.diagnostics:
            return "clean: no diagnostics"
        head = ", ".join(
            f"{len(self._with_severity(s))} {s}"
            for s in reversed(SEVERITIES) if self._with_severity(s)
        )
        lines = [f"{len(self.diagnostics)} diagnostic(s): {head}"]
        lines += [f"  {d}" for d in sorted(
            self.diagnostics,
            key=lambda d: (-SEVERITIES.index(d.severity), d.code))]
        return "\n".join(lines)


class VerificationError(ValueError):
    """Static verification rejected the plan; carries the full report."""

    def __init__(self, report: AnalysisReport, *, strict: bool = False):
        self.report = report
        bad = report.errors + (report.warnings if strict else ())
        shown = "; ".join(f"{d.code}: {d.message}" for d in bad[:4])
        more = f" (+{len(bad) - 4} more)" if len(bad) > 4 else ""
        kind = "ERROR/WARN" if strict else "ERROR"
        super().__init__(
            f"plan verification failed with {len(bad)} {kind} "
            f"diagnostic(s): {shown}{more}"
        )


# --------------------------------------------------------------------------
# The analysis-pass category
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AnalysisPass:
    """A read-only pass: consumes a ``CompileState``, emits ``Diagnostic``s.

    Satisfies the :class:`~repro.core.cfa.passes.Pass` protocol —
    ``requires=("compiled",)`` places it after ``lower_backend`` and
    ``provides=()`` keeps it out of the artifact dependency graph — but its
    ``run`` only *appends* to ``state.diagnostics``; lowering artifacts are
    never touched.  ``codes`` declares the stable diagnostic codes the pass
    may emit (documented in ``docs/analysis.md``).
    """

    name: str
    version: str
    fn: Callable[..., Iterable[Diagnostic]] = dataclasses.field(compare=False)
    codes: tuple[str, ...] = ()
    requires: tuple[str, ...] = ("compiled",)
    provides: tuple[str, ...] = ()

    def run(self, state: CompileState) -> CompileState:
        return dataclasses.replace(
            state,
            diagnostics=tuple(state.diagnostics) + self.diagnose(state),
        )

    def diagnose(self, state: CompileState, **overrides: Any) -> tuple[Diagnostic, ...]:
        """Run the checker directly (outside a pipeline), tagging each
        diagnostic with this pass's name.  ``overrides`` (``plan=``,
        ``waves=``) substitute corrupted artifacts for mutation testing;
        keys the underlying checker does not accept are dropped."""
        if overrides:
            params = inspect.signature(self.fn).parameters
            if not any(p.kind is inspect.Parameter.VAR_KEYWORD
                       for p in params.values()):
                overrides = {k: v for k, v in overrides.items() if k in params}
        out = tuple(self.fn(state, **overrides))
        return tuple(
            d if d.analysis else dataclasses.replace(d, analysis=self.name)
            for d in out
        )


def analysis_pass(
    name: str,
    version: str = "1",
    *,
    codes: Sequence[str] = (),
):
    """Decorator turning ``fn(state, ...) -> Iterable[Diagnostic]`` into a
    registered :class:`AnalysisPass` (the read-only counterpart of
    :func:`~repro.core.cfa.passes.compiler_pass`)."""

    def deco(fn: Callable[..., Iterable[Diagnostic]]) -> AnalysisPass:
        return AnalysisPass(name=name, version=version, fn=fn,
                            codes=tuple(codes))

    return deco


# --------------------------------------------------------------------------
# Pure checkers (geometry- and plan-level; no CompileState required)
# --------------------------------------------------------------------------


def _stored_counts(smap, pts: np.ndarray) -> np.ndarray:
    """How many facets *store* each canonical point under ``smap`` (the
    irredundant discipline's slot count — exactly 1 iff a partition)."""
    counts = np.zeros(len(pts), dtype=np.int64)
    for k in smap.specs:
        counts += smap.stores(k, pts)
    return counts


def check_facet_family(
    space: IterSpace,
    deps: Deps,
    tiling: Tiling,
    *,
    ext_dirs: Mapping[int, int] | None = None,
    contiguity: str = "intra-tile",
    storage: str = "redundant",
) -> list[Diagnostic]:
    """The CFA1xx geometric proofs for one facet family (interior tile).

    * **CFA101** — a facet's address map collides on its own facet point
      set: two writes land in the same slot (single assignment broken).
    * **CFA103** — a flow-in (halo) point resolves to no facet domain
      (redundant) or to no stored owner slot (irredundant) — the read has
      nowhere to come from.
    * **CFA104** — under ``storage != "redundant"`` the owner masks fail to
      *partition* the facet-point union (a gap or an overlap), or a halo
      read resolves to more than one stored slot.

    These are exhaustive checks over the interior tile's point sets — the
    static counterpart of the sampled Hypothesis properties — and apply to
    every tile by translation invariance of the facet layout.
    """
    diags: list[Diagnostic] = []
    widths = facet_widths(deps)
    specs = build_facet_specs(space, deps, tiling, ext_dirs=ext_dirs,
                              contiguity=contiguity)
    tile = interior_tile(space, tiling)

    # CFA101: per-facet write injectivity over the facet point set
    fpts_by_k: dict[int, np.ndarray] = {}
    for k, spec in specs.items():
        fpts = facet_points(tiling, widths, k, tile)
        fpts_by_k[k] = fpts
        offs = spec.offsets(fpts)
        n_dup = len(offs) - len(np.unique(offs))
        if n_dup:
            diags.append(Diagnostic(
                "CFA101", "ERROR",
                f"facet_{k}: {n_dup} of {len(offs)} facet-slot writes "
                f"collide — the address map is not injective on the facet "
                f"point set (single assignment broken)",
                facet=k,
            ))

    fin = flow_in_points(space, deps, tiling, tile)

    if storage == "redundant":
        # CFA103: every halo point must lie in at least one facet domain
        # (the appendix coverage proof, checked rather than trusted)
        if len(fin):
            missing = int((owner_of(specs, fin) < 0).sum())
            if missing:
                diags.append(Diagnostic(
                    "CFA103", "ERROR",
                    f"{missing} of {len(fin)} flow-in points lie outside "
                    f"every facet projection domain — the halo read has no "
                    f"source array",
                ))
        return diags

    # irredundant / compressed: the owner masks must partition the family
    smap = build_storage_map(specs)
    union = (np.unique(np.concatenate(list(fpts_by_k.values()), axis=0), axis=0)
             if fpts_by_k else np.empty((0, space.ndim), dtype=np.int64))
    if len(union):
        counts = _stored_counts(smap, union)
        gaps, dups = int((counts == 0).sum()), int((counts > 1).sum())
        if gaps:
            diags.append(Diagnostic(
                "CFA104", "ERROR",
                f"owner masks leave {gaps} of {len(union)} facet-family "
                f"points unstored — the partition has gaps (those values "
                f"are lost on commit)",
            ))
        if dups:
            diags.append(Diagnostic(
                "CFA104", "ERROR",
                f"owner masks store {dups} of {len(union)} facet-family "
                f"points more than once — the partition overlaps (single "
                f"assignment broken)",
            ))
    if len(fin):
        # every halo read must resolve to exactly one stored owner slot
        counts = _stored_counts(smap, fin)
        unresolved = int((counts == 0).sum())
        multi = int((counts > 1).sum())
        if unresolved:
            diags.append(Diagnostic(
                "CFA103", "ERROR",
                f"{unresolved} of {len(fin)} halo reads resolve to no "
                f"stored owner slot — irredundant storage never wrote the "
                f"value they need",
            ))
        if multi:
            diags.append(Diagnostic(
                "CFA104", "ERROR",
                f"{multi} of {len(fin)} halo reads resolve to more than "
                f"one stored owner slot — ownership is ambiguous",
            ))
    return diags


def plan_accounting(plan: TransferPlan) -> list[Diagnostic]:
    """The CFA1xx accounting checks on a :class:`TransferPlan` — O(#runs).

    * **CFA101** — a CFA plan whose writes transfer *more* elements than
      the layout stores: some slot is written more than once (e.g. a
      duplicated write run).
    * **CFA102** — writes transfer *fewer* elements than the layout stores
      (CFA plans) or than the tile produces (baselines): some slot or
      result is never committed (e.g. a dropped owner block).
    * **CFA105** — reads transfer fewer elements than the tile consumes:
      some halo value is never fetched.

    Cheap enough that ``autotune`` runs it on every candidate plan and
    discards ERROR-level candidates during the search.
    """
    diags: list[Diagnostic] = []
    rt, ru = plan.read_transferred, plan.read_useful
    if rt < ru:
        diags.append(Diagnostic(
            "CFA105", "ERROR",
            f"reads transfer {rt} elements but the tile consumes {ru} — "
            f"{ru - rt} halo element(s) are never fetched",
        ))
    wt = plan.write_transferred
    stored = plan.stored_elems
    if stored is not None and plan.scheme.startswith("cfa"):
        if wt > stored:
            diags.append(Diagnostic(
                "CFA101", "ERROR",
                f"writes transfer {wt} elements but the layout stores only "
                f"{stored} slots per tile — {wt - stored} slot(s) written "
                f"more than once (single assignment broken)",
            ))
        elif wt < stored:
            diags.append(Diagnostic(
                "CFA102", "ERROR",
                f"writes transfer {wt} of the {stored} slots the layout "
                f"stores per tile — {stored - wt} slot(s) never written",
            ))
    elif wt < plan.write_useful:
        diags.append(Diagnostic(
            "CFA102", "ERROR",
            f"writes transfer {wt} elements but the tile produces "
            f"{plan.write_useful} flow-out values — some results are never "
            f"committed",
        ))
    return diags


def check_overlap_schedule(
    space: IterSpace,
    deps: Deps,
    tiling: Tiling,
    waves: Sequence[Sequence[Sequence[int]]] | None = None,
) -> list[Diagnostic]:
    """The CFA2xx static wave-dependence check.

    The dataflow backend pipelines ``prefetch(wave[j+1])`` with
    ``compute(wave[j])`` and ``deferred-commit(wave[j-1])``; that schedule
    is race-free iff every tile dependence points *strictly backwards* in
    wave order — a producer in the same wave (**CFA201**) means the
    prefetch of a consumer races the producer's deferred commit, and a
    producer in a *later* wave (**CFA202**) means the schedule reads a
    value before it exists at all.  ``waves`` defaults to the coordinate-sum
    grouping of ``CFAPipeline.wavefronts`` (provably legal for backward
    dependence vectors); pass an explicit grouping to audit — or corrupt —
    a custom schedule.
    """
    nt = tiling.num_tiles(space)
    all_tiles = list(itertools.product(*(range(n) for n in nt)))
    if waves is None:
        by_sum: dict[int, list[tuple[int, ...]]] = {}
        for q in all_tiles:
            by_sum.setdefault(sum(q), []).append(q)
        waves = [by_sum[s] for s in sorted(by_sum)]
    wave_of: dict[tuple[int, ...], int] = {}
    for i, wv in enumerate(waves):
        for q in wv:
            wave_of[tuple(int(c) for c in q)] = i

    diags: list[Diagnostic] = []
    missing = [q for q in all_tiles if q not in wave_of]
    if missing:
        diags.append(Diagnostic(
            "CFA202", "ERROR",
            f"schedule omits {len(missing)} of {len(all_tiles)} tiles "
            f"(e.g. {missing[0]}) — those tiles never execute",
        ))

    # backward tile dependences, read off the interior tile's flow-in
    tile = interior_tile(space, tiling)
    fin = flow_in_points(space, deps, tiling, tile)
    if not len(fin):
        return diags
    t = np.asarray(tiling.sizes, dtype=np.int64)
    deltas = np.unique(fin // t - np.asarray(tile, dtype=np.int64), axis=0)

    same = cross = 0
    example_same = example_cross = None
    for q in all_tiles:
        wq = wave_of.get(q)
        if wq is None:
            continue
        for dlt in deltas:
            src = tuple(int(c) for c in np.asarray(q) + dlt)
            if any(c < 0 for c in src):
                continue  # boundary tile: that neighbour does not exist
            ws = wave_of.get(src)
            if ws is None:
                continue  # already reported as missing
            if ws == wq:
                same += 1
                example_same = example_same or (src, q, wq)
            elif ws > wq:
                cross += 1
                example_cross = example_cross or (src, q)
    if same:
        src, q, w = example_same
        diags.append(Diagnostic(
            "CFA201", "ERROR",
            f"{same} tile dependence(s) fall within a single wave (e.g. "
            f"tile {q} reads tile {src}, both in wave {w}) — the dataflow "
            f"prefetch of the consumer races the producer's deferred "
            f"commit; overlap=True must be rejected for this schedule",
        ))
    if cross:
        src, q = example_cross
        diags.append(Diagnostic(
            "CFA202", "ERROR",
            f"{cross} tile dependence(s) point to a later wave (e.g. tile "
            f"{q} reads tile {src}, scheduled after it) — the schedule "
            f"consumes values before they are produced",
        ))
    return diags


def lint_plan(
    plan: TransferPlan,
    model: BurstModel,
    *,
    n_ports: int = 1,
    contiguity: str | None = None,
    expected_read_bursts: int | None = None,
    assignment=None,
) -> list[Diagnostic]:
    """The CFA3xx burst-efficiency lint, priced under ``model``.

    * **CFA301** — burst-hostile schedule: runs shorter than the model's
      efficient-burst knee (``BurstModel.setup_elems``) *and* descriptor
      setup above :data:`SETUP_SHARE_WARN` of the modeled transfer time
      (the Memory Controller Wall regime: the plan is descriptor-bound).
    * **CFA302** — contiguity break: more read bursts than the intra-tile
      layout family achieves (WARN, ``fixit="ext_dirs"``), or a weaker
      contiguity level selected at all (INFO, ``fixit="contiguity"``).
    * **CFA303** — redundancy above :data:`REDUNDANCY_WARN`: more than
      half the transferred elements are duplicated halo traffic.
    * **CFA304** — port-load imbalance beyond :data:`BALANCE_WARN` under
      ``assignment`` (the compile-time facet -> port split, whose whole
      facet arrays are atomic and so *can* be lopsided), falling back to
      the best burst-granular §VII repartition over ``n_ports``.

    ``cost_s`` on each diagnostic is the modeled seconds per tile the
    flagged inefficiency costs (recoverable descriptor time, excess-burst
    setup, redundant bytes, slowest-vs-mean port gap).
    """
    diags: list[Diagnostic] = []
    runs = tuple(plan.read_runs) + tuple(plan.write_runs)
    if runs:
        knee = model.setup_elems
        short = [r for r in runs if r < knee]
        setup_total = plan.n_bursts * model.setup_s
        transfer = model.transfer_time_s(plan)
        share = setup_total / transfer if transfer > 0.0 else 0.0
        if short and share > SETUP_SHARE_WARN:
            # the recoverable cost: everything beyond one setup per source
            # array (the best any contiguity fix could reach)
            ideal = (len(set(plan.read_run_hosts)) if plan.read_run_hosts
                     else 1) + (len(set(plan.write_run_hosts))
                                if plan.write_run_hosts else 1)
            diags.append(Diagnostic(
                "CFA301", "WARN",
                f"burst-hostile schedule: {len(short)} of {len(runs)} runs "
                f"are shorter than the {model.name} efficient-burst knee "
                f"(~{knee:.0f} elems) and descriptor setup is {share:.0%} "
                f"of the modeled transfer time",
                fixit="contiguity",
                cost_s=max(0, plan.n_bursts - ideal) * model.setup_s,
            ))
    if (expected_read_bursts is not None
            and plan.n_read_bursts > expected_read_bursts):
        extra = plan.n_read_bursts - expected_read_bursts
        diags.append(Diagnostic(
            "CFA302", "WARN",
            f"{plan.n_read_bursts} read bursts where the intra-tile layout "
            f"family achieves {expected_read_bursts} — {extra} contiguity "
            f"break(s); a different extension-direction assignment merges "
            f"them (§IV-H)",
            fixit="ext_dirs",
            cost_s=extra * model.setup_s,
        ))
    if contiguity is not None and contiguity != "intra-tile":
        diags.append(Diagnostic(
            "CFA302", "INFO",
            f"contiguity level {contiguity!r}: corner reads do not merge "
            f"into facet-block suffixes (§IV-I) — the intra-tile level "
            f"reaches the paper's minimal burst count",
            fixit="contiguity",
        ))
    if plan.redundancy > REDUNDANCY_WARN and plan.storage == "redundant":
        # irredundant/compressed plans already took the storage fixit: their
        # remaining transfer overhead is owner indirection, not duplication
        wasted = plan.transferred - plan.useful
        diags.append(Diagnostic(
            "CFA303", "WARN",
            f"redundancy {plan.redundancy:.0%}: {wasted} of "
            f"{plan.transferred} transferred elements are duplicated halo "
            f"traffic — the irredundant discipline stores each value once",
            fixit="storage",
            cost_s=wasted * model.elem_bytes / model.peak_bytes_per_s,
        ))
    if n_ports > 1:
        times = how = None
        if (assignment is not None and plan.read_run_hosts is not None
                and plan.write_run_hosts is not None):
            by_port: list[list[int]] = [[] for _ in range(n_ports)]
            for rs, hosts in ((plan.read_runs, plan.read_run_hosts),
                              (plan.write_runs, plan.write_run_hosts)):
                for r, h in zip(rs, hosts):
                    by_port[assignment.facet_to_port[h]].append(r)
            times = [model.time_s(tuple(rs), plan.codec_bits) if rs else 0.0
                     for rs in by_port]
            how = "the compile-time facet->port assignment"
        else:
            from .multiport import best_repartition

            ported = best_repartition(plan, n_ports, model)
            times = [
                model.time_s(rr, ported.codec_bits)
                + model.time_s(wr, ported.codec_bits)
                for rr, wr in zip(ported.read_runs_by_port,
                                  ported.write_runs_by_port)
            ]
            how = f"the best repartition strategy {ported.strategy!r}"
        busy = [t for t in times if t > 0.0]
        if busy:
            mean = sum(busy) / len(busy)
            balance = max(busy) / mean
            if balance > BALANCE_WARN:
                diags.append(Diagnostic(
                    "CFA304", "WARN",
                    f"port-load imbalance {balance:.2f} (max/mean over "
                    f"{len(busy)} busy of {n_ports} ports, tolerance "
                    f"{BALANCE_WARN}) under {how} — the slowest port gates "
                    f"the tile",
                    fixit="n_ports",
                    cost_s=max(busy) - mean,
                ))
    return diags


# --------------------------------------------------------------------------
# The four default analyses (CompileState wrappers over the pure checkers)
# --------------------------------------------------------------------------


def _plan_of(state: CompileState) -> TransferPlan | None:
    """The state's interior-tile plan: the compiled stencil's cached one,
    else derived from the layout candidate; None before layout_search."""
    if state.compiled is not None:
        return state.compiled.plan
    cand = state.candidate
    if cand is None or not isinstance(state.space, IterSpace):
        return None
    return cand.plan(state.space, state.program, storage=state.storage,
                     codec=state.codec)


def _cfa_family_kwargs(cand) -> dict:
    return dict(
        ext_dirs=dict(cand.ext_dirs) if cand.ext_dirs is not None else None,
        contiguity=cand.contiguity or "intra-tile",
    )


def _is_cfa_state(state: CompileState) -> bool:
    return (state.candidate is not None
            and getattr(state.candidate, "scheme", None) == "cfa"
            and isinstance(state.space, IterSpace)
            and hasattr(state.program, "deps"))


@analysis_pass("verify_single_assignment",
               codes=("CFA101", "CFA102", "CFA103", "CFA104", "CFA105"))
def verify_single_assignment(
    state: CompileState, *, plan: TransferPlan | None = None,
) -> list[Diagnostic]:
    """CFA1xx: geometric single-assignment/coverage proofs over the facet
    family plus :func:`plan_accounting` on the (possibly injected) plan."""
    diags: list[Diagnostic] = []
    if _is_cfa_state(state):
        cand = state.candidate
        diags += check_facet_family(
            state.space, state.program.deps, Tiling(cand.tile),
            storage=state.storage, **_cfa_family_kwargs(cand),
        )
    p = plan if plan is not None else _plan_of(state)
    if p is not None:
        diags += plan_accounting(p)
    return diags


@analysis_pass("verify_overlap", codes=("CFA201", "CFA202"))
def verify_overlap(
    state: CompileState, *,
    waves: Sequence[Sequence[Sequence[int]]] | None = None,
) -> list[Diagnostic]:
    """CFA2xx: the wave schedule (default or injected) respects every tile
    dependence — the precondition of the dataflow backend's overlap."""
    if not _is_cfa_state(state):
        return []
    return check_overlap_schedule(state.space, state.program.deps,
                                  Tiling(state.candidate.tile), waves=waves)


@analysis_pass("lint_bursts",
               codes=("CFA301", "CFA302", "CFA303", "CFA304"))
def lint_bursts(
    state: CompileState, *, plan: TransferPlan | None = None,
) -> list[Diagnostic]:
    """CFA3xx: :func:`lint_plan` under the bound target's burst model, with
    the expected-burst bound from ``cfa_piece_census`` when applicable."""
    p = plan if plan is not None else _plan_of(state)
    if p is None or state.target is None:
        return []
    model = getattr(state.target, "model", state.target)
    if not isinstance(model, BurstModel):
        return []
    contiguity = None
    expected = None
    if _is_cfa_state(state):
        cand = state.candidate
        contiguity = cand.contiguity or "intra-tile"
        if (contiguity == "intra-tile" and state.storage == "redundant"
                and p.scheme.startswith("cfa")
                and p.read_run_hosts is not None):
            # the §IV-H/I construction: one read burst per host facet, one
            # for the corner suffix, plus any §IV-J unmergeable pieces
            census = cfa_piece_census(
                state.space, state.program.deps, Tiling(cand.tile),
                ext_dirs=(dict(cand.ext_dirs)
                          if cand.ext_dirs is not None else None),
            )
            expected = (len(set(p.read_run_hosts)) + 1
                        + census["unmergeable"])
    return lint_plan(p, model, n_ports=state.n_ports, contiguity=contiguity,
                     expected_read_bursts=expected,
                     assignment=state.port_assignment)


@analysis_pass("verify_contracts",
               codes=("CFA401", "CFA402", "CFA403", "CFA404"))
def verify_contracts(state: CompileState) -> list[Diagnostic]:
    """CFA4xx: backend capabilities, overlap support, codec exactness
    preconditions and the platform port budget vs the lowered state."""
    diags: list[Diagnostic] = []
    ex = state.executor
    if ex is not None and hasattr(state.program, "deps"):
        from .executors import ineligible_reason

        reason = ineligible_reason(ex, state.program, state.space,
                                   state.n_ports, state.storage)
        if reason is not None:
            fix = ("storage" if "storage" in reason
                   else "n_ports" if "port" in reason else None)
            diags.append(Diagnostic(
                "CFA401", "ERROR",
                f"backend contract violated: {reason}",
                fixit=fix,
            ))
        if state.overlap and not ex.caps.overlap:
            diags.append(Diagnostic(
                "CFA402", "ERROR",
                f"overlap=True but backend {ex.name!r} runs fetch/compute/"
                f"commit sequentially — the Fig. 13 DATAFLOW schedule needs "
                f'backend="dataflow"',
            ))
    tgt = state.target
    max_ports = getattr(tgt, "max_ports", None)
    if max_ports is not None and state.n_ports > max_ports:
        diags.append(Diagnostic(
            "CFA404", "ERROR",
            f"n_ports={state.n_ports} exceeds target "
            f"{getattr(tgt, 'name', tgt)!r}'s port budget of {max_ports}",
            fixit="n_ports",
        ))
    cdc = state.codec
    if cdc is not None and hasattr(cdc, "bits"):
        if state.storage != "compressed":
            diags.append(Diagnostic(
                "CFA403", "ERROR",
                f"codec {cdc.name!r} bound under storage="
                f"{state.storage!r} — a block codec only applies to the "
                f"compressed discipline",
                fixit="storage",
            ))
        elif cdc.bits:
            diags.append(Diagnostic(
                "CFA403", "INFO",
                f"codec {cdc.name!r} keeps {cdc.bits}-bit residuals: exact "
                f"only where BlockCodec.exact holds per block; other data "
                f"is quantised on commit",
            ))
    return diags


#: The default analysis suite, in severity-of-subject order: correctness
#: proofs first, then the schedule, then the priced lints, then contracts.
DEFAULT_ANALYSES: tuple[AnalysisPass, ...] = (
    verify_single_assignment,
    verify_overlap,
    lint_bursts,
    verify_contracts,
)


# --------------------------------------------------------------------------
# Entry points
# --------------------------------------------------------------------------


def run_analyses(
    state: CompileState,
    analyses: Sequence[AnalysisPass] | None = None,
    *,
    plan: TransferPlan | None = None,
    waves: Sequence[Sequence[Sequence[int]]] | None = None,
) -> AnalysisReport:
    """Run ``analyses`` (default :data:`DEFAULT_ANALYSES`) over ``state``
    and collect the report.  ``plan``/``waves`` substitute corrupted
    artifacts — the mutation-testing hooks."""
    suite = DEFAULT_ANALYSES if analyses is None else tuple(analyses)
    overrides = {k: v for k, v in (("plan", plan), ("waves", waves))
                 if v is not None}
    diags: list[Diagnostic] = []
    for a in suite:
        diags.extend(a.diagnose(state, **overrides))
    return AnalysisReport(tuple(diags),
                          analyses=tuple((a.name, a.version) for a in suite))


def _state_of(compiled) -> CompileState:
    """Reconstruct the post-lowering ``CompileState`` a ``CompiledStencil``
    came from — what :func:`verify` feeds the analysis passes."""
    return CompileState(
        program=compiled.program,
        space=compiled.space,
        target=compiled.target,
        n_ports=compiled.n_ports,
        layout=compiled.layout,
        backend=compiled.backend,
        storage=compiled.storage,
        codec=compiled.codec,
        overlap=compiled.executor.caps.overlap,
        candidate=compiled.layout,
        decision=compiled.decision,
        storage_map=compiled.storage_map,
        port_assignment=getattr(compiled.pipeline, "port_assignment", None),
        executor=compiled.executor,
        pipeline=compiled.pipeline,
        compiled=compiled,
        distributed=compiled.distributed,
    )


def verify(
    compiled,
    *,
    analyses: Sequence[AnalysisPass] | None = None,
    plan: TransferPlan | None = None,
    waves: Sequence[Sequence[Sequence[int]]] | None = None,
    strict: bool = False,
    raise_on_error: bool = True,
) -> AnalysisReport:
    """Statically verify a :class:`~repro.core.cfa.api.CompiledStencil`.

    Runs the analysis suite over the stencil's reconstructed compile state
    and returns the :class:`AnalysisReport`.  With ``raise_on_error``
    (default) a report containing ERROR diagnostics — or WARN too, under
    ``strict`` — raises :class:`VerificationError` carrying the report.
    ``plan``/``waves`` substitute a corrupted transfer plan or wave
    schedule for the compiled one (mutation testing / what-if audits).

        compiled = cfa.compile("jacobi2d5p", (32, 32, 32))
        report = cfa.verify(compiled)          # raises on ERROR
        report = cfa.verify(compiled, raise_on_error=False)
        print(report.summary())
    """
    report = run_analyses(_state_of(compiled), analyses, plan=plan,
                          waves=waves)
    if raise_on_error and (report.errors or (strict and report.warnings)):
        raise VerificationError(report, strict=strict)
    return report


def verify_pipeline(base=None):
    """A :class:`~repro.core.cfa.passes.PassPipeline` extending ``base``
    (default: the default lowering) with :data:`DEFAULT_ANALYSES` — what
    ``cfa.compile(..., verify=True)`` lowers with.  Analysis passes already
    present in ``base`` are not duplicated."""
    from .passes import PassPipeline, default_pipeline

    base = default_pipeline() if base is None else base
    extra = tuple(a for a in DEFAULT_ANALYSES if a.name not in base.names)
    if not extra:
        return base
    return PassPipeline(tuple(base.passes) + extra)
