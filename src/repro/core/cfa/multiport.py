"""Multi-port facet distribution — the paper's stated future work (§VII):

    "the machine model we have considered may be extended to multi-port
     memory accesses, such as high-bandwidth memory ... one has to find an
     adequate repartition of data over each memory port to balance accesses."

On TPU-class HBM the analogue is distributing the facet arrays across HBM
channels (or, across chips, the sharding of facet arrays over a mesh axis).
Because CFA gives every facet a *static, per-tile-uniform* transfer size,
the balance problem is a deterministic multiprocessor-scheduling instance:
assign facet arrays (the unit of contiguity) to ports so the heaviest port
carries the least possible bytes per tile.

``assign_ports`` implements LPT (longest-processing-time greedy, 4/3-optimal)
over per-tile facet traffic derived from the burst plans; ``port_speedup``
evaluates the resulting aggregate-bandwidth gain under the burst model.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .bandwidth import BurstModel
from .facets import build_facet_specs
from .plans import cfa_plan, interior_tile
from .spaces import Deps, IterSpace, Tiling

__all__ = ["PortAssignment", "assign_ports", "port_speedup"]


@dataclasses.dataclass(frozen=True)
class PortAssignment:
    n_ports: int
    facet_to_port: dict[int, int]  # facet axis -> port id
    port_bytes: tuple[float, ...]  # per-tile traffic per port (elements)

    @property
    def balance(self) -> float:
        """max port load / mean port load (1.0 = perfect)."""
        loads = np.asarray(self.port_bytes)
        mean = loads.mean() if loads.size else 0.0
        return float(loads.max() / mean) if mean > 0 else 1.0


def _facet_traffic(space: IterSpace, deps: Deps, tiling: Tiling) -> dict[int, float]:
    """Per-tile elements moved per facet array (write block + its share of
    the read plan, which CFA's host assignment makes per-facet exact)."""
    specs = build_facet_specs(space, deps, tiling)
    tile = interior_tile(space, tiling)
    from .plans import _assign_hosts, flow_in_points
    from .spaces import facet_widths

    widths = facet_widths(deps)
    fin = flow_in_points(space, deps, tiling, tile)
    hosts = _assign_hosts(fin, tile, tiling, widths, specs)
    traffic = {}
    for k, spec in specs.items():
        traffic[k] = float(spec.block_elems)  # flow-out write
        traffic[k] += float(hosts[k].size)  # flow-in reads served by facet k
    return traffic


def assign_ports(space: IterSpace, deps: Deps, tiling: Tiling,
                 n_ports: int) -> PortAssignment:
    traffic = _facet_traffic(space, deps, tiling)
    loads = [0.0] * n_ports
    assign = {}
    for k in sorted(traffic, key=lambda k: -traffic[k]):  # LPT greedy
        p = int(np.argmin(loads))
        assign[k] = p
        loads[p] += traffic[k]
    return PortAssignment(n_ports, assign, tuple(loads))


def port_speedup(space: IterSpace, deps: Deps, tiling: Tiling,
                 n_ports: int, model: BurstModel) -> dict:
    """Aggregate-bandwidth gain of an n-port split vs a single port.

    Each port serves its facets' bursts independently; tile time = the
    slowest port (ports run concurrently, the paper's balance objective)."""
    plan = cfa_plan(space, deps, tiling)
    t_single = model.time_s(plan.read_runs) + model.time_s(plan.write_runs)

    pa = assign_ports(space, deps, tiling, n_ports)
    specs = build_facet_specs(space, deps, tiling)
    # apportion the plan's runs to ports: writes are per facet (one each, in
    # ascending facet order by construction); reads via the host assignment.
    write_runs_by_port = [[] for _ in range(n_ports)]
    for k, run in zip(sorted(specs), plan.write_runs):
        write_runs_by_port[pa.facet_to_port[k]].append(run)
    # reads: split proportionally to per-facet read traffic
    from .plans import _assign_hosts, flow_in_points
    from .spaces import facet_widths

    tile = interior_tile(space, tiling)
    hosts = _assign_hosts(flow_in_points(space, deps, tiling, tile), tile,
                          tiling, facet_widths(deps), specs)
    read_runs_by_port = [[] for _ in range(n_ports)]
    runs = list(plan.read_runs)
    # plan.read_runs were emitted per-facet in specs order inside cfa_plan
    idx = 0
    for k in specs:
        n_k = 1 if hosts[k].size else 0
        # boxed mode merges each facet's reads into ~1 burst; attribute
        # remaining runs round-robin if counts diverge
        take = runs[idx: idx + max(n_k, 0)]
        idx += len(take)
        read_runs_by_port[pa.facet_to_port[k]].extend(take)
    for r in runs[idx:]:
        read_runs_by_port[int(np.argmin([sum(x) for x in read_runs_by_port]))].append(r)

    t_ports = max(
        model.time_s(tuple(wr)) + model.time_s(tuple(rr))
        for wr, rr in zip(write_runs_by_port, read_runs_by_port)
    )
    return {
        "n_ports": n_ports,
        "balance": pa.balance,
        "t_single_us": 1e6 * t_single,
        "t_multi_us": 1e6 * t_ports,
        "speedup": t_single / t_ports if t_ports else 1.0,
        "assignment": pa.facet_to_port,
    }
