"""Multi-port facet repartition — the paper's stated future work (§VII):

    "the machine model we have considered may be extended to multi-port
     memory accesses, such as high-bandwidth memory ... one has to find an
     adequate repartition of data over each memory port to balance accesses."

On TPU-class HBM the analogue is distributing the facet arrays across HBM
channels (or, across chips, the sharding of facet arrays over a mesh axis).
Because CFA gives every facet a *static, per-tile-uniform* transfer size,
the balance problem is a deterministic multiprocessor-scheduling instance:
assign work units to ports so the heaviest port carries the least possible
time per tile (``BurstModel.time`` of a ``PortedPlan`` = max over ports).

Two granularities of "work unit" are searched:

* **facet-granular** — whole facet arrays go to ports, preserving each
  facet's contiguity untouched.  ``facet-lpt`` is LPT (longest-processing-
  time greedy, 4/3-optimal) over per-facet burst time; ``facet-rr`` is the
  round-robin baseline.  Requires the plan's run->facet attribution
  (``TransferPlan.read_run_hosts``), i.e. a CFA plan.
* **burst-granular** — individual bursts are schedulable: ``burst-lpt``
  LPT-schedules whole bursts across ports; ``stripe`` splits every burst
  into near-equal contiguous chunks, one per port (address interleaving
  across channels, each chunk paying its own descriptor setup).  These work
  for any layout scheme, including the paper's baselines.

``best_repartition`` searches strategies x ports-used (a repartition may
leave ports idle, so more available ports never models slower) and returns
the fastest :class:`PortedPlan` under the burst model.  ``assign_ports`` /
``port_speedup`` are the facet-level entry points used by the autotuner,
the sharded wavefront executor and the multiport benchmark.

Everything here is dimension-generic: facets are keyed by canonical axis,
so a 2-D program's 2 facets or a 4-D program's 4 facets repartition through
the same code as the 3-D Table I suite.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .bandwidth import BurstModel, PortedPlan
from .facets import build_facet_specs
from .plans import TransferPlan, cfa_plan, interior_tile
from .spaces import Deps, IterSpace, Tiling

__all__ = [
    "PortAssignment",
    "PORT_STRATEGIES",
    "assign_ports",
    "repartition",
    "best_repartition",
    "port_speedup",
]

PORT_STRATEGIES = ("facet-lpt", "facet-rr", "burst-lpt", "stripe")


@dataclasses.dataclass(frozen=True)
class PortAssignment:
    n_ports: int
    facet_to_port: dict[int, int]  # facet axis -> port id
    port_bytes: tuple[float, ...]  # per-tile traffic per port (elements)

    @property
    def balance(self) -> float:
        """max port load / mean port load (1.0 = perfect)."""
        loads = np.asarray(self.port_bytes)
        mean = loads.mean() if loads.size else 0.0
        return float(loads.max() / mean) if mean > 0 else 1.0


def _facet_traffic(space: IterSpace, deps: Deps, tiling: Tiling) -> dict[int, float]:
    """Per-tile elements moved per facet array (write block + its share of
    the read plan, which CFA's host assignment makes per-facet exact)."""
    specs = build_facet_specs(space, deps, tiling)
    tile = interior_tile(space, tiling)
    from .plans import _assign_hosts, flow_in_points
    from .spaces import facet_widths

    widths = facet_widths(deps)
    fin = flow_in_points(space, deps, tiling, tile)
    hosts = _assign_hosts(fin, tile, tiling, widths, specs)
    traffic = {}
    for k, spec in specs.items():
        traffic[k] = float(spec.block_elems)  # flow-out write
        traffic[k] += float(hosts[k].size)  # flow-in reads served by facet k
    return traffic


def assign_ports(space: IterSpace, deps: Deps, tiling: Tiling,
                 n_ports: int) -> PortAssignment:
    """LPT assignment of whole facet arrays to ``n_ports`` ports."""
    traffic = _facet_traffic(space, deps, tiling)
    loads = [0.0] * n_ports
    assign = {}
    for k in sorted(traffic, key=lambda k: -traffic[k]):  # LPT greedy
        p = int(np.argmin(loads))
        assign[k] = p
        loads[p] += traffic[k]
    return PortAssignment(n_ports, assign, tuple(loads))


# --------------------------------------------------------------------------
# Repartition strategies: TransferPlan -> PortedPlan
# --------------------------------------------------------------------------


def _run_weight(length: int, model: BurstModel | None) -> float:
    """Scheduling weight of one burst: its modeled time (or elements)."""
    if model is None:
        return float(length)
    return model.setup_s + length * model.elem_bytes / model.peak_bytes_per_s


def _facet_partition(plan: TransferPlan, n_ports: int, *, lpt: bool,
                     model: BurstModel | None):
    """Group runs by host facet, place whole facets on ports (LPT or RR)."""
    if plan.read_run_hosts is None or plan.write_run_hosts is None:
        raise ValueError(
            f"facet-granular repartition needs run->facet attribution, which "
            f"{plan.scheme!r} plans do not carry (use a burst-granular strategy)"
        )
    groups: dict[int, tuple[list[int], list[int]]] = {}
    for length, k in zip(plan.read_runs, plan.read_run_hosts):
        groups.setdefault(k, ([], []))[0].append(length)
    for length, k in zip(plan.write_runs, plan.write_run_hosts):
        groups.setdefault(k, ([], []))[1].append(length)
    weight = {
        k: sum(_run_weight(r, model) for r in rr + wr)
        for k, (rr, wr) in groups.items()
    }
    reads = [[] for _ in range(n_ports)]
    writes = [[] for _ in range(n_ports)]
    loads = [0.0] * n_ports
    assign: dict[int, int] = {}
    if lpt:
        order = sorted(groups, key=lambda k: (-weight[k], k))
    else:  # round-robin in canonical facet-axis order
        order = sorted(groups)
    for i, k in enumerate(order):
        p = int(np.argmin(loads)) if lpt else i % n_ports
        assign[k] = p
        loads[p] += weight[k]
        reads[p].extend(groups[k][0])
        writes[p].extend(groups[k][1])
    return reads, writes, assign


def _burst_lpt_partition(plan: TransferPlan, n_ports: int,
                         model: BurstModel | None):
    """LPT over individual bursts (reads and writes jointly scheduled)."""
    runs = [(length, True) for length in plan.read_runs]
    runs += [(length, False) for length in plan.write_runs]
    runs.sort(key=lambda x: -x[0])
    reads = [[] for _ in range(n_ports)]
    writes = [[] for _ in range(n_ports)]
    loads = [0.0] * n_ports
    for length, is_read in runs:
        p = int(np.argmin(loads))
        loads[p] += _run_weight(length, model)
        (reads if is_read else writes)[p].append(length)
    return reads, writes


def _stripe_partition(plan: TransferPlan, n_ports: int):
    """Split every burst into ``n_ports`` near-equal contiguous chunks.

    Models address-interleaving each extent across channels: chunk ``p`` of
    a burst goes to port ``p`` and pays its own descriptor setup, so striping
    wins exactly when bursts are long relative to the model's setup knee."""
    reads = [[] for _ in range(n_ports)]
    writes = [[] for _ in range(n_ports)]
    for length in plan.read_runs:
        base, rem = divmod(length, n_ports)
        for p in range(n_ports):
            chunk = base + (1 if p < rem else 0)
            if chunk:
                reads[p].append(chunk)
    for length in plan.write_runs:
        base, rem = divmod(length, n_ports)
        for p in range(n_ports):
            chunk = base + (1 if p < rem else 0)
            if chunk:
                writes[p].append(chunk)
    return reads, writes


def repartition(
    plan: TransferPlan,
    n_ports: int,
    strategy: str = "facet-lpt",
    *,
    model: BurstModel | None = None,
) -> PortedPlan:
    """Split ``plan``'s bursts over ``n_ports`` ports with one strategy.

    ``model`` weights LPT bin-packing by modeled burst time (setup included);
    without it, weights are element counts.  Raises ``ValueError`` for a
    facet-granular strategy on a plan without facet attribution.
    """
    if n_ports <= 0:
        raise ValueError(f"n_ports must be positive: {n_ports}")
    if strategy not in PORT_STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; have {PORT_STRATEGIES}")
    assign = None
    if strategy in ("facet-lpt", "facet-rr"):
        reads, writes, facet_assign = _facet_partition(
            plan, n_ports, lpt=(strategy == "facet-lpt"), model=model
        )
        assign = tuple(sorted(facet_assign.items()))
    elif strategy == "burst-lpt":
        reads, writes = _burst_lpt_partition(plan, n_ports, model)
    else:  # stripe
        reads, writes = _stripe_partition(plan, n_ports)
    return PortedPlan(
        scheme=plan.scheme,
        n_ports=n_ports,
        strategy=strategy,
        read_runs_by_port=tuple(tuple(r) for r in reads),
        write_runs_by_port=tuple(tuple(w) for w in writes),
        read_useful=plan.read_useful,
        write_useful=plan.write_useful,
        facet_to_port=assign,
        storage=plan.storage,
        footprint=plan.footprint,
        codec_bits=plan.codec_bits,
    )


def _pad_ports(pp: PortedPlan, n_ports: int) -> PortedPlan:
    """Re-express a p-port plan as an n-port plan with idle trailing ports."""
    if pp.n_ports == n_ports:
        return pp
    pad = n_ports - pp.n_ports
    return dataclasses.replace(
        pp,
        n_ports=n_ports,
        read_runs_by_port=pp.read_runs_by_port + ((),) * pad,
        write_runs_by_port=pp.write_runs_by_port + ((),) * pad,
    )


def best_repartition(
    plan: TransferPlan,
    n_ports: int,
    model: BurstModel,
    strategies: Sequence[str] = PORT_STRATEGIES,
    *,
    time_fn=None,
    compute_s: float = 0.0,
    overlap: bool = False,
) -> PortedPlan:
    """The fastest repartition of ``plan`` over up to ``n_ports`` ports.

    Searches every strategy at every port count ``p <= n_ports`` (using fewer
    ports than available is always legal — idle ports cost nothing — which
    also makes the returned time monotonically non-increasing in ``n_ports``).
    Deterministic tiebreak: earliest strategy in ``strategies``, then fewest
    ports used.  Facet-granular strategies are skipped silently for plans
    without facet attribution; when *no* requested strategy applies (e.g.
    facet-only strategies on a baseline plan) the trivial single-port
    schedule — always legal — is returned with strategy ``"single-port"``.

    ``time_fn`` overrides how candidate :class:`PortedPlan`\\ s are scored
    (default ``model.time``) — e.g. ``calibrate.measure_plan`` to pick the
    repartition by measured wall-clock instead of the analytic model.  The
    ``model`` still weights the LPT bin-packing inside each strategy.
    ``compute_s`` / ``overlap`` are folded into the default score
    (``model.time(pp, compute_s=..., overlap=...)``) so a dataflow
    repartition is picked by its *overlapped* tile time; they are ignored
    when ``time_fn`` is given.
    """
    if time_fn is not None:
        score = time_fn
    else:
        def score(pp):
            return model.time(pp, compute_s=compute_s, overlap=overlap)
    best: PortedPlan | None = None
    best_key: tuple | None = None
    for p in range(1, n_ports + 1):
        for si, strat in enumerate(strategies):
            try:
                pp = repartition(plan, p, strat, model=model)
            except ValueError:
                continue
            key = (score(pp), si, p)
            if best_key is None or key < best_key:
                best, best_key = pp, key
    if best is None:
        best = PortedPlan(
            scheme=plan.scheme,
            n_ports=1,
            strategy="single-port",
            read_runs_by_port=(plan.read_runs,),
            write_runs_by_port=(plan.write_runs,),
            read_useful=plan.read_useful,
            write_useful=plan.write_useful,
            storage=plan.storage,
            footprint=plan.footprint,
            codec_bits=plan.codec_bits,
        )
    return _pad_ports(best, n_ports)


def port_speedup(
    space: IterSpace,
    deps: Deps,
    tiling: Tiling,
    n_ports: int,
    model: BurstModel,
    *,
    strategies: Sequence[str] = PORT_STRATEGIES,
) -> dict:
    """Aggregate-bandwidth gain of an n-port repartition vs a single port.

    Evaluates the interior-tile CFA plan, repartitions it with
    ``best_repartition`` and compares modeled times: each port serves its
    bursts independently; tile time = the slowest port (ports run
    concurrently, the paper's §VII balance objective)."""
    plan = cfa_plan(space, deps, tiling)
    t_single = model.time(plan)
    pp = best_repartition(plan, n_ports, model, strategies)
    t_ports = model.time(pp)
    return {
        "n_ports": n_ports,
        "strategy": pp.strategy,
        "balance": pp.balance,
        "t_single_us": 1e6 * t_single,
        "t_multi_us": 1e6 * t_ports,
        "speedup": t_single / t_ports if t_ports else 1.0,
        "assignment": dict(pp.facet_to_port) if pp.facet_to_port is not None else None,
    }
