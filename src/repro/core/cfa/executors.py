"""Execution backends behind ``repro.cfa.compile`` — one registry, one gate.

Before this module, running a compiled stencil meant picking one of five
hand-wired entry points (the ``CFAPipeline`` sweep variants and the kernel
wrappers), each with its own dimensionality and port-count restrictions
enforced — or not — at a different layer.  Here the same executors are
registered objects with *declared* capabilities, so backend selection, N-D
gating and port-count validation happen in exactly one place
(:func:`check_backend` / :func:`select_backend`).

Registered backends (all return the same payload — the facet-storage dict,
bit-exact across backends):

* ``reference`` — untiled oracle (``reference_volume``) scattered into facet
  storage; the ground truth everything else is compared against.
* ``sweep``     — the tile-by-tile reference loop of §V (Fig. 13).
* ``wavefront`` — anti-diagonal waves of independent tiles, batched (jnp).
* ``pallas``    — wavefront sweep through the Pallas tile-executor kernel
  (``repro.kernels.stencil``), paired with the ``facet_fetch`` read engine's
  layout family; declared 3-D only — the paper's kernel configuration.
* ``sharded``   — port-mesh wavefront: facet arrays resident on their
  assigned port's device, waves executed via ``shard_map`` (§VII).
* ``dataflow``  — software-pipelined wavefront: fetch, compute and commit of
  consecutive tiles overlap (Fig. 13 DATAFLOW made a schedule; the modeled
  counterpart is ``BurstModel.time(..., overlap=True)``).

Custom backends register through :func:`register_executor`; the autotuner's
cache key folds :func:`capability_fingerprint` in, so decisions re-search
when the executor capability set changes.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Protocol, runtime_checkable

import jax.numpy as jnp

from .programs import StencilProgram
from .spaces import IterSpace
from .transform import CFAPipeline

__all__ = [
    "BackendError",
    "Executor",
    "ExecutorCaps",
    "EXECUTORS",
    "register_executor",
    "get_executor",
    "available_backends",
    "ineligible_reason",
    "select_backend",
    "check_backend",
    "capability_fingerprint",
    "host_fingerprint",
]


class BackendError(ValueError):
    """A backend cannot execute the requested (program, space, n_ports)."""


@dataclasses.dataclass(frozen=True)
class ExecutorCaps:
    """Declared capabilities of an execution backend.

    ``ndims`` — iteration-space dimensionalities the backend can execute
    (``None`` = any d >= 2, the ``CFAPipeline`` contract).
    ``multiport`` — whether the backend realises an ``n_ports > 1`` facet
    repartition (anything else requires ``n_ports == 1``).
    ``kernels`` — whether the backend drives the Pallas kernels (so callers
    know an ``interpret=`` knob applies).
    ``storages`` — the facet storage disciplines the backend implements
    (``repro.core.cfa.irredundant.STORAGE_MODES``); a kernel backend whose
    read engine has no decompression stage must not silently accept
    ``storage="compressed"``.
    ``overlap`` — whether the backend overlaps fetch/compute/commit
    (Fig. 13 DATAFLOW); sequential backends should be modeled with
    ``BurstModel.time(..., overlap=False)``.
    """

    ndims: tuple[int, ...] | None = None
    multiport: bool = False
    kernels: bool = False
    storages: tuple[str, ...] = ("redundant", "irredundant", "compressed")
    overlap: bool = False
    description: str = ""


@runtime_checkable
class Executor(Protocol):
    """An execution backend: runs a built pipeline over concrete inputs.

    ``execute`` consumes the live-in planes and returns the facet-storage
    dict — the exact payload of ``CFAPipeline.sweep`` — so results from any
    backend compare bit-for-bit.
    """

    name: str
    caps: ExecutorCaps

    def execute(
        self,
        pipeline: CFAPipeline,
        inputs: jnp.ndarray,
        *,
        dtype=jnp.float32,
        n_ports: int = 1,
        **opts,
    ) -> dict[int, jnp.ndarray]: ...


@dataclasses.dataclass(frozen=True)
class _FnExecutor:
    """An Executor wrapping a plain function (the built-in backends).

    ``opts_allowed`` is the backend's call-option surface; anything else is
    rejected loudly — an ignored ``interpret=False`` on a backend that has
    no kernels (or a typo'd option) must not run silently.
    """

    name: str
    caps: ExecutorCaps
    fn: Callable[..., dict[int, jnp.ndarray]]
    opts_allowed: tuple[str, ...] = ()

    def execute(self, pipeline, inputs, *, dtype=jnp.float32, n_ports=1, **opts):
        unknown = sorted(set(opts) - set(self.opts_allowed))
        if unknown:
            raise TypeError(
                f"backend {self.name!r} does not accept option(s) {unknown}; "
                f"allowed: {sorted(self.opts_allowed) or 'none'}"
            )
        return self.fn(pipeline, inputs, dtype=dtype, n_ports=n_ports, **opts)


# --------------------------------------------------------------------------
# Built-in backends
# --------------------------------------------------------------------------


def _reference(pipeline: CFAPipeline, inputs, *, dtype, n_ports=1):
    """Untiled oracle scattered into facet storage.

    ``reference_volume`` computes every plane over the full space; the
    volume's tile blocks are then committed through the very same
    ``copy_out`` the tiled executors use (``copy_out`` only reads the halo
    buffer's interior), so the returned facets are directly comparable."""
    inputs = inputs.astype(dtype)
    V = pipeline.reference_volume(inputs).astype(dtype)
    facets = pipeline.init_facets(dtype)
    facets = pipeline.load_inputs(facets, inputs)
    w = pipeline.widths
    t = pipeline.tiling.sizes
    interior = pipeline._interior_slices(w)
    for tile in itertools.product(*(range(n) for n in pipeline.num_tiles)):
        block = V[tuple(slice(q * ta, (q + 1) * ta) for q, ta in zip(tile, t))]
        H = jnp.zeros(tuple(wa + ta for wa, ta in zip(w, t)), dtype)
        H = H.at[interior].set(block)
        facets = pipeline.copy_out(facets, tile, H)
    return facets


def _sweep(pipeline: CFAPipeline, inputs, *, dtype, n_ports=1):
    return pipeline._sweep(inputs, dtype)


def _wavefront(pipeline: CFAPipeline, inputs, *, dtype, n_ports=1):
    return pipeline._sweep_wavefront(inputs, dtype, use_kernel=False)


def _pallas(pipeline: CFAPipeline, inputs, *, dtype, n_ports=1,
            interpret: bool = True):
    # interpret=True is the CPU-hosted mode; on a real TPU pass
    # interpret=False through ``CompiledStencil.__call__``.
    return pipeline._sweep_wavefront(inputs, dtype, use_kernel=True,
                                     interpret=interpret)


def _sharded(pipeline: CFAPipeline, inputs, *, dtype, n_ports=1, **opts):
    return pipeline._sweep_wavefront_sharded(inputs, dtype, n_ports=n_ports,
                                             **opts)


def _dataflow(pipeline: CFAPipeline, inputs, *, dtype, n_ports=1,
              use_kernel: bool = False, interpret: bool = True):
    # the kernel path inherits the pallas backend's envelope: the
    # facet_fetch/stencil kernel family is 3-D and has no decode stage
    if use_kernel and pipeline.space.ndim != 3:
        raise BackendError(
            "backend 'dataflow' drives the Pallas tile executor only for "
            f"3-D spaces (use_kernel=True), got a {pipeline.space.ndim}-D "
            "space; drop use_kernel for the host path"
        )
    if use_kernel and pipeline.storage == "compressed":
        raise BackendError(
            "backend 'dataflow' cannot drive the Pallas tile executor over "
            "compressed facet storage (no in-kernel decode stage); drop "
            "use_kernel for the host path"
        )
    return pipeline._sweep_dataflow(inputs, dtype, use_kernel=use_kernel,
                                    interpret=interpret)


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

EXECUTORS: dict[str, Executor] = {}


def register_executor(executor: Executor, *, overwrite: bool = False) -> Executor:
    """Register a backend under ``executor.name`` (also usable on custom
    Executor objects from outside this module)."""
    if not overwrite and executor.name in EXECUTORS:
        raise ValueError(f"backend {executor.name!r} is already registered")
    EXECUTORS[executor.name] = executor
    return executor


def get_executor(name: str) -> Executor:
    try:
        return EXECUTORS[name]
    except KeyError:
        raise BackendError(
            f"unknown backend {name!r}; registered: {sorted(EXECUTORS)}"
        ) from None


register_executor(_FnExecutor(
    "reference",
    ExecutorCaps(description="untiled oracle, scattered into facet storage"),
    _reference,
))
register_executor(_FnExecutor(
    "sweep",
    ExecutorCaps(description="tile-by-tile reference loop (paper §V)"),
    _sweep,
))
register_executor(_FnExecutor(
    "wavefront",
    ExecutorCaps(description="batched anti-diagonal tile waves (jnp)"),
    _wavefront,
))
register_executor(_FnExecutor(
    "pallas",
    ExecutorCaps(ndims=(3,), kernels=True,
                 # the facet_fetch read engine addresses raw facet blocks
                 # (redundant, or irredundant via the owner-block
                 # indirection); it has no in-kernel decode stage, so the
                 # compressed discipline is declared unsupported
                 storages=("redundant", "irredundant"),
                 description="wavefront sweep through the Pallas tile "
                             "executor (facet_fetch/stencil kernel family, "
                             "3-D only)"),
    _pallas,
    opts_allowed=("interpret",),
))
register_executor(_FnExecutor(
    "sharded",
    ExecutorCaps(multiport=True,
                 description="port-mesh wavefront via shard_map (§VII)"),
    _sharded,
    opts_allowed=("mesh", "axis", "assignment", "use_kernel"),
))
register_executor(_FnExecutor(
    "dataflow",
    ExecutorCaps(kernels=True, overlap=True,
                 description="software-pipelined wavefront: fetch/compute/"
                             "commit of consecutive tiles overlap "
                             "(Fig. 13 DATAFLOW)"),
    _dataflow,
    opts_allowed=("use_kernel", "interpret"),
))


# --------------------------------------------------------------------------
# The one gate: capability validation + auto-selection
# --------------------------------------------------------------------------


def _ineligible_reason(
    executor: Executor,
    program: StencilProgram,
    space: IterSpace,
    n_ports: int,
    storage: str = "redundant",
) -> str | None:
    """Why this backend cannot run (program, space, n_ports, storage);
    None if it can."""
    caps = executor.caps
    if caps.ndims is not None and space.ndim not in caps.ndims:
        return (
            f"backend {executor.name!r} executes "
            f"{'/'.join(f'{n}-D' for n in caps.ndims)} spaces only, but "
            f"{program.name!r} @ {space.sizes} is {space.ndim}-D"
        )
    if n_ports > 1 and not caps.multiport:
        return f"backend {executor.name!r} is single-port, got n_ports={n_ports}"
    if storage not in caps.storages:
        return (
            f"backend {executor.name!r} does not implement "
            f"{storage!r} facet storage (declares {caps.storages})"
        )
    return None


def ineligible_reason(
    executor: Executor,
    program: StencilProgram,
    space: IterSpace,
    n_ports: int = 1,
    storage: str = "redundant",
) -> str | None:
    """Why this backend cannot run (program, space, n_ports, storage);
    ``None`` if it can.  The non-raising form of :func:`check_backend` —
    what the CFA401 contract analysis reports verbatim."""
    return _ineligible_reason(executor, program, space, n_ports, storage)


def check_backend(
    executor: Executor,
    program: StencilProgram,
    space: IterSpace,
    n_ports: int = 1,
    storage: str = "redundant",
) -> None:
    """Validate (program, space, n_ports, storage) against the backend's
    declared capabilities; raises :class:`BackendError` with the eligible
    alternatives spelled out."""
    reason = _ineligible_reason(executor, program, space, n_ports, storage)
    if reason is not None:
        # sorted: the error message must be stable regardless of
        # registration order (matches get_executor's unknown-name error)
        raise BackendError(
            f"{reason}; eligible backends: "
            f"{sorted(available_backends(program, space, n_ports, storage))}"
        )


def available_backends(
    program: StencilProgram, space: IterSpace, n_ports: int = 1,
    storage: str = "redundant",
) -> list[str]:
    """Names of registered backends able to run (program, space, n_ports,
    storage)."""
    return [
        name for name, ex in EXECUTORS.items()
        if _ineligible_reason(ex, program, space, n_ports, storage) is None
    ]


def select_backend(
    program: StencilProgram, space: IterSpace, n_ports: int = 1,
    storage: str = "redundant",
    overlap: bool = False,
) -> str:
    """The ``backend="auto"`` rule, in one place:

    1. ``n_ports > 1``  →  ``sharded``   (the only multiport backend);
    2. ``overlap=True`` →  ``dataflow``  (the only backend that pipelines
       fetch/compute/commit, Fig. 13 DATAFLOW);
    3. 3-D spaces       →  ``pallas``    (the paper's kernel configuration)
       — unless the requested storage discipline is outside the kernel
       backend's declared envelope (compressed), in which case
    4. anything else    →  ``wavefront`` (dimension-generic, batched).
    """
    if n_ports > 1:
        return "sharded"
    if overlap:
        return "dataflow"
    if (space.ndim == 3
            and storage in EXECUTORS["pallas"].caps.storages):
        return "pallas"
    return "wavefront"


def host_fingerprint() -> list[list[str]]:
    """Stable identity of the machine a measurement ran on.

    Folded into the autotune cache key for ``score="measured"`` decisions
    (cache schema v5): a wall-clock ranking measured on one host must not
    be silently reused on another, the exact failure mode the analytic
    model never has.  The jax device is resolved lazily — calling this
    initialises the backend, which measured scoring needs anyway.
    """
    import platform

    import jax

    try:
        dev = jax.devices()[0]
        device = getattr(dev, "device_kind", None) or str(dev)
    except RuntimeError:
        device = "none"
    return [
        ["machine", platform.machine()],
        ["system", platform.system()],
        ["python", platform.python_version()],
        ["jax", jax.__version__],
        ["backend", jax.default_backend()],
        ["device", device],
    ]


def capability_fingerprint() -> list[list]:
    """Stable summary of the registered backend capability set.

    Folded into the autotune cache key (schema v3+): a decision computed
    when e.g. the ``pallas`` backend was 3-D-only must not be silently
    reused after a backend's capability envelope (dimensions, ports,
    storage disciplines) changes.
    """
    return [
        [name, list(ex.caps.ndims) if ex.caps.ndims is not None else None,
         ex.caps.multiport, ex.caps.kernels, list(ex.caps.storages),
         ex.caps.overlap]
        for name, ex in sorted(EXECUTORS.items())
    ]
