"""``repro.cfa.compile`` — the jit-style front door over the CFA stack.

The paper's pipeline (§V, Fig. 13) is one conceptual operation — pick a
burst-friendly layout, build the read→execute→write schedule, run it — yet
doing it by hand means wiring four subsystems (``get_program`` →
``autotune`` → ``CFAPipeline`` → an executor entry point) with knobs
duplicated at every step.  This module collapses that into

    compiled = cfa.compile("jacobi2d5p", (16, 32, 32))
    facets   = compiled(inputs)            # the facet-storage payload
    compiled.report()                      # BurstModel bandwidth stats
    compiled.trace()                       # the per-pass lowering trace
    compiled.lower(backend="sharded")      # rebind to another backend

``compile`` is a thin driver over the staged lowering of
:mod:`repro.core.cfa.passes`: it seeds a :class:`~repro.core.cfa.passes.
CompileState` from its arguments, runs the default :class:`~repro.core.
cfa.passes.PassPipeline` (resolve_program → validate_target → distribute →
layout_search → storage_map → port_repartition → select_backend →
lower_backend), and returns the resulting :class:`CompiledStencil` — a
callable carrying the layout, the interior-tile transfer plan, the
bandwidth report, the underlying :class:`CFAPipeline` and the per-pass
trace.

The :class:`Target` registry unifies the paper's ZC706 AXI port model, the
TPU HBM adaptation and custom :class:`BurstModel`\\ s — including each
platform's *port budget*, so ``n_ports`` is validated in one place instead
of at five call sites.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Mapping, Sequence

import jax.numpy as jnp

from .autotune import LayoutCandidate, LayoutDecision
from .bandwidth import AXI_ZC706, TPU_V5E_HBM, BandwidthReport, BurstModel
from .compress import BlockCodec
from .irredundant import rehydrate_facets
from .multiport import best_repartition
from .plans import TransferPlan
from .programs import StencilProgram
from .spaces import IterSpace
from .executors import Executor, check_backend, get_executor
from .passes import CompileState, PassPipeline, PassTrace, default_pipeline
from .transform import CFAPipeline

__all__ = [
    "Target",
    "TARGETS",
    "register_target",
    "get_target",
    "compile",
    "CompiledStencil",
]


# --------------------------------------------------------------------------
# Target registry
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Target:
    """A memory platform: a :class:`BurstModel` plus its port budget.

    ``max_ports`` is how many independent memory ports the platform offers
    (AXI HP ports on the ZC706, HBM channels on a TPU); ``None`` means
    unvalidated (custom models).  ``compile`` rejects ``n_ports`` beyond the
    budget — the §VII repartition cannot use ports the hardware lacks.
    """

    name: str
    model: BurstModel
    max_ports: int | None = None
    description: str = ""

    def __post_init__(self) -> None:
        if self.max_ports is not None and self.max_ports < 1:
            raise ValueError(f"max_ports must be >= 1: {self.max_ports}")


TARGETS: dict[str, Target] = {}


def register_target(target: Target, *, overwrite: bool = False) -> Target:
    if not overwrite and target.name in TARGETS:
        raise ValueError(f"target {target.name!r} is already registered")
    TARGETS[target.name] = target
    return target


register_target(Target(
    name="axi-zc706", model=AXI_ZC706, max_ports=4,
    description="the paper's Zynq ZC706: 4 AXI HP ports, 800 MB/s each (§VI-A)",
))
register_target(Target(
    name="tpu-v5e-hbm", model=TPU_V5E_HBM, max_ports=16,
    description="TPU v5e-class HBM behind DMA engines (the adaptation target)",
))


def get_target(target: "Target | BurstModel | str") -> Target:
    """Resolve a target name, a registered/raw :class:`BurstModel`, or a
    :class:`Target` to the registry entry (raw models wrap unvalidated)."""
    if isinstance(target, Target):
        return target
    if isinstance(target, BurstModel):
        hit = TARGETS.get(target.name)
        if hit is not None:
            if hit.model == target:
                return hit
            # a recalibrated model of a registered platform (same name,
            # tweaked parameters) keeps that platform's port budget — the
            # hardware did not grow ports because the model was re-fit
            return dataclasses.replace(hit, model=target)
        return Target(name=target.name, model=target)
    if isinstance(target, str):
        try:
            return TARGETS[target]
        except KeyError:
            raise ValueError(
                f"unknown target {target!r}; registered: {sorted(TARGETS)}"
            ) from None
    raise TypeError(f"target must be a Target, BurstModel or name: {target!r}")


# --------------------------------------------------------------------------
# CompiledStencil
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CompiledStencil:
    """The result of :func:`compile`: a callable stencil executable.

    ``compiled(inputs)`` runs the tiled computation through facet storage on
    the bound backend and returns the facet dict — the exact payload of
    ``CFAPipeline._sweep``, bit-identical across backends.  The layout, the
    interior-tile :class:`TransferPlan`, the modeled bandwidth
    (:meth:`report`) and the underlying :class:`CFAPipeline` ride along.
    """

    program: StencilProgram
    space: IterSpace
    target: Target
    n_ports: int
    executor: Executor
    pipeline: CFAPipeline
    layout: LayoutCandidate
    decision: LayoutDecision | None = dataclasses.field(default=None, repr=False)
    storage: str = "redundant"
    codec: BlockCodec | None = None  # storage="compressed" only
    # True when the distribute pass split the space over the port mesh
    distributed: bool = False
    # the per-pass lowering record (PassPipeline.run), attached by compile
    lowering: tuple = dataclasses.field(default=(), repr=False, compare=False)
    # the AnalysisReport of compile(..., verify=True); None when the
    # lowering ran without the analysis passes (diagnostics() then runs
    # the suite on demand)
    analysis: object = dataclasses.field(default=None, repr=False, compare=False)
    # compile(..., trace=True): every __call__ records a runtime trace
    trace_enabled: bool = dataclasses.field(default=False, repr=False, compare=False)
    # mutable holder for the most recent run's TraceRecorder (the stencil
    # itself is frozen); read it via last_trace()
    _trace_holder: list = dataclasses.field(default_factory=list, repr=False, compare=False)

    @property
    def backend(self) -> str:
        return self.executor.name

    def trace(self) -> "tuple[PassTrace, ...]":
        """The per-pass lowering trace: each stage's name, version, wall
        time and the state fields it changed (empty when this stencil was
        built outside a :class:`~repro.core.cfa.passes.PassPipeline`)."""
        return self.lowering

    def diagnostics(self):
        """The static-analysis report for this stencil.

        Returns the :class:`~repro.core.cfa.analysis.AnalysisReport`
        attached by ``compile(..., verify=True)``; when the lowering ran
        without the analysis passes, runs the default suite on demand
        (never raising — inspect ``report.errors`` / ``report.ok``)."""
        if self.analysis is not None:
            return self.analysis
        from . import analysis as _analysis

        return _analysis.verify(self, raise_on_error=False)

    @property
    def storage_map(self):
        """The irredundant ownership map (``None`` under redundant storage)."""
        return getattr(self.pipeline, "storage_map", None)

    def __call__(self, inputs: jnp.ndarray, *, dtype=jnp.float32,
                 trace: bool | None = None,
                 **opts) -> dict[int, jnp.ndarray]:
        """Run the stencil: live-in planes (w0, N1, ..) → facet storage.

        ``opts`` pass through to the backend (e.g. ``interpret=False`` for
        the Pallas kernels on a real TPU, ``use_kernel=True`` /
        ``mesh=...`` for the sharded backend).

        ``trace`` overrides the compile-time ``trace=`` knob for this run:
        ``True`` records a runtime :class:`~repro.core.cfa.obs.
        TraceRecorder` (spans + counters; read it via :meth:`last_trace`),
        ``False`` forces tracing off, ``None`` (default) follows the
        compile.  With tracing off no recorder is allocated — the
        executors pay one ``is None`` check per phase."""
        if trace is None:
            trace = self.trace_enabled
        if not trace:
            return self.executor.execute(
                self.pipeline, jnp.asarray(inputs),
                dtype=dtype, n_ports=self.n_ports, **opts,
            )
        from . import obs

        rec = obs.TraceRecorder(
            model=self.target.model,
            label=f"{self.program.name}@{'x'.join(map(str, self.space.sizes))}"
                  f"/{self.backend}",
        )
        rec.meta.update(backend=self.backend, storage=self.storage,
                        n_ports=self.n_ports, layout=self.layout.key)
        rec.add_pass_traces(self.lowering)
        prev = self.pipeline.recorder
        self.pipeline.recorder = rec
        try:
            out = self.executor.execute(
                self.pipeline, jnp.asarray(inputs),
                dtype=dtype, n_ports=self.n_ports, **opts,
            )
        finally:
            self.pipeline.recorder = prev
            self._trace_holder[:] = [rec]
        export_dir = obs.trace_export_dir()
        if export_dir is not None:
            rec.save_chrome(export_dir / f"{rec.label.replace('/', '_')}.json")
        return out

    def last_trace(self):
        """The :class:`~repro.core.cfa.obs.TraceRecorder` of the most
        recent traced run (compile spans folded in), or ``None`` when no
        traced run has happened yet."""
        return self._trace_holder[-1] if self._trace_holder else None

    def runtime_report(self, **kwargs):
        """Measured-vs-modeled attribution of this stencil's interior-tile
        plan (:func:`repro.core.cfa.obs.runtime_report`): per-facet /
        per-port observed time vs ``BurstModel.time``, ranked worst
        deviation first, each row carrying the static lint's fixit."""
        from .obs import runtime_report as _rr

        kwargs.setdefault("n_ports", self.n_ports)
        kwargs.setdefault("contiguity", self.layout.contiguity)
        kwargs.setdefault("overlap", self.executor.caps.overlap)
        return _rr(self.plan, self.target.model, **kwargs)

    @functools.cached_property
    def plan(self) -> TransferPlan:
        """The layout's interior-tile burst schedule (§V-C) under the bound
        storage discipline, computed once (the burst-run enumeration is
        exact, hence not free)."""
        return self.layout.plan(self.space, self.program,
                                storage=self.storage, codec=self.codec)

    def report(self, model: BurstModel | None = None, *,
               measured: bool = False, warmup: int | None = None,
               repeats: int | None = None,
               compute_s: float = 0.0,
               overlap: bool | None = None) -> BandwidthReport:
        """Modeled raw/effective bandwidth of one interior tile under the
        target's burst model (or ``model``); with ``n_ports > 1`` the plan
        is first repartitioned over the ports (best strategy, §VII).

        ``measured=True`` additionally times the exact burst schedule on
        this host (``calibrate.measure_plan``, warmup + median-of-k) and
        fills the report's ``measured_time_s`` and ``model_error`` — the
        modeled time's relative error against the measurement.  When the
        stencil came from an ``autotune(score="measured")`` decision whose
        winner is this layout, the decision's stored measurement is reused
        instead of re-timing.

        ``compute_s`` folds that much per-tile compute into the tile time;
        ``overlap`` (default: whether the bound backend declares
        ``ExecutorCaps.overlap``, i.e. True under ``backend="dataflow"``)
        picks the sequential sum or the Fig. 13 DATAFLOW pipelined
        composition — see ``BurstModel.time``.
        """
        m = model if model is not None else self.target.model
        if overlap is None:
            overlap = self.executor.caps.overlap
        plan = self.plan
        if self.n_ports > 1:
            plan = best_repartition(plan, self.n_ports, m,
                                    compute_s=compute_s, overlap=overlap)
        measured_s = None
        if measured:
            d = self.decision
            stored = d.best if (
                d is not None and d.score == "measured"
                and model is None and warmup is None and repeats is None
                and compute_s == 0.0 and overlap == d.overlap
                and d.best.candidate == self.layout
                and d.best.measured_time_s is not None
            ) else None
            if stored is not None:
                measured_s = stored.measured_time_s
            else:
                from .calibrate import measure_plan

                measured_s = measure_plan(plan, m, warmup=warmup,
                                          repeats=repeats,
                                          compute_s=compute_s,
                                          overlap=overlap)
        return BandwidthReport.evaluate(plan, m, measured_s=measured_s,
                                        compute_s=compute_s, overlap=overlap)

    def lower(self, backend: str) -> "CompiledStencil":
        """Rebind to another backend (re-validated), jit's ``lower`` spirit:
        same program, space, layout, storage and target — different
        executor."""
        ex = get_executor(backend)
        check_backend(ex, self.program, self.space, self.n_ports, self.storage)
        return dataclasses.replace(self, executor=ex)

    def reference(self, inputs: jnp.ndarray) -> jnp.ndarray:
        """The untiled oracle volume (``CFAPipeline.reference_volume``)."""
        return self.pipeline.reference_volume(jnp.asarray(inputs))

    def rehydrate(self, facets: dict[int, jnp.ndarray]) -> dict[int, jnp.ndarray]:
        """Refill non-owned facet slots from their owners, turning an
        irredundant/compressed payload into the redundant layout's payload
        (identity under ``storage="redundant"``) — the bit-exactness bridge
        the acceptance tests compare across disciplines."""
        if self.storage == "redundant":
            return facets
        return rehydrate_facets(facets, self.pipeline.storage_map)

    def describe(self) -> str:
        """One-paragraph human summary (layout, storage, backend, bw)."""
        r = self.report()
        ports = f" x{self.n_ports} ports" if self.n_ports > 1 else ""
        store = "" if self.storage == "redundant" else (
            f", {self.storage} storage (footprint {r.footprint})"
        )
        return (
            f"{self.program.name} @ {self.space.sizes} -> "
            f"layout {self.layout.key}{store}, backend {self.backend}, "
            f"target {self.target.name}{ports}: "
            f"{r.n_bursts} bursts/tile, redundancy {r.redundancy:.1%}, "
            f"effective bw {r.peak_fraction_effective:.1%} of one port's peak"
        )


# --------------------------------------------------------------------------
# compile
# --------------------------------------------------------------------------


def compile(
    program: StencilProgram | str,
    space: IterSpace | Sequence[int],
    *,
    target: Target | BurstModel | str = AXI_ZC706,
    n_ports: int = 1,
    layout: "str | LayoutCandidate | LayoutDecision | Sequence[int]" = "autotune",
    backend: str = "auto",
    storage: str = "redundant",
    codec: "BlockCodec | str | None" = None,
    overlap: bool = False,
    autotune_kwargs: Mapping | None = None,
    host_budget: int | None = None,
    halo_quantize: bool = False,
    passes: PassPipeline | None = None,
    verify: bool = False,
    trace: bool | None = None,
) -> CompiledStencil:
    """Compile ``program`` on ``space`` into an executable stencil.

    * ``target`` — a :class:`Target` (or registered name / BurstModel):
      the burst model scoring layouts plus the platform's port budget.
    * ``n_ports`` — memory ports to repartition facets over (§VII);
      validated against ``target.max_ports`` and the backend's capability.
    * ``layout`` — ``"autotune"`` (default: search the layout family under
      the target's model, co-tuned with the port repartition and scored
      under the requested storage discipline), ``"default"`` (the paper's
      layout at the program's default tile), a :class:`LayoutCandidate`, a
      previous :class:`LayoutDecision`, or a bare tile tuple (the paper's
      layout at that tile).
    * ``backend`` — a registered executor name, or ``"auto"``
      (:func:`repro.core.cfa.executors.select_backend`: sharded when
      ``n_ports > 1``, dataflow when ``overlap=True``, pallas on 3-D when
      it implements the storage, wavefront otherwise).
    * ``overlap`` — request a backend that pipelines fetch/compute/commit
      (Fig. 13 DATAFLOW).  With ``backend="auto"`` this selects
      ``dataflow``; an explicit sequential backend is rejected loudly.
      (To also *rank layouts* by overlapped time, pass
      ``autotune_kwargs=dict(overlap=True, compute_per_elem_s=...)``.)
    * ``storage`` — the facet storage discipline (Ferry 2024):
      ``"redundant"`` (the paper's duplicated layout, default),
      ``"irredundant"`` (each value stored exactly once; halo reads take
      the owner-facet indirection), or ``"compressed"`` (irredundant +
      fixed-ratio block ``codec``); validated against the backend's
      declared ``ExecutorCaps.storages``.
    * ``codec`` — :class:`BlockCodec` or registered name for
      ``storage="compressed"`` (default ``deltapack16``); rejected loudly
      with any other storage.
    * ``autotune_kwargs`` — passed through to :func:`autotune` when
      ``layout="autotune"`` (``seed``, ``budget``, ``footprint_weight``,
      ``cache_dir``, ...).
    * ``host_budget`` — per-host facet-memory budget in bytes for the
      ``distribute`` pass: a space whose estimated facet family exceeds it
      is split over enough ports that each shard fits (``n_ports`` is
      raised, backend auto-selection lowers to ``sharded``) instead of
      raising.  ``None`` (default) never splits.
    * ``halo_quantize`` — route every halo gather through the int8
      compression hooks of ``repro.distributed.compression`` (lossy halo
      traffic; off by default so results stay bit-exact).
    * ``passes`` — a custom :class:`~repro.core.cfa.passes.PassPipeline`
      to lower with instead of :func:`~repro.core.cfa.passes.
      default_pipeline` (stage order is validated at pipeline assembly).
    * ``verify`` — append the static analysis suite
      (:data:`~repro.core.cfa.analysis.DEFAULT_ANALYSES`) to the lowering:
      the single-assignment/coverage proofs, the overlap race check, the
      burst-efficiency lint and the contract checks run as read-only
      passes; any ERROR diagnostic raises :class:`~repro.core.cfa.
      analysis.VerificationError`, and the full report is surfaced as
      ``compiled.diagnostics()``.
    * ``trace`` — record a runtime :class:`~repro.core.cfa.obs.
      TraceRecorder` on every ``compiled(...)`` call (spans per tile
      phase, burst/byte counters, the lowering's :class:`PassTrace`
      stages folded into the same timeline); read it back via
      ``compiled.last_trace()`` and export Chrome trace JSON with
      ``tools/cfa_trace.py``.  Default ``None`` follows the
      ``REPRO_TRACE`` environment knob; tracing off allocates nothing on
      the hot path.
    """
    state = CompileState(
        program=program, space=space, target=target, n_ports=n_ports,
        layout=layout, backend=backend, storage=storage, codec=codec,
        overlap=overlap,
        autotune_kwargs=dict(autotune_kwargs) if autotune_kwargs else None,
        host_budget=host_budget, halo_quantize=halo_quantize,
    )
    pipe = default_pipeline() if passes is None else passes
    if verify:
        from . import analysis as _analysis

        pipe = _analysis.verify_pipeline(pipe)
    final = pipe.run(state)
    if final.compiled is None:
        raise RuntimeError(
            f"pipeline {pipe.names} completed without producing a "
            f"CompiledStencil"
        )
    if trace is None:
        from .obs import trace_enabled_by_env

        trace = trace_enabled_by_env()
    compiled = dataclasses.replace(final.compiled, lowering=final.trace,
                                   trace_enabled=bool(trace))
    if verify:
        report = _analysis.AnalysisReport(
            tuple(final.diagnostics),
            analyses=tuple(
                (p.name, p.version) for p in pipe.passes
                if isinstance(p, _analysis.AnalysisPass)
            ),
        )
        compiled = dataclasses.replace(compiled, analysis=report)
        if report.errors:
            raise _analysis.VerificationError(report)
    return compiled
