"""Burst-transfer plans: CFA vs the paper's three baselines, measured exactly.

Rather than *asserting* contiguity properties, this module enumerates the
exact set of linear addresses each scheme touches for a tile's flow-in reads
and flow-out writes, and counts maximal contiguous runs ("bursts").  This is
the measurement substrate behind the Fig. 15 reproduction:

* **CFA** (this paper): facet-allocated arrays; writes are full facet blocks
  (always one run each, by construction — verified, not assumed); reads are
  the needed flow-in addresses, host-assigned per the paper's rules, with a
  rectangular over-approximation mode mirroring §V-C1.
* **Original layout** (Bayliss et al. [16]): row-major canonical array,
  best-effort maximal runs, zero redundancy.
* **Bounding box** (Pouchet et al. [8]): row-major canonical array, one box
  around the flow-in (resp. flow-out), redundant transfer counted.
* **Data tiling** (Ozturk et al. [19]): block-major array; every touched data
  tile is moved in full, redundant transfer counted.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from .facets import FacetSpec, build_facet_specs, row_major_strides
from .irredundant import STORAGE_MODES, build_storage_map, owner_of
from .spaces import (
    Deps,
    IterSpace,
    Tiling,
    box_points,
    facet_widths,
    flow_in_points,
    flow_out_points,
    facet_points,
    tile_box,
)

__all__ = [
    "TransferPlan",
    "count_runs",
    "cfa_plan",
    "cfa_piece_census",
    "original_layout_plan",
    "bounding_box_plan",
    "data_tiling_plan",
    "interior_tile",
]


@dataclasses.dataclass(frozen=True)
class TransferPlan:
    """Aggregate burst statistics for one tile (reads + writes separable).

    ``read_run_hosts`` / ``write_run_hosts`` attribute each run to the facet
    array (by canonical axis) it is served from — the unit of contiguity a
    multi-port repartition moves around (``repro.core.cfa.multiport``).  The
    CFA plans fill them; the single-array baselines leave them ``None``
    (their runs can still be repartitioned at burst granularity).

    Storage accounting (the footprint axis of the Ferry-2024 follow-up):
    ``storage`` names the discipline the plan was derived under;
    ``stored_elems`` is how many storage slots one tile's writes persist
    (counting duplicates under ``"redundant"``, exactly-once otherwise);
    ``footprint`` is the whole-layout stored-element total across the space;
    ``codec_bits`` is the fixed-ratio compression width (``None`` =
    uncompressed) that ``BurstModel`` turns into reduced bytes per burst.
    """

    scheme: str
    read_runs: tuple[int, ...]  # lengths (elements) of each read burst
    write_runs: tuple[int, ...]
    read_useful: int  # elements actually needed
    write_useful: int
    read_run_hosts: tuple[int, ...] | None = None  # facet axis per read run
    write_run_hosts: tuple[int, ...] | None = None  # facet axis per write run
    storage: str = "redundant"
    stored_elems: int | None = None  # slots one tile's writes persist
    footprint: int | None = None  # whole-layout stored elements
    codec_bits: int | None = None  # fixed-ratio compression width

    def __post_init__(self) -> None:
        if self.read_run_hosts is not None and len(self.read_run_hosts) != len(self.read_runs):
            raise ValueError("read_run_hosts must attribute every read run")
        if self.write_run_hosts is not None and len(self.write_run_hosts) != len(self.write_runs):
            raise ValueError("write_run_hosts must attribute every write run")
        if self.storage not in STORAGE_MODES:
            raise ValueError(
                f"storage must be one of {STORAGE_MODES}: {self.storage!r}"
            )
        # negative/zero guards mirroring the PR 3 __post_init__ hardening:
        # a non-positive storage figure is always an accounting bug, never a
        # legal layout, so it must fail at construction rather than skew a
        # ranking downstream
        if self.stored_elems is not None and self.stored_elems <= 0:
            raise ValueError(
                f"stored_elems must be positive when set: {self.stored_elems}"
            )
        if self.footprint is not None and self.footprint <= 0:
            raise ValueError(
                f"footprint must be positive when set: {self.footprint}"
            )
        if self.codec_bits is not None and self.codec_bits <= 0:
            raise ValueError(
                f"codec_bits must be positive when set: {self.codec_bits}"
            )

    @property
    def n_read_bursts(self) -> int:
        return len(self.read_runs)

    @property
    def n_write_bursts(self) -> int:
        return len(self.write_runs)

    @property
    def n_bursts(self) -> int:
        return self.n_read_bursts + self.n_write_bursts

    @property
    def read_transferred(self) -> int:
        return int(sum(self.read_runs))

    @property
    def write_transferred(self) -> int:
        return int(sum(self.write_runs))

    @property
    def transferred(self) -> int:
        return self.read_transferred + self.write_transferred

    @property
    def useful(self) -> int:
        return self.read_useful + self.write_useful

    @property
    def redundancy(self) -> float:
        return 0.0 if not self.transferred else 1.0 - self.useful / self.transferred


def count_runs(addrs: np.ndarray) -> tuple[int, ...]:
    """Lengths of maximal runs of consecutive addresses (sorted, deduped)."""
    if addrs.size == 0:
        return ()
    a = np.unique(np.asarray(addrs, dtype=np.int64))
    breaks = np.flatnonzero(np.diff(a) != 1)
    starts = np.concatenate(([0], breaks + 1))
    ends = np.concatenate((breaks, [a.size - 1]))
    return tuple(int(e - s + 1) for s, e in zip(starts, ends))


def _boxed_runs(addrs: np.ndarray, gap: int) -> tuple[tuple[int, ...], int]:
    """Rectangular over-approximation (§V-C1): cluster the needed addresses,
    close gaps smaller than ``gap`` (one burst per cluster), and return
    (run lengths, transferred elements).  Redundancy = transferred - needed.
    """
    if addrs.size == 0:
        return (), 0
    a = np.unique(np.asarray(addrs, dtype=np.int64))
    breaks = np.flatnonzero(np.diff(a) > gap)
    starts = np.concatenate(([0], breaks + 1))
    ends = np.concatenate((breaks, [a.size - 1]))
    runs = tuple(int(a[e] - a[s] + 1) for s, e in zip(starts, ends))
    return runs, int(sum(runs))


def interior_tile(space: IterSpace, tiling: Tiling) -> tuple[int, ...]:
    """A representative interior tile (full flow-in/out on every side)."""
    nt = tiling.num_tiles(space)
    return tuple(min(1, n - 1) for n in nt)


# --------------------------------------------------------------------------
# CFA
# --------------------------------------------------------------------------


def _assign_hosts(
    pts: np.ndarray,
    tile: Sequence[int],
    tiling: Tiling,
    widths: Sequence[int],
    specs: Mapping[int, FacetSpec],
) -> dict[int, np.ndarray]:
    """Assign each flow-in point to the facet array it is read from.

    Implements the paper's choices, generalised to any dimension: single-axis
    pieces come from their own facet; a level-l piece (1 < l < d, crossing l
    axes) comes from a candidate facet whose extension direction is another
    crossed axis, so it merges with that host's lower-level run (§IV-H); the
    level-d corner comes from the facet minimising the number of leftover
    runs (§IV-I picks the facet whose extension axis has the thinnest width —
    for time-skewed stencils that is the time axis).  For d >= 4 some mid-
    level pieces have *no* candidate whose extension direction is crossed
    (§IV-J): they fall back to an arbitrary candidate and cost extra bursts,
    which the exact run counting below measures rather than hides
    (``cfa_piece_census`` reports the accounting).
    """
    d = tiling.ndim
    t = np.asarray(tiling.sizes, dtype=np.int64)
    q0 = np.asarray(tile, dtype=np.int64)
    qs = pts // t  # tile coords per point
    delta = qs - q0  # components in {0,-1} under the paper's hypotheses
    # candidate mask: point in facet_k domain AND crossing along k
    cand = np.zeros((len(pts), d), dtype=bool)
    for k, spec in specs.items():
        cand[:, k] = spec.domain_mask(pts) & (delta[:, k] < 0)
    out: dict[int, list[np.ndarray]] = {k: [] for k in specs}
    levels = (delta < 0).sum(axis=1)
    for lvl in np.unique(levels):
        sel = levels == lvl
        sub_cand = cand[sel]
        host = np.full(sel.sum(), -1, dtype=np.int64)
        sub_delta = delta[sel]
        if lvl == 1:
            host = np.argmax(sub_cand, axis=1)
        elif lvl < d:
            # prefer a host h whose extension direction is another crossed
            # axis: the piece then merges with h's lower-level facet read.
            for h in specs:
                c = specs[h].ext_dir
                ok = sub_cand[:, h] & (sub_delta[:, c] < 0) & (host < 0)
                host[ok] = h
            # fallback (non-mergeable piece, paper §IV-J): first candidate
            rem = host < 0
            host[rem] = np.argmax(sub_cand[rem], axis=1)
        else:
            # the level-d corner: host minimising leftover runs = thinnest ext
            order = sorted(specs, key=lambda h: (widths[specs[h].ext_dir], -h))
            for h in order:
                ok = sub_cand[:, h] & (host < 0)
                host[ok] = h
            rem = host < 0
            host[rem] = np.argmax(sub_cand[rem], axis=1)
        if not bool(sub_cand[np.arange(len(host)), host].all()):
            raise AssertionError(
                "flow-in point with no facet candidate — contradicts the "
                "appendix coverage proof; layout bug"
            )
        idx = np.flatnonzero(sel)
        for h in specs:
            out[h].append(idx[host == h])
    return {h: np.concatenate(v) if v else np.empty(0, dtype=np.int64) for h, v in out.items()}


def cfa_piece_census(
    space: IterSpace,
    deps: Deps,
    tiling: Tiling,
    tile: Sequence[int] | None = None,
    *,
    ext_dirs: Mapping[int, int] | None = None,
) -> dict:
    """§IV-D/H/J accounting of one tile's flow-in pieces, for the paper's
    final (intra-tile contiguity) layout family.

    A *piece* is the set of flow-in points sharing a backward neighbour tile
    (offset ``delta`` in {0,-1}^d, §IV-D) and an assigned host facet.
    Returns a dict with

    * ``pieces_by_level`` — piece count per neighbour level (number of
      crossed axes),
    * ``merged``          — pieces that extend an existing burst: level-1
      base reads, mid-level pieces whose host's extension direction is a
      crossed axis (§IV-H), and the level-d corner, whose crossed set
      contains every axis and which intra-tile contiguity makes a block
      suffix (§IV-I),
    * ``unmergeable``     — pieces with no such host.  Impossible for
      d <= 3 (the paper's construction reaches d+1 read bursts); generally
      unavoidable for d >= 4 (§IV-J) — each one starts an extra read burst,
      which ``cfa_plan``'s exact run counting measures.

    The merge model above describes the intra-tile layout only — weaker
    contiguity levels merge by address coincidence, not by construction, so
    their burst counts must be read off ``cfa_plan`` directly.
    """
    if tile is None:
        tile = interior_tile(space, tiling)
    widths = facet_widths(deps)
    specs = build_facet_specs(space, deps, tiling, ext_dirs=ext_dirs,
                              contiguity="intra-tile")
    fin = flow_in_points(space, deps, tiling, tile)
    hosts = _assign_hosts(fin, tile, tiling, widths, specs)
    d = tiling.ndim
    t = np.asarray(tiling.sizes, dtype=np.int64)
    q0 = np.asarray(tile, dtype=np.int64)
    by_level: dict[int, int] = {}
    merged = unmergeable = 0
    for k, idx in hosts.items():
        if idx.size == 0:
            continue
        delta = fin[idx] // t - q0
        for dlt in np.unique(delta, axis=0):
            lvl = int((dlt < 0).sum())
            by_level[lvl] = by_level.get(lvl, 0) + 1
            # the level-d corner crosses every axis, so ext_crossed also
            # covers it (§IV-I: the corner is a suffix of the host's block)
            ext_crossed = dlt[specs[k].ext_dir] < 0
            if lvl == 1 or ext_crossed:
                merged += 1
            else:
                unmergeable += 1
    return {
        "pieces_by_level": dict(sorted(by_level.items())),
        "merged": merged,
        "unmergeable": unmergeable,
    }


def _owner_hosts(
    pts: np.ndarray, specs: Mapping[int, FacetSpec]
) -> dict[int, np.ndarray]:
    """Irredundant read resolution: each point comes from the one facet that
    stores it (``irredundant.owner_of``) — no host choice exists."""
    own = owner_of(specs, pts)
    if (own < 0).any():
        raise AssertionError(
            "flow-in point outside every facet domain — contradicts the "
            "appendix coverage proof; layout bug"
        )
    return {k: np.flatnonzero(own == k) for k in specs}


def cfa_plan(
    space: IterSpace,
    deps: Deps,
    tiling: Tiling,
    tile: Sequence[int] | None = None,
    *,
    boxed: bool = True,
    ext_dirs: Mapping[int, int] | None = None,
    contiguity: str = "intra-tile",
    storage: str = "redundant",
    codec=None,
) -> TransferPlan:
    """CFA transfer plan for one tile.

    Writes: under ``storage="redundant"`` every facet block in full — one
    burst per facet by construction; under ``"irredundant"``/``"compressed"``
    only the owned slots (each value stored exactly once), whose runs the
    exact counting measures — deduplication trades write redundancy for
    extra write bursts, and the plan prices both sides honestly.
    Reads: flow-in points fetched from their host facets (redundant: the
    paper's §IV-H/I host assignment; irredundant: the owner facet — there
    is no choice); ``boxed`` applies the paper's rectangular
    over-approximation (merged bursts + guards), otherwise exact guarded
    runs are counted.  ``ext_dirs``/``contiguity`` select a layout variant
    (see ``build_facet_specs``); the defaults are the paper's final layout,
    which the autotuner treats as one candidate among the whole family.
    ``codec`` (``storage="compressed"`` only) sets ``codec_bits`` so
    ``BurstModel`` times the bursts at the fixed compression ratio.
    """
    if storage not in STORAGE_MODES:
        raise ValueError(f"storage must be one of {STORAGE_MODES}: {storage!r}")
    if codec is not None and storage != "compressed":
        raise ValueError(
            f'a codec only applies to storage="compressed", not {storage!r}'
        )
    if tile is None:
        tile = interior_tile(space, tiling)
    widths = facet_widths(deps)
    specs = build_facet_specs(space, deps, tiling, ext_dirs=ext_dirs, contiguity=contiguity)
    smap = build_storage_map(specs) if storage != "redundant" else None

    fin = flow_in_points(space, deps, tiling, tile)
    if storage == "redundant":
        hosts = _assign_hosts(fin, tile, tiling, widths, specs)
    else:
        hosts = _owner_hosts(fin, specs)
    read_runs: list[int] = []
    read_hosts: list[int] = []
    for k, idx in hosts.items():
        if idx.size == 0:
            continue
        addrs = specs[k].offsets(fin[idx])
        if boxed:
            runs, _ = _boxed_runs(addrs, gap=specs[k].block_elems)
        else:
            runs = count_runs(addrs)
        read_runs.extend(runs)
        read_hosts.extend([k] * len(runs))

    fout = flow_out_points(space, deps, tiling, tile)
    write_runs: list[int] = []
    write_hosts: list[int] = []
    for k, spec in specs.items():
        fpts = facet_points(tiling, widths, k, tile)
        if storage != "redundant":
            fpts = fpts[owner_of(specs, fpts) == k]
            if len(fpts) == 0:
                continue  # facet fully owned by lower axes (w_j == t_j)
            runs = count_runs(spec.offsets(fpts))
        else:
            runs = count_runs(spec.offsets(fpts))
            assert len(runs) == 1, "full-tile contiguity violated — layout bug"
        write_runs.extend(runs)
        write_hosts.extend([k] * len(runs))

    if storage == "redundant":
        stored = sum(s.block_elems for s in specs.values())
        footprint = sum(s.size for s in specs.values())
        codec_bits = None
    else:
        stored = sum(smap.owned_per_block.values())
        footprint = smap.stored_elems
        codec_bits = None
        if storage == "compressed":
            from .compress import get_codec

            bits = get_codec(codec).bits
            codec_bits = bits if bits else None  # "raw" models as uncompressed
    return TransferPlan(
        scheme="cfa" if boxed else "cfa-exact",
        read_runs=tuple(read_runs),
        write_runs=tuple(write_runs),
        read_useful=int(len(fin)),
        write_useful=int(len(fout)),
        read_run_hosts=tuple(read_hosts),
        write_run_hosts=tuple(write_hosts),
        storage=storage,
        stored_elems=int(stored),
        footprint=int(footprint),
        codec_bits=codec_bits,
    )


# --------------------------------------------------------------------------
# Baselines (row-major canonical / block-major layouts)
# --------------------------------------------------------------------------


def _row_major_offsets(pts: np.ndarray, sizes: Sequence[int]) -> np.ndarray:
    return np.atleast_2d(pts) @ row_major_strides(sizes)


def original_layout_plan(
    space: IterSpace, deps: Deps, tiling: Tiling, tile: Sequence[int] | None = None
) -> TransferPlan:
    """Best-effort bursts under the untouched row-major layout (Bayliss [16])."""
    if tile is None:
        tile = interior_tile(space, tiling)
    fin = flow_in_points(space, deps, tiling, tile)
    fout = flow_out_points(space, deps, tiling, tile)
    rr = count_runs(_row_major_offsets(fin, space.sizes))
    wr = count_runs(_row_major_offsets(fout, space.sizes))
    return TransferPlan("original", rr, wr, int(len(fin)), int(len(fout)),
                        footprint=int(np.prod(space.sizes, dtype=np.int64)))


def bounding_box_plan(
    space: IterSpace, deps: Deps, tiling: Tiling, tile: Sequence[int] | None = None
) -> TransferPlan:
    """Rectangular bounding box of flow-in / flow-out (Pouchet et al. [8])."""
    if tile is None:
        tile = interior_tile(space, tiling)

    def _box_runs(pts: np.ndarray) -> tuple[int, ...]:
        if pts.size == 0:
            return ()
        lo, hi = pts.min(axis=0), pts.max(axis=0) + 1
        return count_runs(_row_major_offsets(box_points(lo, hi), space.sizes))

    fin = flow_in_points(space, deps, tiling, tile)
    fout = flow_out_points(space, deps, tiling, tile)
    return TransferPlan("bbox", _box_runs(fin), _box_runs(fout),
                        int(len(fin)), int(len(fout)),
                        footprint=int(np.prod(space.sizes, dtype=np.int64)))


def data_tiling_plan(
    space: IterSpace,
    deps: Deps,
    tiling: Tiling,
    tile: Sequence[int] | None = None,
    *,
    block: Sequence[int] | None = None,
) -> TransferPlan:
    """Block-major data tiling; touched blocks moved whole (Ozturk et al. [19]).

    ``block`` defaults to the iteration tile sizes (the paper reports the best
    performing block <= iteration tile size; callers sweep candidates).
    """
    if tile is None:
        tile = interior_tile(space, tiling)
    blk = np.asarray(block if block is not None else tiling.sizes, dtype=np.int64)
    nb = tuple(-(-n // b) for n, b in zip(space.sizes, blk))
    layout_sizes = tuple(nb) + tuple(int(b) for b in blk)

    def _block_runs(pts: np.ndarray) -> tuple[int, ...]:
        if pts.size == 0:
            return ()
        blocks = np.unique(pts // blk, axis=0)
        all_pts = []
        for qb in blocks:
            lo = qb * blk
            hi = np.minimum(lo + blk, space.sizes)
            bpts = box_points(lo, hi)
            idx = np.concatenate([qb[None, :].repeat(len(bpts), 0), bpts % blk], axis=1)
            all_pts.append(idx)
        return count_runs(_row_major_offsets(np.concatenate(all_pts), layout_sizes))

    fin = flow_in_points(space, deps, tiling, tile)
    fout = flow_out_points(space, deps, tiling, tile)
    return TransferPlan(
        f"data-tiling{tuple(int(b) for b in blk)}",
        _block_runs(fin),
        _block_runs(fout),
        int(len(fin)),
        int(len(fout)),
        footprint=int(np.prod(layout_sizes, dtype=np.int64)),
    )
