"""The paper's Table I benchmark suite as uniform-dependence program specs.

Each program is given in the *post-skew normal form* the paper assumes
(§IV-E: "we expect such a pre-processing to have been done"): a rectangular
iteration space with all dependence vectors backwards in every dimension.
The skew applied to each classic benchmark is recorded in ``skew`` so that
tests can relate the skewed recurrence back to the textbook stencil.

Iteration semantics: axis 0 is the (skewed) time axis; ``plane_update``
computes the value plane at time ``s`` from the ``depth`` previous planes,
where each previous plane is passed *with its backward halo attached* (halo
width ``w_a`` on the low side of each spatial axis ``a``).  Out-of-space
reads are zero (Dirichlet boundary), making the recurrence total on the
rectangular space.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np

from .spaces import Deps, IterSpace, Tiling, facet_widths

__all__ = ["StencilProgram", "PROGRAMS", "get_program"]


@dataclasses.dataclass(frozen=True)
class StencilProgram:
    """A uniform-dependence benchmark in post-skew normal form."""

    name: str
    deps: Deps
    default_tile: tuple[int, ...]
    paper_tiles: tuple[tuple[int, ...], ...]  # Table I tile-size sweep corners
    equivalent_app: str
    skew: tuple[int, ...]  # spatial skew factors applied per spatial axis
    # update: (prev_planes [depth][spatial+halo], widths) -> new plane [spatial]
    plane_update: Callable[[Sequence[jnp.ndarray], tuple[int, ...]], jnp.ndarray]

    @property
    def ndim(self) -> int:
        return self.deps.ndim

    @property
    def widths(self) -> tuple[int, ...]:
        return facet_widths(self.deps)

    def space(self, sizes: Sequence[int]) -> IterSpace:
        return IterSpace(tuple(sizes))

    def tiling(self, sizes: Sequence[int] | None = None) -> Tiling:
        return Tiling(tuple(sizes) if sizes is not None else self.default_tile)


def _shift2(prev: jnp.ndarray, di: int, dj: int, w: tuple[int, ...]) -> jnp.ndarray:
    """Read ``prev`` (with low-side halo (w1, w2)) at spatial offset (di, dj),
    di, dj <= 0, returning the interior-sized plane."""
    w1, w2 = w[1], w[2]
    t1 = prev.shape[0] - w1
    t2 = prev.shape[1] - w2
    return jnp.asarray(prev)[w1 + di : w1 + di + t1, w2 + dj : w2 + dj + t2]


def _jacobi_update(offsets: Sequence[tuple[int, int]], coeffs: Sequence[float]):
    def update(prev_planes: Sequence[jnp.ndarray], w: tuple[int, ...]) -> jnp.ndarray:
        p = prev_planes[-1]  # plane s-1 (depth-1 history used by jacobi family)
        acc = None
        for (di, dj), c in zip(offsets, coeffs):
            v = _shift2(p, di, dj, w) * float(c)  # python float: no promotion
            acc = v if acc is None else acc + v
        return acc

    return update


# --- jacobi2d5p: 5-point Laplace; skew (1,1) -> deps (-1, di-1, dj-1) -------
_J5_OFF = [(-1, -1), (0, -1), (-2, -1), (-1, 0), (-1, -2)]
_J5 = Deps(tuple((-1, a, b) for a, b in _J5_OFF))

# --- jacobi2d9p: 3x3 convolution; skew (1,1) --------------------------------
_J9_OFF = [(a - 1, b - 1) for a in (-1, 0, 1) for b in (-1, 0, 1)]
_J9 = Deps(tuple((-1, a, b) for a, b in _J9_OFF))

# --- gaussian: 5x5 blur; skew (2,2) -> 25 deps ------------------------------
_GA_OFF = [(a - 2, b - 2) for a in range(-2, 3) for b in range(-2, 3)]
_GA = Deps(tuple((-1, a, b) for a, b in _GA_OFF))
_GA_K = np.outer([1, 4, 6, 4, 1], [1, 4, 6, 4, 1]).astype(np.float64)
_GA_K /= _GA_K.sum()

# --- smith-waterman-3seq: 3-sequence alignment; skew s = i+j+k --------------
# original deps: the 7 nonzero corners of {0,-1}^3; skewed by s = i+j+k they
# become (sum, j, k)-space vectors, all strictly backwards on axis 0.
_SW_RAW = [
    (-1, 0, 0), (0, -1, 0), (0, 0, -1),
    (-1, -1, 0), (-1, 0, -1), (0, -1, -1), (-1, -1, -1),
]
_SW = Deps(tuple((a + b + c, b, c) for a, b, c in _SW_RAW))


def _sw_update(prev_planes: Sequence[jnp.ndarray], w: tuple[int, ...]) -> jnp.ndarray:
    """Max-plus alignment recurrence on the skewed lattice (depth 3)."""
    # deps at axis-0 distance 1: (j,k) offsets (0,0),(-1,0),(0,-1)
    # distance 2: (-1,0),(0,-1),(-1,-1);   distance 3: (-1,-1)
    p1, p2, p3 = prev_planes[-1], prev_planes[-2], prev_planes[-3]
    cands = [
        _shift2(p1, 0, 0, w) + 1.0,
        _shift2(p1, -1, 0, w) + 1.0,
        _shift2(p1, 0, -1, w) + 1.0,
        _shift2(p2, -1, 0, w) + 2.0,
        _shift2(p2, 0, -1, w) + 2.0,
        _shift2(p2, -1, -1, w) + 2.0,
        _shift2(p3, -1, -1, w) + 3.0,
    ]
    out = cands[0]
    for c in cands[1:]:
        out = jnp.maximum(out, c)
    return out


def _gol_update(prev_planes: Sequence[jnp.ndarray], w: tuple[int, ...]) -> jnp.ndarray:
    """2nd-order finite difference flavoured 9-point update (jacobi2d9p-gol)."""
    p = prev_planes[-1]
    neigh = None
    for (di, dj) in _J9_OFF:
        v = _shift2(p, di, dj, w)
        neigh = v if neigh is None else neigh + v
    centre = _shift2(p, -1, -1, w)
    return 2.0 * centre - neigh / 9.0


PROGRAMS: dict[str, StencilProgram] = {
    "jacobi2d5p": StencilProgram(
        name="jacobi2d5p",
        deps=_J5,
        default_tile=(16, 16, 16),
        paper_tiles=((16, 16, 16), (32, 32, 32), (64, 64, 64), (128, 128, 128)),
        equivalent_app="Laplace equation",
        skew=(1, 1),
        plane_update=_jacobi_update(_J5_OFF, [0.2] * 5),
    ),
    "jacobi2d9p": StencilProgram(
        name="jacobi2d9p",
        deps=_J9,
        default_tile=(16, 16, 16),
        paper_tiles=((16, 16, 16), (32, 32, 32), (64, 64, 64), (128, 128, 128)),
        equivalent_app="3x3 convolution",
        skew=(1, 1),
        plane_update=_jacobi_update(_J9_OFF, [1.0 / 9.0] * 9),
    ),
    "jacobi2d9p-gol": StencilProgram(
        name="jacobi2d9p-gol",
        deps=_J9,
        default_tile=(16, 16, 16),
        paper_tiles=((16, 16, 16), (32, 32, 32), (64, 64, 64), (128, 128, 128)),
        equivalent_app="2nd-order finite difference",
        skew=(1, 1),
        plane_update=_gol_update,
    ),
    "gaussian": StencilProgram(
        name="gaussian",
        deps=_GA,
        default_tile=(4, 16, 16),
        paper_tiles=((4, 16, 16), (4, 32, 32), (4, 64, 64), (4, 128, 128)),
        equivalent_app="5x5 Gaussian Blur",
        skew=(2, 2),
        plane_update=_jacobi_update(_GA_OFF, list(_GA_K.ravel())),
    ),
    "smith-waterman-3seq": StencilProgram(
        name="smith-waterman-3seq",
        deps=_SW,
        default_tile=(16, 16, 16),
        paper_tiles=((16, 16, 16), (32, 32, 32), (64, 64, 64), (128, 128, 128)),
        equivalent_app="Alignment of 3 sequences",
        skew=(0, 0),  # skew folded into axis 0 = i+j+k
        plane_update=_sw_update,
    ),
}


def get_program(name: str) -> StencilProgram:
    try:
        return PROGRAMS[name]
    except KeyError:
        raise KeyError(f"unknown benchmark {name!r}; have {sorted(PROGRAMS)}") from None
