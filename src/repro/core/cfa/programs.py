"""The paper's Table I benchmark suite as uniform-dependence program specs.

Each program is given in the *post-skew normal form* the paper assumes
(§IV-E: "we expect such a pre-processing to have been done"): a rectangular
iteration space with all dependence vectors backwards in every dimension.
The skew applied to each classic benchmark is recorded in ``skew`` so that
tests can relate the skewed recurrence back to the textbook stencil.

Iteration semantics: axis 0 is the (skewed) time axis; ``plane_update``
computes the value plane at time ``s`` from the ``depth`` previous planes,
where each previous plane is passed *with its backward halo attached* (halo
width ``w_a`` on the low side of each spatial axis ``a``).  Out-of-space
reads are zero (Dirichlet boundary), making the recurrence total on the
rectangular space.

The suite is dimension-generic: a program's iteration space is d-dimensional
(time + d-1 spatial axes) and planes are (d-1)-dimensional.  Besides the 3-D
Table I benchmarks, the registry carries ``heat1d`` (a 1-D heat equation as
a 2-D tiled space) and ``heat3d`` (a 3-D spatial heat equation as a 4-D
space — the §IV-J regime where some k-th-level neighbours no longer merge
into one burst).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np

from .spaces import Deps, IterSpace, Tiling, facet_widths

__all__ = ["StencilProgram", "PROGRAMS", "get_program"]


@dataclasses.dataclass(frozen=True)
class StencilProgram:
    """A uniform-dependence benchmark in post-skew normal form."""

    name: str
    deps: Deps
    default_tile: tuple[int, ...]
    paper_tiles: tuple[tuple[int, ...], ...]  # Table I tile-size sweep corners
    equivalent_app: str
    skew: tuple[int, ...]  # spatial skew factors applied per spatial axis
    # update: (prev_planes [depth][spatial+halo], widths) -> new plane [spatial]
    plane_update: Callable[[Sequence[jnp.ndarray], tuple[int, ...]], jnp.ndarray]

    @property
    def ndim(self) -> int:
        return self.deps.ndim

    @property
    def widths(self) -> tuple[int, ...]:
        return facet_widths(self.deps)

    def space(self, sizes: Sequence[int]) -> IterSpace:
        return IterSpace(tuple(sizes))

    def tiling(self, sizes: Sequence[int] | None = None) -> Tiling:
        return Tiling(tuple(sizes) if sizes is not None else self.default_tile)


def _shiftn(prev: jnp.ndarray, offs: Sequence[int], w: tuple[int, ...]) -> jnp.ndarray:
    """Read ``prev`` (a (d-1)-D plane with low-side halo ``w[1:]``) at the
    spatial offset vector ``offs`` (all components <= 0), returning the
    interior-sized plane.  Dimension-generic ``_shift2``."""
    p = jnp.asarray(prev)
    sl = tuple(
        slice(w[a + 1] + o, w[a + 1] + o + (p.shape[a] - w[a + 1]))
        for a, o in enumerate(offs)
    )
    return p[sl]


def _shift2(prev: jnp.ndarray, di: int, dj: int, w: tuple[int, ...]) -> jnp.ndarray:
    """Read ``prev`` (with low-side halo (w1, w2)) at spatial offset (di, dj),
    di, dj <= 0, returning the interior-sized plane."""
    return _shiftn(prev, (di, dj), w)


def _jacobi_update(offsets: Sequence[tuple[int, ...]], coeffs: Sequence[float]):
    """Depth-1 weighted-sum update over spatial offsets, any dimension."""
    def update(prev_planes: Sequence[jnp.ndarray], w: tuple[int, ...]) -> jnp.ndarray:
        p = prev_planes[-1]  # plane s-1 (depth-1 history used by jacobi family)
        acc = None
        for off, c in zip(offsets, coeffs):
            v = _shiftn(p, off, w) * float(c)  # python float: no promotion
            acc = v if acc is None else acc + v
        return acc

    return update


# --- jacobi2d5p: 5-point Laplace; skew (1,1) -> deps (-1, di-1, dj-1) -------
_J5_OFF = [(-1, -1), (0, -1), (-2, -1), (-1, 0), (-1, -2)]
_J5 = Deps(tuple((-1, a, b) for a, b in _J5_OFF))

# --- jacobi2d9p: 3x3 convolution; skew (1,1) --------------------------------
_J9_OFF = [(a - 1, b - 1) for a in (-1, 0, 1) for b in (-1, 0, 1)]
_J9 = Deps(tuple((-1, a, b) for a, b in _J9_OFF))

# --- gaussian: 5x5 blur; skew (2,2) -> 25 deps ------------------------------
_GA_OFF = [(a - 2, b - 2) for a in range(-2, 3) for b in range(-2, 3)]
_GA = Deps(tuple((-1, a, b) for a, b in _GA_OFF))
_GA_K = np.outer([1, 4, 6, 4, 1], [1, 4, 6, 4, 1]).astype(np.float64)
_GA_K /= _GA_K.sum()

# --- heat1d: 1-D heat equation as a 2-D tiled space; skew (1) ---------------
# textbook: u[t,x] = a*u[t-1,x-1] + (1-2a)*u[t-1,x] + a*u[t-1,x+1]; skewing
# x by t maps the offsets dx in (-1, 0, 1) to backward vectors (-1, dx-1).
_H1_OFF = [(-2,), (-1,), (0,)]
_H1 = Deps(tuple((-1, *o) for o in _H1_OFF))
_H1_A = 0.25  # diffusion number; coeffs (a, 1-2a, a)

# --- heat3d: 3-D spatial heat equation as a 4-D space; skew (1,1,1) ---------
# 7-point stencil: centre + one neighbour per spatial axis and direction;
# skewing each spatial axis by t maps offset d in {-1,0,1} to d-1 on that
# axis.  This is the d >= 4 regime of §IV-J: level-2/3 neighbour pieces
# whose crossed axes miss every candidate facet's extension direction can
# no longer merge into an existing burst.
_H3_OFF = [(0, 0, 0)] + [
    tuple(s if a == ax else 0 for a in range(3))
    for ax in range(3) for s in (-1, 1)
]
_H3 = Deps(tuple((-1, *(c - 1 for c in o)) for o in _H3_OFF))
_H3_A = 0.1  # coeffs: centre 1-6a, each neighbour a


# --- smith-waterman-3seq: 3-sequence alignment; skew s = i+j+k --------------
# original deps: the 7 nonzero corners of {0,-1}^3; skewed by s = i+j+k they
# become (sum, j, k)-space vectors, all strictly backwards on axis 0.
_SW_RAW = [
    (-1, 0, 0), (0, -1, 0), (0, 0, -1),
    (-1, -1, 0), (-1, 0, -1), (0, -1, -1), (-1, -1, -1),
]
_SW = Deps(tuple((a + b + c, b, c) for a, b, c in _SW_RAW))


def _sw_update(prev_planes: Sequence[jnp.ndarray], w: tuple[int, ...]) -> jnp.ndarray:
    """Max-plus alignment recurrence on the skewed lattice (depth 3)."""
    # deps at axis-0 distance 1: (j,k) offsets (0,0),(-1,0),(0,-1)
    # distance 2: (-1,0),(0,-1),(-1,-1);   distance 3: (-1,-1)
    p1, p2, p3 = prev_planes[-1], prev_planes[-2], prev_planes[-3]
    cands = [
        _shift2(p1, 0, 0, w) + 1.0,
        _shift2(p1, -1, 0, w) + 1.0,
        _shift2(p1, 0, -1, w) + 1.0,
        _shift2(p2, -1, 0, w) + 2.0,
        _shift2(p2, 0, -1, w) + 2.0,
        _shift2(p2, -1, -1, w) + 2.0,
        _shift2(p3, -1, -1, w) + 3.0,
    ]
    out = cands[0]
    for c in cands[1:]:
        out = jnp.maximum(out, c)
    return out


def _gol_update(prev_planes: Sequence[jnp.ndarray], w: tuple[int, ...]) -> jnp.ndarray:
    """2nd-order finite difference flavoured 9-point update (jacobi2d9p-gol)."""
    p = prev_planes[-1]
    neigh = None
    for (di, dj) in _J9_OFF:
        v = _shift2(p, di, dj, w)
        neigh = v if neigh is None else neigh + v
    centre = _shift2(p, -1, -1, w)
    return 2.0 * centre - neigh / 9.0


PROGRAMS: dict[str, StencilProgram] = {
    "jacobi2d5p": StencilProgram(
        name="jacobi2d5p",
        deps=_J5,
        default_tile=(16, 16, 16),
        paper_tiles=((16, 16, 16), (32, 32, 32), (64, 64, 64), (128, 128, 128)),
        equivalent_app="Laplace equation",
        skew=(1, 1),
        plane_update=_jacobi_update(_J5_OFF, [0.2] * 5),
    ),
    "jacobi2d9p": StencilProgram(
        name="jacobi2d9p",
        deps=_J9,
        default_tile=(16, 16, 16),
        paper_tiles=((16, 16, 16), (32, 32, 32), (64, 64, 64), (128, 128, 128)),
        equivalent_app="3x3 convolution",
        skew=(1, 1),
        plane_update=_jacobi_update(_J9_OFF, [1.0 / 9.0] * 9),
    ),
    "jacobi2d9p-gol": StencilProgram(
        name="jacobi2d9p-gol",
        deps=_J9,
        default_tile=(16, 16, 16),
        paper_tiles=((16, 16, 16), (32, 32, 32), (64, 64, 64), (128, 128, 128)),
        equivalent_app="2nd-order finite difference",
        skew=(1, 1),
        plane_update=_gol_update,
    ),
    "gaussian": StencilProgram(
        name="gaussian",
        deps=_GA,
        default_tile=(4, 16, 16),
        paper_tiles=((4, 16, 16), (4, 32, 32), (4, 64, 64), (4, 128, 128)),
        equivalent_app="5x5 Gaussian Blur",
        skew=(2, 2),
        plane_update=_jacobi_update(_GA_OFF, list(_GA_K.ravel())),
    ),
    "smith-waterman-3seq": StencilProgram(
        name="smith-waterman-3seq",
        deps=_SW,
        default_tile=(16, 16, 16),
        paper_tiles=((16, 16, 16), (32, 32, 32), (64, 64, 64), (128, 128, 128)),
        equivalent_app="Alignment of 3 sequences",
        skew=(0, 0),  # skew folded into axis 0 = i+j+k
        plane_update=_sw_update,
    ),
    # -- beyond Table I: non-3-D workloads (the N-D executor path) ----------
    "heat1d": StencilProgram(
        name="heat1d",
        deps=_H1,
        default_tile=(8, 8),
        paper_tiles=((8, 8), (16, 16), (32, 32), (64, 64)),
        equivalent_app="1-D heat equation (2-D tiled space)",
        skew=(1,),
        plane_update=_jacobi_update(_H1_OFF, [_H1_A, 1 - 2 * _H1_A, _H1_A]),
    ),
    "heat3d": StencilProgram(
        name="heat3d",
        deps=_H3,
        default_tile=(4, 4, 4, 4),
        paper_tiles=((4, 4, 4, 4), (2, 4, 4, 4), (4, 8, 8, 8)),
        equivalent_app="3-D heat equation (4-D tiled space, §IV-J regime)",
        skew=(1, 1, 1),
        plane_update=_jacobi_update(
            [tuple(c - 1 for c in o) for o in _H3_OFF],
            [1 - 6 * _H3_A] + [_H3_A] * 6,
        ),
    ),
}


def get_program(name: str) -> StencilProgram:
    try:
        return PROGRAMS[name]
    except KeyError:
        raise KeyError(f"unknown benchmark {name!r}; have {sorted(PROGRAMS)}") from None
