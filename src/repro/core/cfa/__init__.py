"""Canonical Facet Allocation (CFA) — the paper's core contribution.

Burst-friendly off-chip memory layout for tiled uniform-dependence programs:
multi-projection facets, single-assignment, data tiling and dimension
permutation (full-tile / inter-tile / intra-tile contiguity), plus the
compiler pass that turns a program spec into a read->execute->write pipeline,
the layout autotuner that searches the layout family per workload, and the
measurement machinery behind the paper's evaluation.

Public API (paper section each symbol reproduces):

Iteration-space machinery (``spaces``)
    * ``IterSpace``        — rectangular iteration space ``E`` (§IV-A).
    * ``Deps``             — uniform, all-backwards dependence pattern (§IV-D/E).
    * ``Tiling``           — rectangular tile sizes ``t_1..t_d`` (§IV-B).
    * ``facet_widths``     — facet thickness ``w_k = max_q |e_k . B_q|`` (§IV-F3).
    * ``flow_in_points``   — a tile's flow-in set ``phi_i(T)`` (appendix A).
    * ``flow_out_points``  — a tile's flow-out set ``phi_o(T)`` (appendix A).
    * ``facet_points``     — the k-th facet ``S_k(T)`` of a tile (appendix B).
    * ``neighbor_offsets`` — backward neighbor tiles by level (§IV-D).

Facet layout (``facets``)
    * ``FacetSpec``          — one facet array's permuted layout (§IV-F..I).
    * ``build_facet_specs``  — the facet family for (space, deps, tiling),
      parameterised by extension dirs and contiguity level (§IV-G/H/I).
    * ``extension_dir``      — the paper's cyclic inter-tile direction (§IV-H).
    * ``CONTIGUITY_LEVELS``  — the three cumulative levels (§IV-G/H/I).

Packing (``allocation``)
    * ``pack_facet`` / ``pack_all`` / ``unpack_into`` — canonical array <->
      facet storage converters (§IV-F4 single-assignment allocation); both
      understand the irredundant owned masks.

Irredundant & compressed storage (``irredundant``/``compress``) — the
Ferry-2024 follow-up layout as a first-class subsystem
    * ``STORAGE_MODES``     — redundant / irredundant / compressed.
    * ``owner_of``          — the deterministic ownership rule (lowest facet
      axis wins a shared point).
    * ``StorageMap`` / ``build_storage_map`` — per-facet owned masks +
      footprint accounting (``stored_elems``, ``redundancy`` == 1.0,
      ``savings``).
    * ``dedup_facets`` / ``rehydrate_facets`` — drop / refill non-owned
      slots (the bit-exactness bridge between disciplines).
    * ``IrredundantPipeline`` / ``CompressedPipeline`` — ``CFAPipeline``
      under owner-only commits and owner-resolved halo reads (+ fixed-ratio
      codec round-trip).
    * ``BlockCodec`` / ``CODECS`` / ``get_codec`` — XOR-delta bit-pack
      block codecs (pure JAX, jit-compatible).

Burst plans (``plans``)
    * ``TransferPlan``         — exact per-tile burst statistics (§V-C).
    * ``count_runs``           — maximal contiguous runs of an address set.
    * ``cfa_plan``             — CFA reads/writes, boxed per §V-C1.
    * ``cfa_piece_census``     — §IV-D/H/J flow-in piece accounting (the
      d >= 4 unmergeable pieces made countable).
    * ``original_layout_plan`` — Bayliss [16] row-major baseline (Fig. 15).
    * ``bounding_box_plan``    — Pouchet [8] bounding-box baseline (Fig. 15).
    * ``data_tiling_plan``     — Ozturk [19] block-major baseline (Fig. 15).
    * ``interior_tile``        — the representative steady-state tile (§V-C).

Bandwidth model (``bandwidth``)
    * ``BurstModel``      — ``time = sum(T_setup + bytes/BW)`` per burst (§II-E);
      ``BurstModel.time`` of a ``PortedPlan`` is the max over per-port
      schedules (ports run concurrently, §VII); ``time(..., compute_s=...,
      overlap=True)`` composes the Fig. 13 DATAFLOW pipelined tile time.
    * ``PortedPlan``      — a plan's bursts repartitioned over n ports (§VII).
    * ``BandwidthReport`` — raw/effective bandwidth of a plan (Fig. 15 axes).
    * ``overlap_speedup`` — modeled overlapped-vs-sequential gain of a plan.
    * ``AXI_ZC706``       — the paper's ZC706 AXI HP port model (§VI-A).
    * ``TPU_V5E_HBM``     — the TPU DMA adaptation target (§VI-A analogue).

Multi-port repartition (``multiport``) — §VII future work made executable
    * ``PortAssignment`` / ``assign_ports`` — LPT placement of whole facet
      arrays on ports (balance = max/mean port load).
    * ``repartition`` / ``best_repartition`` / ``PORT_STRATEGIES`` — facet-
      and burst-granular splits of a ``TransferPlan`` into a ``PortedPlan``.
    * ``port_speedup`` — modeled multi-port gain on the interior-tile plan.

Benchmarks (``programs``)
    * ``StencilProgram`` — a Table I benchmark in post-skew normal form (§IV-E).
    * ``PROGRAMS`` / ``get_program`` — the Table I suite registry.

Pipeline (``transform``)
    * ``CFAPipeline`` — the read->execute->write tile pipeline of §V
      (Fig. 13); built by the ``lower_backend`` pass, run by the executors.

Autotuner (``autotune``) — the §VI "which layout?" question made a subsystem
    * ``autotune``         — staged search over tilings x extension dirs x
      contiguity levels x port repartitions (``n_ports``), scored by
      ``BurstModel``, with an on-disk cache; ``score="measured"`` re-ranks
      the top candidates by measured wall-clock (``SCORE_MODES``).
    * ``LayoutCandidate`` / ``ScoredLayout`` / ``LayoutDecision`` — the search
      space, the per-candidate score, and the ranked result (which carries
      the winning ``PortAssignment`` when ``n_ports > 1``).
    * ``candidate_tilings`` / ``hand_coded_baselines`` — enumeration helpers.
    * ``CacheSchemaError`` — on-disk decision from another cache schema.

Calibration (``calibrate``) — the measured-vs-modeled verification layer
(the paper validates with *measured* throughput, §VI; Zohouri & Matsuoka
2019 show why analytic controller models drift)
    * ``measure_runs`` / ``measure_plan`` — warmup + median-of-k wall-clock
      of a burst schedule / a whole ``TransferPlan``/``PortedPlan`` on the
      host backend (one jitted copy per burst = descriptor setup analogue).
    * ``TransferSample`` / ``fit_burst_model`` / ``CalibratedModel`` — the
      measured points, the least-squares fit of (setup, peak, port
      scaling), and the resulting drop-in ``BurstModel``.
    * ``calibrate`` / ``Calibration`` / ``CalibrationError`` — the full
      sweep (synthetic grid + Table I plans x storages x ports) and its
      JSON record with per-plan modeled-vs-measured relative error.
    * ``measurement_noise`` / ``timing_unusable_reason`` — the host noise
      probe behind the timing tests' skip-with-reason fixture.

Runtime telemetry (``obs``) — what each wave, facet and port *actually*
did, as an inspectable timeline (the runtime counterpart of the CFA1xx
static verifier; Iris argues layout decisions must be justified by
observed utilization)
    * ``TraceRecorder`` / ``Span`` / ``Counters`` — structured spans
      (copy_in / execute_tile / copy_out / halo_resolve per tile, grouped
      by wave and port; the dataflow executor's prefetch/compute/commit
      as concurrent lanes) + deterministic counters that
      ``TraceRecorder.reconcile`` checks exactly against the per-tile
      ``TransferPlan`` accounting and ``BurstModel.plan_bytes``.
    * ``chrome_trace`` / ``validate_chrome_trace`` — Chrome trace-event
      JSON export (Perfetto-loadable; ``tools/cfa_trace.py`` is the CLI)
      and its schema check (``docs/tracing.md``).
    * ``RuntimeReport`` / ``runtime_report`` — measured-vs-modeled
      attribution per plan/port/facet, worst-offender ranked with the
      CFA3xx fixit vocabulary.
    * Enabled per compile via ``compile(..., trace=True)`` /
      ``REPRO_TRACE=1``; read back with ``CompiledStencil.last_trace()``
      (``PassTrace`` compile spans fold into the same timeline).

Lowering passes (``passes``) — ``compile`` as a staged compiler flow
    * ``CompileState``    — the immutable lowering artifact (request fields
      refined in place, artifacts accreted per stage).
    * ``Pass`` / ``PassPipeline`` / ``PipelineError`` — the stage protocol,
      the validated runner (duplicate/missing/mis-ordered stages rejected at
      assembly), and its loud failure mode.
    * ``PassTrace``       — one stage's trace record (name, version, wall
      time, artifact diff); ``CompiledStencil.trace()`` returns the run's
      tuple of them.
    * ``default_pipeline`` / ``DEFAULT_PASSES`` /
      ``default_pass_fingerprint`` — the pinned default lowering
      (resolve_program -> validate_target -> distribute -> layout_search ->
      storage_map -> port_repartition -> select_backend -> lower_backend)
      and its (name, version) fingerprint, the identity the autotune cache
      is keyed by (schema v7).
    * ``estimate_facet_bytes`` — the distribute pass's per-host budget
      metric (``compile(host_budget=...)`` splits over the port mesh when
      the estimate exceeds it).

Static analysis (``analysis``) — the compile-time verifier + burst lint
(Iris pairs layout generation with automated efficiency analysis; Zohouri
& Matsuoka 2019 quantify the sub-burst-length degradation CFA3xx flags)
    * ``verify`` / ``compile(..., verify=True)`` — run the analysis suite
      over a ``CompiledStencil``; ERROR diagnostics raise
      ``VerificationError``; the report rides as
      ``CompiledStencil.diagnostics()``.
    * ``Diagnostic`` / ``AnalysisReport`` / ``VerificationError`` — one
      coded, located, severity-tagged finding; the aggregate; the loud
      failure mode.
    * ``AnalysisPass`` / ``analysis_pass`` / ``DEFAULT_ANALYSES`` — the
      read-only pass category and the default suite: CFA1xx
      single-assignment/coverage proofs, CFA2xx overlap race detection,
      CFA3xx burst-efficiency lint (priced by ``BurstModel``), CFA4xx
      capability/contract checks (code table in ``docs/analysis.md``).
    * ``check_facet_family`` / ``plan_accounting`` /
      ``check_overlap_schedule`` / ``lint_plan`` — the pure checkers
      (``autotune`` discards candidates failing ``plan_accounting``).
    * ``run_analyses`` / ``verify_pipeline`` — suite runner over a
      ``CompileState``; the default lowering + analyses pipeline.
    * ``ineligible_reason`` (``executors``) — the non-raising capability
      gate CFA401 reports verbatim.

Front-end (``api``/``executors``) — one declarative entry point over it all
    * ``compile``          — a thin driver over the default pass pipeline;
      returns a ``CompiledStencil`` (callable; carries ``.layout``,
      ``.plan``, ``.report()``, ``.lower()``, ``.pipeline``, ``.trace()``).
    * ``Target`` / ``TARGETS`` / ``register_target`` / ``get_target`` — the
      platform registry (burst model + port budget).
    * ``Executor`` / ``ExecutorCaps`` / ``EXECUTORS`` / ``register_executor``
      / ``get_executor`` / ``available_backends`` / ``select_backend`` /
      ``BackendError`` — the execution-backend registry and its single
      capability gate (N-D and port-count validation).
"""
from .spaces import (
    IterSpace,
    Deps,
    Tiling,
    facet_widths,
    flow_in_points,
    flow_out_points,
    facet_points,
    neighbor_offsets,
)
from .facets import (
    FacetSpec,
    build_facet_specs,
    extension_dir,
    CONTIGUITY_LEVELS,
)
from .allocation import pack_facet, pack_all, unpack_into
from .compress import BlockCodec, CODECS, get_codec
from .irredundant import (
    STORAGE_MODES,
    StorageMap,
    build_storage_map,
    owner_of,
    dedup_facets,
    rehydrate_facets,
    IrredundantPipeline,
    CompressedPipeline,
)
from .plans import (
    TransferPlan,
    count_runs,
    cfa_plan,
    cfa_piece_census,
    original_layout_plan,
    bounding_box_plan,
    data_tiling_plan,
    interior_tile,
)
from .bandwidth import (
    BurstModel,
    PortedPlan,
    BandwidthReport,
    AXI_ZC706,
    TPU_V5E_HBM,
    overlap_speedup,
)
from .multiport import (
    PortAssignment,
    PORT_STRATEGIES,
    assign_ports,
    repartition,
    best_repartition,
    port_speedup,
)
from .programs import StencilProgram, PROGRAMS, get_program
from .autotune import (
    LayoutCandidate,
    ScoredLayout,
    LayoutDecision,
    CacheSchemaError,
    SCORE_MODES,
    autotune,
    candidate_tilings,
    hand_coded_baselines,
)
from .calibrate import (
    TransferSample,
    CalibratedModel,
    Calibration,
    CalibrationError,
    measure_runs,
    measure_plan,
    fit_burst_model,
    calibrate,
    measurement_noise,
    timing_unusable_reason,
)
from .obs import (
    Span,
    Counters,
    TraceRecorder,
    RuntimeReport,
    runtime_report,
    chrome_trace,
    validate_chrome_trace,
)
from .transform import CFAPipeline
from .passes import (
    CompileState,
    Pass,
    PassPipeline,
    PassTrace,
    PipelineError,
    DEFAULT_PASSES,
    default_pipeline,
    default_pass_fingerprint,
    estimate_facet_bytes,
)
from .executors import (
    BackendError,
    Executor,
    ExecutorCaps,
    EXECUTORS,
    register_executor,
    get_executor,
    available_backends,
    ineligible_reason,
    select_backend,
)
from .analysis import (
    Diagnostic,
    AnalysisReport,
    VerificationError,
    AnalysisPass,
    analysis_pass,
    DEFAULT_ANALYSES,
    check_facet_family,
    plan_accounting,
    check_overlap_schedule,
    lint_plan,
    run_analyses,
    verify,
    verify_pipeline,
)
from .api import (
    Target,
    TARGETS,
    register_target,
    get_target,
    compile,
    CompiledStencil,
)

__all__ = [
    "IterSpace", "Deps", "Tiling", "facet_widths",
    "flow_in_points", "flow_out_points", "facet_points", "neighbor_offsets",
    "FacetSpec", "build_facet_specs", "extension_dir", "CONTIGUITY_LEVELS",
    "pack_facet", "pack_all", "unpack_into",
    "STORAGE_MODES", "StorageMap", "build_storage_map", "owner_of",
    "dedup_facets", "rehydrate_facets",
    "IrredundantPipeline", "CompressedPipeline",
    "BlockCodec", "CODECS", "get_codec",
    "TransferPlan", "count_runs", "cfa_plan", "cfa_piece_census", "original_layout_plan",
    "bounding_box_plan", "data_tiling_plan", "interior_tile",
    "BurstModel", "PortedPlan", "BandwidthReport", "AXI_ZC706", "TPU_V5E_HBM",
    "overlap_speedup",
    "PortAssignment", "PORT_STRATEGIES", "assign_ports",
    "repartition", "best_repartition", "port_speedup",
    "StencilProgram", "PROGRAMS", "get_program",
    "LayoutCandidate", "ScoredLayout", "LayoutDecision", "CacheSchemaError",
    "SCORE_MODES", "autotune", "candidate_tilings", "hand_coded_baselines",
    "TransferSample", "CalibratedModel", "Calibration", "CalibrationError",
    "measure_runs", "measure_plan", "fit_burst_model", "calibrate",
    "measurement_noise", "timing_unusable_reason",
    "Span", "Counters", "TraceRecorder", "RuntimeReport", "runtime_report",
    "chrome_trace", "validate_chrome_trace",
    "CFAPipeline",
    "CompileState", "Pass", "PassPipeline", "PassTrace", "PipelineError",
    "DEFAULT_PASSES", "default_pipeline", "default_pass_fingerprint",
    "estimate_facet_bytes",
    "BackendError", "Executor", "ExecutorCaps", "EXECUTORS",
    "register_executor", "get_executor", "available_backends",
    "ineligible_reason", "select_backend",
    "Diagnostic", "AnalysisReport", "VerificationError",
    "AnalysisPass", "analysis_pass", "DEFAULT_ANALYSES",
    "check_facet_family", "plan_accounting", "check_overlap_schedule",
    "lint_plan", "run_analyses", "verify", "verify_pipeline",
    "Target", "TARGETS", "register_target", "get_target",
    "compile", "CompiledStencil",
]
