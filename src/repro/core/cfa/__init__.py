"""Canonical Facet Allocation (CFA) — the paper's core contribution.

Burst-friendly off-chip memory layout for tiled uniform-dependence programs:
multi-projection facets, single-assignment, data tiling and dimension
permutation (full-tile / inter-tile / intra-tile contiguity), plus the
compiler pass that turns a program spec into a read->execute->write pipeline
and the measurement machinery behind the paper's evaluation.
"""
from .spaces import (
    IterSpace,
    Deps,
    Tiling,
    facet_widths,
    flow_in_points,
    flow_out_points,
    facet_points,
    neighbor_offsets,
)
from .facets import FacetSpec, build_facet_specs, extension_dir
from .allocation import pack_facet, pack_all, unpack_into
from .plans import (
    TransferPlan,
    count_runs,
    cfa_plan,
    original_layout_plan,
    bounding_box_plan,
    data_tiling_plan,
    interior_tile,
)
from .bandwidth import BurstModel, BandwidthReport, AXI_ZC706, TPU_V5E_HBM
from .programs import StencilProgram, PROGRAMS, get_program
from .transform import CFAPipeline

__all__ = [
    "IterSpace", "Deps", "Tiling", "facet_widths",
    "flow_in_points", "flow_out_points", "facet_points", "neighbor_offsets",
    "FacetSpec", "build_facet_specs", "extension_dir",
    "pack_facet", "pack_all", "unpack_into",
    "TransferPlan", "count_runs", "cfa_plan", "original_layout_plan",
    "bounding_box_plan", "data_tiling_plan", "interior_tile",
    "BurstModel", "BandwidthReport", "AXI_ZC706", "TPU_V5E_HBM",
    "StencilProgram", "PROGRAMS", "get_program",
    "CFAPipeline",
]
