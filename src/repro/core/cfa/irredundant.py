"""Irredundant facet storage: every canonical value stored exactly once.

The paper's facet layout buys burst contiguity by *duplicating* halo data:
a point in the tail slab of several axes lies in several facets' projection
domains and is stored — and written — once per facet (``TransferPlan``
measures the tax as ``redundancy``).  The authors' follow-up (Ferry et al.,
2024, *An Irredundant and Compressed Data Layout...*) removes the duplicates
by giving every point exactly one **owner** facet; this module is that
storage discipline as a first-class subsystem:

* :func:`owner_of` — the deterministic ownership rule: a point in several
  facet domains is owned by the **lowest** facet axis (the time facet wins
  corners, matching the paper's host preference for the thinnest/first axis).
  Ownership depends only on intra-tile coordinates, so it is a static,
  tile-independent mask over each facet block.
* :class:`StorageMap` / :func:`build_storage_map` — the per-facet owned
  masks plus the footprint accounting: ``stored_elems`` (each value once),
  ``redundant_elems`` (the paper's layout), ``redundancy`` (stored /
  distinct — 1.0 by construction, pinned by tests), ``savings``.
* :func:`dedup_facets` / :func:`rehydrate_facets` — drop non-owned slots
  (they read as zeros) / refill them from their owner facets, so an
  irredundant execution payload compares bit-for-bit against the redundant
  one.
* :class:`IrredundantPipeline` — a ``CFAPipeline`` whose ``copy_out``
  commits only owned slots and whose ``copy_in`` resolves every halo read
  to the owner facet's storage (the owner-facet indirection; the Pallas
  read engine mirrors it in ``repro.kernels.facet_fetch``).
* :class:`CompressedPipeline` — additionally passes every committed block
  through a fixed-ratio :class:`~repro.core.cfa.compress.BlockCodec`
  round-trip, so results reflect exactly what compressed storage preserved
  (bit-identical under an exact codec; the transfer-time effect is modeled
  by ``BurstModel`` via ``TransferPlan.codec_bits``).

The burst-accounting counterpart (owner-resolved reads, owned-run writes,
``footprint``/``stored_elems`` on the plan) lives in
``repro.core.cfa.plans.cfa_plan(storage="irredundant")``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import ClassVar, Mapping

import numpy as np
import jax.numpy as jnp

from .compress import BlockCodec, get_codec
from .facets import FacetSpec, row_major_strides
from .transform import CFAPipeline

__all__ = [
    "STORAGE_MODES",
    "owner_of",
    "StorageMap",
    "build_storage_map",
    "dedup_facets",
    "rehydrate_facets",
    "IrredundantPipeline",
    "CompressedPipeline",
]

#: The three facet storage disciplines ``cfa.compile`` exposes: the paper's
#: duplicated layout, the deduplicated one, and deduplicated + fixed-ratio
#: block compression (Ferry 2024).
STORAGE_MODES = ("redundant", "irredundant", "compressed")


def owner_of(specs: Mapping[int, FacetSpec], pts: np.ndarray) -> np.ndarray:
    """Owner facet axis per point: the lowest axis whose projection domain
    contains the point; ``-1`` for points in no facet domain."""
    pts = np.atleast_2d(np.asarray(pts, dtype=np.int64))
    owner = np.full(len(pts), -1, dtype=np.int64)
    for k in sorted(specs):  # ascending axis == ownership priority
        m = (owner < 0) & specs[k].domain_mask(pts)
        owner[m] = k
    return owner


@dataclasses.dataclass(frozen=True)
class StorageMap:
    """The irredundant storage discipline for one facet family.

    ``owned[k]`` is a boolean mask over facet ``k``'s *block* (inner dims,
    in ``inner_axes`` order): True where the slot's canonical point is owned
    by facet ``k``.  Ownership never depends on the axis-``k`` (modulo)
    coordinate, so the masks are exact for tile-dependent modulo labelling
    too, and identical for every tile block.
    """

    specs: dict[int, FacetSpec]
    owned: dict[int, np.ndarray]

    @property
    def owned_per_block(self) -> dict[int, int]:
        """Owned slots in one tile's block, per facet."""
        return {k: int(m.sum()) for k, m in self.owned.items()}

    def stores(self, k: int, pts: np.ndarray) -> np.ndarray:
        """Boolean per point: does facet ``k`` *store* it — i.e. the point
        lies in facet ``k``'s projection domain *and* lands on an owned
        slot?  Summed over facets this counts a point's storage slots; the
        static verifier (``analysis.check_facet_family``) proves the count
        is exactly one over the whole family."""
        spec = self.specs[k]
        pts = np.atleast_2d(np.asarray(pts, dtype=np.int64))
        out = np.zeros(len(pts), dtype=bool)
        dom = spec.domain_mask(pts)
        if dom.any():
            inner = spec.coords(pts[dom])[:, len(spec.outer_axes):]
            out[np.flatnonzero(dom)] = self.owned[k][tuple(inner.T)]
        return out

    @property
    def stored_elems(self) -> int:
        """Total slots the irredundant layout stores (each value once)."""
        return sum(
            int(self.owned[k].sum()) * (s.size // s.block_elems)
            for k, s in self.specs.items()
        )

    @property
    def redundant_elems(self) -> int:
        """Total slots the paper's duplicated layout stores."""
        return sum(s.size for s in self.specs.values())

    @property
    def redundancy(self) -> float:
        """Stored slots per distinct value — 1.0: single assignment.

        The ownership rule partitions every tile's facet union, so this is
        1.0 *by construction*; the property tests verify the partition on
        random spaces rather than trusting the closed form.
        """
        return 1.0 if self.stored_elems else 0.0

    @property
    def savings(self) -> float:
        """Fraction of the redundant layout's slots the dedup removes."""
        red = self.redundant_elems
        return 0.0 if not red else 1.0 - self.stored_elems / red


def build_storage_map(specs: Mapping[int, FacetSpec]) -> StorageMap:
    """Derive the owned masks for a facet family.

    A slot of facet ``k``'s block with intra-tile coordinate ``r`` is owned
    iff no lower-axis facet ``j < k`` also covers it, i.e. iff
    ``r_j < t_j - w_j`` for every facet axis ``j < k`` — the complement of
    facet ``j``'s tail slab.  (Facet ``k`` covers its own block by
    definition, and the axis-``k`` inner coordinate is the modulo label,
    which ownership never consults.)
    """
    owned: dict[int, np.ndarray] = {}
    for k, spec in specs.items():
        mask = np.ones(
            tuple(spec.inner_size(a) for a in spec.inner_axes), dtype=bool
        )
        for pos, a in enumerate(spec.inner_axes):
            if a < k and a in specs:
                t_a, w_a = spec.tile_sizes[a], specs[a].width
                sl = [slice(None)] * mask.ndim
                sl[pos] = slice(t_a - w_a, t_a)
                mask[tuple(sl)] = False
        owned[k] = mask
    return StorageMap(specs=dict(specs), owned=owned)


def dedup_facets(
    facets: dict[int, jnp.ndarray], smap: StorageMap
) -> dict[int, jnp.ndarray]:
    """Zero the non-owned slots (what irredundant storage never writes)."""
    out = {}
    for k, arr in facets.items():
        mask = smap.owned[k]
        if mask.all():
            out[k] = arr
        else:  # masks cover the inner dims; outer (tile) dims broadcast
            out[k] = jnp.where(jnp.asarray(mask), arr, jnp.zeros((), arr.dtype))
    return out


def _virtual_shift(spec: FacetSpec, arr: jnp.ndarray) -> int:
    """Flat-offset shift when ``arr`` carries extra leading block rows
    beyond ``spec.shape`` (facet_0's virtual live-in row)."""
    extra = arr.shape[0] - spec.shape[0]
    return extra * int(np.prod(spec.shape[1:], dtype=np.int64))


def rehydrate_facets(
    facets: dict[int, jnp.ndarray], smap: StorageMap
) -> dict[int, jnp.ndarray]:
    """Refill every non-owned slot from its owner facet's storage.

    The inverse of :func:`dedup_facets` given owner values: applied to an
    irredundant execution payload it reconstructs the redundant payload
    bit-for-bit (duplicated slots duplicate the owner's value by
    construction — both were committed from the same tile interior).
    Facet_0's virtual live-in row passes through untouched: facet_0 is
    fully owned (lowest axis), and dead slots of other facets decode to
    in-space points, whose owner storage is a real (shifted) facet_0 row.
    """
    specs = smap.specs
    out = dict(facets)
    for k, spec in specs.items():
        mask = smap.owned[k]
        if mask.all():
            continue
        arr = facets[k]
        # decode every dead slot of the full array to its canonical point
        full_mask = np.broadcast_to(
            mask, tuple(arr.shape[: len(spec.outer_axes)]) + mask.shape
        )
        dead = np.argwhere(~full_mask)  # (n, outer+inner) multi-indices
        n_outer = len(spec.outer_axes)
        t = np.asarray(spec.tile_sizes, dtype=np.int64)
        q = np.zeros((len(dead), spec.ndim), dtype=np.int64)
        for col, a in enumerate(spec.outer_axes):
            q[:, a] = dead[:, col]
        x = np.zeros((len(dead), spec.ndim), dtype=np.int64)
        for col, a in enumerate(spec.inner_axes):
            c = dead[:, n_outer + col]
            if a == spec.axis:  # modulo label -> slab position (per tile)
                w = spec.width
                base = q[:, a] * t[a] + t[a] - w
                x[:, a] = base + (c - base) % w
            else:
                x[:, a] = q[:, a] * t[a] + c
        own = owner_of(specs, x)
        if (own < 0).any() or (own >= k).any():
            raise AssertionError(
                "dead slot without a lower-axis owner — storage-map bug"
            )
        vals = jnp.zeros(len(dead), arr.dtype)
        for j in np.unique(own):
            sel = own == j
            offs = specs[j].offsets(x[sel]) + _virtual_shift(specs[j], facets[j])
            vals = vals.at[np.flatnonzero(sel)].set(
                facets[j].reshape(-1)[jnp.asarray(offs)]
            )
        flat_idx = dead @ row_major_strides(arr.shape)
        out[k] = arr.reshape(-1).at[jnp.asarray(flat_idx)].set(vals).reshape(arr.shape)
    return out


# --------------------------------------------------------------------------
# Execution pipelines
# --------------------------------------------------------------------------


@dataclasses.dataclass
class IrredundantPipeline(CFAPipeline):
    """``CFAPipeline`` under the irredundant storage discipline.

    Same facet shapes, same schedule, two overrides:

    * ``copy_out`` (via ``_store_block``) commits only owned slots — a
      value is written exactly once, to its owner facet;
    * ``copy_in`` (via ``_halo_hosts``) reads every halo point from its
      owner facet, whether or not that facet's axis is crossed — the
      owner-facet indirection (non-owned slots hold nothing).

    The payload therefore has zeros in every non-owned slot; pass it
    through :func:`rehydrate_facets` to compare against a redundant run.
    """

    storage: ClassVar[str] = "irredundant"
    storage_map: StorageMap = dataclasses.field(init=False, repr=False, compare=False)

    def __post_init__(self) -> None:
        super().__post_init__()
        self.storage_map = build_storage_map(self.specs)

    def _halo_hosts(self, pts, lo, taken):
        """Owner-priority halo sourcing: ascending facet axis, domain
        membership only (the crossing direction is irrelevant to where a
        value is *stored*)."""
        maps = {}
        for k, spec in self.specs.items():
            mask = ~taken & spec.domain_mask(pts)
            if mask.any():
                maps[k] = pts[mask]
                taken |= mask
        return maps

    def _commit_block(self, arr, idx, block, spec):
        mask = self.storage_map.owned[spec.axis]
        if mask.all():
            return super()._commit_block(arr, idx, block, spec)
        # owned slots get the new value; non-owned slots stay untouched
        return arr.at[idx].set(jnp.where(jnp.asarray(mask), block, arr[idx]))


@dataclasses.dataclass
class CompressedPipeline(IrredundantPipeline):
    """Irredundant storage + fixed-ratio block compression (Ferry 2024).

    Every committed block is passed through the codec's encode/decode
    round-trip before storage, so the facets hold exactly what compressed
    memory would return — bit-identical to the irredundant pipeline when
    the codec is exact on the data (e.g. the ``raw`` codec, or bit-truncated
    inputs under ``deltapack16``), measurably quantised otherwise.  The
    bytes-per-burst effect is modeled by ``BurstModel`` via
    ``TransferPlan.codec_bits``, not re-simulated here.
    """

    storage: ClassVar[str] = "compressed"
    codec: BlockCodec | str | None = None

    def __post_init__(self) -> None:
        super().__post_init__()
        self.codec = get_codec(self.codec)

    def _commit_block(self, arr, idx, block, spec):
        # storage holds the block layout, so the codec sees it as written
        return super()._commit_block(arr, idx, self.codec.roundtrip(block),
                                     spec)
