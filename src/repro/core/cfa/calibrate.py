"""Measured-vs-modeled calibration of the :class:`BurstModel`.

Every ranking the autotuner produces — layout, ports, storage, codec —
rests on the analytic burst model, and the Memory Controller Wall study
(Zohouri & Matsuoka, 2019) shows real memory controllers drifting far from
exactly such first-order models.  The source paper validates its layout
claims with *measured* throughput (§VI); this module is that measurement
layer for the repo, on the backend we actually have (host/TPU via jax):

1. **Measure** — :func:`measure_runs` times a burst schedule for real:
   each run becomes one jitted device copy over a buffer holding the run's
   *wire bytes* (compression applied via ``compress.stored_bits``, the same
   formula :meth:`BurstModel.burst_bytes` uses), dispatched and blocked on
   individually.  The per-dispatch overhead is the host analogue of the
   per-burst DMA descriptor setup cost T_setup; the per-byte device copy
   cost is the analogue of bytes/BW_peak.  Warmup passes absorb jit
   compilation; the reported figure is the median of k timed passes.
   :func:`measure_plan` applies this to the exact schedules
   :class:`TransferPlan` / :class:`PortedPlan` emit (a ported plan's time
   is the slowest port's schedule, matching ``BurstModel.time``).  Both
   take a ``compute_s`` term and an ``overlap=`` mode: sequential passes
   block each copy then busy-spin the compute; overlapped passes dispatch
   every copy asynchronously, spin the compute while the copies are in
   flight, and block at the end — the measured counterpart of the Fig. 13
   DATAFLOW schedule the ``dataflow`` executor runs.

2. **Fit** — :func:`fit_burst_model` least-squares fits ``t = setup_s *
   n_bursts + wire_bytes / peak_bytes_per_s`` to the single-port samples
   (columns normalised, parameters clamped non-negative) and derives
   per-port-count scaling factors from the multi-port samples, returning a
   :class:`CalibratedModel` — a drop-in :class:`BurstModel` whose
   ``time()`` additionally applies the fitted port scaling.

3. **Verify** — :func:`calibrate` sweeps synthetic burst schedules plus the
   interior-tile plans of real Table I programs across storage disciplines
   and port counts, fits the model, and records per-plan modeled-vs-
   measured relative error into a JSON-serialisable :class:`Calibration` —
   the artifact ``benchmarks/calibration_bench.py`` publishes and the
   differential tests in ``tests/test_calibration.py`` pin.

Timing on a shared host is noisy; :func:`timing_unusable_reason` probes the
clock resolution and the spread of a reference schedule so callers (the
pytest fixture in ``tests/conftest.py``) can *skip with a reason* instead
of flaking.  ``REPRO_TIMING_TESTS=skip|force`` overrides the probe, and
``REPRO_MEASURE_WARMUP`` / ``REPRO_MEASURE_REPEATS`` override the default
measurement fidelity everywhere.

The wall-clock itself — :func:`~repro.core.cfa.obs.now`, the compute
stand-in :func:`~repro.core.cfa.obs.burn`, the fidelity knobs and the
noise probe — lives in :mod:`repro.core.cfa.obs` (one home for every
measurement-fidelity decision); this module re-exports the probe under
its historical names and adds the burst-schedule harness on top.  Pass
``recorder=`` (a :class:`~repro.core.cfa.obs.TraceRecorder`) to
:func:`measure_runs` / :func:`measure_plan` and every timed pass is
emitted as a ``measure``-category span on the shared timeline.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import math
import statistics
from pathlib import Path
from typing import Sequence

import numpy as np

from .bandwidth import AXI_ZC706, BurstModel, PortedPlan
from .compress import get_codec, stored_bits
from .multiport import best_repartition
from .obs import (TraceRecorder, _timing_probe, burn as _burn,
                  measure_defaults as _measure_defaults,
                  measurement_noise, now, timing_unusable_reason)
from .plans import TransferPlan, cfa_plan, interior_tile
from .spaces import IterSpace, Tiling

__all__ = [
    "TransferSample",
    "CalibratedModel",
    "Calibration",
    "CalibrationError",
    "measure_runs",
    "measure_plan",
    "fit_burst_model",
    "calibrate",
    "measurement_noise",
    "timing_unusable_reason",
]


class CalibrationError(ValueError):
    """The sample set cannot support a fit (empty, or no positive times)."""


# --------------------------------------------------------------------------
# Wire-byte accounting (shared with BurstModel.burst_bytes)
# --------------------------------------------------------------------------


def wire_bytes(length: int, elem_bytes: int, codec_bits: int | None = None) -> float:
    """Bytes one burst of ``length`` elements puts on the wire — raw, or
    header + ``codec_bits``-wide residuals under fixed-ratio compression
    (``compress.stored_bits``, the formula ``BurstModel.burst_bytes`` and
    the footprint accounting share)."""
    if not codec_bits:
        return float(length * elem_bytes)
    return stored_bits(length, 8 * elem_bytes, codec_bits) / 8


def _wire_words(length: int, elem_bytes: int, codec_bits: int | None) -> int:
    """The burst's wire bytes expressed in float32 device words (>= 1).

    The measurement buffers are float32 regardless of the model's element
    type: what the copy moves is *bytes*, and a 4-byte word count sidesteps
    dtype availability (e.g. 64-bit modes) entirely.
    """
    return max(1, math.ceil(wire_bytes(length, elem_bytes, codec_bits) / 4))


# --------------------------------------------------------------------------
# The measurement harness
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def _copy_op():
    """One jitted elementwise copy, re-specialised per buffer shape by jax."""
    import jax

    return jax.jit(lambda x: x + 1.0)


@functools.lru_cache(maxsize=None)
def _wire_buffer(n_words: int):
    """A persistent float32 device buffer of ``n_words`` words."""
    import jax.numpy as jnp

    return jnp.zeros((int(n_words),), jnp.float32)


def measure_runs(
    runs: Sequence[int],
    elem_bytes: int = 8,
    *,
    codec_bits: int | None = None,
    warmup: int | None = None,
    repeats: int | None = None,
    compute_s: float = 0.0,
    overlap: bool = False,
    recorder: TraceRecorder | None = None,
    label: str = "",
) -> float:
    """Measured wall-clock seconds to transfer one burst schedule.

    Each run dispatches its own jitted device copy (sized to the run's wire
    bytes) and blocks on the result — per-burst dispatch overhead plus
    per-byte copy cost, the two terms the :class:`BurstModel` models.  The
    schedule is timed as a whole, ``warmup`` untimed passes first (jit
    compilation happens there), then the median over ``repeats`` timed
    passes.  Defaults come from ``REPRO_MEASURE_WARMUP`` /
    ``REPRO_MEASURE_REPEATS`` when unset.  An empty schedule measures 0.

    ``compute_s`` adds that much busy-spun host compute to every pass.
    Sequentially (``overlap=False``) the copies are blocked on one by one
    and the compute runs after them — wall-clock ≈ transfer + compute.
    With ``overlap=True`` every copy is dispatched asynchronously first,
    the compute spins while they are in flight, and the pass blocks at the
    end — wall-clock ≈ max(transfer, compute), the Fig. 13 DATAFLOW
    schedule.

    With ``recorder`` (a :class:`~repro.core.cfa.obs.TraceRecorder`)
    every timed pass is emitted as a ``measure_pass`` span (category
    ``measure``, one ``measure`` summary span per schedule) carrying the
    schedule's burst count and wire bytes — the measurement layer on the
    same timeline as the executors.
    """
    warmup, repeats = _measure_defaults(warmup, repeats)
    if compute_s < 0.0:
        raise ValueError(f"compute_s must be >= 0, got {compute_s}")
    runs = tuple(int(r) for r in runs)
    if any(r <= 0 for r in runs):
        raise ValueError(f"burst lengths must be positive: {runs}")
    if not runs and compute_s == 0.0:
        return 0.0
    copy = _copy_op()
    bufs = [_wire_buffer(_wire_words(r, elem_bytes, codec_bits)) for r in runs]

    if overlap:
        def one_pass() -> float:
            t0 = now()
            futs = [copy(b) for b in bufs]  # async dispatch: copies in flight
            _burn(compute_s)
            for f in futs:
                f.block_until_ready()
            return now() - t0
    else:
        def one_pass() -> float:
            t0 = now()
            for b in bufs:
                copy(b).block_until_ready()
            _burn(compute_s)
            return now() - t0

    for _ in range(warmup):
        one_pass()
    if recorder is None:
        return statistics.median(one_pass() for _ in range(repeats))

    track = f"measure/{label}" if label else "measure"
    bytes_total = sum(wire_bytes(r, elem_bytes, codec_bits) for r in runs)
    t_sched = now()
    times = []
    for i in range(repeats):
        t0 = now()
        times.append(one_pass())
        recorder.add_span("measure_pass", t0, t0 + times[-1], track=track,
                          cat="measure", label=label, n_bursts=len(runs),
                          wire_bytes=bytes_total, overlap=overlap,
                          compute_s=compute_s, index=i)
    med = statistics.median(times)
    recorder.add_span("measure", t_sched, now(), track=track, cat="measure",
                      label=label, n_bursts=len(runs),
                      wire_bytes=bytes_total, repeats=repeats,
                      warmup=warmup, median_s=med)
    recorder.counters.add("measure_passes", repeats)
    recorder.counters.add("measure_schedules", 1)
    return med


def measure_plan(
    plan: TransferPlan | PortedPlan,
    model: BurstModel,
    *,
    warmup: int | None = None,
    repeats: int | None = None,
    compute_s: float = 0.0,
    overlap: bool = False,
    recorder: TraceRecorder | None = None,
    label: str = "",
) -> float:
    """Measured wall-clock seconds for a whole plan under ``model``'s
    element width — the measured counterpart of :meth:`BurstModel.time`.

    A :class:`TransferPlan` times its reads and writes as one schedule; a
    :class:`PortedPlan` times each port's schedule separately and reports
    the slowest (ports run concurrently, so the tile waits for the max —
    the same §VII semantics the analytic model uses).  ``compute_s`` /
    ``overlap`` time the tile's compute alongside the schedule (each
    port's schedule overlaps the same compute term; the tile still waits
    for the slowest port) — see :func:`measure_runs`.  ``recorder``
    forwards to :func:`measure_runs` (per-port schedules get
    ``{label}/port{p}`` span labels).
    """
    cb = getattr(plan, "codec_bits", None)
    label = label or f"plan:{getattr(plan, 'scheme', '?')}"
    kw = dict(codec_bits=cb, warmup=warmup, repeats=repeats,
              compute_s=compute_s, overlap=overlap, recorder=recorder)
    if isinstance(plan, PortedPlan):
        return max(
            measure_runs(rr + wr, model.elem_bytes,
                         label=f"{label}/port{p}", **kw)
            for p, (rr, wr) in enumerate(zip(plan.read_runs_by_port,
                                             plan.write_runs_by_port,
                                             strict=True))
        )
    return measure_runs(plan.read_runs + plan.write_runs, model.elem_bytes,
                        label=label, **kw)


# --------------------------------------------------------------------------
# Samples + fit
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TransferSample:
    """One measured transfer point: a burst schedule and its wall-clock.

    ``runs_by_port`` holds the burst lengths (elements) per port — one
    entry for a single-port schedule.  ``codec_bits`` scales each burst's
    wire bytes under fixed-ratio compression; ``elem_bytes`` is the element
    width the schedule was measured at.
    """

    runs_by_port: tuple[tuple[int, ...], ...]
    elem_bytes: int
    measured_s: float
    codec_bits: int | None = None
    label: str = ""

    def __post_init__(self) -> None:
        object.__setattr__(
            self, "runs_by_port",
            tuple(tuple(int(r) for r in port) for port in self.runs_by_port),
        )
        if not self.runs_by_port:
            raise ValueError("a sample needs at least one port schedule")
        if any(r <= 0 for port in self.runs_by_port for r in port):
            raise ValueError(f"burst lengths must be positive: {self.runs_by_port}")
        if self.elem_bytes < 1:
            raise ValueError(f"elem_bytes must be >= 1: {self.elem_bytes}")
        if not (self.measured_s >= 0.0 and math.isfinite(self.measured_s)):
            raise ValueError(f"measured_s must be finite and >= 0: {self.measured_s}")

    @property
    def n_ports(self) -> int:
        return len(self.runs_by_port)

    @property
    def runs(self) -> tuple[int, ...]:
        """All bursts across ports, flattened."""
        return tuple(r for port in self.runs_by_port for r in port)

    @property
    def n_bursts(self) -> int:
        return len(self.runs)

    @property
    def wire_bytes(self) -> float:
        """Total wire bytes across ports (compression applied)."""
        return sum(wire_bytes(r, self.elem_bytes, self.codec_bits)
                   for r in self.runs)


def _predict_s(model: BurstModel, sample: TransferSample) -> float:
    """Modeled time of a sample's schedule: max over its port schedules."""
    times = [model.time_s(port, sample.codec_bits)
             for port in sample.runs_by_port if port]
    return max(times) if times else 0.0


def fit_burst_model(
    samples: Sequence[TransferSample],
    base: BurstModel = AXI_ZC706,
    *,
    name: str | None = None,
) -> "CalibratedModel":
    """Fit ``base``'s parameters to measured samples.

    Least-squares on the single-port samples, ``t = setup_s * n_bursts +
    wire_bytes / peak``, with column normalisation (setup counts and byte
    totals live many orders of magnitude apart) and non-negativity clamps —
    a fitted model must keep the :class:`BurstModel` invariants (time
    monotone in run lengths, superadditive under run splitting), which any
    ``setup_s >= 0, peak > 0`` pair does.  Multi-port samples calibrate the
    port scaling: for each port count, the median ratio of measured time to
    the fitted max-over-ports prediction becomes that count's factor in
    :attr:`CalibratedModel.port_factors`.

    Raises :class:`CalibrationError` without at least one single-port
    sample with positive measured time.
    """
    single = [s for s in samples if s.n_ports == 1 and s.measured_s > 0]
    if not single:
        raise CalibrationError(
            "need at least one single-port sample with measured_s > 0 to fit"
        )
    A = np.array([[s.n_bursts, s.wire_bytes] for s in single], dtype=float)
    b = np.array([s.measured_s for s in single], dtype=float)
    col = np.linalg.norm(A, axis=0)
    col[col == 0.0] = 1.0
    x, *_ = np.linalg.lstsq(A / col, b, rcond=None)
    setup_s = float(max(x[0] / col[0], 0.0))
    per_byte = float(x[1] / col[1])
    if per_byte <= 0.0:
        # degenerate sample set (e.g. one point): fall back to the base
        # model's per-byte cost rather than inventing an infinite peak
        per_byte = 1.0 / base.peak_bytes_per_s
    fitted = BurstModel(
        name=name if name is not None else f"{base.name}+measured",
        peak_bytes_per_s=1.0 / per_byte,
        setup_s=setup_s,
        elem_bytes=base.elem_bytes,
    )
    factors: dict[int, list[float]] = {}
    for s in samples:
        if s.n_ports <= 1 or s.measured_s <= 0:
            continue
        pred = _predict_s(fitted, s)
        if pred > 0:
            factors.setdefault(s.n_ports, []).append(s.measured_s / pred)
    port_factors = tuple(
        (p, float(statistics.median(fs))) for p, fs in sorted(factors.items())
    )
    return CalibratedModel(
        name=fitted.name,
        peak_bytes_per_s=fitted.peak_bytes_per_s,
        setup_s=fitted.setup_s,
        elem_bytes=fitted.elem_bytes,
        port_factors=port_factors,
        base_name=base.name,
    )


@dataclasses.dataclass(frozen=True)
class CalibratedModel(BurstModel):
    """A :class:`BurstModel` with measured parameters — drop-in everywhere
    a burst model goes (``autotune``, ``compile(target=...)``, reports).

    ``port_factors`` maps a port count to the measured slowdown (or
    speedup) factor relative to the analytic max-over-ports time; ``time``
    applies the factor of the nearest calibrated port count to multi-port
    plans.  ``base_name`` records which preset the fit started from, so
    ``get_target`` keeps the platform's port budget for recalibrated
    models registered under the same name.
    """

    port_factors: tuple[tuple[int, float], ...] = ()
    base_name: str = ""

    def port_factor(self, n_ports: int) -> float:
        """The fitted scaling for ``n_ports`` (nearest calibrated count;
        1.0 for single-port plans or an uncalibrated port axis)."""
        if n_ports <= 1 or not self.port_factors:
            return 1.0
        table = dict(self.port_factors)
        if n_ports in table:
            return table[n_ports]
        nearest = min(table, key=lambda p: (abs(p - n_ports), p))
        return table[nearest]

    def transfer_time_s(self, plan: "TransferPlan | PortedPlan") -> float:
        # the port factor scales the *transfer*; overriding here (not
        # ``time``) lets the inherited compute/overlap composition apply
        # unchanged to calibrated models
        t = super().transfer_time_s(plan)
        return t * self.port_factor(getattr(plan, "n_ports", 1))


# --------------------------------------------------------------------------
# The full calibration sweep
# --------------------------------------------------------------------------

_SYNTH_LENGTHS = (1, 8, 64, 512, 4096, 32768)
_SYNTH_COUNTS = (1, 4, 16)
_STORAGES = ("redundant", "irredundant", "compressed")


def _program_plan(prog_name: str, storage: str,
                  space: Sequence[int] | None = None):
    """The program's interior-tile CFA plan at its default tile."""
    from .programs import get_program

    prog = get_program(prog_name)
    sizes = tuple(space) if space is not None else tuple(
        2 * t for t in prog.default_tile)
    sp, tiling = IterSpace(sizes), Tiling(prog.default_tile)
    codec = get_codec(None) if storage == "compressed" else None
    return cfa_plan(sp, prog.deps, tiling, interior_tile(sp, tiling),
                    storage=storage, codec=codec)


def calibrate(
    model: BurstModel = AXI_ZC706,
    *,
    programs: Sequence[str] = ("jacobi2d5p", "heat3d"),
    storages: Sequence[str] = _STORAGES,
    ports: Sequence[int] = (1, 2),
    lengths: Sequence[int] = _SYNTH_LENGTHS,
    counts: Sequence[int] = _SYNTH_COUNTS,
    warmup: int | None = None,
    repeats: int | None = None,
    name: str | None = None,
    overlap: bool = False,
) -> "Calibration":
    """Measure, fit, and verify ``model`` against this host.

    Two sample families feed the fit:

    * *synthetic* — every (burst length, burst count) grid point, timed as
      a uniform schedule: spans the n_bursts x bytes plane so the
      least-squares system is well conditioned;
    * *plan-derived* — the interior-tile CFA plan of each program under
      each storage discipline and port count (multi-port plans through
      ``best_repartition``): the schedules the autotuner actually ranks.

    Every plan-derived point also becomes a row of
    :attr:`Calibration.plan_errors`, recording modeled-vs-measured and
    fitted-vs-measured relative error — the accountability artifact the
    calibration bench publishes per program.

    ``overlap=True`` additionally measures each plan's *overlapped*
    schedule at the balanced point (``compute_s`` equal to the modeled
    transfer time — where Fig. 13 DATAFLOW pipelining pays the most) and
    records a second plan-error row for it (``overlap: true``), verifying
    the overlapped model against the wall clock.  Overlapped points never
    feed the fit (the fit is transfer-only).
    """
    kw = dict(warmup=warmup, repeats=repeats)
    samples: list[TransferSample] = []
    for L in lengths:
        for c in counts:
            sched = (int(L),) * int(c)
            t = measure_runs(sched, model.elem_bytes, **kw)
            samples.append(TransferSample(
                runs_by_port=(sched,), elem_bytes=model.elem_bytes,
                measured_s=t, label=f"synthetic/{c}x{L}",
            ))
    plan_points = []  # (label fields, plan-or-ported, sample)
    for prog_name in programs:
        for storage in storages:
            plan = _program_plan(prog_name, storage)
            for p in ports:
                target_plan: TransferPlan | PortedPlan = plan
                if p > 1:
                    target_plan = best_repartition(plan, p, model)
                t = measure_plan(target_plan, model, **kw)
                if isinstance(target_plan, PortedPlan):
                    runs_by_port = tuple(
                        rr + wr for rr, wr in zip(
                            target_plan.read_runs_by_port,
                            target_plan.write_runs_by_port, strict=True)
                        if rr + wr
                    )
                else:
                    runs_by_port = (plan.read_runs + plan.write_runs,)
                sample = TransferSample(
                    runs_by_port=runs_by_port,
                    elem_bytes=model.elem_bytes,
                    measured_s=t,
                    codec_bits=plan.codec_bits,
                    label=f"{prog_name}/{storage}/p{p}",
                )
                samples.append(sample)
                plan_points.append((prog_name, storage, p, target_plan,
                                    t, False, 0.0))
                if overlap:
                    # balanced point: compute exactly hides the transfer
                    c = model.transfer_time_s(target_plan)
                    t_ovl = measure_plan(target_plan, model,
                                         compute_s=c, overlap=True, **kw)
                    plan_points.append((prog_name, storage, p, target_plan,
                                        t_ovl, True, c))

    fitted = fit_burst_model(samples, model, name=name)

    rows = []
    for prog_name, storage, p, target_plan, t, ovl, c in plan_points:
        modeled = model.time(target_plan, compute_s=c, overlap=ovl)
        predicted = fitted.time(target_plan, compute_s=c, overlap=ovl)
        rows.append({
            "program": prog_name,
            "storage": storage,
            "n_ports": int(p),
            "codec_bits": getattr(target_plan, "codec_bits", None),
            "n_bursts": int(target_plan.n_bursts),
            "overlap": bool(ovl),
            "compute_s": float(c),
            "modeled_s": float(modeled),
            "fitted_s": float(predicted),
            "measured_s": float(t),
            "rel_err_modeled": _rel_err(modeled, t),
            "rel_err_fitted": _rel_err(predicted, t),
        })

    from .executors import host_fingerprint

    return Calibration(
        target=model.name,
        base=model,
        fitted=fitted,
        samples=tuple(samples),
        plan_errors=tuple(rows),
        noise=measurement_noise(),
        host=tuple(tuple(kv) for kv in host_fingerprint()),
    )


def _rel_err(predicted: float, measured: float) -> float | None:
    """|predicted - measured| / measured (None when measured is 0)."""
    if measured <= 0.0:
        return None
    return abs(predicted - measured) / measured


@dataclasses.dataclass(frozen=True)
class Calibration:
    """The outcome of one :func:`calibrate` run (JSON round-trippable).

    ``base`` is the analytic model that was calibrated, ``fitted`` the
    measured replacement, ``samples`` everything that fed the fit, and
    ``plan_errors`` one row per (program, storage, ports) plan with
    modeled-vs-measured and fitted-vs-measured relative error — the
    numbers the acceptance criteria audit.
    """

    target: str
    base: BurstModel
    fitted: CalibratedModel
    samples: tuple[TransferSample, ...]
    plan_errors: tuple[dict, ...]
    noise: float
    host: tuple[tuple[str, str], ...]

    def max_rel_err(self, which: str = "fitted") -> float:
        """Worst relative error over the plan rows (``"fitted"`` or
        ``"modeled"``); 0.0 when no row has a measurable error."""
        key = f"rel_err_{which}"
        errs = [r[key] for r in self.plan_errors if r.get(key) is not None]
        return max(errs) if errs else 0.0

    def summary(self) -> str:
        f = self.fitted
        lines = [
            f"calibration of {self.target}: {len(self.samples)} samples, "
            f"noise {self.noise:.1%}",
            f"  base:   setup {self.base.setup_s:.3e} s, "
            f"peak {self.base.peak_bytes_per_s:.3e} B/s",
            f"  fitted: setup {f.setup_s:.3e} s, "
            f"peak {f.peak_bytes_per_s:.3e} B/s, "
            f"port factors {dict(f.port_factors) or '{}'}",
            f"  plan error: modeled max {self.max_rel_err('modeled'):.1%}, "
            f"fitted max {self.max_rel_err('fitted'):.1%} "
            f"over {len(self.plan_errors)} plan(s)",
        ]
        return "\n".join(lines)

    # -- serialisation ------------------------------------------------------

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=1)

    @staticmethod
    def from_json(text: str) -> "Calibration":
        d = json.loads(text)
        base = BurstModel(**d["base"])
        f = d["fitted"]
        fitted = CalibratedModel(
            name=f["name"], peak_bytes_per_s=f["peak_bytes_per_s"],
            setup_s=f["setup_s"], elem_bytes=f["elem_bytes"],
            port_factors=tuple((int(p), float(x)) for p, x in f["port_factors"]),
            base_name=f.get("base_name", ""),
        )
        samples = tuple(
            TransferSample(
                runs_by_port=tuple(tuple(port) for port in s["runs_by_port"]),
                elem_bytes=s["elem_bytes"],
                measured_s=s["measured_s"],
                codec_bits=s["codec_bits"],
                label=s["label"],
            )
            for s in d["samples"]
        )
        return Calibration(
            target=d["target"],
            base=base,
            fitted=fitted,
            samples=samples,
            plan_errors=tuple(d["plan_errors"]),
            noise=d["noise"],
            host=tuple(tuple(kv) for kv in d["host"]),
        )

    def save(self, path: Path | str) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.to_json())
        return path
