"""Analytic burst/DMA bandwidth model, single- and multi-port.

The paper measures raw and effective bandwidth on a Zynq ZC706 (64-bit AXI HP
port @ 100 MHz -> 800 MB/s peak).  This container has no FPGA and no TPU, so
we model the same first-order mechanics the paper exploits:

    time(plan) = sum over bursts ( T_setup + bytes / BW_peak )

A burst of length L amortises the fixed per-transaction cost T_setup over L
elements; element-wise access pays it per element.  This is exactly the
latency structure described in §II-E, and is the reason CFA's few-long-bursts
plans approach 100 % of the bus bandwidth in Fig. 15.

**Multi-port extension (paper §VII future work).**  A :class:`PortedPlan`
carries the same burst schedule split over ``n_ports`` independent memory
ports (HBM channels / AXI HP ports).  Ports run concurrently, so

    time(ported plan) = max over ports ( time of that port's bursts )

— the balance objective of §VII ("one has to find an adequate repartition of
data over each memory port to balance accesses").  The repartition strategies
that produce a :class:`PortedPlan` from a :class:`TransferPlan` live in
``repro.core.cfa.multiport``.

**Dataflow overlap (Fig. 13 DATAFLOW).**  The paper's accelerator template
runs READ / EXECUTE / WRITE as concurrent dataflow stages, so a tile's
transfer hides behind the previous tile's compute.  ``time`` therefore takes
a per-tile compute term and an ``overlap=`` mode: sequential phases cost
``transfer + compute``; overlapped phases cost the pipeline fill (one burst
setup — the prologue no double-buffer can hide) plus the max of the
remaining transfer and the compute, i.e. ``min(setup, T) + max(T - min(setup,
T), C)``.  The overlapped time is bounded below by ``max(transfer, compute)``
and above by the sequential sum, and equals the plain transfer time when
``compute_s`` is zero.  ``overlap_speedup`` reports the modeled gain; the
``backend="dataflow"`` executor realises the schedule.

Two presets:

* ``AXI_ZC706``  — the paper's platform (calibration target for Fig. 15).
* ``TPU_V5E_HBM`` — the adaptation target: HBM @ 819 GB/s behind DMA engines
  with a per-descriptor setup cost; "burst" = one contiguous DMA extent.
"""
from __future__ import annotations

import dataclasses

from .compress import stored_bits
from .plans import TransferPlan

__all__ = [
    "BurstModel",
    "PortedPlan",
    "AXI_ZC706",
    "TPU_V5E_HBM",
    "BandwidthReport",
    "overlap_speedup",
]


@dataclasses.dataclass(frozen=True)
class PortedPlan:
    """A tile's burst schedule repartitioned over ``n_ports`` memory ports.

    ``read_runs_by_port[p]`` / ``write_runs_by_port[p]`` are the burst lengths
    (elements) served by port ``p``; a port may be empty (a repartition is
    allowed to leave ports idle — see ``multiport.best_repartition``).
    ``facet_to_port`` records the facet-granular assignment when the strategy
    preserved facet arrays whole (``None`` for burst-granular strategies).
    """

    scheme: str
    n_ports: int
    strategy: str
    read_runs_by_port: tuple[tuple[int, ...], ...]
    write_runs_by_port: tuple[tuple[int, ...], ...]
    read_useful: int
    write_useful: int
    facet_to_port: tuple[tuple[int, int], ...] | None = None
    # storage accounting carried over from the repartitioned TransferPlan
    # (codec_bits drives the per-port burst timing below)
    storage: str = "redundant"
    footprint: int | None = None
    codec_bits: int | None = None

    def __post_init__(self) -> None:
        # Per-port schedules are consumed pairwise (zip with strict=True
        # below); a silent length mismatch would drop ports and under-report
        # the modeled transfer time, so reject it at construction.
        if len(self.read_runs_by_port) != self.n_ports:
            raise ValueError(
                f"read_runs_by_port has {len(self.read_runs_by_port)} "
                f"entries, need n_ports={self.n_ports}"
            )
        if len(self.write_runs_by_port) != self.n_ports:
            raise ValueError(
                f"write_runs_by_port has {len(self.write_runs_by_port)} "
                f"entries, need n_ports={self.n_ports}"
            )

    @property
    def port_elems(self) -> tuple[int, ...]:
        """Elements moved per port (the repartition's load vector)."""
        return tuple(
            int(sum(rr) + sum(wr))
            for rr, wr in zip(self.read_runs_by_port, self.write_runs_by_port,
                              strict=True)
        )

    @property
    def transferred(self) -> int:
        return int(sum(self.port_elems))

    @property
    def useful(self) -> int:
        return self.read_useful + self.write_useful

    @property
    def redundancy(self) -> float:
        return 0.0 if not self.transferred else 1.0 - self.useful / self.transferred

    @property
    def n_bursts(self) -> int:
        return sum(
            len(rr) + len(wr)
            for rr, wr in zip(self.read_runs_by_port, self.write_runs_by_port,
                              strict=True)
        )

    @property
    def balance(self) -> float:
        """max load / mean load over the ports that carry traffic (1.0 =
        perfectly balanced).  Idle ports are a legal repartition choice
        (``best_repartition`` may use fewer ports than available), so they
        do not count against the balance of the ports actually used."""
        loads = [l for l in self.port_elems if l > 0]
        mean = sum(loads) / len(loads) if loads else 0.0
        return float(max(loads) / mean) if mean > 0 else 1.0


@dataclasses.dataclass(frozen=True)
class BurstModel:
    name: str
    peak_bytes_per_s: float
    setup_s: float  # fixed cost per burst/DMA descriptor
    elem_bytes: int

    def burst_bytes(self, length: int, codec_bits: int | None = None) -> float:
        """Wire bytes of one burst of ``length`` elements.

        With ``codec_bits`` (fixed-ratio block compression, Ferry 2024) the
        burst carries one raw header word plus ``codec_bits``-wide residuals
        — same descriptor, fewer bytes; structure (and setup cost) unchanged.
        The size formula is ``compress.stored_bits``, shared with the
        codec's footprint accounting.
        """
        if not codec_bits:
            return length * self.elem_bytes
        return stored_bits(length, 8 * self.elem_bytes, codec_bits) / 8

    def time_s(self, runs: tuple[int, ...], codec_bits: int | None = None) -> float:
        return sum(
            self.setup_s + self.burst_bytes(r, codec_bits) / self.peak_bytes_per_s
            for r in runs
        )

    def transfer_time_s(self, plan: "TransferPlan | PortedPlan") -> float:
        """Modeled transfer time of a whole plan (no compute term).

        Single-port :class:`TransferPlan`: sum over all bursts.  Multi-port
        :class:`PortedPlan`: ports transfer concurrently, so the tile waits
        for the slowest port — the max over per-port burst schedules (§VII).
        A plan carrying ``codec_bits`` is timed at its compressed
        bytes-per-burst.
        """
        cb = getattr(plan, "codec_bits", None)
        if isinstance(plan, PortedPlan):
            # strict: a ragged ported plan must fail loudly, not drop the
            # trailing ports from the max (under-reporting the time)
            return max(
                self.time_s(rr, cb) + self.time_s(wr, cb)
                for rr, wr in zip(plan.read_runs_by_port,
                                  plan.write_runs_by_port, strict=True)
            )
        return self.time_s(plan.read_runs, cb) + self.time_s(plan.write_runs, cb)

    def time(
        self, plan: "TransferPlan | PortedPlan", *,
        compute_s: float = 0.0, overlap: bool = False,
    ) -> float:
        """Modeled tile time: transfers plus ``compute_s`` of tile compute.

        Sequential phases (every executor except ``dataflow``) pay the sum
        ``transfer + compute``.  With ``overlap=True`` (Fig. 13 DATAFLOW:
        fetch/compute/commit run as pipelined stages) the transfer streams
        behind the compute and only the pipeline fill — one burst's setup,
        ``min(setup_s, transfer)`` — stays exposed:

            time = fill + max(transfer - fill, compute_s)

        which is ``<= transfer + compute_s`` (the sequential schedule),
        ``>= max(transfer, compute_s)`` (neither engine can be beaten), and
        exactly the transfer time when ``compute_s == 0``.
        """
        if compute_s < 0.0:
            raise ValueError(f"compute_s must be >= 0, got {compute_s}")
        t = self.transfer_time_s(plan)
        if not overlap:
            return t + compute_s
        fill = min(self.setup_s, t)
        return fill + max(t - fill, compute_s)

    def plan_bytes(self, plan: "TransferPlan | PortedPlan") -> float:
        """Wire bytes the whole plan moves (compression applied per burst)."""
        cb = getattr(plan, "codec_bits", None)
        if isinstance(plan, PortedPlan):
            runs = [r for rr in plan.read_runs_by_port for r in rr]
            runs += [w for wr in plan.write_runs_by_port for w in wr]
        else:
            runs = list(plan.read_runs) + list(plan.write_runs)
        return sum(self.burst_bytes(r, cb) for r in runs)

    @property
    def setup_elems(self) -> float:
        """T_setup expressed in element-transfer time units (the burst-length
        "knee": runs much longer than this amortise the setup away)."""
        return self.setup_s * self.peak_bytes_per_s / self.elem_bytes


# The paper's AXI HP port: 64-bit @ 100 MHz = 800 MB/s; a non-burst access
# costs tens of cycles of addressing/DRAM latency.  25 cycles @ 100 MHz.
AXI_ZC706 = BurstModel(
    name="axi-zc706", peak_bytes_per_s=800e6, setup_s=250e-9, elem_bytes=8
)

# TPU v5e-class HBM: 819 GB/s, ~0.5 us per DMA descriptor (fixed issue +
# address-generation cost), bf16 elements.  The ratio setup*BW/elem_bytes
# plays the same role as the paper's burst-length knee.
TPU_V5E_HBM = BurstModel(
    name="tpu-v5e-hbm", peak_bytes_per_s=819e9, setup_s=0.5e-6, elem_bytes=2
)


@dataclasses.dataclass(frozen=True)
class BandwidthReport:
    scheme: str
    model: str
    raw_bw: float  # transferred (wire) bytes / time
    effective_bw: float  # useful (logical) bytes / time
    peak_fraction_raw: float
    peak_fraction_effective: float
    n_bursts: int
    redundancy: float
    n_ports: int = 1
    storage: str = "redundant"
    footprint: int | None = None  # whole-layout stored elements
    # measured-vs-modeled verification (``repro.core.cfa.calibrate``):
    # wall-clock seconds of the same schedule on this host, and the
    # modeled time's relative error against it; None when not measured
    measured_time_s: float | None = None
    model_error: float | None = None
    # dataflow accounting: the compute term folded into the time and
    # whether transfers were overlapped with it (Fig. 13 DATAFLOW)
    compute_s: float = 0.0
    overlap: bool = False

    @staticmethod
    def evaluate(
        plan: "TransferPlan | PortedPlan", model: BurstModel,
        measured_s: float | None = None,
        *, compute_s: float = 0.0, overlap: bool = False,
    ) -> "BandwidthReport":
        """Bandwidth of a plan under ``model``.

        For a :class:`PortedPlan` the time is the slowest port's (ports run
        concurrently), so raw/effective bandwidth are *aggregate* across
        ports and ``peak_fraction_*`` is relative to a single port's peak —
        an n-port plan can exceed 1.0, which is the point of §VII.  For a
        compressed plan ``raw_bw`` counts wire bytes (never above peak per
        port) while ``effective_bw`` counts the logical bytes delivered —
        compression can push it past the wire peak, which is the point of
        the Ferry-2024 layout.

        ``measured_s`` (a wall-clock measurement of the same schedule, see
        ``calibrate.measure_plan``) fills ``measured_time_s`` and the
        modeled time's relative error ``model_error``.  ``compute_s`` /
        ``overlap`` fold a per-tile compute term into the time the
        bandwidths divide by (``overlap=True`` hides the transfer behind it
        — the dataflow executor's schedule).
        """
        t = model.time(plan, compute_s=compute_s, overlap=overlap)
        raw = model.plan_bytes(plan) / t if t else 0.0
        eff = plan.useful * model.elem_bytes / t if t else 0.0
        err = None
        if measured_s is not None and measured_s > 0.0:
            err = abs(t - measured_s) / measured_s
        return BandwidthReport(
            scheme=plan.scheme,
            model=model.name,
            raw_bw=raw,
            effective_bw=eff,
            peak_fraction_raw=raw / model.peak_bytes_per_s,
            peak_fraction_effective=eff / model.peak_bytes_per_s,
            n_bursts=plan.n_bursts,
            redundancy=plan.redundancy,
            n_ports=getattr(plan, "n_ports", 1),
            storage=getattr(plan, "storage", "redundant"),
            footprint=getattr(plan, "footprint", None),
            measured_time_s=measured_s,
            model_error=err,
            compute_s=compute_s,
            overlap=overlap,
        )


def overlap_speedup(
    plan: "TransferPlan | PortedPlan", model: BurstModel, compute_s: float,
) -> dict:
    """Modeled gain of the dataflow schedule over sequential phases.

    Returns ``t_sequential_s`` (``transfer + compute``), ``t_overlapped_s``
    (Fig. 13 DATAFLOW pipelining, see :meth:`BurstModel.time`), their ratio
    ``speedup``, and the ``bound`` — the best speedup any overlap could give
    this plan, ``(T + C) / max(T, C)`` (2.0 at the balanced point).
    """
    t_seq = model.time(plan, compute_s=compute_s, overlap=False)
    t_ovl = model.time(plan, compute_s=compute_s, overlap=True)
    transfer = model.transfer_time_s(plan)
    best = max(transfer, compute_s)
    return {
        "transfer_s": transfer,
        "compute_s": compute_s,
        "t_sequential_s": t_seq,
        "t_overlapped_s": t_ovl,
        "speedup": t_seq / t_ovl if t_ovl > 0.0 else 1.0,
        "bound": t_seq / best if best > 0.0 else 1.0,
    }
