"""Analytic burst/DMA bandwidth model.

The paper measures raw and effective bandwidth on a Zynq ZC706 (64-bit AXI HP
port @ 100 MHz -> 800 MB/s peak).  This container has no FPGA and no TPU, so
we model the same first-order mechanics the paper exploits:

    time(plan) = sum over bursts ( T_setup + bytes / BW_peak )

A burst of length L amortises the fixed per-transaction cost T_setup over L
elements; element-wise access pays it per element.  This is exactly the
latency structure described in §II-E, and is the reason CFA's few-long-bursts
plans approach 100 % of the bus bandwidth in Fig. 15.

Two presets:

* ``AXI_ZC706``  — the paper's platform (calibration target for Fig. 15).
* ``TPU_V5E_HBM`` — the adaptation target: HBM @ 819 GB/s behind DMA engines
  with a per-descriptor setup cost; "burst" = one contiguous DMA extent.
"""
from __future__ import annotations

import dataclasses

from .plans import TransferPlan

__all__ = ["BurstModel", "AXI_ZC706", "TPU_V5E_HBM", "BandwidthReport"]


@dataclasses.dataclass(frozen=True)
class BurstModel:
    name: str
    peak_bytes_per_s: float
    setup_s: float  # fixed cost per burst/DMA descriptor
    elem_bytes: int

    def time_s(self, runs: tuple[int, ...]) -> float:
        return sum(
            self.setup_s + (r * self.elem_bytes) / self.peak_bytes_per_s for r in runs
        )


# The paper's AXI HP port: 64-bit @ 100 MHz = 800 MB/s; a non-burst access
# costs tens of cycles of addressing/DRAM latency.  25 cycles @ 100 MHz.
AXI_ZC706 = BurstModel(
    name="axi-zc706", peak_bytes_per_s=800e6, setup_s=250e-9, elem_bytes=8
)

# TPU v5e-class HBM: 819 GB/s, ~0.5 us per DMA descriptor (fixed issue +
# address-generation cost), bf16 elements.  The ratio setup*BW/elem_bytes
# plays the same role as the paper's burst-length knee.
TPU_V5E_HBM = BurstModel(
    name="tpu-v5e-hbm", peak_bytes_per_s=819e9, setup_s=0.5e-6, elem_bytes=2
)


@dataclasses.dataclass(frozen=True)
class BandwidthReport:
    scheme: str
    model: str
    raw_bw: float  # transferred bytes / time
    effective_bw: float  # useful bytes / time
    peak_fraction_raw: float
    peak_fraction_effective: float
    n_bursts: int
    redundancy: float

    @staticmethod
    def evaluate(plan: TransferPlan, model: BurstModel) -> "BandwidthReport":
        t = model.time_s(plan.read_runs) + model.time_s(plan.write_runs)
        raw = plan.transferred * model.elem_bytes / t if t else 0.0
        eff = plan.useful * model.elem_bytes / t if t else 0.0
        return BandwidthReport(
            scheme=plan.scheme,
            model=model.name,
            raw_bw=raw,
            effective_bw=eff,
            peak_fraction_raw=raw / model.peak_bytes_per_s,
            peak_fraction_effective=eff / model.peak_bytes_per_s,
            n_bursts=plan.n_bursts,
            redundancy=plan.redundancy,
        )
