"""Canonical <-> facet storage conversion in pure JAX.

``pack`` materialises the CFA facet arrays from a canonical (row-major) value
volume; ``unpack_into`` scatters facet contents back.  Both are compositions
of reshape / static-take / transpose only (no dynamic gathers), so they jit
and differentiate cleanly.  They exist for round-trip validation, for
importing live-in data, and for exporting results — the execution pipeline
itself (transform.py) writes facet blocks directly and never materialises the
canonical volume.

Both directions understand the irredundant storage discipline
(``repro.core.cfa.irredundant``): ``pack_all(..., storage_map=...)`` zeroes
the non-owned slots it would otherwise duplicate into, and
``unpack_into(..., owned=...)`` scatters only owned slots — so a
deduplicated payload round-trips without the dead zeros clobbering values
another facet owns.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .facets import FacetSpec

__all__ = ["pack_facet", "pack_all", "unpack_into"]


def _check_packable(spec: FacetSpec) -> None:
    """The pack/unpack legality gate: w | t_k, so the modulo labelling is
    tile-independent.  Raised up front by every public entry point (not just
    the ``_modulo_perm`` internals) so callers never pay partial reshape
    work — or trip an unrelated reshape error — before the documented
    ``ValueError``."""
    t_k, w = spec.tile_sizes[spec.axis], spec.width
    if t_k % w:
        raise ValueError(
            f"pack/unpack require w | t on axis {spec.axis} (t={t_k}, w={w}); "
            "use the sweep executor for tile-dependent modulo labelling"
        )


def _modulo_perm(spec: FacetSpec) -> np.ndarray:
    """Map slab position j (0..w-1, i.e. x_k = t_k - w + j within the tile) to
    the paper's modulo coordinate m = x_k mod w.  Requires w | t_k so the
    labelling is tile-independent (always true for the Table I suite; the
    sweep executor handles the general case tile-by-tile)."""
    _check_packable(spec)
    t_k, w = spec.tile_sizes[spec.axis], spec.width
    return np.array([(t_k - w + j) % w for j in range(w)], dtype=np.int64)


def _interleaved(spec: FacetSpec, volume_shape: tuple[int, ...]) -> list[int]:
    shape = []
    for a in range(spec.ndim):
        nt = volume_shape[a] // spec.tile_sizes[a]
        shape += [nt, spec.tile_sizes[a]]
    return shape


def pack_facet(volume: jnp.ndarray, spec: FacetSpec) -> jnp.ndarray:
    """Extract facet array ``spec`` from a canonical value volume."""
    _check_packable(spec)
    d = spec.ndim
    t_k, w, k = spec.tile_sizes[spec.axis], spec.width, spec.axis
    W = volume.reshape(_interleaved(spec, volume.shape))  # (q0, r0, q1, r1, ...)
    rdim = 2 * k + 1
    # tail slab along axis k, then relabel to the modulo coordinate
    W = jnp.moveaxis(W, rdim, -1)[..., t_k - w :]
    perm = _modulo_perm(spec)
    inv = np.argsort(perm)  # modulo index m -> slab position j
    W = jnp.moveaxis(W[..., inv], -1, rdim)
    order = [2 * a for a in spec.outer_axes] + [2 * a + 1 for a in spec.inner_axes]
    return W.transpose(order)


def pack_all(volume: jnp.ndarray, specs: dict[int, FacetSpec],
             storage_map=None) -> dict[int, jnp.ndarray]:
    """Pack every facet; with an irredundant ``storage_map``
    (:class:`repro.core.cfa.irredundant.StorageMap`), non-owned slots are
    zeroed — the exact payload an irredundant execution commits.

    Validates w | t for *all* facets up front, so a mixed family fails with
    the documented ``ValueError`` before any array is materialised.
    """
    for s in specs.values():
        _check_packable(s)
    packed = {k: pack_facet(volume, s) for k, s in specs.items()}
    if storage_map is None:
        return packed
    from .irredundant import dedup_facets

    return dedup_facets(packed, storage_map)


def unpack_into(volume: jnp.ndarray, facet: jnp.ndarray, spec: FacetSpec,
                owned: np.ndarray | None = None) -> jnp.ndarray:
    """Scatter a facet array's contents back into a canonical volume.

    ``owned`` (the facet's mask from an irredundant
    :class:`~repro.core.cfa.irredundant.StorageMap`, in block/inner-dims
    order) restricts the scatter to owned slots, so a deduplicated facet's
    dead zeros never clobber canonical points another facet owns.
    """
    _check_packable(spec)
    d = spec.ndim
    t_k, w, k = spec.tile_sizes[spec.axis], spec.width, spec.axis
    order = [2 * a for a in spec.outer_axes] + [2 * a + 1 for a in spec.inner_axes]
    inv_order = np.argsort(order)
    W = facet.transpose(list(inv_order))  # back to (q0, r0(, modulo on k), ...)
    rdim = 2 * k + 1
    perm = _modulo_perm(spec)  # slab position j -> modulo index m
    W = jnp.moveaxis(jnp.moveaxis(W, rdim, -1)[..., perm], -1, rdim)
    V = volume.reshape(_interleaved(spec, volume.shape))
    idx = [slice(None)] * (2 * d)
    idx[rdim] = slice(t_k - w, t_k)
    if owned is not None:
        # the mask lives in block (inner-dims) order and is constant along
        # the modulo axis; route it through the same transpose/moveaxis as
        # the data, then let the interleaved (q, r) dims broadcast over it
        M = np.broadcast_to(np.asarray(owned, bool), facet.shape)
        M = M.transpose(list(inv_order))
        M = np.moveaxis(np.moveaxis(M, rdim, -1)[..., perm], -1, rdim)
        W = jnp.where(jnp.asarray(M), W, V[tuple(idx)])
    V = V.at[tuple(idx)].set(W)
    return V.reshape(volume.shape)
