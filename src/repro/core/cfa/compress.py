"""Fixed-ratio per-block compression codecs for facet storage (pure JAX).

The irredundant-layout follow-up to the source paper (Ferry et al., 2024,
*An Irredundant and Compressed Data Layout to Optimize Bandwidth Utilization
of FPGA Accelerators*) pairs deduplicated facet storage with a *fixed-ratio*
block compression: every facet block is stored in a statically known number
of bits, so burst lengths — and the DMA descriptors that move them — stay
compile-time constants while each burst carries fewer bytes.  This module is
that codec, adapted to JAX:

* **XOR-delta + bit-pack** (:class:`BlockCodec` with ``bits`` in {8,16,32}):
  a block is flattened, consecutive raw words are XOR'd (smooth stencil data
  makes neighbouring bit patterns agree in their high bits, so residuals
  concentrate near zero *in the high-order sense*), each residual keeps its
  ``bits`` high-order bits, and residuals are packed densely into words.
  The first element of each block is stored raw (the per-block header), so
  the stored size is exactly ``elem_bits + (n-1) * bits`` — fixed ratio.
* **lossless iff the dropped low-order residual bits are zero**: the codec
  never changes burst *structure*, only bytes-per-burst, and
  :meth:`BlockCodec.exact` reports whether a given block round-trips
  bit-identically (the tests pin this on bit-truncated data;
  :meth:`BlockCodec.roundtrip` is what the compressed execution pipeline
  stores, so results always reflect what compression preserved).

Everything is shape-static (reshape / shift / or / ``associative_scan``
with XOR), hence jit-compatible; the transfer-time effect is modeled by
``BurstModel`` via ``TransferPlan.codec_bits`` (reduced bytes per burst).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["BlockCodec", "CODECS", "DEFAULT_CODEC", "get_codec", "stored_bits"]


def stored_bits(n_elems: int, elem_bits: int, bits: int | None) -> int:
    """Fixed-ratio stored size of an ``n_elems`` run of ``elem_bits`` words:
    one raw header word + ``bits``-wide residuals (``None``/0 =
    uncompressed).  The single size formula shared by the codec's footprint
    accounting and ``BurstModel``'s bytes-per-burst model — change the
    framing here and both stay consistent."""
    if n_elems <= 0:
        return 0
    if not bits:
        return n_elems * elem_bits
    return elem_bits + (n_elems - 1) * min(bits, elem_bits)


def _uint_dtype(itemsize: int):
    try:
        return {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32, 8: jnp.uint64}[itemsize]
    except KeyError:
        raise ValueError(f"unsupported element width: {itemsize} bytes") from None


@dataclasses.dataclass(frozen=True)
class BlockCodec:
    """Fixed-ratio XOR-delta bit-packing of one storage block.

    ``bits`` is the stored width of each residual (``0`` marks the identity
    codec ``raw``: no transform, ratio 1.0).  Residuals keep their *high*
    ``bits`` bits — the sign/exponent end of IEEE words — so truncation
    degrades mantissa tails first, and data whose XOR-deltas fit in ``bits``
    high bits round-trips exactly.
    """

    name: str
    bits: int

    def __post_init__(self) -> None:
        if self.bits < 0:
            raise ValueError(f"codec bits must be >= 0: {self.bits}")
        if self.bits and self.bits not in (8, 16, 32):
            raise ValueError(
                f"fixed-ratio packing needs bits in (8, 16, 32): {self.bits}"
            )

    # -- the model-side knob -------------------------------------------------

    def stored_bits(self, n_elems: int, elem_bits: int) -> int:
        """Exact stored size of an ``n_elems`` block of ``elem_bits`` words
        (one raw header word + fixed-width residuals)."""
        return stored_bits(n_elems, elem_bits, self.bits)

    def ratio(self, n_elems: int, elem_bits: int = 32) -> float:
        """stored bits / raw bits for an ``n_elems`` block (<= 1.0)."""
        if n_elems <= 0:
            return 1.0
        return self.stored_bits(n_elems, elem_bits) / (n_elems * elem_bits)

    # -- pure-JAX encode / decode -------------------------------------------

    def _widths(self, dtype) -> tuple[int, int]:
        elem_bits = 8 * np.dtype(dtype).itemsize
        b = min(self.bits, elem_bits) if self.bits else elem_bits
        if elem_bits % b:
            raise ValueError(
                f"codec {self.name!r}: {b} residual bits do not pack into "
                f"{elem_bits}-bit words"
            )
        return elem_bits, b

    def encode(self, block: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
        """-> (header, packed): the raw first word and the densely packed
        high-``bits`` XOR residuals of the flattened block."""
        u = _uint_dtype(np.dtype(block.dtype).itemsize)
        x = jax.lax.bitcast_convert_type(block, u).ravel()
        elem_bits, b = self._widths(block.dtype)
        header = x[:1]
        if not self.bits or x.size <= 1:
            return header, x[1:]
        resid = (x[1:] ^ x[:-1]) >> (elem_bits - b)  # keep the high bits
        per = elem_bits // b  # residuals per packed word
        pad = (-resid.size) % per
        resid = jnp.pad(resid, (0, pad)).reshape(-1, per)
        packed = jnp.zeros(resid.shape[0], dtype=u)
        for i in range(per):
            packed = packed | (resid[:, i] << i * b)
        return header, packed

    def decode(self, header: jnp.ndarray, packed: jnp.ndarray,
               shape: tuple[int, ...], dtype) -> jnp.ndarray:
        """Inverse of :meth:`encode` (up to the dropped low-order bits)."""
        u = _uint_dtype(np.dtype(dtype).itemsize)
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        elem_bits, b = self._widths(dtype)
        if not self.bits or n <= 1:
            words = jnp.concatenate([header, packed])[:n]
            return jax.lax.bitcast_convert_type(words, dtype).reshape(shape)
        per = elem_bits // b
        mask = jnp.asarray((1 << b) - 1, dtype=u)  # b <= elem_bits, so it fits
        resid = jnp.stack(
            [(packed >> i * b) & mask for i in range(per)],
            axis=1,
        ).ravel()[: n - 1]
        deltas = resid << (elem_bits - b)  # low-order bits are lost
        words = jax.lax.associative_scan(
            jnp.bitwise_xor, jnp.concatenate([header, deltas])
        )
        return jax.lax.bitcast_convert_type(words, dtype).reshape(shape)

    def roundtrip(self, block: jnp.ndarray) -> jnp.ndarray:
        """What storage retains: ``decode(encode(block))`` — bit-identical
        when the data's XOR-deltas fit the ratio, truncated otherwise."""
        if not self.bits:
            return block
        header, packed = self.encode(block)
        return self.decode(header, packed, tuple(block.shape), block.dtype)

    def exact(self, block: jnp.ndarray) -> bool:
        """True iff the block survives the fixed ratio bit-identically."""
        a = jnp.asarray(block)
        return bool((self.roundtrip(a) == a).all())


#: Registered codecs: ``raw`` is the identity (ratio 1.0, always exact);
#: ``deltapack{8,16,32}`` keep that many high residual bits per element.
CODECS: dict[str, BlockCodec] = {
    "raw": BlockCodec("raw", bits=0),
    "deltapack8": BlockCodec("deltapack8", bits=8),
    "deltapack16": BlockCodec("deltapack16", bits=16),
    "deltapack32": BlockCodec("deltapack32", bits=32),
}

DEFAULT_CODEC = "deltapack16"


def get_codec(codec: "BlockCodec | str | None") -> BlockCodec:
    """Resolve a codec name (or pass a :class:`BlockCodec` through);
    ``None`` means :data:`DEFAULT_CODEC`."""
    if codec is None:
        return CODECS[DEFAULT_CODEC]
    if isinstance(codec, BlockCodec):
        return codec
    try:
        return CODECS[codec]
    except KeyError:
        raise ValueError(
            f"unknown codec {codec!r}; registered: {sorted(CODECS)}"
        ) from None
