"""Layout autotuner: search the CFA layout family for the fastest layout.

The paper evaluates *one* layout per benchmark — the final CFA family with
cyclic extension directions, intra-tile contiguity, and a hand-picked tile
size (Table I).  Iris (Soldavini et al., 2022) and the irredundant-layout
follow-up (Ferry et al., 2024) both show the real bandwidth wins come from
*searching* the layout space per workload.  This module is that search:

    given   a StencilProgram, an IterSpace and a BurstModel,
    explore  candidate Tilings x extension-direction assignments x
             contiguity levels (full-tile / inter-tile / intra-tile, §IV-G/H/I)
             x port repartitions (``n_ports > 1``, §VII future work),
             plus the paper's three baselines as hand-coded seeds,
    score    each candidate's interior-tile TransferPlan under the BurstModel
             (modeled effective bandwidth = useful bytes / modeled time; with
             ``n_ports > 1`` the time is the slowest port's after the best
             ``multiport`` repartition, so layout and repartition co-tune),
    return   a ranked LayoutDecision (carrying the winning port assignment).

The hand-coded plans (``cfa_plan`` at the program's default tile,
``original_layout_plan``, ``bounding_box_plan``, ``data_tiling_plan``) are
always seeded into the candidate set, so the decision's best candidate scores
at least as well as every baseline by construction.

Decisions are memoised in a persistent on-disk cache keyed by
(program, space, model, search parameters) so repeated runs are free.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import math
import os
import tempfile
import warnings
from pathlib import Path
from typing import Sequence

import numpy as np

from .bandwidth import AXI_ZC706, BandwidthReport, BurstModel, PortedPlan
from .compress import get_codec
from .facets import CONTIGUITY_LEVELS, extension_dir
from .irredundant import STORAGE_MODES
from .multiport import PORT_STRATEGIES, PortAssignment, best_repartition
from .plans import (
    TransferPlan,
    bounding_box_plan,
    cfa_plan,
    data_tiling_plan,
    interior_tile,
    original_layout_plan,
)
from .programs import StencilProgram, get_program
from .spaces import IterSpace, Tiling

__all__ = [
    "LayoutCandidate",
    "ScoredLayout",
    "LayoutDecision",
    "CacheSchemaError",
    "SCORE_MODES",
    "autotune",
    "candidate_tilings",
    "hand_coded_baselines",
    "default_cache_dir",
    "clear_cache",
]

# v7: the pass-pipeline fingerprint (repro.core.cfa.passes) — the ordered
# (pass name, version) list of the lowering that ran the search is folded
# into the cache key AND stored on the decision (``pass_pipeline``), and
# the loader rejects a fingerprint mismatch loudly: a decision computed by
# one lowering (e.g. before a pass was reordered, added or re-versioned)
# must not silently drive another.
# v6: the dataflow overlap axis (Fig. 13 DATAFLOW, ``backend="dataflow"``)
# — decision-level ``overlap`` + ``compute_per_elem_s`` knobs, per-candidate
# overlap/compute_s fields on ScoredLayout (time_s becomes the overlapped
# tile time when enabled), both folded into the cache key; the executor
# capability fingerprint also grew the per-backend overlap flag.
# v5: the score axis (modeled / measured wall-clock ranking, see
# ``calibrate``) — decision-level ``score``, per-candidate
# measured_time_s/model_error on ScoredLayout, score + host fingerprint +
# measurement fidelity folded into the cache key, and a loud score-mismatch
# rejection in the cache loader so modeled- and measured-scored decisions
# can never be interchanged.
# v4: storage axis (redundant / irredundant / compressed facet storage,
# Ferry 2024) — per-candidate footprint/stored_elems/codec_bits fields on
# ScoredLayout, decision-level storage + footprint_weight, and both folded
# into the cache key.
# v3: the cache key folds in the registered executor-backend capability
# set (next to the target model identity it already carried), so decisions
# re-search when the backend envelope changes; older schemas are rejected
# loudly (CacheSchemaError -> warning) instead of silently deserializing.
# v2: n_ports search dimension + per-candidate port fields (ScoredLayout)
# and the decision-level n_ports.
_CACHE_VERSION = 7

# how a candidate's rank is scored: by the analytic BurstModel, or by
# measured wall-clock of the top modeled candidates (calibrate.measure_plan)
SCORE_MODES = ("modeled", "measured")


class CacheSchemaError(ValueError):
    """An on-disk autotune decision uses a different cache schema version."""


# --------------------------------------------------------------------------
# Candidates
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayoutCandidate:
    """One point of the layout search space.

    ``scheme`` is one of ``cfa`` (the paper's facet family), ``original``
    (Bayliss [16]), ``bbox`` (Pouchet [8]) or ``data-tiling`` (Ozturk [19]).
    ``ext_dirs``/``contiguity`` parameterise the CFA family (§IV-H/I);
    ``block`` parameterises data tiling.
    """

    scheme: str
    tile: tuple[int, ...]
    ext_dirs: tuple[tuple[int, int], ...] | None = None  # (facet axis, c_k)
    contiguity: str | None = None
    block: tuple[int, ...] | None = None

    @property
    def key(self) -> str:
        """Canonical, deterministic identity string (also the rank tiebreak)."""
        parts = [self.scheme, "x".join(map(str, self.tile))]
        if self.ext_dirs is not None:
            parts.append("e" + ",".join(f"{k}:{c}" for k, c in self.ext_dirs))
        if self.contiguity is not None:
            parts.append(self.contiguity)
        if self.block is not None:
            parts.append("b" + "x".join(map(str, self.block)))
        return "/".join(parts)

    def plan(self, space: IterSpace, program: StencilProgram, *,
             storage: str = "redundant", codec=None) -> TransferPlan:
        """The candidate's interior-tile transfer plan.

        ``storage``/``codec`` select the facet storage discipline for CFA
        candidates (``cfa_plan``); the single-array baselines keep their own
        (duplicate-free by construction) storage accounting.
        """
        tiling = Tiling(self.tile)
        tile = interior_tile(space, tiling)
        if self.scheme == "cfa":
            return cfa_plan(
                space,
                program.deps,
                tiling,
                tile,
                ext_dirs=dict(self.ext_dirs) if self.ext_dirs is not None else None,
                contiguity=self.contiguity or "intra-tile",
                storage=storage,
                codec=codec if storage == "compressed" else None,
            )
        if self.scheme == "original":
            return original_layout_plan(space, program.deps, tiling, tile)
        if self.scheme == "bbox":
            return bounding_box_plan(space, program.deps, tiling, tile)
        if self.scheme == "data-tiling":
            return data_tiling_plan(space, program.deps, tiling, tile, block=self.block)
        raise ValueError(f"unknown layout scheme {self.scheme!r}")

    def is_default_cfa_layout(self, ndim: int) -> bool:
        """True iff this is the paper's final layout family (the only one the
        ``facet_fetch`` Pallas kernel's BlockSpecs hard-code)."""
        if self.scheme != "cfa" or (self.contiguity or "intra-tile") != "intra-tile":
            return False
        if self.ext_dirs is None:
            return True
        return all(c == extension_dir(k, ndim) for k, c in self.ext_dirs)


@dataclasses.dataclass(frozen=True)
class ScoredLayout:
    """A candidate plus its BurstModel score (per interior tile).

    With ``n_ports > 1`` the *time and bandwidth* figures describe the
    candidate after its best port repartition: ``time_s`` is the slowest
    port's time (ports run concurrently), ``raw_bw``/``effective_bw`` are
    aggregate across ports, and ``port_strategy``/``port_assignment``/
    ``port_balance``/``port_speedup_vs_single`` record how the repartition
    was realised (assignment is ``None`` for burst-granular strategies,
    which split below facet granularity).  The *layout* figures —
    ``n_read_bursts``/``n_write_bursts``/``transferred``/``useful``/
    ``redundancy`` — always describe the underlying single-port plan (a
    ``stripe`` split issues more, shorter bursts; that cost is reflected in
    ``time_s``, not re-counted here).
    """

    candidate: LayoutCandidate
    n_read_bursts: int
    n_write_bursts: int
    transferred: int  # elements moved (incl. redundancy)
    useful: int  # elements actually needed
    redundancy: float
    time_s: float  # modeled transfer time for one interior tile
    raw_bw: float
    effective_bw: float  # useful bytes / modeled time — the ranking metric
    peak_fraction_effective: float
    n_ports: int = 1
    port_strategy: str | None = None
    port_assignment: tuple[tuple[int, int], ...] | None = None  # facet -> port
    port_balance: float | None = None
    port_speedup_vs_single: float | None = None
    # storage axis (schema v4): discipline, whole-layout stored elements,
    # per-tile stored slots, fixed-ratio compression width
    storage: str = "redundant"
    footprint: int | None = None
    stored_elems: int | None = None
    codec_bits: int | None = None
    # measured scoring (schema v5): wall-clock of this candidate's plan on
    # this host and the modeled time's relative error against it; filled
    # for the measured top candidates of an autotune(score="measured") run
    measured_time_s: float | None = None
    model_error: float | None = None
    # dataflow axis (schema v6): the per-tile compute seconds folded into
    # time_s, and whether the transfer was overlapped with it (Fig. 13
    # DATAFLOW — the schedule backend="dataflow" runs)
    overlap: bool = False
    compute_s: float = 0.0

    @property
    def n_bursts(self) -> int:
        return self.n_read_bursts + self.n_write_bursts

    @staticmethod
    def from_plan(
        candidate: LayoutCandidate,
        plan: TransferPlan,
        model: BurstModel,
        *,
        n_ports: int = 1,
        port_strategies: Sequence[str] = PORT_STRATEGIES,
        overlap: bool = False,
        compute_s: float = 0.0,
    ) -> "ScoredLayout":
        tkw = dict(compute_s=compute_s, overlap=overlap)
        t = t_single = model.time(plan, **tkw)
        ports: dict = {}
        scored_plan: TransferPlan | PortedPlan = plan
        if n_ports > 1:
            pp = best_repartition(plan, n_ports, model, port_strategies,
                                  **tkw)
            t = model.time(pp, **tkw)
            scored_plan = pp
            ports = dict(
                n_ports=n_ports,
                port_strategy=pp.strategy,
                port_assignment=pp.facet_to_port,
                port_balance=pp.balance,
                port_speedup_vs_single=t_single / t if t else 1.0,
            )
        rep = BandwidthReport.evaluate(scored_plan, model, **tkw)
        return ScoredLayout(
            overlap=overlap,
            compute_s=compute_s,
            candidate=candidate,
            n_read_bursts=plan.n_read_bursts,
            n_write_bursts=plan.n_write_bursts,
            transferred=plan.transferred,
            useful=plan.useful,
            redundancy=plan.redundancy,
            time_s=t,
            raw_bw=rep.raw_bw,
            effective_bw=rep.effective_bw,
            peak_fraction_effective=rep.peak_fraction_effective,
            storage=plan.storage,
            footprint=plan.footprint,
            stored_elems=plan.stored_elems,
            codec_bits=plan.codec_bits,
            **ports,
        )


def _rank_key(s: ScoredLayout, footprint_weight: float = 0.0) -> tuple:
    # Highest effective bandwidth first; deterministic tiebreaks.  With a
    # footprint weight the objective becomes bandwidth per stored element
    # (to the ``footprint_weight`` power): weight 0 ranks purely by speed,
    # weight 1 by effective bytes/s per slot the layout keeps resident —
    # the footprint axis of the trade-off curve.
    # Measured candidates (score="measured", schema v5) outrank unmeasured
    # ones and sort by their wall-clock; in a modeled decision no candidate
    # carries a measurement, so the leading pair is constant and the order
    # is the pure-model ranking below.
    eff = s.effective_bw
    if footprint_weight and s.footprint:
        eff = eff / (s.footprint ** footprint_weight)
    measured = (0, s.measured_time_s) if s.measured_time_s is not None else (1, 0.0)
    return (*measured, -eff, s.n_bursts, s.redundancy, s.candidate.key)


# --------------------------------------------------------------------------
# Decision
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayoutDecision:
    """Ranked outcome of one autotuning run (JSON round-trippable)."""

    program: str
    space: tuple[int, ...]
    widths: tuple[int, ...]
    model: str
    seed: int
    budget: int
    evaluated: int
    ranked: tuple[ScoredLayout, ...]  # best first
    n_ports: int = 1
    storage: str = "redundant"  # facet storage discipline searched under
    codec: str | None = None  # block codec name (storage="compressed" only)
    footprint_weight: float = 0.0  # footprint exponent in the ranking
    score: str = "modeled"  # ranking basis: analytic model or measured clock
    # dataflow axis (schema v6): rank by the overlapped tile time with this
    # much compute per tile element (seconds)
    overlap: bool = False
    compute_per_elem_s: float = 0.0
    # pass-pipeline axis (schema v7): the ordered (name, version)
    # fingerprint of the lowering pipeline this decision was searched for
    pass_pipeline: tuple[tuple[str, str], ...] | None = None
    from_cache: bool = dataclasses.field(default=False, compare=False)

    @property
    def best(self) -> ScoredLayout:
        return self.ranked[0]

    @property
    def port_assignment(self) -> PortAssignment | None:
        """The winning CFA candidate's facet->port repartition, if any.

        ``None`` for single-port decisions and for winners whose best
        repartition is burst-granular (``stripe`` / ``burst-lpt`` split below
        the facet, so there is no whole-facet assignment to report).
        """
        try:
            s = self.best_cfa()
        except LookupError:
            return None
        if s.n_ports <= 1 or s.port_assignment is None:
            return None
        from .programs import get_program

        plan = s.candidate.plan(IterSpace(self.space), get_program(self.program),
                                storage=self.storage, codec=self.codec)
        f2p = dict(s.port_assignment)
        loads = [0.0] * s.n_ports
        for length, k in zip(plan.read_runs, plan.read_run_hosts or ()):
            loads[f2p[k]] += length
        for length, k in zip(plan.write_runs, plan.write_run_hosts or ()):
            loads[f2p[k]] += length
        return PortAssignment(
            n_ports=s.n_ports,
            facet_to_port=f2p,
            port_bytes=tuple(loads),
        )

    def best_cfa(self, *, kernel_compatible: bool = False) -> ScoredLayout:
        """Best CFA-family candidate (facet storage is what the pipeline and
        the Pallas kernels consume).

        ``kernel_compatible`` further restricts to layouts the
        ``facet_fetch`` kernel's static BlockSpecs can address: 3-D spaces
        only (the kernel's block maps are 3-D), the paper's default layout,
        facet widths dividing the tile, and at least two tiles per axis (so
        an interior exists).
        """
        d = len(self.space)
        if kernel_compatible and d != 3:
            raise LookupError(
                f"the facet_fetch kernel addresses 3-D layouts only; "
                f"{self.program} @ {self.space} is {d}-D"
            )
        for s in self.ranked:
            c = s.candidate
            if c.scheme != "cfa":
                continue
            if kernel_compatible:
                if not c.is_default_cfa_layout(d):
                    continue
                if any(w and t % w for w, t in zip(self.widths, c.tile)):
                    continue
                if any(n // t < 2 for n, t in zip(self.space, c.tile)):
                    continue
            return s
        raise LookupError(
            f"no {'kernel-compatible ' if kernel_compatible else ''}CFA candidate "
            f"in decision for {self.program} @ {self.space}"
        )

    # -- serialisation ------------------------------------------------------

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        d.pop("from_cache")
        d["version"] = _CACHE_VERSION
        return json.dumps(d, indent=1)

    @staticmethod
    def from_json(text: str) -> "LayoutDecision":
        d = json.loads(text)
        version = d.pop("version", None)
        if version != _CACHE_VERSION:
            raise CacheSchemaError(
                f"autotune cache schema v{version}, need v{_CACHE_VERSION} "
                f"(v7 adds the pass-pipeline fingerprint — the ordered "
                f"name/version list of the lowering that ran the search — "
                f"on top of the v6 dataflow overlap axis, the v5 scoring "
                f"basis, the v4 storage discipline and the v3 target + "
                f"backend capability set); delete the stale file "
                f"or clear_cache() to re-search"
            )
        ranked = []
        for s in d.pop("ranked"):
            c = s.pop("candidate")
            cand = LayoutCandidate(
                scheme=c["scheme"],
                tile=tuple(c["tile"]),
                ext_dirs=tuple(map(tuple, c["ext_dirs"])) if c["ext_dirs"] is not None else None,
                contiguity=c["contiguity"],
                block=tuple(c["block"]) if c["block"] is not None else None,
            )
            pa = s.get("port_assignment")
            if pa is not None:
                s["port_assignment"] = tuple((int(k), int(p)) for k, p in pa)
            ranked.append(ScoredLayout(candidate=cand, **s))
        return LayoutDecision(
            program=d["program"],
            space=tuple(d["space"]),
            widths=tuple(d["widths"]),
            model=d["model"],
            seed=d["seed"],
            budget=d["budget"],
            evaluated=d["evaluated"],
            ranked=tuple(ranked),
            n_ports=d.get("n_ports", 1),
            storage=d.get("storage", "redundant"),
            codec=d.get("codec"),
            footprint_weight=d.get("footprint_weight", 0.0),
            score=d.get("score", "modeled"),
            overlap=d.get("overlap", False),
            compute_per_elem_s=d.get("compute_per_elem_s", 0.0),
            pass_pipeline=(tuple((str(n), str(v)) for n, v in d["pass_pipeline"])
                           if d.get("pass_pipeline") is not None else None),
        )

    def summary(self, top: int = 8) -> str:
        """Human-readable ranking table (used by the hillclimb CLI)."""
        lines = [
            f"{self.program} @ space {self.space}  model={self.model}  "
            f"seed={self.seed}  evaluated={self.evaluated} candidates"
            f"{f'  ports={self.n_ports}' if self.n_ports > 1 else ''}"
            f"{f'  storage={self.storage}' if self.storage != 'redundant' else ''}"
            f"{f'  score={self.score}' if self.score != 'modeled' else ''}"
            f"{'  overlap' if self.overlap else ''}"
            f"{'  [cache]' if self.from_cache else ''}",
            f"{'rank':>4} {'eff-bw':>8} {'raw-bw':>8} {'bursts':>6} "
            f"{'redun':>6}  candidate",
        ]
        for i, s in enumerate(self.ranked[:top]):
            peak = s.effective_bw / s.peak_fraction_effective if s.peak_fraction_effective else 0.0
            raw_frac = s.raw_bw / peak if peak else 0.0
            port = f"  [{s.port_strategy} x{s.n_ports}]" if s.n_ports > 1 else ""
            lines.append(
                f"{i:>4} {s.peak_fraction_effective:>7.1%} {raw_frac:>7.1%} "
                f"{s.n_bursts:>6} {s.redundancy:>6.1%}  {s.candidate.key}{port}"
            )
        return "\n".join(lines)


# --------------------------------------------------------------------------
# Candidate enumeration
# --------------------------------------------------------------------------


def candidate_tilings(
    widths: Sequence[int],
    space_sizes: Sequence[int],
    *,
    max_halo_elems: int | None = 64 * 1024,
) -> list[tuple[int, ...]]:
    """Legal rectangular tilings: per axis, divisors of N_a in [w_a, N_a).

    A tile spanning a whole axis degenerates the tiling (no flow across that
    axis), so it is only allowed when no proper divisor fits the facet width.
    ``max_halo_elems`` bounds the on-chip halo buffer prod(t_a + w_a) — the
    paper's BRAM constraint, our VMEM constraint.  Deterministic order:
    descending tile volume (longer bursts first), then lexicographic.

    The enumeration is per-dimension (one divisor list per axis, product
    across axes), so 2-D and 4-D spaces get search spaces of the right
    shape automatically; the seeded sampling in ``autotune`` keeps the
    larger d >= 4 products within budget.
    """
    per_axis: list[list[int]] = []
    for n, w in zip(space_sizes, widths):
        lo = max(1, w)
        divs = [t for t in range(lo, n + 1) if n % t == 0]
        proper = [t for t in divs if t < n]
        per_axis.append(proper or divs)
    out = []
    for t in itertools.product(*per_axis):
        halo = math.prod(ta + wa for ta, wa in zip(t, widths))
        if max_halo_elems is not None and halo > max_halo_elems:
            continue
        out.append(t)
    out.sort(key=lambda t: (-math.prod(t), t))
    return out


def _ext_dir_assignments(widths: Sequence[int]) -> list[tuple[tuple[int, int], ...]]:
    """All per-facet extension-direction assignments (c_k != k, §IV-H)."""
    d = len(widths)
    axes = [k for k in range(d) if widths[k] > 0]
    if d == 1:
        return [tuple((k, k) for k in axes)]
    choices = [[(k, c) for c in range(d) if c != k] for k in axes]
    return [tuple(combo) for combo in itertools.product(*choices)]


def hand_coded_baselines(
    program: StencilProgram,
    space: IterSpace,
    model: BurstModel,
    tile: Sequence[int] | None = None,
    *,
    n_ports: int = 1,
    port_strategies: Sequence[str] = PORT_STRATEGIES,
    storage: str = "redundant",
    codec=None,
    overlap: bool = False,
    compute_per_elem_s: float = 0.0,
) -> dict[str, ScoredLayout]:
    """The paper's hand-coded plans at one tile size, scored under ``model``.

    These are the seeds the autotuner must beat (or match): ``cfa_plan`` with
    the default layout, ``original_layout_plan``, ``bounding_box_plan``, and
    ``data_tiling_plan`` with the block-size sweep of Fig. 15.  With
    ``n_ports > 1`` each baseline is also given its best repartition (the
    single-array baselines can only use burst-granular strategies), keeping
    the comparison against multi-port CFA candidates apples-to-apples.
    """
    t = tuple(tile) if tile is not None else program.default_tile
    cands = {
        "cfa": LayoutCandidate("cfa", t, contiguity="intra-tile"),
        "original": LayoutCandidate("original", t),
        "bbox": LayoutCandidate("bbox", t),
    }
    for div in (1, 2, 4):
        blk = tuple(max(1, x // div) for x in t)
        cands[f"data-tiling/{div}"] = LayoutCandidate("data-tiling", t, block=blk)
    out = {}
    for name, cand in cands.items():
        out[name] = ScoredLayout.from_plan(
            cand, cand.plan(space, program, storage=storage, codec=codec),
            model, n_ports=n_ports, port_strategies=port_strategies,
            overlap=overlap,
            compute_s=compute_per_elem_s * math.prod(cand.tile),
        )
    return out


# --------------------------------------------------------------------------
# Cache
# --------------------------------------------------------------------------


def default_cache_dir() -> Path:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro-cfa" / "autotune"


def clear_cache(cache_dir: Path | str | None = None) -> int:
    """Delete all cached decisions; returns the number removed."""
    root = Path(cache_dir) if cache_dir is not None else default_cache_dir()
    n = 0
    if root.is_dir():
        for f in root.glob("*.json"):
            f.unlink()
            n += 1
    return n


def _cache_key(
    program: StencilProgram,
    space: IterSpace,
    model: BurstModel,
    seed: int,
    budget: int,
    tilings: Sequence[tuple[int, ...]] | None,
    contiguity_levels: Sequence[str],
    max_halo_elems: int | None,
    refine_top: int,
    n_ports: int,
    port_strategies: Sequence[str],
    storage: str,
    codec_id: list | None,
    footprint_weight: float,
    score: str = "modeled",
    measure_top: int | None = None,
    measure_kwargs: dict | None = None,
    overlap: bool = False,
    compute_per_elem_s: float = 0.0,
    pass_fingerprint: tuple[tuple[str, str], ...] | None = None,
) -> str:
    from .executors import capability_fingerprint, host_fingerprint

    blob = json.dumps(
        {
            "version": _CACHE_VERSION,
            "program": program.name,
            "deps": list(map(list, program.deps.vectors)),
            "space": list(space.sizes),
            # the executor capability set (schema v3): a decision is only
            # reusable on the backend envelope it was searched for; the
            # "model" entry below is the target identity (name + parameters)
            "backends": capability_fingerprint(),
            "model": [model.name, model.peak_bytes_per_s, model.setup_s, model.elem_bytes],
            "seed": seed,
            "budget": budget,
            "tilings": list(map(list, tilings)) if tilings is not None else None,
            "contiguity": list(contiguity_levels),
            "max_halo_elems": max_halo_elems,
            "refine_top": refine_top,
            "n_ports": n_ports,
            "port_strategies": list(port_strategies),
            # the storage axis (schema v4)
            "storage": storage,
            "codec": codec_id,
            "footprint_weight": footprint_weight,
            # the score axis (schema v5): a measured decision is only valid
            # on the host (and at the measurement fidelity) it was timed on
            "score": score,
            "host": host_fingerprint() if score == "measured" else None,
            "measure_top": measure_top if score == "measured" else None,
            "measure_kwargs": (sorted((measure_kwargs or {}).items())
                               if score == "measured" else None),
            # the dataflow overlap axis (schema v6)
            "overlap": overlap,
            "compute_per_elem_s": compute_per_elem_s,
            # the pass-pipeline fingerprint (schema v7): a reordered or
            # re-versioned lowering pipeline searches under a fresh key
            "passes": (list(map(list, pass_fingerprint))
                       if pass_fingerprint is not None else None),
        },
        sort_keys=True,
    )
    return hashlib.sha256(blob.encode()).hexdigest()[:32]


def _cache_load(
    path: Path,
    score: str = "modeled",
    pass_fingerprint: tuple[tuple[str, str], ...] | None = None,
) -> LayoutDecision | None:
    try:
        text = path.read_text()
    except OSError:
        return None  # no cache entry for this key
    try:
        decision = LayoutDecision.from_json(text)
        if (pass_fingerprint is not None
                and decision.pass_pipeline != pass_fingerprint):
            # a decision searched under a different lowering pipeline
            # (pass reordered, added, or re-versioned) may rank layouts
            # a current pass would lower differently — reject loudly so
            # the re-search is visible, never silent (schema v7)
            raise CacheSchemaError(
                f"cache entry was searched under pass pipeline "
                f"{decision.pass_pipeline!r} but the current pipeline is "
                f"{pass_fingerprint!r}; an edited lowering invalidates "
                f"cached layout decisions — re-searching"
            )
        if decision.score != score:
            # modeled- and measured-scored decisions rank by different
            # objectives; silently serving one for the other would defeat
            # the whole measured/modeled split — reject loudly instead
            raise CacheSchemaError(
                f"cache entry was written with score={decision.score!r} but "
                f"queried with score={score!r}; measured and modeled "
                f"rankings are never interchangeable — re-searching"
            )
        return decision
    except CacheSchemaError as e:
        # an old-schema decision under this key must not be silently
        # deserialized OR silently dropped: say why a re-search happens
        warnings.warn(f"ignoring {path}: {e}", RuntimeWarning, stacklevel=3)
        return None
    except (ValueError, KeyError, TypeError) as e:
        warnings.warn(
            f"ignoring corrupt autotune cache entry {path}: {e!r}",
            RuntimeWarning, stacklevel=3,
        )
        return None


def _cache_store(path: Path, decision: LayoutDecision) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as fh:
            fh.write(decision.to_json())
        os.replace(tmp, path)
    except OSError:
        try:
            os.unlink(tmp)
        except OSError:
            pass


# --------------------------------------------------------------------------
# The search
# --------------------------------------------------------------------------


def _plan_verifies(plan) -> bool:
    """Does the candidate's plan pass the static CFA1xx accounting checks?
    ERROR-level candidates are discarded during the search — a layout whose
    plan double-writes or under-covers must never win on modeled time."""
    from .analysis import plan_accounting  # lazy: analysis imports passes

    return not any(d.severity == "ERROR" for d in plan_accounting(plan))


def _sample(items: list, k: int, rng: np.random.Generator) -> list:
    """First half deterministically (best-guess order), rest seeded-random."""
    if len(items) <= k:
        return list(items)
    head = items[: k // 2]
    tail = items[k // 2 :]
    pick = rng.choice(len(tail), size=k - len(head), replace=False)
    return head + [tail[i] for i in sorted(pick)]


def autotune(
    program: StencilProgram | str,
    space: IterSpace | Sequence[int],
    model: BurstModel = AXI_ZC706,
    *,
    seed: int = 0,
    budget: int = 96,
    tilings: Sequence[Sequence[int]] | None = None,
    contiguity_levels: Sequence[str] = CONTIGUITY_LEVELS,
    max_halo_elems: int | None = 64 * 1024,
    refine_top: int = 3,
    n_ports: int = 1,
    port_strategies: Sequence[str] = PORT_STRATEGIES,
    storage: str = "redundant",
    codec=None,
    footprint_weight: float = 0.0,
    score: str = "modeled",
    measure_top: int = 8,
    measure_kwargs: dict | None = None,
    overlap: bool = False,
    compute_per_elem_s: float = 0.0,
    pass_fingerprint: Sequence[Sequence[str]] | None = None,
    cache: bool = True,
    cache_dir: Path | str | None = None,
) -> LayoutDecision:
    """Search the layout space for ``program`` on ``space`` under ``model``.

    Three staged passes, deterministic given ``seed``:

    1. *seeds* — the hand-coded baselines at the program's default tile
       (guaranteeing the decision never scores below them); these ~6 plans
       are always scored, even when ``budget`` is smaller;
    2. *tiling sweep* — the paper-default CFA layout across candidate
       tilings (``candidate_tilings`` unless ``tilings`` overrides);
    3. *layout refinement* — extension-direction assignments x contiguity
       levels on the ``refine_top`` best tilings from stage 2, plus a
       data-tiling block sweep on the best tiling.

    With ``n_ports > 1`` every candidate is additionally co-tuned with its
    best port repartition (``multiport.best_repartition`` over
    ``port_strategies`` x ports-used), and scores/ranking reflect the
    multi-port time — the slowest port, ports running concurrently (§VII).
    The winning facet->port split is carried on each ``ScoredLayout`` and
    surfaced as ``decision.port_assignment``.

    ``storage`` scores every CFA candidate under a facet storage discipline
    (``"redundant"`` — the paper's duplicated layout — or the Ferry-2024
    ``"irredundant"``/``"compressed"`` modes; ``codec`` picks the
    fixed-ratio block codec for the latter), and ``footprint_weight``
    re-weights the ranking by bandwidth per stored element (see
    ``_rank_key``), so footprint-constrained deployments can trade peak
    speed for smaller resident layouts along a reproducible curve.

    ``score="measured"`` re-ranks the top ``measure_top`` modeled
    candidates by *measured wall-clock* of their exact burst schedules on
    this host (``calibrate.measure_plan``; ``measure_kwargs`` forwards
    ``warmup``/``repeats``): the measured candidates lead the ranking in
    wall-clock order, each carrying ``measured_time_s`` and the modeled
    time's relative ``model_error``; unmeasured candidates follow in
    modeled order.  Measured decisions cache under a key that folds in the
    host fingerprint and measurement fidelity (schema v5), and the loader
    rejects any modeled/measured score mismatch loudly — the two rankings
    are never interchangeable.

    ``overlap=True`` ranks every candidate by its *overlapped* tile time
    (Fig. 13 DATAFLOW — the ``backend="dataflow"`` schedule), with
    ``compute_per_elem_s`` seconds of tile compute per tile element
    (per-candidate ``compute_s`` = rate x tile volume, so bigger tiles
    carry proportionally more compute to hide transfers behind).  Under
    overlap the search prefers layouts whose transfer fits under the
    compute shadow instead of the absolutely shortest transfer — a
    different optimum whenever compute is non-trivial (schema v6).

    Stages 2 and 3 stay within ``budget`` total evaluations (so
    ``decision.evaluated <= max(budget, number of seeds)``).

    Results are memoised on disk (``cache_dir`` or $REPRO_AUTOTUNE_CACHE or
    ``~/.cache/repro-cfa/autotune``) keyed by every argument above, so a
    repeated call is a single file read (``decision.from_cache`` is True).
    """
    prog = get_program(program) if isinstance(program, str) else program
    sp = space if isinstance(space, IterSpace) else IterSpace(tuple(space))
    if sp.ndim != prog.ndim:
        raise ValueError(
            f"space {sp.sizes} has {sp.ndim} dims but program {prog.name!r} "
            f"is {prog.ndim}-D"
        )
    if n_ports < 1:
        raise ValueError(f"n_ports must be >= 1: {n_ports}")
    if storage not in STORAGE_MODES:
        raise ValueError(f"storage must be one of {STORAGE_MODES}: {storage!r}")
    if codec is not None and storage != "compressed":
        raise ValueError(
            f'a codec only applies to storage="compressed", not {storage!r}'
        )
    if footprint_weight < 0:
        # a negative exponent would silently invert the objective (prefer
        # the LARGEST footprint) — reject like the other search knobs
        raise ValueError(
            f"footprint_weight must be >= 0: {footprint_weight}"
        )
    if score not in SCORE_MODES:
        raise ValueError(f"score must be one of {SCORE_MODES}: {score!r}")
    if measure_top < 1:
        raise ValueError(f"measure_top must be >= 1: {measure_top}")
    if compute_per_elem_s < 0:
        raise ValueError(
            f"compute_per_elem_s must be >= 0: {compute_per_elem_s}"
        )
    cdc = get_codec(codec) if storage == "compressed" else None
    codec_id = [cdc.name, cdc.bits] if cdc is not None else None
    til = tuple(tuple(int(x) for x in t) for t in tilings) if tilings is not None else None
    mkw = dict(measure_kwargs or {})
    if pass_fingerprint is None:
        # a bare autotune() call searches for the default lowering pipeline;
        # compile() threads the fingerprint of whatever pipeline it runs
        from .passes import default_pass_fingerprint
        pass_fingerprint = default_pass_fingerprint()
    fp = tuple((str(n), str(v)) for n, v in pass_fingerprint)

    key = _cache_key(prog, sp, model, seed, budget, til, contiguity_levels,
                     max_halo_elems, refine_top, n_ports, port_strategies,
                     storage, codec_id, footprint_weight,
                     score, measure_top, mkw,
                     overlap, compute_per_elem_s, fp)
    path = (Path(cache_dir) if cache_dir is not None else default_cache_dir()) / f"{key}.json"
    if cache:
        hit = _cache_load(path, score, fp)
        if hit is not None:
            return dataclasses.replace(hit, from_cache=True)

    rng = np.random.default_rng(seed)
    widths = prog.widths

    scored: dict[str, ScoredLayout] = {}

    def score_candidate(cand: LayoutCandidate) -> ScoredLayout | None:
        if cand.key in scored:
            return scored[cand.key]
        try:
            plan = cand.plan(sp, prog, storage=storage, codec=cdc)
        except ValueError:
            return None  # illegal candidate (e.g. w > t); skip
        # (AssertionError deliberately propagates: it flags a layout bug,
        # e.g. a non-contiguous facet write, never an illegal candidate.)
        if not _plan_verifies(plan):
            return None  # statically rejected (ERROR-level diagnostics)
        s = ScoredLayout.from_plan(
            cand, plan, model, n_ports=n_ports,
            port_strategies=port_strategies, overlap=overlap,
            compute_s=compute_per_elem_s * math.prod(cand.tile),
        )
        scored[cand.key] = s
        return s

    # -- stage 1: hand-coded seeds -----------------------------------------
    default_tile_ok = all(
        n % t == 0 and t >= max(1, w)
        for n, t, w in zip(sp.sizes, prog.default_tile, widths)
    )
    if default_tile_ok:
        seeds = hand_coded_baselines(prog, sp, model, n_ports=n_ports,
                                     port_strategies=port_strategies,
                                     storage=storage, codec=cdc,
                                     overlap=overlap,
                                     compute_per_elem_s=compute_per_elem_s)
        for s in seeds.values():
            scored.setdefault(s.candidate.key, s)

    # -- stage 2: default layout across tilings ----------------------------
    all_tilings = list(til) if til is not None else candidate_tilings(
        widths, sp.sizes, max_halo_elems=max_halo_elems
    )
    remaining = max(0, budget - len(scored))
    for t in _sample(all_tilings, remaining * 2 // 3, rng):
        score_candidate(LayoutCandidate("cfa", tuple(t), contiguity="intra-tile"))

    # -- stage 3: layout refinement on the best tilings --------------------
    d = sp.ndim
    cfa_scored = sorted(
        (s for s in scored.values() if s.candidate.scheme == "cfa"),
        key=lambda s: _rank_key(s, footprint_weight),
    )
    top_tiles = []
    for s in cfa_scored:
        if s.candidate.tile not in top_tiles:
            top_tiles.append(s.candidate.tile)
        if len(top_tiles) >= refine_top:
            break
    if top_tiles and len(scored) < budget:
        # data-tiling block sweep at the winning tiling
        t = top_tiles[0]
        for div in (1, 2, 4):
            if len(scored) >= budget:
                break
            blk = tuple(max(1, x // div) for x in t)
            score_candidate(LayoutCandidate("data-tiling", t, block=blk))
    variants = []
    for t in top_tiles:
        for lvl in contiguity_levels:
            for ext in _ext_dir_assignments(widths):
                # the cyclic default is the same layout as ext_dirs=None —
                # canonicalise so it dedupes against the stage-2 candidate
                if all(c == extension_dir(k, d) for k, c in ext):
                    ext = None
                v = LayoutCandidate("cfa", t, ext_dirs=ext, contiguity=lvl)
                if v.key not in scored and all(x.key != v.key for x in variants):
                    variants.append(v)
    remaining = max(0, budget - len(scored))
    for v in _sample(variants, remaining, rng):
        score_candidate(v)

    # -- measured re-ranking (score="measured", schema v5) -----------------
    if score == "measured":
        from .calibrate import measure_plan

        modeled_order = sorted(scored.values(),
                               key=lambda s: _rank_key(s, footprint_weight))
        for s in modeled_order[:measure_top]:
            plan = s.candidate.plan(sp, prog, storage=storage, codec=cdc)
            timed_plan: TransferPlan | PortedPlan = plan
            c_s = compute_per_elem_s * math.prod(s.candidate.tile)
            if n_ports > 1:
                timed_plan = best_repartition(plan, n_ports, model,
                                              port_strategies,
                                              compute_s=c_s, overlap=overlap)
            t_meas = measure_plan(timed_plan, model, compute_s=c_s,
                                  overlap=overlap, **mkw)
            err = (abs(s.time_s - t_meas) / t_meas) if t_meas > 0 else None
            scored[s.candidate.key] = dataclasses.replace(
                s, measured_time_s=t_meas, model_error=err,
            )

    decision = LayoutDecision(
        program=prog.name,
        space=sp.sizes,
        widths=widths,
        model=model.name,
        seed=seed,
        budget=budget,
        evaluated=len(scored),
        ranked=tuple(sorted(scored.values(),
                            key=lambda s: _rank_key(s, footprint_weight))),
        n_ports=n_ports,
        storage=storage,
        codec=cdc.name if cdc is not None else None,
        footprint_weight=footprint_weight,
        score=score,
        overlap=overlap,
        compute_per_elem_s=compute_per_elem_s,
        pass_pipeline=fp,
    )
    if cache:
        _cache_store(path, decision)
    return decision
