"""Pass-pipeline lowering: ``cfa.compile`` as staged, inspectable passes.

The paper frames the burst-friendly layout as a *source-to-source compiler
pass*; Iris (Soldavini et al., 2022) shows automatic layout generation
structured as a staged compiler flow.  This module makes our lowering that
shape: an immutable :class:`CompileState` artifact flows through a
:class:`PassPipeline` of small, individually-testable passes, each refining
one aspect of the compilation —

    resolve_program   programs/spaces/storage knobs -> concrete objects
    validate_target   platform registry lookup + port-budget gate
    distribute        split an over-budget space across the port mesh
    layout_search     autotune / explicit layout -> LayoutCandidate
    storage_map       the irredundant ownership map (Ferry 2024)
    port_repartition  compile-time facet -> port assignment (§VII)
    select_backend    the ExecutorCaps capability gate
    lower_backend     build the CFAPipeline + CompiledStencil

``cfa.compile`` (:mod:`repro.core.cfa.api`) is a thin driver over
:func:`default_pipeline`; the result is bit-exact and API-compatible with
the pre-pipeline monolith.  Every run records a per-pass trace — name,
version, wall time, and a summary of the state fields the pass changed —
surfaced as ``CompiledStencil.trace()`` and dumped by
``tools/dump_pipeline.py``.

The pipeline validates its own shape at assembly time: duplicate pass
names, a stage whose declared ``requires`` no earlier stage provides, or a
pipeline that never provides ``"compiled"`` are all rejected loudly with
:class:`PipelineError` — a silently re-ordered lowering must not run.  The
ordered (name, version) list is the *pipeline fingerprint*
(:func:`default_pass_fingerprint`); the autotune cache folds it into its
key and its stored decisions (schema v7), so editing or re-ordering the
lowering invalidates cached layout decisions loudly instead of silently
serving stale ones.

The ``distribute`` pass is what makes multi-host a sharding decision: when
the facet family's estimated bytes exceed a per-host ``host_budget``, the
space is split over enough ports that every shard fits, ``n_ports`` is
raised accordingly, and backend auto-selection then lowers to the sharded
executor (facet arrays resident on their port's device via
``repro.distributed.sharding.port_mesh``) — an oversized space compiles to
sharded execution instead of raising.  ``halo_quantize=True`` additionally
routes every halo gather through the int8 compression hooks of
``repro.distributed.compression`` (lossy, off by default).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Mapping, Protocol, Sequence, runtime_checkable

from .autotune import LayoutCandidate, LayoutDecision, autotune
from .compress import BlockCodec, get_codec
from .facets import build_facet_specs
from .irredundant import STORAGE_MODES, StorageMap, build_storage_map
from .multiport import PortAssignment, assign_ports
from .programs import StencilProgram, get_program
from .spaces import IterSpace, Tiling

__all__ = [
    "CompileState",
    "Pass",
    "PassPipeline",
    "PassTrace",
    "PipelineError",
    "default_pipeline",
    "default_pass_fingerprint",
    "estimate_facet_bytes",
    "DEFAULT_PASSES",
]


class PipelineError(ValueError):
    """A malformed pass pipeline: duplicate, missing or mis-ordered stages."""


# --------------------------------------------------------------------------
# The artifact
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CompileState:
    """The immutable lowering artifact: request fields in, artifacts accreted.

    The request fields (``program`` .. ``halo_quantize``) mirror
    ``cfa.compile``'s signature and are *refined in place* — after
    ``resolve_program``/``validate_target`` they hold concrete
    ``StencilProgram``/``IterSpace``/``Target`` objects.  The artifact
    fields start ``None`` and accrete per stage; ``compiled`` is the final
    product.  Passes never mutate: each returns a new state via
    ``dataclasses.replace``.
    """

    # -- request ------------------------------------------------------------
    program: Any  # StencilProgram | str -> StencilProgram
    space: Any  # IterSpace | Sequence[int] -> IterSpace
    target: Any = None  # Target | BurstModel | str -> Target
    n_ports: int = 1
    layout: Any = "autotune"
    backend: str = "auto"  # -> resolved executor name
    storage: str = "redundant"
    codec: Any = None  # BlockCodec | str | None -> BlockCodec | None
    overlap: bool = False
    autotune_kwargs: Mapping | None = None
    # the distribute pass: per-host facet-memory budget in bytes (None =
    # single-host, never split) and the optional int8 halo-traffic hook
    host_budget: int | None = None
    halo_quantize: bool = False

    # -- artifacts (accreted per stage) --------------------------------------
    candidate: LayoutCandidate | None = None
    decision: LayoutDecision | None = dataclasses.field(default=None, repr=False)
    storage_map: StorageMap | None = dataclasses.field(default=None, repr=False)
    port_assignment: PortAssignment | None = None
    executor: Any = None  # Executor
    pipeline: Any = None  # CFAPipeline
    compiled: Any = None  # CompiledStencil
    distributed: bool = False
    # analysis passes (repro.core.cfa.analysis) append Diagnostic records
    # here; lowering passes never touch it
    diagnostics: tuple = ()
    # bookkeeping (excluded from trace diffs): the running pipeline's
    # fingerprint (seeded by PassPipeline.run) and the accreted trace
    pass_fingerprint: tuple = dataclasses.field(default=None, repr=False, compare=False)
    trace: tuple = dataclasses.field(default=(), repr=False, compare=False)


_UNTRACED_FIELDS = ("trace", "pass_fingerprint")


# --------------------------------------------------------------------------
# Pass protocol + trace
# --------------------------------------------------------------------------


@runtime_checkable
class Pass(Protocol):
    """One lowering stage: ``run`` maps a CompileState to a refined one.

    ``requires``/``provides`` declare abstract artifact tokens (e.g.
    ``"layout"``, ``"backend"``) used by :class:`PassPipeline` to validate
    stage order at assembly time; ``(name, version)`` pairs form the
    pipeline fingerprint the autotune cache is keyed by.
    """

    name: str
    version: str
    requires: tuple[str, ...]
    provides: tuple[str, ...]

    def run(self, state: CompileState) -> CompileState: ...


@dataclasses.dataclass(frozen=True)
class PassTrace:
    """One pass's trace record: identity, wall time, and the artifact diff
    (state fields the pass changed, each with a short human summary)."""

    name: str
    version: str
    wall_s: float
    changed: tuple[tuple[str, str], ...]  # (field, summary of new value)

    def to_dict(self) -> dict:
        return {
            "pass": self.name,
            "version": self.version,
            "wall_s": self.wall_s,
            "changed": dict(self.changed),
        }


@dataclasses.dataclass(frozen=True)
class _FnPass:
    """A Pass wrapping a plain function (the built-in stages)."""

    name: str
    version: str
    requires: tuple[str, ...]
    provides: tuple[str, ...]
    fn: Callable[[CompileState], CompileState] = dataclasses.field(compare=False)

    def run(self, state: CompileState) -> CompileState:
        return self.fn(state)


def compiler_pass(
    name: str,
    version: str = "1",
    *,
    requires: Sequence[str] = (),
    provides: Sequence[str] = (),
):
    """Decorator turning ``fn(state) -> state`` into a registered Pass."""

    def deco(fn: Callable[[CompileState], CompileState]) -> _FnPass:
        return _FnPass(name=name, version=version, requires=tuple(requires),
                       provides=tuple(provides), fn=fn)

    return deco


def _summarize(v: Any) -> str:
    """A one-line human summary of an artifact value (for trace diffs)."""
    if v is None:
        return "None"
    if isinstance(v, (bool, int, float, str)):
        return repr(v)
    kind = type(v).__name__
    if isinstance(v, StencilProgram):
        return f"{v.name} ({v.ndim}-D)"
    if isinstance(v, IterSpace):
        return f"space {v.sizes}"
    if isinstance(v, LayoutCandidate):
        return v.key
    if isinstance(v, LayoutDecision):
        tail = " [cache]" if v.from_cache else ""
        return f"{v.evaluated} candidates -> {v.best.candidate.key}{tail}"
    if isinstance(v, StorageMap):
        return f"stored {v.stored_elems} elems (saves {v.savings:.1%})"
    if isinstance(v, PortAssignment):
        return (f"{v.n_ports} ports, facets "
                f"{dict(sorted(v.facet_to_port.items()))}")
    if isinstance(v, BlockCodec):
        return f"codec {v.name}"
    if hasattr(v, "caps") and hasattr(v, "name"):  # an Executor
        return f"executor {v.name}"
    if hasattr(v, "model") and hasattr(v, "max_ports"):  # a Target
        return f"target {v.name} (max_ports={v.max_ports})"
    if hasattr(v, "tiling") and hasattr(v, "specs"):  # a CFAPipeline
        return f"{kind}(tile={v.tiling.sizes})"
    if hasattr(v, "executor") and hasattr(v, "layout"):  # a CompiledStencil
        return f"backend {v.backend}, layout {v.layout.key}"
    if (isinstance(v, tuple) and v
            and all(hasattr(d, "code") and hasattr(d, "severity") for d in v)):
        # a Diagnostic tuple (duck-typed: passes must not import analysis)
        by_sev = {s: sum(1 for d in v if d.severity == s)
                  for s in ("ERROR", "WARN", "INFO")}
        head = ", ".join(f"{s}={n}" for s, n in by_sev.items() if n)
        return f"{len(v)} diagnostic(s): {head}"
    if isinstance(v, tuple):
        return repr(v)
    return kind


def _diff(before: CompileState, after: CompileState) -> tuple[tuple[str, str], ...]:
    changed = []
    for f in dataclasses.fields(CompileState):
        if f.name in _UNTRACED_FIELDS:
            continue
        old, new = getattr(before, f.name), getattr(after, f.name)
        if old is not new and old != new:
            changed.append((f.name, _summarize(new)))
    return tuple(changed)


# --------------------------------------------------------------------------
# The runner
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PassPipeline:
    """An ordered sequence of passes, validated at assembly time.

    * duplicate pass names are rejected (a stage must not run twice);
    * every pass's declared ``requires`` must be provided by an earlier
      pass (so a missing or mis-ordered stage fails at construction, not
      mid-lowering);
    * the pipeline must end up providing ``"compiled"`` — a lowering that
      cannot produce a ``CompiledStencil`` is not a lowering.

    ``run`` threads a :class:`CompileState` through the stages, recording a
    :class:`PassTrace` per pass (also retrievable as :meth:`trace` after a
    run); ``fingerprint`` is the ordered (name, version) identity the
    autotune cache is keyed by (schema v7).
    """

    passes: tuple[Pass, ...]

    def __post_init__(self) -> None:
        object.__setattr__(self, "passes", tuple(self.passes))
        seen: set[str] = set()
        provided: set[str] = set()
        for p in self.passes:
            if p.name in seen:
                raise PipelineError(
                    f"duplicate pass {p.name!r}: each lowering stage runs "
                    f"exactly once"
                )
            seen.add(p.name)
            missing = [r for r in p.requires if r not in provided]
            if missing:
                raise PipelineError(
                    f"pass {p.name!r} requires {missing} but no earlier "
                    f"pass provides it — stage missing or mis-ordered "
                    f"(pipeline so far: {[q.name for q in self.passes if q.name in seen]})"
                )
            provided.update(p.provides)
        if "compiled" not in provided:
            raise PipelineError(
                f"pipeline {[p.name for p in self.passes]} never provides "
                f"'compiled' — a lower_backend stage is required"
            )

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(p.name for p in self.passes)

    def fingerprint(self) -> tuple[tuple[str, str], ...]:
        """The ordered (name, version) identity of this lowering."""
        return tuple((p.name, p.version) for p in self.passes)

    def without(self, name: str) -> "PassPipeline":
        """A new pipeline with the named stage removed (re-validated)."""
        if name not in self.names:
            raise PipelineError(f"no pass named {name!r} in {self.names}")
        return PassPipeline(tuple(p for p in self.passes if p.name != name))

    def replaced(self, name: str, new: Pass) -> "PassPipeline":
        """A new pipeline with the named stage swapped out (re-validated)."""
        if name not in self.names:
            raise PipelineError(f"no pass named {name!r} in {self.names}")
        return PassPipeline(tuple(
            new if p.name == name else p for p in self.passes
        ))

    def run(self, state: CompileState) -> CompileState:
        """Thread ``state`` through every stage, tracing each pass."""
        if state.pass_fingerprint is None:
            state = dataclasses.replace(state,
                                        pass_fingerprint=self.fingerprint())
        for p in self.passes:
            t0 = time.perf_counter()
            new = p.run(state)
            wall = time.perf_counter() - t0
            if not isinstance(new, CompileState):
                raise PipelineError(
                    f"pass {p.name!r} returned {type(new).__name__}, not a "
                    f"CompileState"
                )
            entry = PassTrace(name=p.name, version=p.version, wall_s=wall,
                              changed=_diff(state, new))
            state = dataclasses.replace(new, trace=new.trace + (entry,))
        object.__setattr__(self, "_last_trace", state.trace)
        return state

    def trace(self) -> tuple[PassTrace, ...]:
        """The per-pass trace of the most recent :meth:`run` (empty before)."""
        return getattr(self, "_last_trace", ())


# --------------------------------------------------------------------------
# The built-in stages
# --------------------------------------------------------------------------


@compiler_pass("resolve_program", provides=("program",))
def resolve_program(state: CompileState) -> CompileState:
    """Resolve program/space names to objects; validate the storage knobs."""
    prog = (get_program(state.program) if isinstance(state.program, str)
            else state.program)
    sp = (state.space if isinstance(state.space, IterSpace)
          else IterSpace(tuple(state.space)))
    if prog.ndim != sp.ndim:
        raise ValueError(
            f"program {prog.name!r} is {prog.ndim}-D but the space "
            f"{sp.sizes} is {sp.ndim}-D"
        )
    if state.storage not in STORAGE_MODES:
        raise ValueError(
            f"storage must be one of {STORAGE_MODES}: {state.storage!r}"
        )
    if state.codec is not None and state.storage != "compressed":
        raise ValueError(
            f'a codec only applies to storage="compressed", not '
            f'{state.storage!r}'
        )
    cdc = get_codec(state.codec) if state.storage == "compressed" else None
    return dataclasses.replace(state, program=prog, space=sp, codec=cdc)


@compiler_pass("validate_target", requires=("program",), provides=("target",))
def validate_target(state: CompileState) -> CompileState:
    """Resolve the target and gate ``n_ports`` against its port budget."""
    from .api import get_target

    # a hand-built CompileState may leave target unset; resolve it to the
    # same platform compile() defaults to
    tgt = get_target(state.target if state.target is not None
                     else "axi-zc706")
    if state.n_ports < 1:
        raise ValueError(f"n_ports must be >= 1: {state.n_ports}")
    if tgt.max_ports is not None and state.n_ports > tgt.max_ports:
        raise ValueError(
            f"target {tgt.name!r} has {tgt.max_ports} memory port(s); "
            f"n_ports={state.n_ports} exceeds the platform budget"
        )
    return dataclasses.replace(state, target=tgt)


def estimate_facet_bytes(
    program: StencilProgram,
    space: IterSpace,
    *,
    tile: Sequence[int] | None = None,
    elem_bytes: int = 4,
) -> int:
    """Estimated bytes of the whole facet family for ``program`` on
    ``space`` — the distribute pass's budget metric.

    Facet ``k`` stores ``w_k`` planes per tile (``num_tiles x w_k x
    prod_{a != k} t_a`` elements), so the total depends mildly on the
    tiling; budget decisions are made against the program's default tile
    (clipped to the space) unless ``tile`` overrides — the layout search
    runs *after* distribution, so the exact tile is not yet known.
    """
    N = space.sizes
    t = tuple(tile) if tile is not None else program.default_tile
    t = tuple(max(1, min(int(ta), int(na))) for ta, na in zip(t, N))
    num_tiles = math.prod(-(-na // ta) for na, ta in zip(N, t))
    total = 0
    for k, wk in enumerate(program.widths):
        if wk <= 0:
            continue
        block = wk * math.prod(ta for a, ta in enumerate(t) if a != k)
        total += num_tiles * block
    return total * elem_bytes


@compiler_pass("distribute", requires=("program", "target"),
               provides=("distribution",))
def distribute(state: CompileState) -> CompileState:
    """Split an over-budget space across the port mesh.

    With no ``host_budget`` this is a no-op (single-host lowering).  When
    the estimated facet bytes exceed the budget, the space is split over
    ``ceil(estimate / budget)`` ports — each port's device then holds only
    its assigned facet arrays (``shard_facets``), so per-host residency
    fits the budget — and ``n_ports`` is raised accordingly; backend
    auto-selection lowers the result to the sharded executor.  A budget so
    small that even the target's full port complement cannot satisfy it is
    rejected loudly.
    """
    if state.host_budget is None:
        return state
    if state.host_budget <= 0:
        raise ValueError(
            f"host_budget must be positive bytes: {state.host_budget}"
        )
    est = estimate_facet_bytes(state.program, state.space,
                               elem_bytes=state.target.model.elem_bytes)
    if est <= state.host_budget:
        return state
    shards = -(-est // state.host_budget)
    ports = max(state.n_ports, int(shards))
    if state.target.max_ports is not None and ports > state.target.max_ports:
        raise ValueError(
            f"space {state.space.sizes} needs ~{est} B of facet storage = "
            f"{int(shards)} shard(s) under the {state.host_budget} B/host "
            f"budget, but target {state.target.name!r} offers only "
            f"{state.target.max_ports} port(s); raise host_budget or pick "
            f"a target with more ports"
        )
    return dataclasses.replace(state, n_ports=ports, distributed=True)


@compiler_pass("layout_search", requires=("program", "target"),
               provides=("layout",))
def layout_search(state: CompileState) -> CompileState:
    """Resolve the layout request to a CFA candidate (autotune wrapped).

    ``"autotune"`` runs the staged search (co-tuned with the — possibly
    distribute-raised — port count and scored under the requested storage
    discipline), forwarding the running pipeline's fingerprint so cached
    decisions are keyed by the lowering that produced them (schema v7).
    """
    layout = state.layout
    cand: LayoutCandidate
    decision: LayoutDecision | None
    if isinstance(layout, str):
        if layout == "autotune":
            kwargs = dict(state.autotune_kwargs or {})
            kwargs.setdefault("pass_fingerprint", state.pass_fingerprint)
            decision = autotune(state.program, state.space,
                                state.target.model, n_ports=state.n_ports,
                                storage=state.storage, codec=state.codec,
                                **kwargs)
            cand = decision.best_cfa().candidate
        elif layout == "default":
            cand, decision = LayoutCandidate(
                "cfa", state.program.default_tile, contiguity="intra-tile",
            ), None
        else:
            raise ValueError(
                f"layout must be 'autotune', 'default', a LayoutCandidate, "
                f"a LayoutDecision or a tile tuple; got {layout!r}"
            )
    elif isinstance(layout, LayoutCandidate):
        if layout.scheme != "cfa":
            raise ValueError(
                f"only 'cfa'-scheme layouts are executable (facet storage); "
                f"got scheme {layout.scheme!r} — the baseline schemes exist "
                f"for plan/bandwidth comparison only"
            )
        cand, decision = layout, None
    elif isinstance(layout, LayoutDecision):
        if (layout.program != state.program.name
                or tuple(layout.space) != state.space.sizes):
            raise ValueError(
                f"decision is for {layout.program!r} @ {tuple(layout.space)}, "
                f"not {state.program.name!r} @ {state.space.sizes}"
            )
        cand, decision = layout.best_cfa().candidate, layout
    elif isinstance(layout, Sequence):
        cand, decision = LayoutCandidate(
            "cfa", tuple(int(t) for t in layout), contiguity="intra-tile",
        ), None
    else:
        raise TypeError(f"cannot interpret layout {layout!r}")
    return dataclasses.replace(state, candidate=cand, decision=decision)


@compiler_pass("storage_map", requires=("program", "layout"),
               provides=("storage_map",))
def storage_map(state: CompileState) -> CompileState:
    """Compute the irredundant ownership map (None under redundant storage).

    The map is a pure function of the facet family, exposed here as an
    inspectable artifact; the lowered Irredundant/Compressed pipeline
    recomputes the identical map from the same specs.
    """
    if state.storage == "redundant":
        return state
    cand = state.candidate
    specs = build_facet_specs(
        state.space, state.program.deps, Tiling(cand.tile),
        ext_dirs=dict(cand.ext_dirs) if cand.ext_dirs is not None else None,
        contiguity=cand.contiguity or "intra-tile",
    )
    return dataclasses.replace(state, storage_map=build_storage_map(specs))


@compiler_pass("port_repartition", requires=("program", "layout"),
               provides=("ports",))
def port_repartition(state: CompileState) -> CompileState:
    """Fix the facet -> port split at compile time (§VII).

    Reuses the autotune decision's winning assignment when it was computed
    for this exact port count and tile; otherwise the LPT split of
    ``multiport.assign_ports``.  Single-port lowerings carry no assignment.
    """
    if state.n_ports <= 1:
        return state
    assignment = None
    d = state.decision
    if d is not None and getattr(d, "n_ports", 1) == state.n_ports:
        try:
            best = d.best_cfa()
        except LookupError:
            best = None
        if (best is not None
                and tuple(best.candidate.tile) == tuple(state.candidate.tile)):
            assignment = d.port_assignment  # may still be None (burst-granular)
    if assignment is None:
        assignment = assign_ports(state.space, state.program.deps,
                                  Tiling(state.candidate.tile), state.n_ports)
    return dataclasses.replace(state, port_assignment=assignment)


@compiler_pass("select_backend", requires=("program", "target"),
               provides=("backend",))
def select_backend(state: CompileState) -> CompileState:
    """Resolve ``backend="auto"`` and gate against declared capabilities."""
    from . import executors

    name = (executors.select_backend(state.program, state.space,
                                     state.n_ports, state.storage,
                                     state.overlap)
            if state.backend == "auto" else state.backend)
    ex = executors.get_executor(name)
    executors.check_backend(ex, state.program, state.space, state.n_ports,
                            state.storage)
    if state.overlap and not ex.caps.overlap:
        raise executors.BackendError(
            f"overlap=True needs a backend that pipelines fetch/compute/"
            f"commit, but {name!r} runs its phases sequentially; use "
            f'backend="dataflow" (or "auto")'
        )
    return dataclasses.replace(state, backend=name, executor=ex)


@compiler_pass("lower_backend",
               requires=("program", "target", "layout", "backend"),
               provides=("compiled",))
def lower_backend(state: CompileState) -> CompileState:
    """Instantiate the CFAPipeline for the storage discipline and wrap it
    with the bound executor into the final ``CompiledStencil``."""
    from .api import CompiledStencil
    from .irredundant import CompressedPipeline, IrredundantPipeline
    from .transform import CFAPipeline

    cand = state.candidate
    pipe_kwargs = dict(
        ext_dirs=cand.ext_dirs,
        contiguity=cand.contiguity or "intra-tile",
        decision=state.decision,
        port_assignment=state.port_assignment,
        halo_quantize=state.halo_quantize,
    )
    if state.storage == "redundant":
        pipeline = CFAPipeline(state.program, state.space,
                               Tiling(cand.tile), **pipe_kwargs)
    elif state.storage == "irredundant":
        pipeline = IrredundantPipeline(state.program, state.space,
                                       Tiling(cand.tile), **pipe_kwargs)
    else:
        pipeline = CompressedPipeline(state.program, state.space,
                                      Tiling(cand.tile), codec=state.codec,
                                      **pipe_kwargs)
    compiled = CompiledStencil(
        program=state.program, space=state.space, target=state.target,
        n_ports=state.n_ports, executor=state.executor, pipeline=pipeline,
        layout=cand, decision=state.decision, storage=state.storage,
        codec=state.codec, distributed=state.distributed,
    )
    return dataclasses.replace(state, pipeline=pipeline, compiled=compiled)


# --------------------------------------------------------------------------
# The default lowering
# --------------------------------------------------------------------------

#: the pinned default pass surface, in lowering order
DEFAULT_PASSES: tuple[Pass, ...] = (
    resolve_program,
    validate_target,
    distribute,
    layout_search,
    storage_map,
    port_repartition,
    select_backend,
    lower_backend,
)


def default_pipeline() -> PassPipeline:
    """A fresh instance of the default lowering pipeline."""
    return PassPipeline(DEFAULT_PASSES)


def default_pass_fingerprint() -> tuple[tuple[str, str], ...]:
    """The default pipeline's ordered (name, version) fingerprint — the
    identity the autotune cache folds into its key (schema v7)."""
    return tuple((p.name, p.version) for p in DEFAULT_PASSES)
