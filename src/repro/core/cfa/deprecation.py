"""Deprecation plumbing for the legacy composite entry points.

``repro.cfa.compile`` is the one front door; the pre-existing drivers
(``CFAPipeline.from_autotuned`` / ``sweep`` / ``sweep_wavefront`` /
``sweep_wavefront_sharded`` and the kernel ``*_from_autotuned`` wrappers)
remain as shims that call :func:`warn_deprecated` and delegate to the same
internals the registered executors use.
"""
from __future__ import annotations

import warnings

__all__ = ["warn_deprecated"]


def warn_deprecated(old: str, new: str) -> None:
    """Emit the legacy-entry-point deprecation warning, attributed to the
    shim's caller."""
    warnings.warn(
        f"{old} is deprecated; use {new} (see repro.cfa.compile)",
        DeprecationWarning,
        stacklevel=3,
    )
