"""Facet array specifications: multi-projection, single-assignment, and the
dimension permutations that give CFA its three contiguity levels (§IV.F-I).

For each canonical axis ``k`` with facet width ``w_k > 0`` we allocate one
*facet array*.  Its index space is

    [ outer (tile-coordinate) dims, permuted ] x [ inner (intra-tile) dims, permuted ]

with the following paper-faithful layout rules:

* **single-assignment** (§IV-F4): the tile coordinate along ``k`` itself is an
  outer dimension, so no two tiles share storage; it is placed *first* among
  the outer dims.
* **full-tile contiguity** (§IV-G): the inner dims form one contiguous block
  per tile (data tiling with the iteration tile sizes), so each facet write is
  a single burst.
* **inter-tile contiguity** (§IV-H): every facet gets an *extension direction*
  ``c_k`` (a projected axis).  The tile coordinate of ``c_k`` is the last
  outer dim and ``c_k`` itself is the first inner dim, so a read that spans
  the facet of tile ``q`` and the trailing slab of tile ``q - e_{c_k}`` is one
  contiguous run ("facet extensions", Fig. 8).
* **intra-tile contiguity** (§IV-I): the modulo dimension ``x_k mod w_k`` is
  the last inner dim, so corner sets from 3rd-level neighbours are contiguous
  suffixes of a facet block.

By default we assign extension directions cyclically, ``c_k = (k+1) mod d``;
for d = 3 this reproduces exactly the paper's final layout family

    facet_i[ii][kk][jj] [j][k]          (w_i folded away when w_i == 1)
    facet_j[jj][ii][kk] [k][i][j%w_j]
    facet_k[kk][jj][ii] [i][j][k%w_k]

and yields the paper's 4-bursts-per-3D-tile read plan.  For d >= 4 some
k-th-level neighbours cannot be merged (paper §IV-J) — the planner then simply
counts the extra bursts; nothing breaks.

Both the extension-direction assignment and the contiguity level are
*layout knobs*: ``build_facet_specs`` accepts any per-facet extension
direction and any of the three cumulative contiguity levels

    "full-tile"   §IV-G only: blocked facets, canonical inner order
    "inter-tile"  + §IV-H: extension dim first inner / last outer
    "intra-tile"  + §IV-I: modulo dim last inner (the paper's final layout)

so the layout autotuner (``repro.core.cfa.autotune``) can search the whole
family rather than hard-coding the paper's single point.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

import numpy as np

from .spaces import Deps, IterSpace, Tiling, facet_widths

__all__ = [
    "FacetSpec",
    "build_facet_specs",
    "extension_dir",
    "CONTIGUITY_LEVELS",
]

#: The paper's three cumulative contiguity levels (§IV-G/H/I), weakest first.
CONTIGUITY_LEVELS = ("full-tile", "inter-tile", "intra-tile")


def row_major_strides(shape: Sequence[int]) -> np.ndarray:
    """Row-major strides (elements) of ``shape`` — the one linearisation
    convention every address map in this package shares."""
    strides = np.ones(len(shape), dtype=np.int64)
    for i in range(len(shape) - 2, -1, -1):
        strides[i] = strides[i + 1] * shape[i + 1]
    return strides


def extension_dir(axis: int, ndim: int) -> int:
    """Cyclic inter-tile contiguity direction ``c_k = (k+1) mod d``.

    §IV-H needs at least one projected axis to extend along, so for
    ``ndim == 1`` there is none: the convention ``c_k == k`` explicitly
    means "no extension direction" (the facet layout degenerates to
    full-tile blocks).  ``build_facet_specs`` validates that ``c_k == k``
    is only ever used in that degenerate case.  For ``ndim == 2`` the
    choice is forced: the single other axis.
    """
    if not (0 <= axis < ndim):
        raise ValueError(f"facet axis {axis} out of range for ndim={ndim}")
    if ndim == 1:
        return axis  # degenerate: no projected axes (explicit "none" marker)
    return (axis + 1) % ndim


@dataclasses.dataclass(frozen=True)
class FacetSpec:
    """Layout of one facet array (normal axis ``axis``, thickness ``width``)."""

    axis: int
    width: int
    tile_sizes: tuple[int, ...]
    num_tiles: tuple[int, ...]
    outer_axes: tuple[int, ...]  # order of tile-coordinate dims
    inner_axes: tuple[int, ...]  # order of intra-tile dims; ``axis`` = modulo dim
    ext_dir: int = -1  # inter-tile contiguity direction c_k; -1 = cyclic default

    def __post_init__(self) -> None:
        if self.ext_dir < 0:
            object.__setattr__(self, "ext_dir", extension_dir(self.axis, self.ndim))
        if not (0 <= self.ext_dir < self.ndim):
            raise ValueError(
                f"extension direction {self.ext_dir} out of range for "
                f"{self.ndim}-D facet_{self.axis}"
            )
        if self.ext_dir == self.axis and self.ndim > 1:
            raise ValueError(
                f"facet_{self.axis}: ext_dir == axis is the degenerate 1-D "
                "marker only; a d >= 2 facet must extend along a projected axis"
            )

    @property
    def ndim(self) -> int:
        return len(self.tile_sizes)

    def inner_size(self, a: int) -> int:
        return self.width if a == self.axis else self.tile_sizes[a]

    @property
    def shape(self) -> tuple[int, ...]:
        """Array shape: outer (tile) dims then inner (intra-tile) dims."""
        return tuple(self.num_tiles[a] for a in self.outer_axes) + tuple(
            self.inner_size(a) for a in self.inner_axes
        )

    @property
    def block_elems(self) -> int:
        """Elements in one tile's facet block (one burst write)."""
        return math.prod(self.inner_size(a) for a in self.inner_axes)

    @property
    def size(self) -> int:
        return math.prod(self.shape)

    # ---- address maps ----------------------------------------------------

    def domain_mask(self, pts: np.ndarray) -> np.ndarray:
        """Which iteration points lie in this facet's projection domain
        ``D(p_k) = { x : t_k - w_k <= x_k mod t_k }`` (§IV-F3)."""
        t_k = self.tile_sizes[self.axis]
        return (pts[:, self.axis] % t_k) >= (t_k - self.width)

    def coords(self, pts: np.ndarray) -> np.ndarray:
        """Facet-array multi-indices for iteration points (must be in domain).

        Applies the modulo projection ``p_k(x) = (..., x_k mod w_k, ...)``
        composed with data tiling and the dimension permutations.
        """
        pts = np.atleast_2d(np.asarray(pts, dtype=np.int64))
        if not bool(self.domain_mask(pts).all()):
            raise ValueError(f"points outside facet_{self.axis} projection domain")
        t = np.asarray(self.tile_sizes, dtype=np.int64)
        q = pts // t  # tile coordinates
        r = pts % t  # intra-tile coordinates
        cols = []
        for a in self.outer_axes:
            cols.append(q[:, a])
        for a in self.inner_axes:
            if a == self.axis:
                cols.append(pts[:, a] % self.width)  # paper's modulo projection
            else:
                cols.append(r[:, a])
        return np.stack(cols, axis=1)

    def offsets(self, pts: np.ndarray) -> np.ndarray:
        """Row-major linear offsets within the facet array for iteration points."""
        return self.coords(pts) @ row_major_strides(self.shape)

    def block_start(self, tile: Sequence[int]) -> int:
        """Linear offset of the first element of tile T's facet block."""
        strides = row_major_strides(self.shape)
        q = np.asarray(tile, dtype=np.int64)
        idx = np.array([q[a] for a in self.outer_axes], dtype=np.int64)
        return int(idx @ strides[: len(self.outer_axes)])


def _facet_axis_orders(
    k: int, c: int, d: int, contiguity: str
) -> tuple[tuple[int, ...], tuple[int, ...]]:
    """(outer_axes, inner_axes) for facet ``k`` with extension dir ``c`` at the
    requested contiguity level (levels are cumulative, §IV-G -> H -> I)."""
    if contiguity not in CONTIGUITY_LEVELS:
        raise ValueError(f"contiguity must be one of {CONTIGUITY_LEVELS}: {contiguity!r}")
    if contiguity == "full-tile" or c == k:
        # §IV-G only: blocked facet, canonical order, no extension direction.
        outer = (k, *(a for a in range(d) if a != k))
        inner = tuple(range(d))
        if contiguity == "intra-tile" and c == k:
            inner = (*(a for a in range(d) if a != k), k)
        return outer, inner
    rest = [a for a in range(d) if a not in (k, c)]
    # outer: k first (single-assignment axis), others ascending, c's tile
    # coordinate last (inter-tile contiguity, §IV-H).
    outer = (k, *rest, c)
    if contiguity == "inter-tile":
        # inner: extension dim first, remaining axes canonical.
        inner = (c, *(a for a in range(d) if a != c))
    else:
        # intra-tile (§IV-I): additionally the modulo dim (axis k) goes last.
        inner = (c, *rest, k)
    return outer, inner


def build_facet_specs(
    space: IterSpace,
    deps: Deps,
    tiling: Tiling,
    *,
    ext_dirs: Mapping[int, int] | Sequence[tuple[int, int]] | None = None,
    contiguity: str = "intra-tile",
) -> dict[int, FacetSpec]:
    """Construct a CFA facet family for a (space, deps, tiling) triple.

    ``ext_dirs`` maps facet axis -> inter-tile extension direction (defaults
    to the cyclic ``(k+1) mod d`` of the paper); ``contiguity`` selects one of
    ``CONTIGUITY_LEVELS``.  The defaults reproduce the paper's final layout.
    """
    d = space.ndim
    widths = facet_widths(deps)
    nt = tiling.num_tiles(space)
    ext = dict(ext_dirs) if ext_dirs is not None else {}
    specs: dict[int, FacetSpec] = {}
    for k in range(d):
        w = widths[k]
        if w <= 0:
            continue
        if w > tiling.sizes[k]:
            raise ValueError(
                f"facet width {w} exceeds tile size {tiling.sizes[k]} on axis {k}; "
                "tiles must be at least as deep as the dependence pattern"
            )
        c = ext.get(k, extension_dir(k, d))
        if d == 1:
            if c != k:
                raise ValueError(
                    f"1-D space: facet_{k} has no projected axis to extend "
                    f"along; the only legal value is c == k (got {c})"
                )
        elif not (0 <= c < d) or c == k:
            raise ValueError(
                f"invalid extension direction {c} for facet axis {k}: must "
                f"be a projected axis (0 <= c < {d}, c != {k})"
            )
        outer, inner = _facet_axis_orders(k, c, d, contiguity)
        specs[k] = FacetSpec(
            axis=k,
            width=w,
            tile_sizes=tuple(tiling.sizes),
            num_tiles=nt,
            outer_axes=outer,
            inner_axes=inner,
            ext_dir=c,
        )
    return specs
