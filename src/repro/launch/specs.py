"""Input specs and sharding trees for every (architecture x shape) cell.

``input_specs`` returns weak-type-correct ``ShapeDtypeStruct`` stand-ins for
every model input (the shannon/kernels pattern): shardable, no device
allocation.  ``build_cell`` assembles everything the dry-run needs: the step
function, abstract arguments, and in/out sharding trees.

Shape cells (LM transformer shapes are seq_len x global_batch):

* train_4k     — seq 4096,   batch 256 (training; lowers train_step)
* prefill_32k  — seq 32768,  batch 32  (inference prefill)
* decode_32k   — seq 32768,  batch 128 (one new token, KV cache of seq_len)
* long_500k    — seq 524288, batch 1   (long-context decode; SSM/hybrid only)

Modality stubs: [vlm]/[audio] context embeddings are precomputed
(B, n_ctx, d) tensors.  Enc-dec prefill applies seq_len to the *encoder*
(frames) and an 8x-shorter decoder prefix (DESIGN.md).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import DP_AXES, sanitize_tree, translate_specs
from repro.models.config import ArchConfig
from repro.models.lm import init_caches, init_lm, spec_lm
from repro.optim import make_optimizer, opt_state_specs
from repro.train.steps import TrainHParams, make_decode_step, make_prefill_step, make_train_step

__all__ = ["SHAPE_CELLS", "cell_applicable", "build_cell", "Cell"]

SHAPE_CELLS = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}


def cell_applicable(cfg: ArchConfig, cell: str) -> tuple[bool, str]:
    if cell == "long_500k" and not cfg.supports_long_context:
        return False, (
            "pure full-attention arch: O(S^2) attention at 524288 requires a "
            "sub-quadratic mechanism this model does not have (DESIGN.md skip)"
        )
    return True, ""


@dataclasses.dataclass
class Cell:
    arch: str
    cell: str
    kind: str
    step: Any  # callable to jit
    args: tuple  # abstract args (ShapeDtypeStruct trees)
    in_shardings: tuple
    out_shardings: Any


def _dp_size(mesh: Mesh) -> int:
    return int(mesh.shape.get("pod", 1) * mesh.shape.get("data", 1))


def _cache_specs(cache_abs, batch: int, mesh: Mesh, *, pure_dp: bool = False):
    """Sharding specs for the (period-stacked) decode cache tree.

    Batch shards over (pod, data) when divisible; otherwise (batch-1
    long-context) the KV-cache *sequence-block* axis shards over 'data'
    (flash-decode style).  Pure-DP archs shard sequence blocks over the
    otherwise-idle 'model' axis instead of kv heads."""
    batch_ok = batch % _dp_size(mesh) == 0

    def rule(path, leaf):
        name = None
        for k in reversed(path):
            if hasattr(k, "name"):
                name = k.name
                break
            if hasattr(k, "key"):
                name = k.key
                break
        bdim = DP_AXES if batch_ok else None
        head_dim = None if pure_dp else "model"
        if name in ("k", "v"):  # (periods, B, nb, H, bs, D)
            nb_dim = "model" if pure_dp else (None if batch_ok else "data")
            return P(None, bdim, nb_dim, head_dim, None, None)
        if name == "state":  # (periods, B, H, Pd, N)
            return P(None, bdim, head_dim, None, None)
        if name in ("conv_x",):  # (periods, B, K-1, din)
            return P(None, bdim, None, head_dim)
        if name in ("conv_B", "conv_C"):
            return P(None, bdim, None, None)
        if name in ("cross_k", "cross_v"):  # (periods, B, S_src, H, D)
            return P(None, bdim, None, head_dim, None)
        return P()

    return jax.tree_util.tree_map_with_path(rule, cache_abs)


def _abstract(f, *args, **kw):
    return jax.eval_shape(functools.partial(f, **kw), *args)


def input_specs(cfg: ArchConfig, cell: str) -> dict:
    """ShapeDtypeStruct stand-ins for the cell's model inputs."""
    info = SHAPE_CELLS[cell]
    seq, batch, kind = info["seq"], info["batch"], info["kind"]
    tok = jnp.int32
    out: dict[str, Any] = {}
    if kind == "train":
        out["tokens"] = jax.ShapeDtypeStruct((batch, seq), tok)
        if cfg.family == "vlm":
            out["context"] = jax.ShapeDtypeStruct(
                (batch, cfg.n_context_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.is_encdec:
            out["context"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                                  jnp.bfloat16)
    elif kind == "prefill":
        dec_seq = seq
        if cfg.is_encdec:
            dec_seq = max(seq // 8, 128)
            out["context"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                                  jnp.bfloat16)
        elif cfg.family == "vlm":
            out["context"] = jax.ShapeDtypeStruct(
                (batch, cfg.n_context_tokens, cfg.d_model), jnp.bfloat16)
        out["tokens"] = jax.ShapeDtypeStruct((batch, dec_seq), tok)
    else:  # decode
        out["token"] = jax.ShapeDtypeStruct((batch,), tok)
        out["position"] = jax.ShapeDtypeStruct((), tok)
    return out


def policy_for(cfg: ArchConfig, cell: str) -> dict:
    """use_mesh policy per (arch, cell): pure-DP archs fold 'model' into the
    batch axes; serving keeps activations on the training policy but the
    caller also strips FSDP from the weights (see build_cell)."""
    if cfg.parallelism == "dp":
        return {"dp_axes": ("pod", "data", "model"), "drop_axes": {"model"}}
    return {"dp_axes": DP_AXES, "drop_axes": frozenset()}


def default_hparams(cfg: ArchConfig) -> TrainHParams:
    """Per-arch training hyper-parameters for the production mesh: the
    largest models micro-batch via gradient accumulation so the per-device
    activation working set stays inside HBM (EXPERIMENTS.md §Memory)."""
    accum = 4 if cfg.d_model >= 5120 else 1
    return TrainHParams(accum=accum)


def build_cell(cfg: ArchConfig, cell: str, mesh: Mesh,
               hp: TrainHParams | None = None) -> Cell:
    info = SHAPE_CELLS[cell]
    seq, batch, kind = info["seq"], info["batch"], info["kind"]
    hp = hp or default_hparams(cfg)
    ins = input_specs(cfg, cell)
    pol = policy_for(cfg, cell)
    dp = pol["dp_axes"]

    params_abs = _abstract(lambda k: init_lm(k, cfg), jax.random.PRNGKey(0))
    pspecs = spec_lm(cfg)
    if pol["drop_axes"]:  # pure-DP: weights lose their TP axes
        pspecs = translate_specs(pspecs, drop=pol["drop_axes"])
    if kind != "train":
        # serving weights are not FSDP-sharded: per-layer parameter
        # all-gathers have no business in a decode step (§Perf H2b)
        pspecs = translate_specs(pspecs, drop=("data", "pod"))
    psh = sanitize_tree(pspecs, params_abs, mesh)

    if kind == "train":
        opt_init, _ = make_optimizer(cfg.optimizer)
        opt_abs = _abstract(opt_init, params_abs)
        ospecs = opt_state_specs(pspecs, params_abs, cfg.optimizer)
        osh = sanitize_tree(ospecs, opt_abs, mesh)
        batch_abs = ins
        bspec = {
            "tokens": P(dp, None),
            **({"context": P(dp, None, None)} if "context" in ins else {}),
        }
        bsh = sanitize_tree(bspec, batch_abs, mesh)
        step = make_train_step(cfg, hp)
        return Cell(cfg.name, cell, kind, step,
                    (params_abs, opt_abs, batch_abs),
                    (psh, osh, bsh),
                    (psh, osh, None))

    pure_dp = cfg.parallelism == "dp"
    logits_spec = P(DP_AXES, None if pure_dp else "model")
    if kind == "prefill":
        step = make_prefill_step(cfg, max_seq=None)
        args = [params_abs, ins["tokens"]]
        shardings = [psh, sanitize_tree(P(dp, None), ins["tokens"], mesh)]
        if "context" in ins:
            args.append(ins["context"])
            shardings.append(
                sanitize_tree(P(dp, None, None), ins["context"], mesh))
        dec_len = args[1].shape[1]
        src_len = ins["context"].shape[1] if "context" in ins else 0
        cache_abs = _abstract(
            lambda: init_caches(cfg, batch, dec_len, src_len))
        csh = sanitize_tree(_cache_specs(cache_abs, batch, mesh,
                                         pure_dp=pure_dp), cache_abs, mesh)
        logits_sh = sanitize_tree(
            logits_spec,
            jax.ShapeDtypeStruct((batch, cfg.padded_vocab), jnp.bfloat16), mesh)
        return Cell(cfg.name, cell, kind, step, tuple(args), tuple(shardings),
                    (logits_sh, csh))

    # decode
    src_len = 0
    if cfg.family == "vlm":
        src_len = cfg.n_context_tokens
    if cfg.is_encdec:
        src_len = cfg.n_context_tokens
    cache_abs = _abstract(
        lambda: init_caches(cfg, batch, seq, src_len,
                            dtype=jnp.dtype(cfg.kv_cache_dtype)))
    csh = sanitize_tree(_cache_specs(cache_abs, batch, mesh, pure_dp=pure_dp),
                        cache_abs, mesh)
    tok_sh = sanitize_tree(P(DP_AXES), ins["token"], mesh)
    pos_sh = sanitize_tree(P(), ins["position"], mesh)
    step = make_decode_step(cfg)
    logits_sh = sanitize_tree(
        logits_spec,
        jax.ShapeDtypeStruct((batch, cfg.padded_vocab), jnp.bfloat16), mesh)
    return Cell(cfg.name, cell, kind, step,
                (params_abs, cache_abs, ins["token"], ins["position"]),
                (psh, csh, tok_sh, pos_sh),
                (logits_sh, csh))
