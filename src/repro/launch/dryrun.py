"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh) cell.

Proves the distribution config is coherent without real hardware: sharding
mismatches, compile-time OOMs and unsupported collectives all surface here.
Emits one JSON record per cell (memory analysis, cost analysis, per-kind
collective bytes parsed from the post-SPMD HLO) that §Roofline reads.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--cell C]
        [--mesh single|multi|both] [--out benchmarks/results/dryrun]
"""
# The forced device count MUST precede any other import that could touch jax
# (jax locks the device count on first init).  Do not move these two lines.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCH_NAMES, get_config
from repro.distributed.sharding import use_mesh
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SHAPE_CELLS, build_cell, cell_applicable, policy_for

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes per collective kind from post-optimization HLO.

    Two passes: (1) map every defined value name to its byte size from the
    definition's result type; (2) for each collective op, sum the sizes of
    its named operands.  ``-start`` variants are counted; ``-done`` are not
    (they carry the same buffers)."""
    sizes: dict[str, int] = {}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        name, rhs = m.groups()
        ty = rhs.split(" ", 1)[0] if not rhs.startswith("(") else rhs[: rhs.index(")") + 1]
        sizes[name] = _type_bytes(ty)
    out = {k: 0 for k in COLLECTIVE_KINDS}
    counts = {k: 0 for k in COLLECTIVE_KINDS}
    op_re = re.compile(
        r"=\s*(?:\([^)]*\)|\S+)\s+(" + "|".join(COLLECTIVE_KINDS) + r")(?:-start)?\(([^)]*)\)"
    )
    for ln in lines:
        if "-done(" in ln:
            continue
        m = op_re.search(ln)
        if not m:
            continue
        kind, operands = m.groups()
        total = 0
        for op in operands.split(","):
            op = op.strip().lstrip("%")
            if op in sizes:
                total += sizes[op]
        out[kind] += total
        counts[kind] += 1
    return {"bytes": out, "counts": counts}


def run_cell(arch: str, cell: str, mesh_kind: str, out_dir: Path,
             hlo_dir: Path | None = None) -> dict:
    cfg = get_config(arch)
    rec = {"arch": arch, "cell": cell, "mesh": mesh_kind}
    ok, why = cell_applicable(cfg, cell)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    try:
        t0 = time.time()
        with use_mesh(mesh, **policy_for(cfg, cell)):
            c = build_cell(cfg, cell, mesh)
            jitted = jax.jit(c.step, in_shardings=c.in_shardings,
                             out_shardings=c.out_shardings)
            lowered = jitted.lower(*c.args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        coll = parse_collective_bytes(hlo)
        try:  # loop-aware static analysis (benchmarks/hlo_analysis.py)
            import sys
            sys.path.insert(0, str(Path(__file__).resolve().parents[3]))
            from benchmarks.hlo_analysis import analyze_hlo
            st = analyze_hlo(hlo)
            loop_aware = {
                "flops": st.flops,
                "collective_bytes": st.collective_bytes,
                "collective_counts": st.collective_counts,
                "hbm_traffic_bytes": st.hbm_traffic_bytes,
                "while_trips": st.while_trips,
            }
        except Exception as e:
            loop_aware = {"error": str(e)}
        rec.update(
            status="ok",
            t_lower_s=round(t1 - t0, 2),
            t_compile_s=round(t2 - t1, 2),
            flops=cost.get("flops", -1.0),
            bytes_accessed=cost.get("bytes accessed", -1.0),
            loop_aware=loop_aware,
            memory={
                k: getattr(mem, k)
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "alias_size_in_bytes",
                          "generated_code_size_in_bytes")
                if hasattr(mem, k)
            },
            collectives=coll,
            n_devices=mesh.devices.size,
            hlo_lines=len(hlo.splitlines()),
        )
        if hlo_dir is not None:
            hlo_dir.mkdir(parents=True, exist_ok=True)
            (hlo_dir / f"{arch}__{cell}__{mesh_kind}.hlo.txt").write_text(hlo)
    except Exception as e:  # a failure here is a bug in the system
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:])
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id (default all)")
    ap.add_argument("--cell", default=None, choices=[*SHAPE_CELLS, None])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    hlo_dir = out_dir / "hlo" if args.save_hlo else None

    archs = [args.arch] if args.arch else ARCH_NAMES
    cells = [args.cell] if args.cell else list(SHAPE_CELLS)
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]

    n_fail = 0
    for arch in archs:
        for cell in cells:
            for mk in meshes:
                path = out_dir / f"{arch}__{cell}__{mk}.json"
                if path.exists():
                    rec = json.loads(path.read_text())
                    if rec.get("status") in ("ok", "skipped"):
                        print(f"[cached] {arch} {cell} {mk}: {rec['status']}")
                        continue
                rec = run_cell(arch, cell, mk, out_dir, hlo_dir)
                path.write_text(json.dumps(rec, indent=1))
                line = f"{arch} {cell} {mk}: {rec['status']}"
                if rec["status"] == "ok":
                    la_flops = rec.get("loop_aware", {}).get("flops", rec["flops"])
                    line += (f" flops={la_flops:.3e}"
                             f" compile={rec['t_compile_s']}s")
                    mem = rec.get("memory", {})
                    if "argument_size_in_bytes" in mem:
                        gb = (mem["argument_size_in_bytes"]
                              + mem.get("temp_size_in_bytes", 0)) / 2**30
                        line += f" perdev_mem={gb:.2f}GiB"
                elif rec["status"] == "error":
                    n_fail += 1
                    line += f" !! {rec['error'][:200]}"
                print(line, flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
