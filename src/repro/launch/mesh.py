"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state; ``dryrun.py`` sets the forced host device count
before calling it.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "mesh_for_devices"]


def make_production_mesh(*, multi_pod: bool = False):
    """The assignment's target mesh: 16x16 (one v5e-class pod, 256 chips) or
    2x16x16 (two pods, 512 chips).  Axes: 'pod' (DCN) x 'data' (DP/FSDP) x
    'model' (TP/EP)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_for_devices(n: int | None = None, model: int = 1):
    """A small mesh over whatever devices exist (tests, examples)."""
    n = n or len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
