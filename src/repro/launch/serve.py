"""Serving launcher: batched prefill + decode over the facet-layout KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models.lm import init_lm
from repro.train.steps import make_decode_step, make_prefill_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; >0 = temperature sampling")
    ap.add_argument("--top-k", type=int, default=0, help="top-k filter (0=off)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_lm(jax.random.PRNGKey(args.seed), cfg)
    max_seq = args.prompt_len + args.gen
    prefill = jax.jit(make_prefill_step(cfg, max_seq=max_seq))
    decode = jax.jit(make_decode_step(cfg))

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len)), jnp.int32)
    ctx = None
    if cfg.family in ("vlm", "encdec"):
        ctx = jnp.asarray(
            rng.normal(size=(args.batch, cfg.n_context_tokens, cfg.d_model)) * 0.02,
            jnp.bfloat16)

    def pick(logits, key):
        lv = logits[:, : cfg.vocab].astype(jnp.float32)
        if args.temperature <= 0:
            return jnp.argmax(lv, -1).astype(jnp.int32)
        lv = lv / args.temperature
        if args.top_k > 0:
            kth = jnp.sort(lv, axis=-1)[:, -args.top_k][:, None]
            lv = jnp.where(lv < kth, -jnp.inf, lv)
        return jax.random.categorical(key, lv, axis=-1).astype(jnp.int32)

    key = jax.random.PRNGKey(args.seed + 1)
    t0 = time.time()
    if ctx is not None:
        logits, caches = prefill(params, prompts, ctx)
    else:
        logits, caches = prefill(params, prompts)
    jax.block_until_ready(logits)
    t1 = time.time()

    key, sub = jax.random.split(key)
    tok = pick(logits, sub)
    out_tokens = [tok]
    for i in range(args.gen - 1):
        logits, caches = decode(params, caches, tok, jnp.int32(args.prompt_len + i))
        key, sub = jax.random.split(key)
        tok = pick(logits, sub)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t2 = time.time()

    gen = np.stack([np.asarray(t) for t in out_tokens], 1)
    print(f"prefill: {args.batch}x{args.prompt_len} tokens in {t1-t0:.2f}s")
    print(f"decode: {args.batch}x{args.gen} tokens in {t2-t1:.2f}s "
          f"({args.batch*args.gen/(t2-t1):.1f} tok/s)")
    print("sample generations (token ids):")
    for row in gen[:2]:
        print(" ", row[:16].tolist())


if __name__ == "__main__":
    main()
