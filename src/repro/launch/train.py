"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --steps 100 --batch 8 --seq 128

``--smoke`` selects the reduced same-family config (CPU-runnable); without
it the full published config is used (requires a real cluster — the mesh
comes from ``make_production_mesh``).  The loop is fault-tolerant: rerun the
same command after a kill and it restarts from the latest checkpoint.
"""
from __future__ import annotations

import argparse
from pathlib import Path

import jax

from repro.configs import get_config, get_smoke_config
from repro.launch.mesh import make_production_mesh, mesh_for_devices
from repro.train.loop import Trainer
from repro.train.steps import TrainHParams


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.smoke:
        mesh = None if len(jax.devices()) == 1 else mesh_for_devices()
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    hp = TrainHParams(peak_lr=args.lr, accum=args.accum,
                      total_steps=max(args.steps, 10), warmup=min(20, args.steps))
    trainer = Trainer(cfg, batch=args.batch, seq=args.seq,
                      ckpt_dir=Path(args.ckpt_dir) / cfg.name, hp=hp, mesh=mesh,
                      ckpt_every=args.ckpt_every)
    start = trainer.step
    log = trainer.run(args.steps)
    for m in log:
        print(" ".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                       for k, v in m.items()))
    print(f"ran {trainer.step - start} steps (resumed from {start})")
    if args.metrics_out:
        trainer.save_metrics(args.metrics_out)
    trainer.data.close()


if __name__ == "__main__":
    main()
