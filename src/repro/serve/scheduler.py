"""Continuous batching over the facet-layout KV cache.

The serving loop keeps a fixed number of *lanes* (batch slots). Each lane
runs its own sequence at its own position — admitted whenever a lane frees
up, retired on max-tokens/EOS — so decode steps always run at full batch
occupancy instead of waiting for the slowest request (the task-level
pipeline of paper Fig. 13, applied to requests).

The facet(block) cache makes lane management cheap: a lane's state is a
batch-row slice of the block arrays; admission writes one lane's prefilled
blocks (contiguous extents), no re-packing of other lanes.

Single-process reference implementation (the same step functions jit and
shard under the production mesh; admission is host-side control flow).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cfa.obs import TraceRecorder, now
from repro.models.config import ArchConfig
from repro.models.lm import init_caches, lm_decode, lm_prefill

__all__ = ["Request", "ContinuousBatcher"]

_TRACK = "serve/sched"  # single scheduler lane in the trace timeline


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (L,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, cfg: ArchConfig, params, *, lanes: int, max_seq: int,
                 eos: int | None = None,
                 recorder: TraceRecorder | None = None):
        self.cfg = cfg
        self.params = params
        self.lanes = lanes
        self.max_seq = max_seq
        self.eos = eos
        self.recorder = recorder
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * lanes
        self.positions = np.zeros(lanes, np.int32)  # next write index per lane
        self.caches = init_caches(cfg, lanes, max_seq, 0)
        self.last_tok = np.zeros(lanes, np.int32)
        self.ticks = 0
        self.tokens = 0
        self._elapsed_s = 0.0

        self._prefill1 = jax.jit(
            lambda p, t: lm_prefill(p, t, cfg, max_seq=max_seq))
        self._decode = jax.jit(
            lambda p, c, t, pos: lm_decode(p, c, t, pos, cfg))

    # ------------------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        rec = self.recorder
        for lane in range(self.lanes):
            if self.active[lane] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            t0 = now() if rec is not None else 0.0
            logits, c1 = self._prefill1(self.params, jnp.asarray(req.prompt)[None])
            # splice the single-request cache into this lane's batch row
            self.caches = jax.tree.map(
                lambda full, one: full.at[:, lane].set(one[:, 0]),
                self.caches, c1)
            tok = int(jnp.argmax(logits[0, : self.cfg.vocab]))
            req.out.append(tok)
            self.active[lane] = req
            self.positions[lane] = len(req.prompt)
            self.last_tok[lane] = tok
            if rec is not None:
                rec.add_span("admit", t0, now(), track=_TRACK, cat="serve",
                             rid=req.rid, lane=lane,
                             prompt_len=len(req.prompt))
                rec.counters.add("serve_admitted", 1)
            self._maybe_retire(lane)

    def _retire(self, lane: int) -> None:
        req = self.active[lane]
        req.done = True
        self.active[lane] = None
        rec = self.recorder
        if rec is not None:
            rec.instant("retire", track=_TRACK, cat="serve",
                        rid=req.rid, lane=lane, n_out=len(req.out))
            rec.counters.add("serve_retired", 1)

    def _maybe_retire(self, lane: int) -> None:
        req = self.active[lane]
        if req is None:
            return
        if len(req.out) >= req.max_new or (
                self.eos is not None and req.out and req.out[-1] == self.eos):
            self._retire(lane)

    # ------------------------------------------------------------------

    def step(self) -> int:
        """Admit, run one decode tick over all lanes, retire. Returns the
        number of active lanes that produced a token."""
        rec = self.recorder
        t0 = now()
        self._admit()
        live = [i for i, r in enumerate(self.active) if r is not None]
        if live:
            logits, self.caches = self._decode(
                self.params, self.caches, jnp.asarray(self.last_tok),
                jnp.asarray(self.positions))
            toks = np.asarray(jnp.argmax(logits[:, : self.cfg.vocab], -1),
                              np.int32)
            for lane in live:
                req = self.active[lane]
                req.out.append(int(toks[lane]))
                self.positions[lane] += 1
                self.last_tok[lane] = toks[lane]
                if self.positions[lane] >= self.max_seq - 1:
                    self._retire(lane)
                else:
                    self._maybe_retire(lane)
        self.ticks += 1
        self.tokens += len(live)
        self._elapsed_s += now() - t0
        if rec is not None:
            rec.add_span("step", t0, now(), track=_TRACK, cat="serve",
                         tick=self.ticks, occupancy=len(live),
                         queue_depth=len(self.queue))
            rec.counter_event("occupancy", len(live))
            rec.counters.add("serve_ticks", 1)
            rec.counters.add("serve_tokens", len(live))
        return len(live)

    def stats(self) -> dict:
        """Tick accounting: decode throughput and current load."""
        return {
            "ticks": self.ticks,
            "tokens": self.tokens,
            "elapsed_s": self._elapsed_s,
            "tokens_per_sec": (self.tokens / self._elapsed_s
                               if self._elapsed_s > 0 else 0.0),
            "occupancy": sum(r is not None for r in self.active) / self.lanes,
            "queue_depth": len(self.queue),
        }

    def run(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if not self.queue and all(r is None for r in self.active):
                return
            self.step()
        raise RuntimeError("scheduler did not drain")
