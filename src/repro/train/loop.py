"""The training loop: fault-tolerant runner tying together data, steps,
checkpointing and metrics.

Fault-tolerance contract (1000+-node posture):
* restart-from-latest: on start, the loop restores the newest committed
  checkpoint (elastically resharded onto whatever mesh we now have);
* preemption handling: a sentinel file (``<ckpt_dir>/PREEMPT``) — standing in
  for the cluster's preemption signal — triggers an immediate blocking
  checkpoint and a clean exit;
* periodic async checkpoints overlap disk I/O with compute;
* straggler mitigation: the data pipeline's per-step deadline skips a slow
  batch rather than stalling the step (counted in metrics).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import SyntheticTokens
from repro.distributed.sharding import use_mesh
from repro.models.config import ArchConfig
from repro.models.lm import init_lm
from repro.optim import make_optimizer
from repro.train.steps import TrainHParams, make_train_step

__all__ = ["Trainer"]


class Trainer:
    def __init__(self, cfg: ArchConfig, *, batch: int, seq: int,
                 ckpt_dir: str | Path, hp: TrainHParams | None = None,
                 mesh=None, seed: int = 0, ckpt_every: int = 50,
                 data=None):
        self.cfg = cfg
        self.hp = hp or TrainHParams()
        self.mesh = mesh
        self.ckpt = CheckpointManager(ckpt_dir)
        self.ckpt_every = ckpt_every
        self.data = data or SyntheticTokens(vocab=cfg.vocab, batch=batch, seq=seq,
                                            seed=seed)
        opt_init, _ = make_optimizer(cfg.optimizer)
        with use_mesh(self.mesh):
            self.params = init_lm(jax.random.PRNGKey(seed), cfg)
            self.opt_state = opt_init(self.params)
            self.step_fn = jax.jit(make_train_step(cfg, self.hp), donate_argnums=(0, 1))
        self.step = 0
        self.metrics_log: list[dict] = []
        self._maybe_restore()

    # ------------------------------------------------------------------

    def _maybe_restore(self) -> None:
        latest = self.ckpt.latest_step()
        if latest is None:
            return
        state = self.ckpt.restore(latest, (self.params, self.opt_state))
        self.params, self.opt_state = state
        self.step = latest
        if hasattr(self.data, "seek"):
            self.data.seek(latest)  # deterministic data: resume exactly

    def _preempted(self) -> bool:
        return (self.ckpt.dir / "PREEMPT").exists()

    # ------------------------------------------------------------------

    def run(self, n_steps: int, *, log_every: int = 10,
            step_deadline_s: float | None = None) -> list[dict]:
        with use_mesh(self.mesh):
            end = self.step + n_steps
            while self.step < end:
                t0 = time.time()
                batch = self.data.next(deadline_s=step_deadline_s)
                batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch)
                self.step += 1
                if self.step % log_every == 0 or self.step == end:
                    m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                    m.update(step=self.step, dt=time.time() - t0,
                             skipped_batches=self.data.stats["skipped"])
                    self.metrics_log.append(m)
                if self.step % self.ckpt_every == 0:
                    self.ckpt.save(self.step, (self.params, self.opt_state))
                if self._preempted():
                    self.ckpt.save(self.step, (self.params, self.opt_state),
                                   blocking=True)
                    break
            self.ckpt.wait()
        return self.metrics_log

    def save_metrics(self, path: str | Path) -> None:
        Path(path).write_text(json.dumps(self.metrics_log, indent=1))
