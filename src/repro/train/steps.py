"""Lowerable entry points: train_step / prefill_step / decode_step builders.

These are the functions the multi-pod dry-run lowers and compiles for every
(architecture x input-shape x mesh) cell, and the ones the real launcher
jits.  They are pure (params, state, batch) -> (params, state, metrics)
functions; sharding comes from in_shardings at the jit boundary plus the
internal constraints in the model code.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.lm import init_caches, lm_decode, lm_forward, lm_prefill
from repro.optim import clip_by_global_norm, cosine_warmup, make_optimizer

__all__ = ["TrainHParams", "loss_fn", "make_train_step", "make_prefill_step",
           "make_decode_step"]


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    peak_lr: float = 3e-4
    warmup: int = 200
    total_steps: int = 10_000
    clip_norm: float = 1.0
    aux_coef: float = 0.01  # MoE load-balance loss coefficient
    accum: int = 1  # gradient-accumulation microbatches
    remat: bool = True
    remat_policy: str = "none"  # none | dots | nothing
    shard_grads: bool = True  # pin grads to param sharding (ZeRO RS; §Perf H1)
    compress_grads: bool = False  # int8 error-feedback DP compression

    def policy(self):
        if self.remat_policy == "dots":
            return jax.checkpoint_policies.checkpoint_dots
        if self.remat_policy == "nothing":
            return jax.checkpoint_policies.nothing_saveable
        return None


def loss_fn(params, batch: dict, cfg: ArchConfig, hp: TrainHParams):
    """Next-token cross entropy (padded-vocab masked) + MoE aux loss."""
    tokens = batch["tokens"]  # (B, S)
    logits, aux = lm_forward(
        params, tokens, cfg,
        cross_src=batch.get("context"),
        remat=hp.remat, remat_policy=hp.policy(),
    )
    # Shift: predict t+1 from <=t.  The cross entropy is computed in a
    # vocab-sharding-preserving form: no gather/scatter over the (model-
    # sharded) vocab axis — padded-vocab masking is an additive row, the
    # target pick is a masked reduction.  (take_along_axis here makes GSPMD
    # materialise full-vocab f32 logits AND cotangents per device — 40 GiB
    # for qwen3 train_4k; measured, see EXPERIMENTS.md §Perf iteration 0.)
    lf = logits[:, :-1]
    targets = tokens[:, 1:]
    vp = cfg.padded_vocab
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, (1, 1, vp), 2)
    pad_mask = jnp.where(vocab_ids >= cfg.vocab, -1e30, 0.0).astype(jnp.float32)
    lf = lf.astype(jnp.float32) + pad_mask
    m = jax.lax.stop_gradient(lf.max(axis=-1, keepdims=True))
    shifted = lf - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    picked = jnp.sum(
        jnp.where(vocab_ids == targets[..., None], shifted, 0.0), axis=-1
    ) + m[..., 0]
    ce = (lse - picked).mean()
    return ce + hp.aux_coef * aux, {"ce": ce, "aux": aux}


def make_train_step(cfg: ArchConfig, hp: TrainHParams = TrainHParams()):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    _, opt_update = make_optimizer(cfg.optimizer)
    param_specs = None
    if hp.shard_grads:
        from repro.models.lm import spec_lm

        param_specs = spec_lm(cfg)

    def train_step(params, opt_state, batch):
        if hp.accum > 1:
            def micro(carry, mb):
                gacc, lacc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb, cfg, hp)
                return (jax.tree.map(jnp.add, gacc, g), lacc + l), None

            mbs = jax.tree.map(
                lambda x: x.reshape(hp.accum, x.shape[0] // hp.accum, *x.shape[1:]),
                batch,
            )
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / hp.accum, grads)
            loss = loss / hp.accum
            metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch, cfg, hp)
        if param_specs is not None:
            from repro.distributed.sharding import constrain_tree

            grads = constrain_tree(grads, param_specs)
        if hp.compress_grads:
            from repro.distributed.compression import ef_compress, ef_init

            # stateless form: residual folded into the next step via opt mu;
            # full error feedback lives in the Trainer (kept simple here)
            grads, _ = ef_compress(grads, ef_init(grads))
        grads, gnorm = clip_by_global_norm(grads, hp.clip_norm)
        lr = cosine_warmup(opt_state.step, peak_lr=hp.peak_lr,
                           warmup=hp.warmup, total=hp.total_steps)
        params, opt_state = opt_update(grads, opt_state, params, lr)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, *, max_seq: int | None = None):
    def prefill_step(params, tokens, context=None):
        return lm_prefill(params, tokens, cfg, cross_src=context, max_seq=max_seq)

    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def decode_step(params, caches, token, position):
        return lm_decode(params, caches, token, position, cfg)

    return decode_step
