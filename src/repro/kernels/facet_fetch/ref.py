"""Oracle for the facet-fetch kernel: the exact gather-based copy-in."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.cfa import CFAPipeline, IterSpace, Tiling
from repro.core.cfa.programs import get_program


def fetch_interior_halos_ref(program_name, facets, space, tile):
    prog = get_program(program_name)
    pipe = CFAPipeline(prog, IterSpace(space), Tiling(tile))
    nt = pipe.num_tiles
    outs = []
    for q0 in range(1, nt[0]):
        for q1 in range(1, nt[1]):
            for q2 in range(1, nt[2]):
                outs.append(pipe.copy_in(facets, (q0, q1, q2)))
    H = jnp.stack(outs)
    return H.reshape(nt[0] - 1, nt[1] - 1, nt[2] - 1, *outs[0].shape)
