from .facet_fetch import fetch_interior_halos
from .ref import fetch_interior_halos_ref

__all__ = [
    "fetch_interior_halos",
    "fetch_interior_halos_ref",
    "fetch_interior_halos_sharded",
]


def fetch_interior_halos_sharded(program_name, facets, space, tile,
                                 assignment, mesh=None, *, axis="port",
                                 interpret=True, storage="redundant"):
    """Block-wise halo fetch with facet arrays resident on their ports.

    The multi-port analogue of ``fetch_interior_halos``: the facet arrays are
    first placed on their assigned port's device
    (``repro.distributed.sharding.shard_facets``), then each is pulled into
    the fetch engine's device with one explicit transfer per facet — the
    read traffic sources from the port that owns each facet, exactly as the
    ``assignment`` (a ``multiport.PortAssignment``) prescribes.  (The jit'd
    kernel itself runs on one device: its BlockSpec DMAs model the per-port
    channel reads, as on real hardware where every HBM channel feeds the
    same compute die.)  Returns the same
    (n0-1, n1-1, n2-1, w0+t0, w1+t1, w2+t2) halo volume.
    """
    import jax

    from repro.distributed.sharding import port_mesh, shard_facets

    if mesh is None:
        mesh = port_mesh(assignment.n_ports, axis)
    facets = shard_facets(facets, assignment.facet_to_port, mesh, axis)
    # one transfer per facet, sourced from its owning port's device (skipped
    # for facets already resident there, e.g. a single-device mesh)
    dev0 = list(mesh.devices.reshape(-1))[0]
    facets = {
        k: v if getattr(v, "devices", None) is not None and v.devices() == {dev0}
        else jax.device_put(v, dev0)
        for k, v in facets.items()
    }
    return fetch_interior_halos(program_name, facets, tuple(space),
                                tuple(tile), interpret=interpret,
                                storage=storage)
