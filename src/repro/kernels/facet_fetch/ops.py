from .facet_fetch import fetch_interior_halos
from .ref import fetch_interior_halos_ref

__all__ = [
    "fetch_interior_halos",
    "fetch_interior_halos_ref",
    "fetch_interior_halos_from_autotuned",
]


def fetch_interior_halos_from_autotuned(program_name, facets, decision, *,
                                        interpret=True):
    """Block-wise halo fetch at an autotuned LayoutDecision's winning layout.

    The kernel's static BlockSpecs address only the paper-default facet
    layout, so the decision's best *kernel-compatible* CFA candidate is used
    (default extension dirs, intra-tile contiguity, w | t, >= 2 tiles/axis);
    ``facets`` must have been allocated at that candidate's tile sizes, e.g.
    via ``CFAPipeline.from_autotuned(..., kernel_compatible=True)``.
    """
    best = decision.best_cfa(kernel_compatible=True)
    return fetch_interior_halos(
        program_name, facets, tuple(decision.space),
        tuple(best.candidate.tile), interpret=interpret,
    )
