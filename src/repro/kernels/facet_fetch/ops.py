from .facet_fetch import fetch_interior_halos
from .ref import fetch_interior_halos_ref

__all__ = ["fetch_interior_halos", "fetch_interior_halos_ref"]
