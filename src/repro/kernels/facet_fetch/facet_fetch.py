"""Pallas TPU kernel: the CFA read engine (paper Fig. 13/14, 'read' stage).

Assembles a tile's halo buffer from facet arrays where every input is one
facet *block* addressed by a pure BlockSpec index map — demonstrating the
central adaptation claim of DESIGN.md: CFA's full-tile contiguity makes each
flow-in piece exactly one contiguous HBM extent, i.e. one DMA descriptor.

Per interior tile (q0, q1, q2) the seven backward-neighbour pieces map to:

    facet_0 blocks (q0-1; q1|q1-1; q2|q2-1)   — 4 blocks (time halo + corners)
    facet_1 blocks (q0; q1-1; q2|q2-1)        — 2 blocks (x1 halo + extension)
    facet_2 block  (q0; q1; q2-1)             — 1 block  (x2 halo)

(The paper merges pairs of adjacent blocks into single bursts — e.g. the two
facet_1 blocks are contiguous in HBM because the extension direction's tile
coordinate is the last outer dim; Pallas expresses them as two block reads
that the DMA engine coalesces.)

Boundary tiles (any q == 0) take the jnp copy-in path
(``CFAPipeline.copy_in``); this kernel serves the steady-state interior,
which is where the bandwidth is spent.

**Irredundant storage** (``storage="irredundant"``, Ferry 2024): the facet
arrays store every value exactly once, so the slots a facet block shares
with a lower-axis facet are dead and the fetch must take the *owner-facet
indirection*: four extra owner blocks per tile —

    facet_0 blocks (q0; q1-1|q1; q2|q2-1)   — 3 blocks (x0-tails the x1/x2
                                              halo pieces no longer carry)
    facet_1 block  (q0; q1; q2-1)           — 1 block  (the x1-tail rows of
                                              the x2 halo piece)

— are composited over the dead sub-regions, highest-priority owner last.
Every input is still one facet block addressed by a pure BlockSpec index
map: deduplication costs extra DMA descriptors, never gather addressing.
The ``compressed`` discipline has no in-kernel decode stage and is
rejected (see ``ExecutorCaps.storages``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.cfa.programs import StencilProgram, get_program
from repro.core.cfa.transform import CFAPipeline

__all__ = ["fetch_interior_halos"]


def _assemble(h_ref, f0a, f0b, f0c, f0d, f1a, f1b, f2a, *, w, t):
    """Assemble H[(w0+t0), (w1+t1), (w2+t2)] from seven facet blocks.

    Block layouts (inner dim orders from repro.core.cfa.facets):
      facet_0: (t1, t2, w0)   facet_1: (t2, t0, w1)   facet_2: (t0, t1, w2)
    """
    w0, w1, w2 = w
    t0, t1, t2 = t
    h_ref[...] = jnp.zeros_like(h_ref)
    # time halo: full (x1, x2) cross-section of tile (q0-1, q1, q2)
    h_ref[:w0, w1:, w2:] = f0a[...].transpose(2, 0, 1)
    # x1 halo (+ its time corner): facet_1 of (q0, q1-1, q2) spans full t0
    h_ref[w0:, :w1, w2:] = f1a[...].transpose(1, 2, 0)
    # x2 halo: facet_2 of (q0, q1, q2-1) spans full (t0, t1)
    h_ref[w0:, w1:, :w2] = f2a[...]
    # corner (x0-tail, x1-tail): subset of facet_0 block (q0-1, q1-1, q2)
    h_ref[:w0, :w1, w2:] = f0b[...][t1 - w1 :, :, :].transpose(2, 0, 1)
    # corner (x0-tail, x2-tail): subset of facet_0 block (q0-1, q1, q2-1)
    h_ref[:w0, w1:, :w2] = f0c[...][:, t2 - w2 :, :].transpose(2, 0, 1)
    # corner (x1-tail, x2-tail): subset of facet_1 block (q0, q1-1, q2-1)
    h_ref[w0:, :w1, :w2] = f1b[...][t2 - w2 :, :, :].transpose(1, 2, 0)
    # S3 corner: subset of facet_0 block (q0-1, q1-1, q2-1)
    h_ref[:w0, :w1, :w2] = (
        f0d[...][t1 - w1 :, t2 - w2 :, :].transpose(2, 0, 1)
    )


def _kernel(f0a, f0b, f0c, f0d, f1a, f1b, f2a, h_ref, *, w, t):
    _assemble(h_ref, f0a, f0b, f0c, f0d, f1a, f1b, f2a, w=w, t=t)


def _kernel_irredundant(f0a, f0b, f0c, f0d, f1a, f1b, f2a,
                        g0b, g0c, g0d, g1c, h_ref, *, w, t):
    """The owner-facet indirection: composite the dead sub-regions of the
    facet_1/facet_2 pieces from their owner blocks, lowest priority first
    (facet_2 piece < facet_1 overwrite < facet_0 overwrite), so every halo
    value comes from the one facet that stores it."""
    w0, w1, w2 = w
    t0, t1, t2 = t
    _assemble(h_ref, f0a, f0b, f0c, f0d, f1a, f1b, f2a, w=w, t=t)
    # x1 halo piece: its x0-tail rows are owned by facet_0 of (q0, q1-1, q2)
    h_ref[t0:, :w1, w2:] = g0b[...][t1 - w1 :, :, :].transpose(2, 0, 1)
    # x2 halo piece: x1-tail band owned by facet_1 of (q0, q1, q2-1) ...
    h_ref[w0:, t1:, :w2] = g1c[...][t2 - w2 :, :, :].transpose(1, 2, 0)
    # ... then the x0-tail band by facet_0 of (q0, q1, q2-1) (covers the
    # x0-tail ∩ x1-tail sliver facet_1 does not store either)
    h_ref[t0:, w1:, :w2] = g0c[...][:, t2 - w2 :, :].transpose(2, 0, 1)
    # corner (x1-tail, x2-tail): x0-tail rows from facet_0 of (q0, q1-1, q2-1)
    h_ref[t0:, :w1, :w2] = g0d[...][t1 - w1 :, t2 - w2 :, :].transpose(2, 0, 1)


@functools.partial(jax.jit, static_argnames=("program_name", "space", "tile",
                                              "interpret", "storage"))
def fetch_interior_halos(
    program_name: str,
    facets: dict,  # CFAPipeline facet arrays (facet_0 includes virtual row)
    space: tuple[int, int, int],
    tile: tuple[int, int, int],
    *,
    interpret: bool = True,
    storage: str = "redundant",
) -> jnp.ndarray:
    """Halo buffers for all interior tiles, gathered block-wise.

    Returns (n0-1, n1-1, n2-1, w0+t0, w1+t1, w2+t2); entry (i, j, k)
    corresponds to tile (i+1, j+1, k+1).  ``storage="irredundant"`` takes
    the owner-facet indirection (four extra owner blocks per tile) over
    deduplicated facet arrays; the result is identical to the redundant
    fetch over redundant arrays.
    """
    prog = get_program(program_name)
    from repro.core.cfa import IterSpace, Tiling, build_facet_specs

    if len(space) != 3 or prog.ndim != 3:
        raise ValueError(
            "the facet_fetch kernel's static BlockSpecs address 3-D facet "
            f"layouts only (got a {len(space)}-D space); non-3-D programs "
            "take CFAPipeline.copy_in / kernels.stencil instead"
        )
    if storage not in ("redundant", "irredundant"):
        raise ValueError(
            f"the facet_fetch kernel has no in-kernel decode stage: storage "
            f"must be 'redundant' or 'irredundant', got {storage!r}"
        )
    specs = build_facet_specs(IterSpace(space), prog.deps, Tiling(tile))
    w = tuple(specs[a].width if a in specs else 0 for a in range(3))
    t = tile
    for a in range(3):
        if w[a] and t[a] % w[a]:
            raise ValueError(
                f"kernel fetch requires w | t (axis {a}: t={t[a]}, w={w[a]}); "
                "tile-dependent modulo labelling takes the jnp copy-in path")
    nt = tuple(n // x for n, x in zip(space, tile))
    g = (nt[0] - 1, nt[1] - 1, nt[2] - 1)
    if min(g) < 1:
        raise ValueError("need at least 2 tiles per axis for interior fetch")
    t0, t1, t2 = t
    w0, w1, w2 = w

    # facet_0 array: (nt0+1, nt2, nt1, t1, t2, w0); tile (a,b,c) block is at
    # outer index (a+1, c, b) — the +1 skips the virtual live-in row.  We
    # read tile (q0-1+da, ...) = (i+da, ...) -> outer index i+1+da.
    f0 = lambda da, db, dc: pl.BlockSpec(
        (None, None, None, t1, t2, w0),
        lambda i, j, k, da=da, db=db, dc=dc: (i + 1 + da, k + 1 + dc,
                                              j + 1 + db, 0, 0, 0))
    # facet_1: (nt1, nt0, nt2, t2, t0, w1); tile (a,b,c) at (b, a, c).
    f1 = lambda db, dc: pl.BlockSpec(
        (None, None, None, t2, t0, w1),
        lambda i, j, k, db=db, dc=dc: (j + db, i + 1, k + 1 + dc, 0, 0, 0))
    # facet_2: (nt2, nt1, nt0, t0, t1, w2); tile (a,b,c) at (c, b, a).
    f2 = pl.BlockSpec(
        (None, None, None, t0, t1, w2),
        lambda i, j, k: (k, j + 1, i + 1, 0, 0, 0))

    out_shape = (g[0], g[1], g[2], w0 + t0, w1 + t1, w2 + t2)
    in_specs = [
        f0(0, 0, 0),  # (q0-1, q1, q2): outer idx (q0-1+1, ...) = (i, ...)
        f0(0, -1, 0),
        f0(0, 0, -1),
        f0(0, -1, -1),
        f1(0, 0),
        f1(0, -1),
        f2,
    ]
    operands = [facets[0], facets[0], facets[0], facets[0], facets[1],
                facets[1], facets[2]]
    if storage == "irredundant":
        # the owner blocks: facet_0 of (q0, q1-1, q2), (q0, q1, q2-1) and
        # (q0, q1-1, q2-1) — q0 = i+1, so outer index i+2 past the virtual
        # row — plus facet_1 of (q0, q1, q2-1)
        in_specs += [f0(1, -1, 0), f0(1, 0, -1), f0(1, -1, -1), f1(1, -1)]
        operands += [facets[0], facets[0], facets[0], facets[1]]
        kernel = functools.partial(_kernel_irredundant, w=w, t=t)
    else:
        kernel = functools.partial(_kernel, w=w, t=t)
    return pl.pallas_call(
        kernel,
        grid=g,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (None, None, None, w0 + t0, w1 + t1, w2 + t2),
            lambda i, j, k: (i, j, k, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(out_shape, facets[0].dtype),
        interpret=interpret,
    )(*operands)
