"""Pallas TPU kernels (validated with interpret=True on CPU).

Each subpackage follows the <name>.py (pl.pallas_call + BlockSpec) /
ops.py (jit'd wrapper) / ref.py (pure-jnp oracle) convention.
"""
