"""Jit'd public wrappers for the CFA stencil tile executor."""
from __future__ import annotations

import jax.numpy as jnp

from .stencil import execute_tiles
from .ref import execute_tiles_ref

__all__ = [
    "execute_tiles",
    "execute_tiles_ref",
    "stencil_tile_op",
    "execute_tiles_from_autotuned",
]


def stencil_tile_op(
    program_name: str,
    halos: jnp.ndarray,
    tile: tuple[int, int, int],
    *,
    use_kernel: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    """Execute a batch of stencil tiles; kernel path or jnp reference path."""
    if use_kernel:
        return execute_tiles(program_name, halos, tile, interpret=interpret)
    return execute_tiles_ref(program_name, halos, tile)


def execute_tiles_from_autotuned(
    program_name: str,
    halos: jnp.ndarray,
    decision,
    *,
    kernel_compatible: bool = False,
    use_kernel: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    """Execute tile batches at the tile size an autotuned LayoutDecision chose.

    ``decision`` is a ``repro.core.cfa.autotune.LayoutDecision`` (e.g. from
    ``CFAPipeline.from_autotuned(...).decision``); the halo batch must have
    been gathered at the decision's winning tile sizes.  When the halos came
    from ``fetch_interior_halos_from_autotuned`` (which is restricted to
    kernel-addressable layouts), pass ``kernel_compatible=True`` here too so
    both wrappers resolve the *same* candidate's tile.
    """
    tile = tuple(decision.best_cfa(kernel_compatible=kernel_compatible).candidate.tile)
    return stencil_tile_op(program_name, halos, tile,
                           use_kernel=use_kernel, interpret=interpret)
