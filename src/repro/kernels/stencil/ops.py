"""Jit'd public wrappers for the CFA stencil tile executor."""
from __future__ import annotations

import jax.numpy as jnp

from .stencil import execute_tiles
from .ref import execute_tiles_ref

__all__ = ["execute_tiles", "execute_tiles_ref", "stencil_tile_op"]


def stencil_tile_op(
    program_name: str,
    halos: jnp.ndarray,
    tile: tuple[int, int, int],
    *,
    use_kernel: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    """Execute a batch of stencil tiles; kernel path or jnp reference path."""
    if use_kernel:
        return execute_tiles(program_name, halos, tile, interpret=interpret)
    return execute_tiles_ref(program_name, halos, tile)
