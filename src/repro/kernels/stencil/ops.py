"""Jit'd public wrappers for the CFA stencil tile executor.

``execute_tiles`` / ``execute_tiles_sharded`` are the executor adapters the
``pallas`` and ``sharded`` backends of ``repro.cfa.compile`` drive.
"""
from __future__ import annotations

import jax.numpy as jnp

from .stencil import execute_tiles
from .ref import execute_tiles_ref

__all__ = [
    "execute_tiles",
    "execute_tiles_ref",
    "stencil_tile_op",
    "execute_tiles_sharded",
]


def stencil_tile_op(
    program_name: str,
    halos: jnp.ndarray,
    tile: tuple[int, ...],
    *,
    use_kernel: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    """Execute a batch of stencil tiles; kernel path or jnp reference path."""
    if use_kernel:
        return execute_tiles(program_name, halos, tile, interpret=interpret)
    return execute_tiles_ref(program_name, halos, tile)


def execute_tiles_sharded(
    program_name: str,
    halos: jnp.ndarray,  # (B, w0+t0, .., w_{d-1}+t_{d-1}), B % mesh axis size == 0
    tile: tuple[int, ...],
    mesh,
    *,
    axis: str = "port",
    interpret: bool = True,
) -> jnp.ndarray:  # (B, t0, .., t_{d-1})
    """Execute a halo batch with its shards on different port-devices.

    The multi-port analogue of ``execute_tiles``: the batch (one wavefront of
    independent tiles) is split over the ``axis`` mesh dimension and each
    shard runs the Pallas tile executor on its own device — tiles on
    different ports genuinely execute concurrently.  The caller pads the
    batch to a multiple of the mesh axis size (the sharded executor's
    ``CFAPipeline._sweep_wavefront_sharded`` does).
    """
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.sharding import shard_map_compat

    n = int(mesh.shape[axis])
    if halos.shape[0] % n:
        raise ValueError(
            f"halo batch ({halos.shape[0]}) must be a multiple of the mesh "
            f"axis size ({n}); pad the wavefront first"
        )
    # commit the batch to the mesh (shard_map rejects inputs committed to a
    # different device set, e.g. halos gathered on the default device)
    halos = jax.device_put(halos, NamedSharding(mesh, P(axis)))

    def shard(h):
        return execute_tiles(program_name, h, tile, interpret=interpret)

    return shard_map_compat(
        shard, mesh=mesh, in_specs=P(axis), out_specs=P(axis), check_vma=False
    )(halos)
