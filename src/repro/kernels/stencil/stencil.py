"""Pallas TPU kernel: CFA stencil tile executor.

TPU adaptation of the paper's "execute" stage (Fig. 13).  One grid step
processes one iteration tile:

* the tile's halo buffer (its flow-in, gathered from facet arrays by
  contiguous block DMAs — see ``repro.core.cfa.transform``) is staged into
  VMEM by the BlockSpec pipeline (Pallas double-buffers grid steps, which is
  the TPU analogue of the paper's read/execute/write DATAFLOW overlap);
* the plane recurrence runs entirely in VMEM: ``t0`` time planes are produced
  with vector shifts on (t1+w1, t2+w2) planes — no HBM traffic between time
  steps (this is the temporal locality tiling bought us);
* the interior volume is emitted; facet extraction (transpose + contiguous
  block store) happens at the XLA level where it fuses with the DMA.

Block shapes: the minor two dims of both the halo buffer and the output are
the spatial dims, which the caller sizes to multiples of (8, 128) for
sublane/lane alignment — the CFA layout guarantees those extents are
contiguous in HBM, which is what makes these DMAs "bursts".
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.cfa.programs import StencilProgram, get_program


def _tile_kernel(h_ref, o_ref, scratch, *, program: StencilProgram,
                 tile: tuple[int, ...]):
    w = program.widths
    d = len(tile)
    spatial = tuple(slice(w[a], None) for a in range(1, d))
    # Stage the halo buffer into the scratch working set once; all further
    # reads/writes are VMEM-local.
    scratch[...] = h_ref[...]
    for s in range(tile[0]):  # t0 is static: fully unrolled time loop
        prev = [scratch[w[0] + s - m] for m in range(w[0], 0, -1)]
        plane = program.plane_update(prev, w)  # static shapes: VMEM values
        scratch[(w[0] + s, *spatial)] = plane
    o_ref[...] = scratch[(slice(w[0], None), *spatial)]


@functools.partial(jax.jit, static_argnames=("program_name", "tile", "interpret"))
def execute_tiles(
    program_name: str,
    halos: jnp.ndarray,  # (B, w0+t0, .., w_{d-1}+t_{d-1})
    tile: tuple[int, ...],
    *,
    interpret: bool = True,
) -> jnp.ndarray:  # (B, t0, .., t_{d-1})
    """Run the tile executor kernel over a batch of gathered halo buffers.

    Dimension-generic: ``tile`` has one entry per iteration-space axis
    (time first), so 2-D (``heat1d``), 3-D (Table I) and 4-D (``heat3d``)
    programs share this path.
    """
    program = get_program(program_name)
    w = program.widths
    d = len(tile)
    if program.ndim != d:
        raise ValueError(f"{program_name} is {program.ndim}-D, tile is {d}-D")
    hshape = tuple(w[a] + tile[a] for a in range(d))
    if halos.shape[1:] != hshape:
        raise ValueError(f"halos must be (B, {hshape}), got {halos.shape}")
    B = halos.shape[0]
    zeros = (0,) * d
    kernel = functools.partial(_tile_kernel, program=program, tile=tile)
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[pl.BlockSpec((None, *hshape), lambda b: (b, *zeros))],
        out_specs=pl.BlockSpec((None, *tile), lambda b: (b, *zeros)),
        out_shape=jax.ShapeDtypeStruct((B, *tile), halos.dtype),
        scratch_shapes=[pltpu.VMEM(hshape, halos.dtype)],
        interpret=interpret,
    )(halos)
