"""Pure-jnp oracle for the CFA stencil tile executor.

Given a batch of halo buffers (flow-in gathered from facet arrays, low-side
halo of width ``w`` per axis), compute the tiles' interior planes with the
program's plane recurrence.  This is the reference the Pallas kernel is
validated against; it is also exactly what ``CFAPipeline.execute_tile`` does,
vectorised over a batch of tiles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.cfa.programs import StencilProgram, get_program


def execute_tiles_ref(
    program: StencilProgram | str,
    halos: jnp.ndarray,  # (B, w0+t0, .., w_{d-1}+t_{d-1})
    tile: tuple[int, ...],
) -> jnp.ndarray:  # (B, t0, .., t_{d-1})
    if isinstance(program, str):
        program = get_program(program)
    w = program.widths
    d = len(tile)
    spatial = tuple(slice(w[a], None) for a in range(1, d))

    def one(H):
        for s in range(tile[0]):
            prev = [H[w[0] + s - m] for m in range(w[0], 0, -1)]
            plane = program.plane_update(prev, w)
            H = H.at[(w[0] + s, *spatial)].set(plane)
        return H[(slice(w[0], None), *spatial)]

    return jax.vmap(one)(halos)
