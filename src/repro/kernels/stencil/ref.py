"""Pure-jnp oracle for the CFA stencil tile executor.

Given a batch of halo buffers (flow-in gathered from facet arrays, low-side
halo of width ``w`` per axis), compute the tiles' interior planes with the
program's plane recurrence.  This is the reference the Pallas kernel is
validated against; it is also exactly what ``CFAPipeline.execute_tile`` does,
vectorised over a batch of tiles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.cfa.programs import StencilProgram, get_program


def execute_tiles_ref(
    program: StencilProgram | str,
    halos: jnp.ndarray,  # (B, w0+t0, w1+t1, w2+t2)
    tile: tuple[int, int, int],
) -> jnp.ndarray:  # (B, t0, t1, t2)
    if isinstance(program, str):
        program = get_program(program)
    w = program.widths
    t0, t1, t2 = tile

    def one(H):
        for s in range(t0):
            prev = [H[w[0] + s - m] for m in range(w[0], 0, -1)]
            plane = program.plane_update(prev, w)
            H = H.at[w[0] + s, w[1] :, w[2] :].set(plane)
        return H[w[0] :, w[1] :, w[2] :]

    return jax.vmap(one)(halos)
