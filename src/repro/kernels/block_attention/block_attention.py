"""Pallas TPU kernel: flash-decode attention over a facet(block)-layout KV cache.

CFA applied to serving (DESIGN.md §3): the KV cache is stored as sequence-
tiled blocks ``(B, nb, Hkv, bs, D)`` — the block index is the single-
assignment outer dimension, and each ``(bs, D)`` extent is contiguous in HBM.
Decode attention then streams the cache block-by-block:

* one DMA per (head, block) — a long "burst" in the paper's terms, versus the
  canonical ``(B, S, Hkv, D)`` layout whose per-head reads stride by
  ``Hkv*D`` every token;
* online-softmax state (m, l, acc) lives in VMEM scratch and persists across
  the sequential block grid — the read->execute pipeline overlap is Pallas
  grid double-buffering, exactly the DATAFLOW structure of paper Fig. 13.

Grid: ``(B, nb)`` with the block dimension minor (sequential per batch row).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention"]

_NEG_INF = float("-inf")


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            block_size: int, groups: int):
    j = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k = k_ref[...].astype(jnp.float32)  # (Hkv, bs, D)
    v = v_ref[...].astype(jnp.float32)  # (Hkv, bs, D)
    q = q_ref[...].astype(jnp.float32)  # (Hq, D)
    hkv, bs, d = k.shape
    qg = q.reshape(hkv, groups, d)

    scores = jax.lax.dot_general(
        qg, k, (((2,), (2,)), ((0,), (0,)))
    ) / jnp.sqrt(jnp.float32(d))  # (Hkv, G, bs)

    length = len_ref[0]
    pos = j * block_size + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bs), 2)
    scores = jnp.where(pos < length, scores, _NEG_INF)
    scores = scores.reshape(hkv * groups, bs)  # (Hq, bs)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, scores.max(axis=-1, keepdims=True))
    # guard: fully-masked block (all -inf) must not poison the accumulator
    alpha = jnp.exp(m_prev - m_new)
    alpha = jnp.where(jnp.isfinite(m_new), alpha, 1.0)
    p = jnp.exp(scores - m_new)
    p = jnp.where(jnp.isfinite(m_new), p, 0.0)

    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p.reshape(hkv, groups, bs), v, (((2,), (1,)), ((0,), (0,)))
    ).reshape(hkv * groups, d)  # (Hq, D)
    acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(j == nb - 1)
    def _emit():
        o_ref[...] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention(
    q: jnp.ndarray,  # (B, Hq, D)
    k_blocks: jnp.ndarray,  # (B, nb, Hkv, bs, D) facet layout
    v_blocks: jnp.ndarray,  # (B, nb, Hkv, bs, D)
    lengths: jnp.ndarray,  # (B,) int32
    *,
    interpret: bool = True,
) -> jnp.ndarray:  # (B, Hq, D)
    B, nb, Hkv, bs, D = k_blocks.shape
    Hq = q.shape[1]
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} must be a multiple of Hkv={Hkv}")
    groups = Hq // Hkv
    kernel = functools.partial(_kernel, block_size=bs, groups=groups)
    return pl.pallas_call(
        kernel,
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((None, 1), lambda b, j: (b, 0)),  # lengths (SMEM-class)
            pl.BlockSpec((None, Hq, D), lambda b, j: (b, 0, 0)),  # q
            pl.BlockSpec((None, None, Hkv, bs, D), lambda b, j: (b, j, 0, 0, 0)),
            pl.BlockSpec((None, None, Hkv, bs, D), lambda b, j: (b, j, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, Hq, D), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Hq, 1), jnp.float32),  # running max
            pltpu.VMEM((Hq, 1), jnp.float32),  # running denominator
            pltpu.VMEM((Hq, D), jnp.float32),  # running numerator
        ],
        interpret=interpret,
    )(lengths.reshape(B, 1).astype(jnp.int32), q, k_blocks, v_blocks)
