"""Public ops for the facet-layout KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .block_attention import decode_attention
from .ref import decode_attention_ref, blockify, deblockify

__all__ = [
    "decode_attention",
    "decode_attention_ref",
    "blockify",
    "deblockify",
    "append_token",
]


def append_token(
    k_blocks: jnp.ndarray,  # (B, nb, Hkv, bs, D)
    v_blocks: jnp.ndarray,
    k_new: jnp.ndarray,  # (B, Hkv, D)
    v_new: jnp.ndarray,
    position: jnp.ndarray,  # scalar int32 — write position (same for the batch)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Append one token's KV at ``position``: a single in-block write per head
    (the CFA flow-out stance — all writes are block-local and contiguous)."""
    bs = k_blocks.shape[3]
    position = jnp.asarray(position, jnp.int32)
    blk = position // bs
    row = position % bs
    zero = jnp.int32(0)

    def upd(blocks, new):
        # (B, nb, Hkv, bs, D) <- (B, 1, Hkv, 1, D) at (0, blk, 0, row, 0)
        return jax.lax.dynamic_update_slice(
            blocks, new[:, None, :, None, :],
            (zero, blk, zero, row, zero),
        )

    return upd(k_blocks, k_new), upd(v_blocks, v_new)
