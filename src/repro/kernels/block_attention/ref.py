"""Pure-jnp oracle for block-layout (facet) KV-cache decode attention.

The reference computes standard GQA decode attention over a *canonical*
``(B, S, Hkv, D)`` cache; the kernel computes the same function over the CFA
block layout ``(B, nb, Hkv, bs, D)``.  ``blockify``/``deblockify`` are the
layout converters (the analogue of ``pack``/``unpack`` for the KV "facets":
the sequence axis is tiled, the block index is the single-assignment outer
dimension, and each ``(bs, D)`` extent is one contiguous burst).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["decode_attention_ref", "blockify", "deblockify"]


def blockify(cache: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """(B, S, Hkv, D) -> (B, nb, Hkv, bs, D); S must divide by block_size."""
    B, S, H, D = cache.shape
    assert S % block_size == 0
    nb = S // block_size
    return cache.reshape(B, nb, block_size, H, D).transpose(0, 1, 3, 2, 4)


def deblockify(blocks: jnp.ndarray) -> jnp.ndarray:
    """(B, nb, Hkv, bs, D) -> (B, S, Hkv, D)."""
    B, nb, H, bs, D = blocks.shape
    return blocks.transpose(0, 1, 3, 2, 4).reshape(B, nb * bs, H, D)


def decode_attention_ref(
    q: jnp.ndarray,  # (B, Hq, D)
    k_cache: jnp.ndarray,  # (B, S, Hkv, D) canonical layout
    v_cache: jnp.ndarray,  # (B, S, Hkv, D)
    lengths: jnp.ndarray,  # (B,) int32 — valid prefix length per sequence
) -> jnp.ndarray:  # (B, Hq, D)
    B, S, Hkv, D = k_cache.shape
    Hq = q.shape[1]
    assert Hq % Hkv == 0
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    k = k_cache.astype(jnp.float32)
    v = v_cache.astype(jnp.float32)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, k) / jnp.sqrt(D).astype(jnp.float32)
    mask = jnp.arange(S)[None, :] < lengths[:, None]  # (B, S)
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v)
    return out.reshape(B, Hq, D).astype(q.dtype)
