"""Pallas TPU kernel: chunked Mamba2 SSD scan with facet state passing.

The SSD recurrence is a 1-D uniform-dependence tiled loop (chunks = tiles);
the inter-chunk state is exactly the chunk's CFA flow-out facet: dependence
depth 1 along the sequence-tile axis, so each chunk emits one (H, P, N)
state block, stored contiguously and consumed by the next chunk only —
write-one-burst / read-one-burst, the paper's stance, realised here as a VMEM
scratch carried across the sequential chunk grid.

Within a chunk of length L (the tile execute stage), with ``l`` the running
log-decay cumsum:

    y_intra[t] = sum_{s<=t} exp(l_t - l_s) (C_t . B_s) x_s      (masked GEMMs)
    y_inter[t] = exp(l_t) * C_t . S_prev
    S_next     = exp(l_L) S_prev + sum_s exp(l_L - l_s) x_s (x) B_s

All contractions map onto the MXU; chunk length and head dims are chosen as
multiples of (8, 128) by the caller for lane/sublane alignment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan"]


def _kernel(x_ref, loga_ref, b_ref, c_ref, y_ref, sfin_ref, state, *, nchunks: int):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    x = x_ref[...].astype(jnp.float32)  # (L, H, P)
    loga = loga_ref[...].astype(jnp.float32)  # (L, H)
    Bm = b_ref[...].astype(jnp.float32)  # (L, N)
    C = c_ref[...].astype(jnp.float32)  # (L, N)
    L, H, P = x.shape

    lcum = jnp.cumsum(loga, axis=0)  # (L, H): l_t, inclusive of step t
    ltot = lcum[-1]  # (H,)

    # ---- inter-chunk: read the incoming facet (previous chunk's state) ----
    S_prev = state[...]  # (H, P, N)
    # y_inter[t,h,p] = exp(l[t,h] - loga[t,h]) * sum_n C[t,n] S_prev[h,p,n]
    # (the state seen by step t excludes step t's own decay-then-update; the
    #  reference applies a_t to S_{t-1} *before* the update, so the factor is
    #  exp(l_t) which already includes a_t.)
    cs = jax.lax.dot_general(S_prev, C, (((2,), (1,)), ((), ())))  # (H, P, L)
    y_inter = jnp.exp(lcum).transpose(1, 0)[:, None, :] * cs  # (H, P, L)

    # ---- intra-chunk: masked decay attention ----
    G = jax.lax.dot_general(C, Bm, (((1,), (1,)), ((), ())))  # (L, L): C_t . B_s
    ti = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    mask = ti >= si
    # decay[h,t,s] = exp(l_t[h] - l_s[h]) for s <= t
    ldiff = lcum.transpose(1, 0)[:, :, None] - lcum.transpose(1, 0)[:, None, :]
    W = jnp.where(mask[None], jnp.exp(ldiff) * G[None], 0.0)  # (H, L, L)
    y_intra = jax.lax.dot_general(
        W, x.transpose(1, 0, 2), (((2,), (1,)), ((0,), (0,)))
    )  # (H, L, P)

    y = y_intra.transpose(1, 0, 2) + y_inter.transpose(2, 0, 1)  # (L, H, P)
    y_ref[...] = y.astype(y_ref.dtype)

    # ---- flow-out facet: next chunk state ----
    # S_next[h,p,n] = exp(ltot[h]) S_prev + sum_s exp(ltot[h]-l_s[h]) x_s B_s
    wout = jnp.exp(ltot[None, :] - lcum)  # (L, H)
    xw = x * wout[:, :, None]  # (L, H, P)
    dS = jax.lax.dot_general(
        xw.transpose(1, 2, 0), Bm, (((2,), (0,)), ((), ()))
    )  # (H, P, N)
    state[...] = jnp.exp(ltot)[:, None, None] * S_prev + dS

    @pl.when(c_idx == nchunks - 1)
    def _emit():
        sfin_ref[...] = state[...].astype(sfin_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jnp.ndarray,  # (B, T, H, P)
    loga: jnp.ndarray,  # (B, T, H)
    Bmat: jnp.ndarray,  # (B, T, N)
    C: jnp.ndarray,  # (B, T, N)
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan; returns (y (B,T,H,P), final state (B,H,P,N))."""
    Bb, T, H, P = x.shape
    N = Bmat.shape[-1]
    if T % chunk:
        raise ValueError(f"T={T} must divide by chunk={chunk}")
    nc = T // chunk
    kernel = functools.partial(_kernel, nchunks=nc)
    y, sfin = pl.pallas_call(
        kernel,
        grid=(Bb, nc),
        in_specs=[
            pl.BlockSpec((None, chunk, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((None, chunk, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((None, H, P, N), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, T, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bb, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        interpret=interpret,
    )(x, loga, Bmat, C)
    return y, sfin
