"""Public ops for the SSD chunk scan."""
from __future__ import annotations

import jax.numpy as jnp

from .ssd import ssd_scan
from .ref import ssd_scan_ref

__all__ = ["ssd_scan", "ssd_scan_ref", "ssd_decode_step"]


def ssd_decode_step(
    state: jnp.ndarray,  # (B, H, P, N)
    x_t: jnp.ndarray,  # (B, H, P)
    loga_t: jnp.ndarray,  # (B, H)
    B_t: jnp.ndarray,  # (B, N)
    C_t: jnp.ndarray,  # (B, N)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token SSD update (decode): the state *is* the whole cache.

    One (H, P, N) read-modify-write per token — contiguous by construction,
    the degenerate (chunk = 1) case of the facet scheme.
    """
    a_t = jnp.exp(loga_t.astype(jnp.float32))[:, :, None, None]
    S = a_t * state.astype(jnp.float32) + (
        x_t.astype(jnp.float32)[..., None] * B_t.astype(jnp.float32)[:, None, None, :]
    )
    y_t = jnp.einsum("bhpn,bn->bhp", S, C_t.astype(jnp.float32))
    return y_t.astype(x_t.dtype), S
