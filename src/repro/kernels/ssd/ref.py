"""Pure-jnp oracle for the Mamba2 SSD (state-space duality) scan.

Sequential reference recurrence, per head h with state S in R^{P x N}:

    S_t = a_t * S_{t-1} + x_t (outer) B_t
    y_t = S_t C_t

where ``a_t = exp(loga_t)`` is the per-head scalar decay.  This is the exact
(slow) semantics the chunked Pallas kernel must reproduce: the chunked form
splits the sum into an intra-chunk term and an inter-chunk term carried by
the chunk state — which in CFA terms is the flow-out facet of the chunk
(thickness = the dependence depth of the recurrence, i.e. one state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ssd_scan_ref"]


def ssd_scan_ref(
    x: jnp.ndarray,  # (B, T, H, P)
    loga: jnp.ndarray,  # (B, T, H) — log decay, <= 0
    Bmat: jnp.ndarray,  # (B, T, N) — input projection (ngroups = 1)
    C: jnp.ndarray,  # (B, T, N) — output projection
    init_state: jnp.ndarray | None = None,  # (B, H, P, N)
) -> tuple[jnp.ndarray, jnp.ndarray]:  # y (B, T, H, P), final state (B, H, P, N)
    Bb, T, H, P = x.shape
    N = Bmat.shape[-1]
    xf = x.astype(jnp.float32)
    lf = loga.astype(jnp.float32)
    Bf = Bmat.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    s0 = (
        jnp.zeros((Bb, H, P, N), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def step(S, inp):
        x_t, l_t, B_t, C_t = inp  # (B,H,P), (B,H), (B,N), (B,N)
        a_t = jnp.exp(l_t)[:, :, None, None]  # (B,H,1,1)
        S = a_t * S + x_t[..., None] * B_t[:, None, None, :]
        y_t = jnp.einsum("bhpn,bn->bhp", S, C_t)
        return S, y_t

    inputs = (
        xf.transpose(1, 0, 2, 3),
        lf.transpose(1, 0, 2),
        Bf.transpose(1, 0, 2),
        Cf.transpose(1, 0, 2),
    )
    S, ys = jax.lax.scan(step, s0, inputs)
    y = ys.transpose(1, 0, 2, 3).astype(x.dtype)  # (B, T, H, P)
    return y, S
