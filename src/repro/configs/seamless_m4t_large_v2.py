"""seamless-m4t-large-v2 [audio]: enc-dec, 24L encoder + 24L decoder,
d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.  [arXiv:2308.11596; hf]

The speech frontend is a STUB: input_specs() provides precomputed frame
embeddings (B, T_frames, d_model); the transformer backbone (conformer-less
simplification, documented in DESIGN.md) is what the cells exercise."""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    head_dim=64,
    rope_theta=10_000.0,
    period=("dec",),
    enc_layers=24,
    n_context_tokens=4096,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=512, head_dim=16, enc_layers=2, n_context_tokens=8, tp=1,
    kv_block=16,
)
