"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — cross-attention image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

The vision frontend is a STUB: input_specs() provides precomputed patch
embeddings (B, 1600, d_model)."""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    head_dim=128,
    rope_theta=500_000.0,
    period=("attn", "attn", "attn", "attn", "cross"),
    n_context_tokens=1600,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=512, head_dim=16, n_context_tokens=8, tp=1, kv_block=16,
    moe_group_size=32,
)
