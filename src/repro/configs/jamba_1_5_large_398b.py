"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2 — Mamba+attention 1:7 interleave, MoE every
other layer.  [arXiv:2403.19887; hf]

Period of 8: [attn, mamba x7], MoE replacing the dense FFN on odd positions.
Optimizer: adafactor (AdamW state for 398B params does not fit a single
v5e pod; see EXPERIMENTS.md memory table)."""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    head_dim=128,
    rope_theta=10_000.0,
    period=("attn",) + ("mamba",) * 7,
    moe_positions=(1, 3, 5, 7),
    moe_experts=16,
    moe_top_k=2,
    moe_d_ff=24576,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    optimizer="adafactor",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=512, head_dim=16, moe_experts=4, moe_top_k=2, moe_d_ff=128,
    ssm_state=16, ssm_head_dim=16, ssm_chunk=8, tp=1, kv_block=16,
    moe_group_size=32,
)
