"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (GQA kv=16) d_ff=1024 vocab=50304,
MoE 64 experts top-8 on every layer.  [arXiv:2409.02060; hf]"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    head_dim=128,
    rope_theta=10_000.0,
    qk_norm=True,
    period=("attn",),
    moe_positions=(0,),
    moe_experts=64,
    moe_top_k=8,
    moe_d_ff=1024,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32,
    vocab=512, head_dim=16, moe_experts=8, moe_top_k=2, moe_d_ff=32,
    tp=1, kv_block=16, moe_group_size=32,
)
