"""phi4-mini-3.8b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064 — RoPE SwiGLU GQA.  [arXiv:2412.08905; hf]

TPU note: 24 query heads pad to 32 for tp=16 (DESIGN.md)."""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=200064,
    head_dim=128,
    rope_theta=10_000.0,
    period=("attn",),
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=48, n_heads=3, n_kv_heads=1, d_ff=128,
    vocab=512, head_dim=16, tp=1, kv_block=16,
)
