"""mamba2-370m [ssm]: 48L d_model=1024 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060; unverified]

The most CFA-representative architecture: the SSD chunk scan is a 1-D
uniform-dependence tiled loop whose inter-chunk states are flow-out facets
(DESIGN.md §Arch-applicability)."""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=1,   # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    head_dim=64,
    period=("mamba",),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, vocab=512, ssm_state=16, ssm_head_dim=16,
    ssm_chunk=8, tp=1, kv_block=16,
)
