"""granite-20b [dense]: 52L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152 — llama-arch, code.  [arXiv:2405.04324; hf]

TPU note: the single MQA kv head is stored replicated to tp=16 so the KV
cache shards exactly (16x cache memory vs ideal MQA; documented trade)."""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    head_dim=128,
    rope_theta=10_000.0,
    period=("attn",),
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
    vocab=512, head_dim=16, tp=1, kv_block=16,
)
