"""Assigned-architecture registry: one module per architecture, each exporting
``CONFIG`` (the exact published configuration) and ``SMOKE`` (a reduced
same-family configuration for CPU smoke tests)."""
from __future__ import annotations

import importlib

from repro.models.config import ArchConfig

ARCH_IDS = [
    "llama_3_2_vision_11b",
    "olmoe_1b_7b",
    "llama4_scout_17b_a16e",
    "phi4_mini_3_8b",
    "granite_20b",
    "deepseek_67b",
    "qwen3_0_6b",
    "mamba2_370m",
    "jamba_1_5_large_398b",
    "seamless_m4t_large_v2",
]

# public ids use dashes/dots like the assignment sheet
_ALIASES = {
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "granite-20b": "granite_20b",
    "deepseek-67b": "deepseek_67b",
    "qwen3-0.6b": "qwen3_0_6b",
    "mamba2-370m": "mamba2_370m",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
}

ARCH_NAMES = list(_ALIASES)


def _module(name: str):
    key = _ALIASES.get(name, name)
    return importlib.import_module(f"repro.configs.{key}")


def get_config(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    return _module(name).SMOKE
