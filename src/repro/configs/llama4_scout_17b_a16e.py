"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 — MoE, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

TPU note: 40 query heads pad to 48 for tp=16 (DESIGN.md)."""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    head_dim=128,
    rope_theta=500_000.0,
    period=("attn",),
    moe_positions=(0,),
    moe_experts=16,
    moe_top_k=1,
    moe_d_ff=8192,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=5, n_kv_heads=1, d_ff=64,
    vocab=512, head_dim=16, moe_experts=4, moe_top_k=1, moe_d_ff=64,
    tp=1, kv_block=16, moe_group_size=32,
)
