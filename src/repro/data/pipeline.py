"""Synthetic token pipeline with sequence packing and host->device prefetch.

The host side mirrors the paper's read stage: batches are assembled in
device-tile-major order so each device's shard is one contiguous extent
(a single "burst" per device per step — CFA's full-tile contiguity applied
to the input pipeline), and a background thread keeps ``prefetch`` batches
in flight so the accelerator never waits on the host (the paper's
read/execute overlap).

Straggler mitigation: ``next`` takes a deadline; a batch that misses it is
skipped and counted (at cluster scale: the slow host's shard is replaced by
the backup stream; here: emulated and surfaced in ``stats``).
"""
from __future__ import annotations

import queue
import threading
import time

import numpy as np

__all__ = ["SyntheticTokens", "PackedDocs"]


class SyntheticTokens:
    """Deterministic, seekable synthetic LM batches (tokens only)."""

    def __init__(self, *, vocab: int, batch: int, seq: int, seed: int = 0,
                 prefetch: int = 2):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.seed = seed
        self.step = 0
        self._lock = threading.Lock()
        self._next = 0
        self._gen = 0
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self.stats = {"skipped": 0, "produced": 0}
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        return {
            "tokens": rng.integers(0, self.vocab, size=(self.batch, self.seq),
                                   dtype=np.int32)
        }

    def _producer(self) -> None:
        while not self._stop.is_set():
            with self._lock:
                gen, step = self._gen, self._next
                self._next += 1
            b = self.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((gen, step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            self.stats["produced"] += 1

    def seek(self, step: int) -> None:
        """Restart the stream at ``step`` (deterministic resume after a
        checkpoint restore); stale prefetched batches are discarded."""
        with self._lock:
            self._gen += 1
            self._next = step
        self.step = step

    def next(self, deadline_s: float | None = None) -> dict:
        """Next batch; on deadline miss, skip ahead (straggler mitigation)."""
        while True:
            try:
                gen, step, b = self._q.get(
                    timeout=deadline_s if deadline_s else 300.0)
            except queue.Empty:
                self.stats["skipped"] += 1
                b = self.batch_at(self.step)  # deterministic fallback
                step = self.step
                break
            if gen == self._gen:
                break  # else: stale pre-seek batch, discard
        self.step = step + 1
        return b

    def close(self) -> None:
        self._stop.set()


class PackedDocs(SyntheticTokens):
    """Documents of random length packed into fixed-length rows with EOS
    separators — contiguous packing, no padding waste."""

    def __init__(self, *, vocab: int, batch: int, seq: int, seed: int = 0,
                 mean_doc_len: int = 512, eos: int = 0, prefetch: int = 2):
        self.mean_doc_len = mean_doc_len
        self.eos = eos
        super().__init__(vocab=vocab, batch=batch, seq=seq, seed=seed,
                         prefetch=prefetch)

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step, 7))
        rows = np.empty((self.batch, self.seq), dtype=np.int32)
        for r in range(self.batch):
            fill = 0
            while fill < self.seq:
                n = int(rng.geometric(1.0 / self.mean_doc_len))
                n = min(max(n, 2), self.seq - fill)
                rows[r, fill : fill + n] = rng.integers(
                    1, self.vocab, size=n, dtype=np.int32)
                rows[r, fill + n - 1] = self.eos
                fill += n
        return {"tokens": rows}
